"""OverSketch properties: Lemma 6.1 spectral bounds (statistically),
unbiasedness, straggler-drop consistency, chunked streaming equality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sketch as sk


def _gram_err(key, n, d, cfg, drop=0):
    a = jax.random.normal(key, (n, d)) / np.sqrt(n)
    cs = sk.sample_countsketch(jax.random.fold_in(key, 1), n, cfg)
    at = sk.apply_sketch(cs, a)
    mask = jnp.arange(cfg.total_blocks) >= drop
    h = sk.sketched_gram(at, mask)
    h_true = a.T @ a
    return float(jnp.linalg.norm(h - h_true, 2) / jnp.linalg.norm(h_true, 2))


def test_config_accounting():
    cfg = sk.OverSketchConfig(sketch_dim=2048, block_size=256,
                              straggler_tolerance=0.25)
    assert cfg.num_blocks == 8
    assert cfg.num_redundant == 2
    assert cfg.total_blocks == 10
    assert cfg.total_dim == 2560


def test_config_divisibility():
    with pytest.raises(ValueError):
        sk.OverSketchConfig(sketch_dim=1000, block_size=256)


def test_spectral_approximation_improves_with_sketch_dim():
    """Larger m => smaller eps (Thm 3.1 sketch-dim scaling)."""
    key = jax.random.PRNGKey(0)
    errs = []
    for m, b in [(512, 64), (2048, 256), (8192, 1024)]:
        cfg = sk.OverSketchConfig(m, b, 0.25)
        errs.append(_gram_err(key, 600, 20, cfg))
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 0.12


def test_straggler_drop_keeps_accuracy():
    """Dropping <= e blocks with rescale stays comparably accurate."""
    key = jax.random.PRNGKey(1)
    cfg = sk.OverSketchConfig(2048, 256, 0.25)
    full = _gram_err(key, 500, 25, cfg, drop=0)
    dropped = _gram_err(key, 500, 25, cfg, drop=cfg.num_redundant)
    assert dropped < 3 * full + 0.1


def test_unbiasedness():
    """E[S_i S_i^T] = I: the average of many independent block grams -> A^T A."""
    key = jax.random.PRNGKey(2)
    n, d = 200, 10
    a = jax.random.normal(key, (n, d)) / np.sqrt(n)
    cfg = sk.OverSketchConfig(sketch_dim=64 * 64, block_size=64,
                              straggler_tolerance=0.0)
    cs = sk.sample_countsketch(jax.random.fold_in(key, 3), n, cfg)
    h = sk.sketched_gram(sk.apply_sketch(cs, a))
    h_true = a.T @ a
    assert float(jnp.linalg.norm(h - h_true) / jnp.linalg.norm(h_true)) < 0.2


def test_eigenvalue_sandwich():
    """Lemma 6.1: (1-eps) lam_min <= lam(H_hat) <= (1+eps) lam_max, for a
    moderate eps at this sketch size."""
    key = jax.random.PRNGKey(3)
    n, d = 800, 12
    a = jax.random.normal(key, (n, d)) / np.sqrt(n)
    cfg = sk.OverSketchConfig(4096, 512, 0.25)
    h = sk.oversketched_gram(jax.random.fold_in(key, 9), a, cfg)
    ev_true = jnp.linalg.eigvalsh(a.T @ a)
    ev_hat = jnp.linalg.eigvalsh(h)
    eps = 0.5
    assert ev_hat[0] >= (1 - eps) * ev_true[0] - 1e-6
    assert ev_hat[-1] <= (1 + eps) * ev_true[-1] + 1e-6


def test_chunked_apply_matches_full():
    key = jax.random.PRNGKey(4)
    n, d, chunks = 384, 17, 4
    a = jax.random.normal(key, (n, d))
    cfg = sk.OverSketchConfig(256, 64, 0.5)
    cs = sk.sample_countsketch(jax.random.fold_in(key, 5), n, cfg)
    full = sk.apply_sketch(cs, a)
    chunk_rows = n // chunks
    chunked = sk.apply_sketch_chunked(
        cs, lambda c: jax.lax.dynamic_slice_in_dim(a, c * chunk_rows,
                                                   chunk_rows), chunks,
        chunk_rows, d)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


def test_distributed_gram_matches_local():
    """shard_map masked-psum path == single-device masked gram."""
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    key = jax.random.PRNGKey(5)
    n, d = 256, 9
    a = jax.random.normal(key, (n, d))
    cfg = sk.OverSketchConfig(256, 64, 0.5)
    cs = sk.sample_countsketch(jax.random.fold_in(key, 6), n, cfg)
    surv = jnp.arange(cfg.total_blocks) != 2
    local = sk.sketched_gram(sk.apply_sketch(cs, a), surv)
    dist = sk.distributed_sketched_gram(a, cs, surv, mesh=mesh,
                                        block_axis="model")
    np.testing.assert_allclose(np.asarray(local), np.asarray(dist),
                               rtol=1e-5, atol=1e-5)
