"""Baseline optimizers: convergence + straggler accounting + gradient-coding
decodability + AdamW behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Dataset, LogisticRegression, StragglerModel
from repro.optim import (FirstOrderConfig, GiantConfig, adamw, decode_weights,
                         exact_newton, first_order, giant)


@pytest.fixture(scope="module")
def logistic_problem():
    key = jax.random.PRNGKey(0)
    n, d = 1200, 20
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d), minval=-1, maxval=1)
    wstar = jax.random.normal(kw, (d,))
    y = jnp.where(jax.random.uniform(ky, (n,)) < jax.nn.sigmoid(x @ wstar),
                  1.0, -1.0)
    return Dataset(x=x, y=y), LogisticRegression(lam=1e-4), d


def test_gd_decreases(logistic_problem):
    data, obj, d = logistic_problem
    h = first_order(obj, data, jnp.zeros(d),
                    FirstOrderConfig(iters=15, method="gd"))
    assert h["fval"][-1] < h["fval"][0]


def test_nag_beats_gd_in_iterations(logistic_problem):
    data, obj, d = logistic_problem
    gd = first_order(obj, data, jnp.zeros(d),
                     FirstOrderConfig(iters=25, method="gd"), model=None)
    nag = first_order(obj, data, jnp.zeros(d),
                      FirstOrderConfig(iters=25, method="nag"), model=None)
    assert nag["fval"][-1] <= gd["fval"][-1] + 1e-3


def test_giant_converges_fast(logistic_problem):
    data, obj, d = logistic_problem
    h = giant(obj, data, jnp.zeros(d), GiantConfig(iters=5, num_workers=12),
              model=None)
    assert h["gnorm"][-1] < 5e-2
    assert h["fval"][-1] < h["fval"][0]


def test_giant_policies_time_ordering(logistic_problem):
    """With a heavy tail, ignore-stragglers < wait-all in simulated time
    (paper Fig. 6/7 observation)."""
    data, obj, d = logistic_problem
    model = StragglerModel(p_tail=0.2, tail_hi=4.0)
    t_ign = giant(obj, data, jnp.zeros(d),
                  GiantConfig(iters=4, num_workers=24, policy="ignore"),
                  model=model)["time"][-1]
    t_wait = giant(obj, data, jnp.zeros(d),
                   GiantConfig(iters=4, num_workers=24, policy="wait_all"),
                   model=model)["time"][-1]
    assert t_ign < t_wait


def test_gcode_charges_replication_cost(logistic_problem):
    """Gradient coding does r-fold work/comm — slower per phase than ignore
    (the paper's EPSILON observation)."""
    data, obj, d = logistic_problem
    model = StragglerModel(p_tail=0.02)
    t_gc = first_order(obj, data, jnp.zeros(d),
                       FirstOrderConfig(iters=4, policy="gcode",
                                        gcode_redundancy=3,
                                        backtracking=False), model=model)
    t_ig = first_order(obj, data, jnp.zeros(d),
                       FirstOrderConfig(iters=4, policy="ignore",
                                        backtracking=False), model=model)
    assert t_gc["time"][-1] > t_ig["time"][-1]


def test_exact_newton_reaches_optimum(logistic_problem):
    data, obj, d = logistic_problem
    h = exact_newton(obj, data, jnp.zeros(d), iters=7, model=None)
    assert h["gnorm"][-1] < 1e-4


def test_gradient_coding_decode_weights():
    """Any W-(r-1) finished workers admit exact-decode weights."""
    w, r = 12, 3
    finished = np.ones(w, bool)
    finished[[2, 7]] = False                      # r-1 = 2 stragglers
    wts = decode_weights(finished, w, r)
    assert wts is not None
    from repro.optim import assignment
    b = np.zeros((w, w))
    for i in range(w):
        b[i, assignment(w, r)[i]] = 1
    np.testing.assert_allclose(b.T @ wts, np.ones(w), atol=1e-6)
    assert np.allclose(wts[~finished], 0)


def test_gradient_coding_undecodable_detected():
    w, r = 8, 2
    finished = np.ones(w, bool)
    finished[[0, 1]] = False                      # adjacent pair, r-1=1 only
    assert decode_weights(finished, w, r) is None


def test_adamw_reduces_loss():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (256, 10))
    wstar = jax.random.normal(jax.random.fold_in(key, 1), (10,))
    y = x @ wstar
    params = {"w": jnp.zeros(10)}
    cfg = adamw.AdamWConfig(lr=5e-2, warmup_steps=5, total_steps=200,
                            weight_decay=0.0)
    state = adamw.init(params)

    def loss(p):
        r = x @ p["w"] - y
        return 0.5 * jnp.mean(r * r)

    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw.apply(cfg, g, state, params)
    assert float(loss(params)) < 0.01 * l0


def test_adamw_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9]                    # warmup
    assert lrs[50] > lrs[99]                  # decay
    assert lrs[99] >= 0.099                   # floor
