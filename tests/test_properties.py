"""Property-based invariants for the sketching subsystem (hypothesis-optional).

Three families of properties, all via the ``_hypothesis_compat`` shim so
tier-1 collection never requires hypothesis (the tests skip cleanly when
it is absent, and CI runs them in a dedicated job with it installed):

1. **Per-block Gram unbiasedness** (paper Lemma 6.1 / base.py contract):
   ``E[A^T S S^T A] = A^T A`` for every registered family — checked by
   Monte-Carlo averaging the survivor-rescaled Gram estimate over fresh
   sketch draws, against a tolerance a few sigma above the estimator's
   MC error ("Newton Meets Marchenko-Pastur" says correctness must hold
   across wide m/d regimes, so shapes are drawn, not fixed).
2. **k-of-n survivor-mask invariance** (OverSketch Eq. 4 semantics):
   dropping blocks + rescaling is EXACT — the masked estimator equals
   the plain average over the surviving subset, for any mask including
   the single-survivor edge.
3. **Fused-kernel agreement across padding edges**: the d-tiled fused
   sketch->Gram kernel matches the unfused oracle to 1e-4 with n not a
   multiple of tile_n, d not a multiple of d_tile, and forced-small
   tiles so the multi-tile (d_i, d_j) grid runs on CPU-sized shapes.

Families are looped inside the test bodies (not pytest.parametrize): the
hypothesis-compat shim replaces @given tests with zero-arg skippers, so
externally injected params would break hypothesis-less collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro import sketching
from repro.core.sketch import OverSketchConfig
from repro.kernels import ops, ref

FAMILIES = ["oversketch", "srht", "sjlt", "gaussian", "nystrom", "leverage"]
_CFG = OverSketchConfig(sketch_dim=64, block_size=16,
                        straggler_tolerance=0.25)   # 4 + 1 blocks


def _data(seed, n, d):
    a = jax.random.normal(jax.random.PRNGKey(seed ^ 0x5EED), (n, d))
    return a / jnp.sqrt(jnp.asarray(n, jnp.float32))


# ------------------------------------------------- per-block unbiasedness
@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 2**16),
       n=st.sampled_from([24, 33, 40]),      # 33: not a multiple of anything
       d=st.sampled_from([5, 8]))
def test_gram_unbiased(seed, n, d):
    """MC mean of the rescaled masked Gram converges to A^T A, for every
    registered family."""
    a = _data(seed, n, d)
    target = a.T @ a
    draws = 32
    key = jax.random.PRNGKey(seed)
    for family in FAMILIES:
        fam = sketching.get(family, _CFG)
        grams = [fam.gram(fam.sample(jax.random.fold_in(key, i), n), a, None)
                 for i in range(draws)]
        mean = jnp.mean(jnp.stack(grams), axis=0)
        rel = float(jnp.linalg.norm(mean - target) / jnp.linalg.norm(target))
        # MC error of the mean over draws * total_blocks block-grams is
        # ~ sqrt(d/b / (draws*blocks)) ~ 0.04-0.06 here; 0.3 is > 4 sigma.
        assert rel < 0.3, f"{family}: relative bias {rel:.3f}"


# ------------------------------------------- k-of-n survivor invariance
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16),
       n=st.sampled_from([40, 100, 129]),
       d=st.sampled_from([7, 17]),
       single=st.booleans(),
       idx=st.integers(0, 4))
def test_survivor_mask_invariance(seed, n, d, single, idx):
    """Masked + rescaled == plain average over the surviving subset; the
    straggler-drop rescale is exact for every family and any mask, down
    to a single survivor."""
    blocks = _CFG.total_blocks
    idx = idx % blocks
    a = _data(seed + 1, n, d)
    if single:
        mask = jnp.zeros((blocks,), bool).at[idx].set(True)
    else:
        mask = jax.random.bernoulli(jax.random.PRNGKey(seed + 2), 0.5,
                                    (blocks,)).at[idx].set(True)
    for family in FAMILIES:
        fam = sketching.get(family, _CFG)
        state = fam.sample(jax.random.PRNGKey(seed), n)
        got = fam.gram(state, a, mask)
        a_t = fam.apply(state, a)
        kept = a_t[np.asarray(mask)]
        expect = jnp.einsum("kbd,kbe->de", kept, kept) / kept.shape[0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"family={family}")


# --------------------------------- fused kernel across padding edges
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**20),
       n=st.integers(50, 300),               # crosses tile_n boundaries
       d=st.integers(3, 150),                # crosses the 128-lane tile
       tile_n=st.sampled_from([64, 128]),
       d_tile=st.sampled_from([128, 256]),
       single=st.booleans())
def test_fused_kernel_padding_edges(seed, n, d, tile_n, d_tile, single):
    """ops.sketch_gram_{count,srht,sjlt} vs the unfused jnp oracle, with
    shapes straddling every padding edge and forced-small d_tile so the
    multi-tile (d_i, d_j) grid (diagonal + off-diagonal folds) executes
    on CPU-sized shapes."""
    k, b, s = 2, 32, 3
    key = jax.random.PRNGKey(seed)
    kh, ks, ka, kr, km = jax.random.split(key, 5)
    a = jax.random.normal(ka, (n, d)) / jnp.sqrt(jnp.asarray(n, jnp.float32))
    if single:
        surv = jnp.zeros((k,), bool).at[1].set(True)
    else:
        surv = jax.random.bernoulli(km, 0.6, (k,)).at[0].set(True)
    kw = dict(tile_n=tile_n, d_tile=d_tile)
    h = jax.random.randint(kh, (k, n), 0, b, dtype=jnp.int32)
    sg = jax.random.rademacher(ks, (k, n), dtype=jnp.float32)
    n_pad = 1 << max(0, (n - 1).bit_length())
    rows = jax.random.randint(kr, (k, b), 0, n_pad, dtype=jnp.int32)
    hj = jax.random.randint(kh, (k, s, n), 0, b, dtype=jnp.int32)
    sj = jax.random.rademacher(jax.random.fold_in(ks, 1), (k, s, n),
                               dtype=jnp.float32)
    cells = [
        ("count", ops.sketch_gram_count(h, sg, a, b, surv, **kw),
         ref.sketch_gram_count(h, sg, a, b, surv)),
        ("srht", ops.sketch_gram_srht(rows, sg, a, surv, **kw),
         ref.sketch_gram_srht(rows, sg, a, surv)),
        ("sjlt", ops.sketch_gram_sjlt(hj, sj, a, b, surv, **kw),
         ref.sketch_gram_sjlt(hj, sj, a, b, surv)),
    ]
    for mode, out, expect in cells:
        assert out.shape == (d, d)
        err = float(jnp.abs(out - expect).max())
        assert err <= 1e-4, f"mode={mode}: max_err={err:.2e}"


# ------------------------------------------------------- plain (no-shim)
def test_all_six_families_registered():
    """The property sweep above covers exactly the registered set."""
    assert sorted(FAMILIES) == sketching.available()


def test_fused_path_reporting_consistent():
    """fused_path agrees with has_fused_gram across the registry."""
    for name in sketching.available():
        fam = sketching.get(name, _CFG)
        path = fam.fused_path(512)
        if fam.has_fused_gram:
            assert path in ("fused", "fused_tiled")
        else:
            assert path == "unfused"
