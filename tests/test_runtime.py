"""Runtime fleet engine: policy registry semantics, lifecycle (cold start /
failure-retry) accounting, cost monotonicity, trace record/replay
bit-exactness, empirical calibration, and Newton-under-failures
convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Dataset, LogisticRegression, NewtonConfig,
                        OverSketchConfig, oversketched_newton)
from repro.core.straggler import SimClock, StragglerModel
from repro.runtime import (CostModel, FleetConfig, TraceRecorder,
                           available_policies, calibrate_from_times,
                           load_trace)

POLICIES = ("coded_decode", "hedged", "k_of_n", "speculative", "wait_all")


def _logistic(key, n=1200, d=20):
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d), minval=-1, maxval=1)
    wstar = jax.random.normal(kw, (d,))
    y = jnp.where(jax.random.uniform(ky, (n,)) < jax.nn.sigmoid(x @ wstar),
                  1.0, -1.0)
    return Dataset(x=x, y=y)


# ----------------------------------------------------------------- registry
def test_all_five_policies_registered():
    assert set(POLICIES) <= set(available_policies())


def test_every_policy_runs_through_the_engine():
    for policy in POLICIES:
        clock = SimClock(StragglerModel())
        e, mask = clock.phase(jax.random.PRNGKey(1), 16, policy=policy, k=12)
        assert float(e) > 0
        assert mask.shape == (16,)
        assert clock.time == float(e)
        assert clock.dollars > 0


def test_unknown_policy_raises():
    clock = SimClock(StragglerModel())
    with pytest.raises(ValueError, match="unknown policy"):
        clock.phase(jax.random.PRNGKey(0), 8, policy="bogus")


# ------------------------------------------------------------ policy sanity
def test_k_of_n_no_slower_than_wait_all():
    for seed in range(5):
        key = jax.random.PRNGKey(seed)
        e_all, _ = SimClock(StragglerModel(p_tail=0.1)).phase(
            key, 64, policy="wait_all")
        e_k, _ = SimClock(StragglerModel(p_tail=0.1)).phase(
            key, 64, policy="k_of_n", k=48)
        assert float(e_k) <= float(e_all) + 1e-9


def test_coded_decode_waits_for_required_worker():
    """A predicate that demands one specific straggler forces the wait."""
    key = jax.random.PRNGKey(3)
    clock = SimClock(StragglerModel(p_tail=0.3, tail_hi=3.0))
    need = 13
    e, mask = clock.phase(key, 16, policy="coded_decode", k=1,
                          decodable=lambda m: bool(m[need]))
    assert bool(mask[need])


def test_cost_monotone_in_fleet_size():
    def dollars(n):
        clock = SimClock(StragglerModel())
        clock.phase(jax.random.PRNGKey(0), n, policy="wait_all",
                    flops_per_worker=1e5)
        return clock.dollars
    d = [dollars(n) for n in (8, 32, 128)]
    assert d[0] < d[1] < d[2]


def test_speculative_and_hedged_bill_extra_attempts():
    """Relaunch/duplicate attempts show up as extra invocations."""
    model = StragglerModel(p_tail=0.3, tail_lo=3.0, tail_hi=6.0)
    key = jax.random.PRNGKey(5)
    base = SimClock(model)
    base.phase(key, 64, policy="wait_all")
    for policy in ("speculative", "hedged"):
        clock = SimClock(model)
        clock.phase(key, 64, policy=policy)
        assert clock.ledger.invocations > base.ledger.invocations, policy


# ---------------------------------------------------------------- lifecycle
def test_cold_starts_slow_the_phase():
    key = jax.random.PRNGKey(7)
    warm = SimClock(StragglerModel(body_sigma=0.01, p_tail=0.0))
    cold = SimClock(StragglerModel(body_sigma=0.01, p_tail=0.0),
                    fleet=FleetConfig(cold_start_prob=1.0,
                                      cold_start_lo=1.0, cold_start_hi=2.0))
    e_warm, _ = warm.phase(key, 32, policy="wait_all")
    e_cold, _ = cold.phase(key, 32, policy="wait_all")
    assert float(e_cold) >= float(e_warm) + 1.0


def test_failure_retry_bills_every_attempt():
    """failure_rate=1 forces max_retries failures per worker before the
    guaranteed-success attempt: (max_retries + 1) invocations each."""
    n, retries = 16, 2
    clock = SimClock(StragglerModel(),
                     fleet=FleetConfig(failure_rate=1.0, max_retries=retries))
    e, mask = clock.phase(jax.random.PRNGKey(9), n, policy="wait_all")
    assert clock.ledger.invocations == n * (retries + 1)
    assert bool(np.asarray(mask).all())
    ok = SimClock(StragglerModel())
    e_ok, _ = ok.phase(jax.random.PRNGKey(9), n, policy="wait_all")
    assert float(e) > float(e_ok)   # retries cost wall time too


def test_newton_converges_under_failures_and_cold_starts():
    data = _logistic(jax.random.PRNGKey(11))
    obj = LogisticRegression(lam=1e-4)
    cfg = NewtonConfig(iters=8, sketch=OverSketchConfig(512, 64, 0.25),
                       coded_block_rows=128)
    clock = SimClock(StragglerModel(),
                     fleet=FleetConfig(failure_rate=0.15,
                                       cold_start_prob=0.25))
    res = oversketched_newton(obj, data, jnp.zeros(data.x.shape[1]), cfg,
                              model=clock)
    assert res.history["gnorm"][-1] < 1e-3
    assert res.history["time"] == sorted(res.history["time"])
    assert res.history["cost"] == sorted(res.history["cost"])
    # The same run on a failure-free fleet is strictly faster.
    res0 = oversketched_newton(obj, data, jnp.zeros(data.x.shape[1]), cfg)
    assert res0.history["time"][-1] < res.history["time"][-1]


# --------------------------------------------------------- pipeline overlap
def test_not_before_overlap_makespan_not_longer():
    """run_phase(not_before=t) launches a phase in the past: the clock
    advances to max(now, t + elapsed), so an overlapped schedule is never
    slower than the sequential one — and billing is identical (overlap
    moves work on the timeline, it does not unbill it)."""
    key = jax.random.PRNGKey(21)
    k2 = jax.random.fold_in(key, 1)

    seq = SimClock(StragglerModel())
    seq.phase(key, 16, policy="wait_all", flops_per_worker=2e5)
    seq.phase(k2, 16, policy="wait_all", flops_per_worker=2e5)

    ovl = SimClock(StragglerModel())
    ovl.phase(key, 16, policy="wait_all", flops_per_worker=2e5)
    ovl.phase(k2, 16, policy="wait_all", flops_per_worker=2e5,
              not_before=0.0)
    assert ovl.time < seq.time          # equal-work phases overlap strictly
    assert ovl.dollars == seq.dollars


def test_not_before_fully_hidden_phase_is_free_in_time():
    key = jax.random.PRNGKey(22)
    clock = SimClock(StragglerModel())
    clock.phase(key, 16, policy="wait_all", flops_per_worker=1e6)
    t = clock.time
    d = clock.dollars
    # A short phase launched at time 0 finished long ago: no clock motion.
    e, _ = clock.phase(jax.random.fold_in(key, 1), 4, policy="wait_all",
                       flops_per_worker=1e3, not_before=0.0)
    assert e > 0
    assert clock.time == t
    assert clock.dollars > d            # still billed


def test_overlapped_phases_replay_bit_exact(tmp_path):
    def drive(clock):
        clock.phase(jax.random.PRNGKey(0), 12, policy="wait_all",
                    flops_per_worker=3e5)
        clock.phase(jax.random.PRNGKey(1), 12, policy="k_of_n", k=10,
                    flops_per_worker=3e5, not_before=0.0)
        return clock

    rec = TraceRecorder()
    recorded = drive(SimClock(StragglerModel(), recorder=rec))
    path = tmp_path / "overlap.jsonl"
    rec.dump(path)
    replayed = drive(SimClock(StragglerModel(), replay=load_trace(path)))
    assert replayed.time == recorded.time
    assert replayed.dollars == recorded.dollars


def test_newton_overlap_encode_no_slower_same_iterates():
    """The coded-matvec master's one-time encodes (Sec. 4.1) hide behind
    compute when overlap_encode=True: same iterates, makespan <= the
    serialized schedule."""
    data = _logistic(jax.random.PRNGKey(23), n=600, d=12)
    obj = LogisticRegression(lam=1e-4)
    base = dict(iters=3, sketch=OverSketchConfig(256, 64, 0.25),
                coded_block_rows=64)
    r_ovl = oversketched_newton(obj, data, jnp.zeros(12),
                                NewtonConfig(**base))
    r_seq = oversketched_newton(obj, data, jnp.zeros(12),
                                NewtonConfig(overlap_encode=False, **base))
    assert r_ovl.history["fval"] == r_seq.history["fval"]
    assert r_ovl.history["time"][-1] <= r_seq.history["time"][-1]
    assert r_ovl.history["cost"][-1] == pytest.approx(
        r_seq.history["cost"][-1])


# ------------------------------------------------------------ record/replay
def test_phase_replay_is_bit_exact(tmp_path):
    def drive(clock):
        for s in range(4):
            clock.phase(jax.random.PRNGKey(s), 24, policy="k_of_n", k=20,
                        flops_per_worker=2e5, comm_units=1.0)
        clock.charge(0.613)
        return clock

    rec = TraceRecorder()
    fleet = FleetConfig(failure_rate=0.2, cold_start_prob=0.3)
    recorded = drive(SimClock(StragglerModel(), fleet=fleet, recorder=rec))
    path = tmp_path / "trace.jsonl"
    rec.dump(path)
    replayed = drive(SimClock(StragglerModel(), replay=load_trace(path)))
    assert replayed.time == recorded.time
    assert replayed.dollars == recorded.dollars


def test_replay_rejects_drifted_schedule(tmp_path):
    rec = TraceRecorder()
    clock = SimClock(StragglerModel(), recorder=rec)
    clock.phase(jax.random.PRNGKey(0), 16, policy="wait_all")
    path = tmp_path / "drift.jsonl"
    rec.dump(path)
    replay = SimClock(StragglerModel(), replay=load_trace(path))
    with pytest.raises(ValueError, match="not the same schedule"):
        replay.phase(jax.random.PRNGKey(0), 32, policy="wait_all")


def test_newton_trace_replay_end_to_end(tmp_path):
    """Same seed + recorded trace -> identical (time, cost) trajectories."""
    data = _logistic(jax.random.PRNGKey(13), n=600, d=12)
    obj = LogisticRegression(lam=1e-4)
    cfg = NewtonConfig(iters=4, sketch=OverSketchConfig(256, 64, 0.25),
                       coded_block_rows=64)
    rec = TraceRecorder()
    r1 = oversketched_newton(obj, data, jnp.zeros(12), cfg,
                             model=SimClock(StragglerModel(), recorder=rec))
    path = tmp_path / "newton.jsonl"
    rec.dump(path)
    r2 = oversketched_newton(
        obj, data, jnp.zeros(12), cfg,
        model=SimClock(StragglerModel(), replay=load_trace(path)))
    assert r1.history["time"] == r2.history["time"]
    assert r1.history["cost"] == r2.history["cost"]


# -------------------------------------------------------------- calibration
def test_calibration_recovers_fig1_shape():
    model = StragglerModel(base_time=135.0, invoke_overhead=0.0)
    times = np.asarray(model.sample_times(jax.random.PRNGKey(0), 3600))
    fit = calibrate_from_times(times)
    assert abs(fit.base_time - 135.0) / 135.0 < 0.05
    assert 0.005 < fit.p_tail < 0.05
    refit = np.asarray(fit.sample_times(jax.random.PRNGKey(1), 3600))
    assert abs(float(np.median(refit)) - float(np.median(times))) \
        / float(np.median(times)) < 0.1


def test_calibration_rejects_garbage():
    with pytest.raises(ValueError, match="positive"):
        calibrate_from_times([1.0, -2.0, 3.0])


# --------------------------------------------------------------------- cost
def test_cost_model_meters_add_up():
    cm = CostModel()
    assert cm.dollars(1.0, 0, 0, 0) == pytest.approx(cm.usd_per_gb_second)
    assert cm.dollars(0, 1e6, 0, 0) == pytest.approx(0.2, rel=1e-3)
    ec2 = CostModel(usd_per_invocation=0.0, usd_per_s3_put=0.0,
                    usd_per_s3_get=0.0)
    assert ec2.dollars(0, 1e6, 1e3, 1e3) == 0.0


def test_reserved_billing_charges_wall_clock_for_the_whole_fleet():
    """A fixed cluster bills n x elapsed (idle-behind-the-straggler time
    included), not the sum of per-worker durations."""
    n = 32
    key = jax.random.PRNGKey(17)
    lam = SimClock(StragglerModel(p_tail=0.2, tail_hi=3.0))
    e_lam, _ = lam.phase(key, n, policy="wait_all")
    ec2 = SimClock(StragglerModel(p_tail=0.2, tail_hi=3.0),
                   cost=CostModel(billing="reserved"))
    e_ec2, _ = ec2.phase(key, n, policy="wait_all")
    assert float(e_lam) == float(e_ec2)          # same fleet, same clock
    cm = CostModel(billing="reserved")
    assert ec2.ledger.gb_seconds == pytest.approx(
        cm.memory_gb * n * float(e_ec2))
    # wall-clock x fleet >= sum of per-worker durations, strictly so
    # whenever any worker idles behind the straggler
    assert ec2.ledger.gb_seconds > lam.ledger.gb_seconds
