"""Phase-DAG scheduler subsystem tests.

Contracts pinned here:

1. **Determinism**: same seed => bit-identical ``(seconds, dollars)`` for
   ANY topological declaration order of the same DAG — the scheduler
   canonicalizes dispatch, so declaration order never leaks into totals.
2. **Makespan dominance**: a DAG schedule is never slower than the
   sequential dispatch of the same phases (property-tested over random
   DAGs via the hypothesis shim), and a chain DAG — every edge serializes
   — is bit-identical to it.
3. **Warm-pool dynamics**: bursty DAG schedules pay at least as many cold
   starts as steady sequential ones; TTL expiry and MRU reuse behave.
4. **Per-phase Lambda sizing**: ``memory_gb`` overrides bill proportionally
   and round-trip through the v2 trace schema; pre-v2 replays are
   untouched (see also test_golden_trace).
5. **Optimizer wiring**: ``oversketched_newton(schedule="dag")`` produces
   the same iterates as sequential with a strictly smaller makespan and
   equal dollars; GIANT's chain DAG is bit-equal to sequential.
6. **Fleet calibration**: the committed synthetic Lambda trace
   (``fixtures/lambda_trace_synthetic.jsonl``) round-trips through
   ``calibrate_fleet_from_trace`` to the FleetConfig that recorded it.

Regenerate the synthetic Lambda fixture (only after an INTENTIONAL
schema/engine change):

    PYTHONPATH=src python tests/test_scheduler.py --regen-lambda
"""
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import newton, sketch
from repro.core.objectives import Dataset, LogisticRegression
from repro.core.straggler import SimClock, StragglerModel
from repro.optim.giant import GiantConfig, giant
from repro.runtime import (FleetConfig, TraceRecorder,
                           calibrate_fleet_from_trace, load_trace)
from repro.scheduler import (DagRun, PhaseSpec, WarmPool, canonical_order,
                             lambda_memory_gb, run_dag, validate_dag)

LAMBDA_FIXTURE = pathlib.Path(__file__).parent / "fixtures" / \
    "lambda_trace_synthetic.jsonl"
# The fleet the synthetic "public" Lambda trace was recorded under; the
# calibration round-trip must recover these numbers.
LAMBDA_FLEET = FleetConfig(failure_rate=0.2, cold_start_prob=0.3,
                           cold_start_lo=0.5, cold_start_hi=2.0)

MODEL = StragglerModel(p_tail=0.1, tail_hi=3.0)


def _diamond(workers=12):
    """grad chain || hessian fan-out -> join: the Newton iteration shape."""
    return [
        PhaseSpec("gx", workers, policy="k_of_n", k=workers - 2,
                  flops_per_worker=3e5, comm_units=1.0),
        PhaseSpec("gxt", workers, policy="k_of_n", k=workers - 2,
                  flops_per_worker=3e5, comm_units=1.0, deps=("gx",)),
        PhaseSpec("hess", 2 * workers, policy="k_of_n", k=2 * workers - 3,
                  flops_per_worker=6e5, comm_units=1.0),
        PhaseSpec("ls", workers, flops_per_worker=1e5, comm_units=0.5,
                  deps=("gxt", "hess")),
    ]


def _logistic(n=800, d=16):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    y = jnp.sign(x @ jax.random.normal(jax.random.PRNGKey(1), (d,)))
    return LogisticRegression(), Dataset(x=x, y=y), jnp.zeros(d)


# ------------------------------------------------------------- validation
def test_duplicate_phase_name_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        validate_dag([PhaseSpec("a", 2), PhaseSpec("a", 2)])


def test_unknown_dep_rejected():
    with pytest.raises(ValueError, match="unknown"):
        validate_dag([PhaseSpec("a", 2, deps=("ghost",))])


def test_cycle_rejected():
    with pytest.raises(ValueError, match="cycle"):
        validate_dag([PhaseSpec("a", 2, deps=("b",)),
                      PhaseSpec("b", 2, deps=("a",))])


def test_canonical_order_is_declaration_invariant():
    specs = _diamond()
    base = [s.name for s in canonical_order(specs)]
    assert base == [s.name for s in canonical_order(specs[::-1])]
    assert set(base) == {s.name for s in specs}


def test_dispatch_rejects_undispatched_dep_and_redispatch():
    run = DagRun(SimClock(MODEL), key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="undispatched"):
        run.dispatch(PhaseSpec("b", 2, deps=("a",)))
    run.dispatch(PhaseSpec("a", 2))
    with pytest.raises(ValueError, match="already dispatched"):
        run.dispatch(PhaseSpec("a", 2))


# ------------------------------------------------------------ determinism
def test_topological_declaration_orders_bit_identical():
    specs = _diamond()
    totals = set()
    # Three distinct topological declaration orders of the same DAG.
    for perm in ([0, 1, 2, 3], [2, 0, 1, 3], [0, 2, 1, 3]):
        clock = SimClock(MODEL)
        run_dag(clock, jax.random.PRNGKey(0), [specs[i] for i in perm])
        totals.add((clock.time, clock.dollars))
    assert len(totals) == 1


def test_topological_orders_bit_identical_with_pool():
    specs = _diamond()
    totals = set()
    for perm in ([0, 1, 2, 3], [2, 0, 1, 3]):
        pool = WarmPool(ttl=5.0)
        clock = SimClock(MODEL, fleet=FleetConfig(), pool=pool)
        run_dag(clock, jax.random.PRNGKey(0), [specs[i] for i in perm])
        totals.add((clock.time, clock.dollars,
                    pool.warm_hits, pool.cold_starts))
    assert len(totals) == 1


# ------------------------------------------------------ makespan dominance
def test_dag_beats_sequential_on_diamond_and_bills_identically():
    specs = _diamond()
    dag_clock, seq_clock = SimClock(MODEL), SimClock(MODEL)
    run_dag(dag_clock, jax.random.PRNGKey(0), specs)
    run_dag(seq_clock, jax.random.PRNGKey(0), specs, sequential=True)
    assert dag_clock.time < seq_clock.time
    assert dag_clock.dollars == seq_clock.dollars


def test_chain_dag_bit_identical_to_sequential():
    chain = [PhaseSpec("a", 6, flops_per_worker=2e5),
             PhaseSpec("b", 6, flops_per_worker=2e5, deps=("a",)),
             PhaseSpec("c", 6, flops_per_worker=2e5, deps=("b",))]
    c1, c2 = SimClock(MODEL), SimClock(MODEL)
    run_dag(c1, jax.random.PRNGKey(3), chain)
    run_dag(c2, jax.random.PRNGKey(3), chain, sequential=True)
    assert c1.time == c2.time
    assert c1.dollars == c2.dollars


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_random_dag_makespan_never_exceeds_sequential(data):
    """Random DAGs: edges drawn per-phase from earlier phases; DAG makespan
    <= sequential (ULP slack for overlap re-rounding), dollars identical."""
    n = data.draw(st.integers(min_value=2, max_value=6), label="phases")
    specs = []
    for i in range(n):
        deps = tuple(
            f"p{j}" for j in range(i)
            if data.draw(st.booleans(), label=f"edge {j}->{i}"))
        specs.append(PhaseSpec(
            f"p{i}",
            workers=data.draw(st.integers(min_value=2, max_value=8),
                              label=f"workers {i}"),
            flops_per_worker=1e5 * data.draw(
                st.integers(min_value=1, max_value=5), label=f"work {i}"),
            comm_units=1.0, deps=deps))
    seed = data.draw(st.integers(min_value=0, max_value=2 ** 16),
                     label="seed")
    dag_clock, seq_clock = SimClock(MODEL), SimClock(MODEL)
    run_dag(dag_clock, jax.random.PRNGKey(seed), specs)
    run_dag(seq_clock, jax.random.PRNGKey(seed), specs, sequential=True)
    assert dag_clock.time <= seq_clock.time * (1 + 1e-12)
    assert dag_clock.dollars == seq_clock.dollars


# --------------------------------------------------------------- warm pool
def test_pool_reuse_and_ttl_expiry():
    pool = WarmPool(ttl=10.0)
    assert not pool.acquire(0.0)          # empty: cold
    pool.release(1.0)
    assert not pool.acquire(0.5)          # not free yet at t=0.5
    pool.release(2.0)
    assert pool.acquire(5.0)              # MRU: takes the t=2.0 container
    assert pool.acquire(10.5)             # t=1.0 container, idle 9.5 < ttl
    assert not pool.acquire(10.6)         # pool drained
    pool.release(3.0)
    assert not pool.acquire(20.0)         # idle 17 s > ttl: expired


def test_pool_mru_keeps_hot_container_capacity_evicts_lru():
    pool = WarmPool(ttl=100.0, capacity=2)
    for t in (1.0, 2.0, 3.0):
        pool.release(t)
    assert len(pool) == 2                 # t=1.0 evicted
    assert pool.free_at(3.5) == 2
    assert pool.acquire(3.5)
    assert pool.free_at(3.5) == 1


def test_prewarmed_pool_skips_initial_colds():
    pool = WarmPool(ttl=100.0, prewarmed=4)
    clock = SimClock(MODEL, pool=pool)
    clock.phase(jax.random.PRNGKey(0), 4, flops_per_worker=1e5)
    assert pool.cold_starts == 0
    assert pool.warm_hits == 4


def test_bursty_dag_pays_at_least_as_many_colds_as_steady_sequential():
    specs = _diamond()
    cold = {}
    for label, sequential in (("dag", False), ("seq", True)):
        pool = WarmPool(ttl=300.0)
        clock = SimClock(MODEL, fleet=FleetConfig(), pool=pool)
        run_dag(clock, jax.random.PRNGKey(2), specs, sequential=sequential)
        cold[label] = pool.cold_starts
    # The DAG launches gx and hess concurrently: no warm containers can be
    # shared between them, so the burst pays strictly more cold starts.
    assert cold["dag"] > cold["seq"]


def test_pool_cold_starts_slow_the_phase():
    def run(pool):
        clock = SimClock(StragglerModel(p_tail=0.0),
                         fleet=FleetConfig(cold_start_lo=1.0,
                                           cold_start_hi=2.0),
                         pool=pool)
        elapsed, _ = clock.phase(jax.random.PRNGKey(5), 8,
                                 flops_per_worker=1e5)
        return elapsed
    cold_elapsed = run(WarmPool(ttl=50.0))             # empty pool: all cold
    warm_elapsed = run(WarmPool(ttl=50.0, prewarmed=8))
    assert cold_elapsed > warm_elapsed + 0.9           # >= cold_start_lo


# ------------------------------------------------- per-phase memory sizing
def test_lambda_memory_gb_granularity_and_clamps():
    assert lambda_memory_gb(0.0) == 0.125
    assert lambda_memory_gb(2 ** 30, headroom=1.0) == 1.0
    assert lambda_memory_gb(2 ** 30 + 1, headroom=1.0) == 1.0625
    assert lambda_memory_gb(2 ** 40) == 10.0
    with pytest.raises(ValueError):
        lambda_memory_gb(-1.0)


def test_memory_override_bills_proportionally():
    def gb_seconds(mem):
        clock = SimClock(StragglerModel())
        clock.phase(jax.random.PRNGKey(1), 8, flops_per_worker=2e5,
                    memory_gb=mem)
        return clock.ledger.gb_seconds
    assert np.isclose(gb_seconds(1.0) * 3.0, gb_seconds(None))
    assert np.isclose(gb_seconds(0.5) * 6.0, gb_seconds(None))


def test_memory_override_respected_by_reserved_billing():
    from repro.runtime import CostModel
    clock = SimClock(StragglerModel(), cost=CostModel(billing="reserved"))
    elapsed, _ = clock.phase(jax.random.PRNGKey(1), 4,
                             flops_per_worker=2e5, memory_gb=1.0)
    assert np.isclose(clock.ledger.gb_seconds, 1.0 * 4 * elapsed)


# ------------------------------------------------------- trace schema v2
def test_dag_pool_memory_trace_replays_bit_identical(tmp_path):
    def drive(clock):
        run_dag(clock, jax.random.PRNGKey(4), [
            PhaseSpec("a", 8, flops_per_worker=2e5, memory_gb=1.5),
            PhaseSpec("b", 8, flops_per_worker=2e5, deps=("a",)),
            PhaseSpec("c", 12, policy="k_of_n", k=10,
                      flops_per_worker=3e5, memory_gb=0.5),
        ])
        return clock
    rec = TraceRecorder(worker_times=True, lifecycle=True)
    live = drive(SimClock(MODEL, fleet=FleetConfig(failure_rate=0.1),
                          pool=WarmPool(ttl=30.0), recorder=rec))
    path = tmp_path / "dag.jsonl"
    rec.dump(path)
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert any(r.get("memory_gb") == 1.5 for r in rows)
    assert all("pool" in r for r in rows)
    assert all("retries" in r and "cold_delays" in r for r in rows)
    replayed = drive(SimClock(MODEL, replay=load_trace(path)))
    assert replayed.time == live.time
    assert replayed.dollars == live.dollars


def test_default_recording_has_no_v2_fields():
    """Runs without pool/memory/lifecycle opt-ins must record byte-level
    v1 rows — pre-v2 consumers and fixtures stay untouched."""
    rec = TraceRecorder()
    clock = SimClock(MODEL, recorder=rec)
    clock.phase(jax.random.PRNGKey(0), 6, flops_per_worker=1e5)
    (row,) = rec.rows
    for field in ("memory_gb", "pool", "retries", "cold_delays"):
        assert field not in row


# ------------------------------------------------------- optimizer wiring
def test_newton_dag_same_iterates_faster_makespan_equal_dollars():
    obj, data, w0 = _logistic()
    scfg = sketch.OverSketchConfig(sketch_dim=256, block_size=64,
                                   straggler_tolerance=0.25)
    cfg = newton.NewtonConfig(iters=3, sketch=scfg, schedule="dag")
    res_dag = newton.oversketched_newton(obj, data, w0, cfg, model=MODEL)
    res_seq = newton.oversketched_newton(
        obj, data, w0, dataclasses.replace(cfg, schedule="sequential"),
        model=MODEL)
    assert res_dag.history["fval"] == res_seq.history["fval"]
    assert res_dag.history["time"][-1] < res_seq.history["time"][-1]
    assert res_dag.history["cost"] == res_seq.history["cost"]


def test_newton_distavg_dag_overlaps_and_matches_iterates():
    obj, data, w0 = _logistic()
    scfg = sketch.OverSketchConfig(sketch_dim=128, block_size=32,
                                   straggler_tolerance=0.25)
    cfg = newton.NewtonConfig(iters=3, sketch=scfg,
                              sketch_mode="distributed-avg", debias=True,
                              schedule="dag")
    res_dag = newton.oversketched_newton(obj, data, w0, cfg, model=MODEL)
    res_seq = newton.oversketched_newton(
        obj, data, w0, dataclasses.replace(cfg, schedule="sequential"),
        model=MODEL)
    assert res_dag.history["fval"] == res_seq.history["fval"]
    assert res_dag.history["time"][-1] < res_seq.history["time"][-1]


def test_newton_phase_memory_cheaper_than_fleet_wide_3gb():
    obj, data, w0 = _logistic()
    scfg = sketch.OverSketchConfig(sketch_dim=256, block_size=64,
                                   straggler_tolerance=0.25)
    cfg = newton.NewtonConfig(iters=2, sketch=scfg)
    sized = dataclasses.replace(cfg, phase_memory=True)
    res = newton.oversketched_newton(obj, data, w0, cfg, model=MODEL)
    res_sized = newton.oversketched_newton(obj, data, w0, sized, model=MODEL)
    assert res_sized.history["cost"][-1] < res.history["cost"][-1]
    assert res_sized.history["fval"] == res.history["fval"]


def test_newton_dag_trace_record_replay_round_trip(tmp_path):
    obj, data, w0 = _logistic()
    cfg = newton.NewtonConfig(
        iters=2, schedule="dag",
        sketch=sketch.OverSketchConfig(sketch_dim=128, block_size=32,
                                       straggler_tolerance=0.25))
    rec = TraceRecorder()
    clock = SimClock(MODEL, pool=WarmPool(ttl=60.0),
                     fleet=FleetConfig(), recorder=rec)
    live = newton.oversketched_newton(obj, data, w0, cfg, model=clock)
    path = tmp_path / "newton_dag.jsonl"
    rec.dump(path)
    replay_clock = SimClock(MODEL, replay=load_trace(path))
    replayed = newton.oversketched_newton(obj, data, w0, cfg,
                                          model=replay_clock)
    assert replayed.history["time"] == live.history["time"]
    assert replayed.history["cost"] == live.history["cost"]


def test_giant_dag_chain_bit_equal_to_sequential():
    obj, data, w0 = _logistic()
    cfg = GiantConfig(iters=2, num_workers=8, schedule="dag")
    h_dag = giant(obj, data, w0, cfg, model=MODEL)
    h_seq = giant(obj, data, w0,
                  dataclasses.replace(cfg, schedule="sequential"),
                  model=MODEL)
    assert h_dag["time"] == h_seq["time"]
    assert h_dag["cost"] == h_seq["cost"]
    assert h_dag["fval"] == h_seq["fval"]


def test_newton_rejects_bad_schedule_and_metric():
    obj, data, w0 = _logistic()
    with pytest.raises(ValueError, match="schedule"):
        newton.oversketched_newton(
            obj, data, w0, newton.NewtonConfig(iters=1, schedule="zigzag"),
            model=None)
    with pytest.raises(ValueError, match="adaptive_metric"):
        newton.oversketched_newton(
            obj, data, w0,
            newton.NewtonConfig(iters=1, adaptive_metric="psychic"),
            model=None)
    with pytest.raises(ValueError, match="blocks"):
        newton.oversketched_newton(
            obj, data, w0,
            newton.NewtonConfig(iters=1, adaptive_sketch=True,
                                adaptive_metric="mp",
                                sketch_mode="distributed-avg"),
            model=None)
    # The exact-Hessian path never reports m_eff: the mp metric would be
    # silently inert, so it must be rejected just like distributed-avg.
    with pytest.raises(ValueError, match="oversketch"):
        newton.oversketched_newton(
            obj, data, w0,
            newton.NewtonConfig(iters=1, adaptive_sketch=True,
                                adaptive_metric="mp",
                                hessian_policy="exact"),
            model=None)


# ------------------------------------------------ MP-driven adaptive sketch
def test_mp_metric_grows_from_iteration_zero():
    """gamma = 1 - d/m starts below target => growth fires immediately,
    before any f-decrease stall is observable."""
    obj, data, w0 = _logistic()
    scfg = sketch.OverSketchConfig(sketch_dim=32, block_size=16,
                                   straggler_tolerance=0.25)
    cfg = newton.NewtonConfig(iters=3, sketch=scfg, adaptive_sketch=True,
                              adaptive_metric="mp", adaptive_mp_target=0.75)
    res = newton.oversketched_newton(obj, data, w0, cfg, model=MODEL)
    dims = res.history["sketch_dim"]
    assert dims[1] == 2 * dims[0]
    stall = dataclasses.replace(cfg, adaptive_metric="stall")
    res_stall = newton.oversketched_newton(obj, data, w0, stall, model=MODEL)
    # The stall heuristic cannot grow before iteration 2 (needs prev_f).
    assert res_stall.history["sketch_dim"][1] == dims[0]


def test_mp_metric_leaves_ample_sketch_alone():
    obj, data, w0 = _logistic()
    scfg = sketch.OverSketchConfig(sketch_dim=256, block_size=64,
                                   straggler_tolerance=0.25)
    cfg = newton.NewtonConfig(iters=3, sketch=scfg, adaptive_sketch=True,
                              adaptive_metric="mp", adaptive_mp_target=0.75)
    res = newton.oversketched_newton(obj, data, w0, cfg, model=MODEL)
    assert res.history["sketch_dim"] == [256, 256, 256]


def test_mp_helpers():
    from repro import sketching
    assert sketching.mp_stalled(16, 32, target=0.75)          # gamma = 0.5
    assert not sketching.mp_stalled(16, 256, target=0.75)     # gamma ~ 0.94
    assert sketching.rows_for_target(16, 0.75) == 64
    with pytest.raises(ValueError):
        sketching.rows_for_target(16, 1.5)


# ------------------------------------------------------- fleet calibration
def test_lambda_fixture_round_trips_fleet_config():
    fleet = calibrate_fleet_from_trace(LAMBDA_FIXTURE)
    assert abs(fleet.failure_rate - LAMBDA_FLEET.failure_rate) < 0.05
    assert abs(fleet.cold_start_prob - LAMBDA_FLEET.cold_start_prob) < 0.05
    assert abs(fleet.cold_start_lo - LAMBDA_FLEET.cold_start_lo) < 0.1
    assert abs(fleet.cold_start_hi - LAMBDA_FLEET.cold_start_hi) < 0.1


def test_lambda_fixture_straggler_shape_still_calibrates():
    from repro.runtime import calibrate_from_trace
    model = calibrate_from_trace(LAMBDA_FIXTURE)
    assert model.base_time > 0
    assert 0.0 <= model.p_tail <= 1.0


def test_calibrate_fleet_requires_lifecycle_rows(tmp_path):
    rec = TraceRecorder()          # no lifecycle opt-in
    clock = SimClock(MODEL, recorder=rec)
    clock.phase(jax.random.PRNGKey(0), 4, flops_per_worker=1e5)
    path = tmp_path / "v1.jsonl"
    rec.dump(path)
    with pytest.raises(ValueError, match="lifecycle"):
        calibrate_fleet_from_trace(path)


# ----------------------------------------------------------------- fixture
def _regen_lambda():
    """Record the synthetic "public" Lambda trace: 40 mixed phases under a
    KNOWN fleet (LAMBDA_FLEET) with lifecycle + worker-time recording —
    the stand-in for the real public trace the ROADMAP calibration item
    wants, with ground truth attached."""
    rec = TraceRecorder(worker_times=True, lifecycle=True)
    clock = SimClock(StragglerModel(base_time=2.0, p_tail=0.04,
                                    tail_hi=2.0),
                     fleet=LAMBDA_FLEET, recorder=rec)
    for i in range(40):
        workers = (16, 32, 48)[i % 3]
        clock.phase(jax.random.PRNGKey(1000 + i), workers,
                    policy=("wait_all", "k_of_n")[i % 2],
                    k=max(1, int(0.9 * workers)) if i % 2 else None,
                    flops_per_worker=2e5 * (1 + i % 4), comm_units=1.0)
    LAMBDA_FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    with open(LAMBDA_FIXTURE, "w") as f:
        f.write(json.dumps(
            {"kind": "meta", "jax_version": jax.__version__,
             "generator": "tests/test_scheduler.py --regen-lambda",
             "fleet": {"failure_rate": LAMBDA_FLEET.failure_rate,
                       "cold_start_prob": LAMBDA_FLEET.cold_start_prob,
                       "cold_start_lo": LAMBDA_FLEET.cold_start_lo,
                       "cold_start_hi": LAMBDA_FLEET.cold_start_hi}}) + "\n")
        for row in rec.rows:
            f.write(json.dumps(row) + "\n")
    print(f"wrote {LAMBDA_FIXTURE} ({len(rec.rows)} rows)")


if __name__ == "__main__":
    import sys
    if "--regen-lambda" in sys.argv:
        _regen_lambda()
    else:
        sys.exit("usage: python tests/test_scheduler.py --regen-lambda")
