"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.

All kernels run in interpret mode on CPU (the TPU-target validation path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


# ---------------------------------------------------------------- count sketch
@pytest.mark.parametrize("k,n,d,b", [
    (1, 64, 32, 64),
    (3, 300, 70, 128),
    (5, 1000, 17, 256),     # ragged d
    (2, 129, 130, 64),      # ragged both
])
def test_count_sketch_shapes(k, n, d, b):
    key = jax.random.PRNGKey(k * 100 + n)
    kh, ks, ka = jax.random.split(key, 3)
    h = jax.random.randint(kh, (k, n), 0, b, dtype=jnp.int32)
    sigma = jax.random.rademacher(ks, (k, n), dtype=jnp.float32)
    a = jax.random.normal(ka, (n, d))
    out = ops.count_sketch_apply(h, sigma, a, b)
    expect = ref.count_sketch_apply(h, sigma, a, b)
    assert out.shape == (k, b, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_count_sketch_dtypes(dtype):
    key = jax.random.PRNGKey(7)
    kh, ks, ka = jax.random.split(key, 3)
    k, n, d, b = 2, 128, 64, 64
    h = jax.random.randint(kh, (k, n), 0, b, dtype=jnp.int32)
    sigma = jax.random.rademacher(ks, (k, n), dtype=jnp.float32)
    a = jax.random.normal(ka, (n, d)).astype(dtype)
    out = ops.count_sketch_apply(h, sigma, a, b)
    expect = ref.count_sketch_apply(h, sigma, a.astype(jnp.float32), b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(8, 200),
       d=st.integers(1, 100))
def test_count_sketch_property(seed, n, d):
    b = 64
    key = jax.random.PRNGKey(seed)
    kh, ks, ka = jax.random.split(key, 3)
    h = jax.random.randint(kh, (2, n), 0, b, dtype=jnp.int32)
    sigma = jax.random.rademacher(ks, (2, n), dtype=jnp.float32)
    a = jax.random.normal(ka, (n, d))
    out = ops.count_sketch_apply(h, sigma, a, b)
    expect = ref.count_sketch_apply(h, sigma, a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ oversketch gram
@pytest.mark.parametrize("k,b,d", [
    (4, 64, 32),
    (6, 128, 100),   # ragged d
    (10, 256, 256),
    (3, 65, 33),     # ragged b and d
])
def test_oversketch_gram_shapes(k, b, d):
    key = jax.random.PRNGKey(k + b + d)
    a_t = jax.random.normal(key, (k, b, d))
    surv = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.8, (k,))
    surv = surv.at[0].set(True)   # at least one survivor
    out = ops.oversketch_gram(a_t, surv)
    expect = ref.oversketch_gram(a_t, surv)
    assert out.shape == (d, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_oversketch_gram_all_masked_is_safe():
    a_t = jnp.ones((3, 64, 16))
    out = ops.oversketch_gram(a_t, jnp.zeros((3,), bool))
    assert np.isfinite(np.asarray(out)).all()


# ------------------------------------------------------------- coded matvec
@pytest.mark.parametrize("w,b,s", [
    (4, 64, 128),
    (9, 32, 333),    # ragged s
    (25, 64, 512),
])
def test_coded_matvec_shapes(w, b, s):
    key = jax.random.PRNGKey(w + s)
    enc = jax.random.normal(key, (w, b, s))
    x = jax.random.normal(jax.random.fold_in(key, 1), (s,))
    erased = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.2, (w,))
    out = ops.coded_block_matvec(enc, x, erased)
    expect = ref.coded_block_matvec(enc, x, erased)
    assert out.shape == (w, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------- fused sketch->gram
def _sketch_inputs(seed, k, n, d, b, n_pad_srht=None):
    key = jax.random.PRNGKey(seed)
    kh, ks, ka, kr, km = jax.random.split(key, 5)
    h = jax.random.randint(kh, (k, n), 0, b, dtype=jnp.int32)
    sigma = jax.random.rademacher(ks, (k, n), dtype=jnp.float32)
    # 1/sqrt(n) row scale keeps Gram entries O(1) so the <= 1e-4 max-abs
    # acceptance bound is an absolute float32 figure, not a moving target.
    a = jax.random.normal(ka, (n, d)) / jnp.sqrt(jnp.asarray(n, jnp.float32))
    n_pad = n_pad_srht or (1 << max(0, (n - 1).bit_length()))
    rows = jax.random.randint(kr, (k, b), 0, n_pad, dtype=jnp.int32)
    surv = jax.random.bernoulli(km, 0.6, (k,)).at[0].set(True)
    return h, sigma, a, rows, surv


@pytest.mark.parametrize("k,n,d,b", [
    (2, 128, 32, 64),
    (4, 700, 37, 64),      # non-power-of-two n, ragged d
    (3, 1000, 130, 128),   # d % 128 != 0 on both sides of a tile
    (5, 520, 64, 256),     # n % tile_n != 0
])
def test_sketch_gram_count_fused_matches_unfused(k, n, d, b):
    h, sigma, a, _, surv = _sketch_inputs(k * 7 + n, k, n, d, b)
    out = ops.sketch_gram_count(h, sigma, a, b, surv)
    expect = ref.sketch_gram_count(h, sigma, a, b, surv)
    assert out.shape == (d, d)
    assert float(jnp.abs(out - expect).max()) <= 1e-4


@pytest.mark.parametrize("k,n,d,b", [
    (2, 64, 20, 32),
    (3, 700, 37, 64),      # non-power-of-two n (pads to 1024 internally)
    (2, 1024, 130, 128),   # ragged d
])
def test_sketch_gram_srht_fused_matches_unfused(k, n, d, b):
    _, sigma, a, rows, surv = _sketch_inputs(k * 11 + n, k, n, d, b)
    out = ops.sketch_gram_srht(rows, sigma, a, surv)
    expect = ref.sketch_gram_srht(rows, sigma, a, surv)
    assert out.shape == (d, d)
    assert float(jnp.abs(out - expect).max()) <= 1e-4


def test_sketch_gram_single_survivor():
    k, n, d, b = 4, 300, 24, 64
    h, sigma, a, rows, _ = _sketch_inputs(0, k, n, d, b)
    surv = jnp.zeros((k,), bool).at[2].set(True)
    for out, expect in [
        (ops.sketch_gram_count(h, sigma, a, b, surv),
         ref.sketch_gram_count(h, sigma, a, b, surv)),
        (ops.sketch_gram_srht(rows, sigma, a, surv),
         ref.sketch_gram_srht(rows, sigma, a, surv)),
    ]:
        assert float(jnp.abs(out - expect).max()) <= 1e-4


def test_sketch_gram_all_masked_is_safe():
    k, n, d, b = 3, 200, 16, 64
    h, sigma, a, rows, _ = _sketch_inputs(1, k, n, d, b)
    surv = jnp.zeros((k,), bool)
    assert np.isfinite(
        np.asarray(ops.sketch_gram_count(h, sigma, a, b, surv))).all()
    assert np.isfinite(
        np.asarray(ops.sketch_gram_srht(rows, sigma, a, surv))).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sketch_gram_dtypes(dtype):
    k, n, d, b = 2, 256, 40, 64
    h, sigma, a, rows, surv = _sketch_inputs(2, k, n, d, b)
    a = a.astype(dtype)
    # Both kernels accumulate in float32, so after the (exact) bf16->f32
    # cast they must match the f32 oracle on the same cast values.
    a32 = a.astype(jnp.float32)
    out_c = ops.sketch_gram_count(h, sigma, a, b, surv)
    np.testing.assert_allclose(
        np.asarray(out_c), np.asarray(ref.sketch_gram_count(h, sigma, a32,
                                                            b, surv)),
        rtol=1e-4, atol=1e-4)
    out_s = ops.sketch_gram_srht(rows, sigma, a, surv)
    np.testing.assert_allclose(
        np.asarray(out_s), np.asarray(ref.sketch_gram_srht(rows, sigma,
                                                           a32, surv)),
        rtol=1e-4, atol=1e-4)


# ------------------------------- fused-vs-unfused differential sweep (tiled)
# d = 64 fits one resident output tile; 1536 and 4096 are past the old
# single-tile VMEM budget, where pre-tiling code silently fell back to the
# unfused pair — the path/pick assertions pin that the d-tiled fused grid
# actually runs there now.
_SWEEP_N = {64: 300, 1536: 192, 4096: 128}


@pytest.mark.parametrize("d", [64, 1536, 4096])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("family", ["oversketch", "srht", "sjlt"])
def test_fused_differential_sweep(family, dtype, d):
    from repro import sketching
    from repro.core.sketch import OverSketchConfig, sketched_gram

    b = 64
    n = _SWEEP_N[d]
    fam = sketching.get(family, OverSketchConfig(128, b, 0.25))
    d_pad = d + ((-d) % 128)
    nnz = getattr(fam, "nnz_per_row", 1)
    expect_path = "fused" if d <= 1024 else "fused_tiled"
    assert fam.fused_path(d) == expect_path
    assert ops.fused_path(b, d, nnz=nnz) == expect_path
    if expect_path == "fused_tiled":
        assert ops.pick_d_tile(b, d, nnz=nnz) < d_pad

    key = jax.random.PRNGKey(d + 13 * (dtype == jnp.bfloat16))
    state = fam.sample(key, n)
    a = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    a = (a / jnp.sqrt(jnp.asarray(n, jnp.float32))).astype(dtype)
    surv = jnp.ones((fam.cfg.total_blocks,), bool).at[0].set(False)
    fused = fam.gram_fused(state, a, surv)
    assert fused is not None           # the decline path is gone for any d
    # The kernel casts to f32 up front; the unfused oracle runs on the
    # exactly-cast values so <= 1e-4 is an absolute f32 agreement bound.
    a32 = a.astype(jnp.float32)
    expect = sketched_gram(fam.apply(state, a32), surv)
    assert fused.shape == (d, d)
    assert float(jnp.abs(fused - expect).max()) <= 1e-4


def test_fused_runs_to_d8192():
    """Acceptance bound: power-of-two-padded d up to 8192 takes the tiled
    fused grid (never None, never the unfused pair) and agrees."""
    k, n, d, b = 1, 64, 8192, 64
    h, sigma, a, _, _ = _sketch_inputs(3, k, n, d, b)
    surv = jnp.ones((k,), bool)
    assert ops.fused_path(b, d) == "fused_tiled"
    out = ops.sketch_gram_count(h, sigma, a, b, surv)
    expect = ref.sketch_gram_count(h, sigma, a, b, surv)
    assert float(jnp.abs(out - expect).max()) <= 1e-4


def test_sketch_gram_forced_tiny_tile_matches():
    """Forcing d_tile below d exercises the multi-tile grid on shapes the
    default pick would run single-tile — diag/off-diag fold coverage."""
    k, n, d, b = 3, 520, 200, 64
    h, sigma, a, rows, surv = _sketch_inputs(4, k, n, d, b)
    out = ops.sketch_gram_count(h, sigma, a, b, surv, d_tile=128)
    assert float(jnp.abs(out - ref.sketch_gram_count(h, sigma, a, b,
                                                     surv)).max()) <= 1e-4
    out_s = ops.sketch_gram_srht(rows, sigma, a, surv, d_tile=128)
    assert float(jnp.abs(out_s - ref.sketch_gram_srht(rows, sigma, a,
                                                      surv)).max()) <= 1e-4


# --------------------------------------------------- fused sjlt entry point
@pytest.mark.parametrize("k,s,n,d,b", [
    (2, 1, 128, 32, 64),    # s=1 degenerates to count-sketch
    (3, 4, 700, 37, 64),    # non-power-of-two n, ragged d
    (2, 8, 300, 130, 128),  # deep slot axis, d crossing a lane tile
])
def test_sketch_gram_sjlt_fused_matches_unfused(k, s, n, d, b):
    key = jax.random.PRNGKey(k * 3 + s + n)
    kh, ks, ka, km = jax.random.split(key, 4)
    h = jax.random.randint(kh, (k, s, n), 0, b, dtype=jnp.int32)
    sigma = jax.random.rademacher(ks, (k, s, n), dtype=jnp.float32)
    a = jax.random.normal(ka, (n, d)) / jnp.sqrt(jnp.asarray(n, jnp.float32))
    surv = jax.random.bernoulli(km, 0.6, (k,)).at[0].set(True)
    out = ops.sketch_gram_sjlt(h, sigma, a, b, surv)
    expect = ref.sketch_gram_sjlt(h, sigma, a, b, surv)
    assert out.shape == (d, d)
    assert float(jnp.abs(out - expect).max()) <= 1e-4


def test_sjlt_s1_equals_count_sketch():
    """SJLT with one slot IS count-sketch: both fused entry points agree."""
    k, n, d, b = 2, 256, 40, 64
    h, sigma, a, _, surv = _sketch_inputs(5, k, n, d, b)
    out_c = ops.sketch_gram_count(h, sigma, a, b, surv)
    out_j = ops.sketch_gram_sjlt(h[:, None, :], sigma[:, None, :], a, b, surv)
    np.testing.assert_allclose(np.asarray(out_j), np.asarray(out_c),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------ two-pass fwht
@pytest.mark.parametrize("k,n,d", [
    (2, 64, 20),       # tiny d (pads to one 128 lane tile)
    (1, 1024, 130),    # d % tile_d != 0
    (2, 2048, 17),
    (1, 4096, 256),
])
def test_fwht_two_pass_matches_butterfly_oracle(k, n, d):
    x = jax.random.normal(jax.random.PRNGKey(n + d), (k, n, d))
    np.testing.assert_allclose(np.asarray(ops.fwht_two_pass(x)),
                               np.asarray(ref.fwht(x)),
                               rtol=1e-4, atol=1e-4)


def test_fwht_two_pass_rejects_non_pow2():
    with pytest.raises(ValueError, match="power of two"):
        ops.fwht_two_pass(jnp.zeros((1, 100, 4)))


def test_fwht_dispatches_two_pass_beyond_panel_budget():
    """An n whose monolithic (n, td) panel exceeds the documented VMEM
    budget must still go through ops.fwht (via the two-pass kernel) and
    match the oracle."""
    from repro.kernels.srht import MAX_PANEL_BYTES, panel_vmem_bytes
    n = 32768
    assert panel_vmem_bytes(n, d=8) > MAX_PANEL_BYTES
    x = jax.random.normal(jax.random.PRNGKey(5), (1, n, 8))
    np.testing.assert_allclose(np.asarray(ops.fwht(x)),
                               np.asarray(ref.fwht(x)),
                               rtol=1e-4, atol=1e-4)


def test_fwht_two_pass_is_involution():
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 512, 64))
    y = ops.fwht_two_pass(ops.fwht_two_pass(x))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------- dtype sweep, remaining entry points
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_dtypes_both_paths(dtype):
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 256, 40)).astype(dtype)
    expect = ref.fwht(x.astype(jnp.float32))
    for out in (ops.fwht(x), ops.fwht_two_pass(x)):
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_oversketch_gram_dtypes(dtype):
    key = jax.random.PRNGKey(9)
    a_t = (jax.random.normal(key, (3, 64, 40)) / 8.0).astype(dtype)
    surv = jnp.ones((3,), bool).at[1].set(False)
    out = ops.oversketch_gram(a_t, surv)
    expect = ref.oversketch_gram(a_t.astype(jnp.float32), surv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_coded_matvec_dtypes(dtype):
    key = jax.random.PRNGKey(10)
    enc = (jax.random.normal(key, (4, 32, 200)) / 14.0).astype(dtype)
    x = jax.random.normal(jax.random.fold_in(key, 1), (200,)).astype(dtype)
    erased = jnp.zeros((4,), bool).at[2].set(True)
    out = ops.coded_block_matvec(enc, x, erased)
    expect = ref.coded_block_matvec(enc.astype(jnp.float32),
                                    x.astype(jnp.float32), erased)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------- end-to-end kernels inside newton
def test_newton_with_kernels_matches_reference_path():
    from repro.core import (Dataset, LogisticRegression, NewtonConfig,
                            OverSketchConfig, oversketched_newton)
    key = jax.random.PRNGKey(11)
    n, d = 600, 20
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d), minval=-1, maxval=1)
    wstar = jax.random.normal(kw, (d,))
    y = jnp.where(jax.random.uniform(ky, (n,)) <
                  jax.nn.sigmoid(x @ wstar), 1.0, -1.0)
    data = Dataset(x=x, y=y)
    obj = LogisticRegression(lam=1e-4)
    base = dict(iters=4, sketch=OverSketchConfig(256, 64, 0.25),
                coded_block_rows=64)
    r_ref = oversketched_newton(obj, data, jnp.zeros(d),
                                NewtonConfig(**base), model=None)
    r_ker = oversketched_newton(obj, data, jnp.zeros(d),
                                NewtonConfig(use_kernels=True, **base),
                                model=None)
    # Same sketch seed => identical Hessians => identical trajectories.
    np.testing.assert_allclose(np.asarray(r_ref.w), np.asarray(r_ker.w),
                               rtol=1e-4, atol=1e-5)
