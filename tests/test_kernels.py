"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.

All kernels run in interpret mode on CPU (the TPU-target validation path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


# ---------------------------------------------------------------- count sketch
@pytest.mark.parametrize("k,n,d,b", [
    (1, 64, 32, 64),
    (3, 300, 70, 128),
    (5, 1000, 17, 256),     # ragged d
    (2, 129, 130, 64),      # ragged both
])
def test_count_sketch_shapes(k, n, d, b):
    key = jax.random.PRNGKey(k * 100 + n)
    kh, ks, ka = jax.random.split(key, 3)
    h = jax.random.randint(kh, (k, n), 0, b, dtype=jnp.int32)
    sigma = jax.random.rademacher(ks, (k, n), dtype=jnp.float32)
    a = jax.random.normal(ka, (n, d))
    out = ops.count_sketch_apply(h, sigma, a, b)
    expect = ref.count_sketch_apply(h, sigma, a, b)
    assert out.shape == (k, b, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_count_sketch_dtypes(dtype):
    key = jax.random.PRNGKey(7)
    kh, ks, ka = jax.random.split(key, 3)
    k, n, d, b = 2, 128, 64, 64
    h = jax.random.randint(kh, (k, n), 0, b, dtype=jnp.int32)
    sigma = jax.random.rademacher(ks, (k, n), dtype=jnp.float32)
    a = jax.random.normal(ka, (n, d)).astype(dtype)
    out = ops.count_sketch_apply(h, sigma, a, b)
    expect = ref.count_sketch_apply(h, sigma, a.astype(jnp.float32), b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(8, 200),
       d=st.integers(1, 100))
def test_count_sketch_property(seed, n, d):
    b = 64
    key = jax.random.PRNGKey(seed)
    kh, ks, ka = jax.random.split(key, 3)
    h = jax.random.randint(kh, (2, n), 0, b, dtype=jnp.int32)
    sigma = jax.random.rademacher(ks, (2, n), dtype=jnp.float32)
    a = jax.random.normal(ka, (n, d))
    out = ops.count_sketch_apply(h, sigma, a, b)
    expect = ref.count_sketch_apply(h, sigma, a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ oversketch gram
@pytest.mark.parametrize("k,b,d", [
    (4, 64, 32),
    (6, 128, 100),   # ragged d
    (10, 256, 256),
    (3, 65, 33),     # ragged b and d
])
def test_oversketch_gram_shapes(k, b, d):
    key = jax.random.PRNGKey(k + b + d)
    a_t = jax.random.normal(key, (k, b, d))
    surv = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.8, (k,))
    surv = surv.at[0].set(True)   # at least one survivor
    out = ops.oversketch_gram(a_t, surv)
    expect = ref.oversketch_gram(a_t, surv)
    assert out.shape == (d, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


def test_oversketch_gram_all_masked_is_safe():
    a_t = jnp.ones((3, 64, 16))
    out = ops.oversketch_gram(a_t, jnp.zeros((3,), bool))
    assert np.isfinite(np.asarray(out)).all()


# ------------------------------------------------------------- coded matvec
@pytest.mark.parametrize("w,b,s", [
    (4, 64, 128),
    (9, 32, 333),    # ragged s
    (25, 64, 512),
])
def test_coded_matvec_shapes(w, b, s):
    key = jax.random.PRNGKey(w + s)
    enc = jax.random.normal(key, (w, b, s))
    x = jax.random.normal(jax.random.fold_in(key, 1), (s,))
    erased = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.2, (w,))
    out = ops.coded_block_matvec(enc, x, erased)
    expect = ref.coded_block_matvec(enc, x, erased)
    assert out.shape == (w, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------- end-to-end kernels inside newton
def test_newton_with_kernels_matches_reference_path():
    from repro.core import (Dataset, LogisticRegression, NewtonConfig,
                            OverSketchConfig, oversketched_newton)
    key = jax.random.PRNGKey(11)
    n, d = 600, 20
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d), minval=-1, maxval=1)
    wstar = jax.random.normal(kw, (d,))
    y = jnp.where(jax.random.uniform(ky, (n,)) <
                  jax.nn.sigmoid(x @ wstar), 1.0, -1.0)
    data = Dataset(x=x, y=y)
    obj = LogisticRegression(lam=1e-4)
    base = dict(iters=4, sketch=OverSketchConfig(256, 64, 0.25),
                coded_block_rows=64)
    r_ref = oversketched_newton(obj, data, jnp.zeros(d),
                                NewtonConfig(**base), model=None)
    r_ker = oversketched_newton(obj, data, jnp.zeros(d),
                                NewtonConfig(use_kernels=True, **base),
                                model=None)
    # Same sketch seed => identical Hessians => identical trajectories.
    np.testing.assert_allclose(np.asarray(r_ref.w), np.asarray(r_ker.w),
                               rtol=1e-4, atol=1e-5)
