"""Straggler model + simulation clock invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import straggler as sg


def test_sample_shapes_and_positivity():
    m = sg.StragglerModel()
    t = m.sample_times(jax.random.PRNGKey(0), 100)
    assert t.shape == (100,)
    assert (np.asarray(t) > 0).all()


def test_tail_fraction_close_to_p():
    """~2% of workers straggle (Fig. 1)."""
    m = sg.StragglerModel(p_tail=0.02, body_sigma=0.01)
    t = np.asarray(m.sample_times(jax.random.PRNGKey(1), 20000))
    med = np.median(t)
    frac = (t > 1.25 * med).mean()
    assert 0.005 < frac < 0.05


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999), k_frac=st.floats(0.1, 1.0))
def test_policy_ordering(seed, k_frac):
    """k-of-n <= wait-all, and k-of-n monotone in k."""
    m = sg.StragglerModel(p_tail=0.1)
    t = m.sample_times(jax.random.PRNGKey(seed), 64)
    k = max(1, int(64 * k_frac))
    assert float(sg.k_of_n_time(t, k)) <= float(sg.wait_all_time(t)) + 1e-6
    if k > 1:
        assert float(sg.k_of_n_time(t, k - 1)) <= float(sg.k_of_n_time(t, k)) + 1e-6


def test_k_of_n_mask_has_at_least_k():
    m = sg.StragglerModel(p_tail=0.2)
    t = m.sample_times(jax.random.PRNGKey(3), 50)
    mask = sg.k_of_n_mask(t, 30)
    assert int(mask.sum()) >= 30


def test_speculative_beats_wait_all_with_heavy_tail():
    m = sg.StragglerModel(p_tail=0.3, tail_lo=3.0, tail_hi=6.0)
    wins = 0
    for s in range(20):
        t = m.sample_times(jax.random.PRNGKey(s), 100)
        spec = float(sg.speculative_time(t, jax.random.PRNGKey(1000 + s), m))
        if spec <= float(sg.wait_all_time(t)) + 1e-6:
            wins += 1
    assert wins >= 15


def test_clock_accumulates():
    clock = sg.SimClock(sg.StragglerModel())
    e1, m1 = clock.phase(jax.random.PRNGKey(0), 16, policy="wait_all")
    e2, m2 = clock.phase(jax.random.PRNGKey(1), 16, policy="k_of_n", k=12)
    assert clock.time == float(e1) + float(e2)
    assert m1.all()
    assert int(m2.sum()) >= 12
