"""Straggler model + simulation clock invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import straggler as sg


def test_sample_shapes_and_positivity():
    m = sg.StragglerModel()
    t = m.sample_times(jax.random.PRNGKey(0), 100)
    assert t.shape == (100,)
    assert (np.asarray(t) > 0).all()


def test_tail_fraction_close_to_p():
    """~2% of workers straggle (Fig. 1)."""
    m = sg.StragglerModel(p_tail=0.02, body_sigma=0.01)
    t = np.asarray(m.sample_times(jax.random.PRNGKey(1), 20000))
    med = np.median(t)
    frac = (t > 1.25 * med).mean()
    assert 0.005 < frac < 0.05


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 999), k_frac=st.floats(0.1, 1.0))
def test_policy_ordering(seed, k_frac):
    """k-of-n <= wait-all, and k-of-n monotone in k."""
    m = sg.StragglerModel(p_tail=0.1)
    t = m.sample_times(jax.random.PRNGKey(seed), 64)
    k = max(1, int(64 * k_frac))
    assert float(sg.k_of_n_time(t, k)) <= float(sg.wait_all_time(t)) + 1e-6
    if k > 1:
        assert float(sg.k_of_n_time(t, k - 1)) <= float(sg.k_of_n_time(t, k)) + 1e-6


def test_k_of_n_mask_has_at_least_k():
    m = sg.StragglerModel(p_tail=0.2)
    t = m.sample_times(jax.random.PRNGKey(3), 50)
    mask = sg.k_of_n_mask(t, 30)
    assert int(mask.sum()) >= 30


def test_speculative_beats_wait_all_with_heavy_tail():
    m = sg.StragglerModel(p_tail=0.3, tail_lo=3.0, tail_hi=6.0)
    wins = 0
    for s in range(20):
        t = m.sample_times(jax.random.PRNGKey(s), 100)
        spec = float(sg.speculative_time(t, jax.random.PRNGKey(1000 + s), m))
        if spec <= float(sg.wait_all_time(t)) + 1e-6:
            wins += 1
    assert wins >= 15


def test_speculative_relaunch_does_the_phase_work():
    """Regression: relaunched stragglers must redo the phase's ACTUAL work.
    The old default re-sampled with work_per_worker=1.0, so heavy phases
    got unrealistically fast relaunches and speculative baselines looked
    optimistic (fig10)."""
    work = 50.0
    m = sg.StragglerModel(p_tail=0.2, tail_lo=5.0, tail_hi=5.0,
                          invoke_overhead=0.0)
    key = jax.random.PRNGKey(21)
    times = m.sample_times(key, 100, work_per_worker=work)
    deadline = float(jnp.sort(times)[89])   # watch_fraction=0.9 deadline
    spec = float(sg.speculative_time(times, jax.random.PRNGKey(1021), m,
                                     work_per_worker=work))
    # A relaunch doing the real work needs ~`work` more seconds; the buggy
    # unit-work relaunch finished ~1s after the deadline.
    assert spec > deadline + 0.5 * work
    # and relaunching never does worse than waiting (a relaunch can
    # straggle too, in which case the original's finish is kept)
    assert spec <= float(sg.wait_all_time(times)) + 1e-6


def test_clock_phase_speculative_threads_work():
    """The engine's speculative policy relaunches with the phase work too:
    a heavy phase's elapsed must reflect work-scaled relaunches."""
    m = sg.StragglerModel(p_tail=0.2, tail_lo=5.0, tail_hi=5.0,
                          invoke_overhead=0.0)
    work = 50.0
    clock = sg.SimClock(m)
    elapsed, _ = clock.phase(jax.random.PRNGKey(22), 100,
                             work_per_worker=work, policy="speculative")
    body_time = work * 1.3     # generous bound on a non-straggler's time
    assert float(elapsed) > body_time + 0.5 * work


def test_clock_accumulates():
    clock = sg.SimClock(sg.StragglerModel())
    e1, m1 = clock.phase(jax.random.PRNGKey(0), 16, policy="wait_all")
    e2, m2 = clock.phase(jax.random.PRNGKey(1), 16, policy="k_of_n", k=12)
    assert clock.time == float(e1) + float(e2)
    assert m1.all()
    assert int(m2.sum()) >= 12
