"""Optional-hypothesis shim: property tests skip cleanly when the package
is missing, plain tests in the same module still collect and run.

Usage in a test module:

    from _hypothesis_compat import given, settings, st

With hypothesis installed these are the real objects.  Without it, ``given``
wraps the test in a ``pytest.importorskip("hypothesis")`` call so the test
reports as skipped (not a collection error), ``settings`` is a no-op
decorator, and ``st`` builds inert strategy placeholders.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg replacement: the wrapped test's parameters are
            # hypothesis-filled, so they must not leak into the signature
            # pytest sees (it would look for fixtures of those names).
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Inert stand-ins for strategies referenced in decorators."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
