"""Objective correctness: gradients vs jax.grad, Hessian square roots vs
jax.hessian, matvec-hook equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import objectives as ob

jax.config.update("jax_enable_x64", False)


def _logistic_data(key, n=200, d=12):
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d), minval=-1, maxval=1)
    w = jax.random.normal(kw, (d,))
    y = jnp.where(jax.random.uniform(ky, (n,)) < jax.nn.sigmoid(x @ w),
                  1.0, -1.0)
    return ob.Dataset(x=x, y=y), w


def _softmax_data(key, n=150, d=8, k=4):
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n, d))
    w = jax.random.normal(kw, (k, d))
    y = jax.nn.one_hot(jax.random.categorical(ky, x @ w.T), k)
    return ob.Dataset(x=x, y=y), w.reshape(-1)


@pytest.mark.parametrize("factory,obj", [
    (_logistic_data, ob.LogisticRegression(lam=1e-3)),
    (_softmax_data, ob.SoftmaxRegression(num_classes=4)),
])
def test_gradient_matches_autodiff(factory, obj):
    data, w0 = factory(jax.random.PRNGKey(0))
    w = 0.3 * w0
    g = obj.gradient(w, data)
    g_auto = jax.grad(lambda ww: obj.value(ww, data))(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_auto),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("factory,obj", [
    (_logistic_data, ob.LogisticRegression(lam=1e-3)),
    (_softmax_data, ob.SoftmaxRegression(num_classes=4)),
])
def test_gradient_via_hook_matches_direct(factory, obj):
    data, w0 = factory(jax.random.PRNGKey(1))
    w = 0.1 * w0
    g_direct = obj.gradient(w, data)
    g_hook = obj.gradient_via(w, data)   # default plain matvec hook
    np.testing.assert_allclose(np.asarray(g_direct), np.asarray(g_hook),
                               rtol=1e-5, atol=1e-6)


def test_logistic_hess_sqrt():
    data, w0 = _logistic_data(jax.random.PRNGKey(2))
    obj = ob.LogisticRegression(lam=1e-3)
    w = 0.2 * w0
    a = obj.hess_sqrt(w, data)
    h = a.T @ a + obj.hess_reg * jnp.eye(a.shape[1])
    h_auto = jax.hessian(lambda ww: obj.value(ww, data))(w)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_auto),
                               rtol=1e-3, atol=1e-5)


def test_softmax_hess_sqrt():
    """A^T A must equal the dK x dK softmax Hessian (paper Eq. 12 layout)."""
    data, w0 = _softmax_data(jax.random.PRNGKey(3), n=60, d=5, k=3)
    obj = ob.SoftmaxRegression(num_classes=3)
    w = 0.2 * w0
    a = obj.hess_sqrt(w, data)
    h = a.T @ a
    h_auto = jax.hessian(lambda ww: obj.value(ww, data))(w)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_auto),
                               rtol=1e-3, atol=1e-5)


def test_ridge_hessian_exact():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (100, 7))
    y = jax.random.normal(jax.random.fold_in(key, 1), (100,))
    data = ob.Dataset(x=x, y=y)
    obj = ob.RidgeRegression(lam=0.1)
    w = jnp.zeros(7)
    a = obj.hess_sqrt(w, data)
    h = a.T @ a + obj.hess_reg * jnp.eye(7)
    h_auto = jax.hessian(lambda ww: obj.value(ww, data))(w)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_auto),
                               rtol=1e-4, atol=1e-5)
    g = obj.gradient(w, data)
    g_auto = jax.grad(lambda ww: obj.value(ww, data))(w)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_auto),
                               rtol=1e-4, atol=1e-6)


def test_lp_ipm_gradient_and_hessian():
    key = jax.random.PRNGKey(5)
    n, m = 80, 6
    a_mat = jax.random.normal(key, (n, m))
    x0 = jnp.zeros(m)
    b = a_mat @ x0 + 1.0 + jax.random.uniform(jax.random.fold_in(key, 1),
                                              (n,))
    c = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    data = ob.Dataset(x=a_mat, y=b)
    obj = ob.LinearProgramIPM(c=c, tau=5.0)
    g = obj.gradient(x0, data)
    g_auto = jax.grad(lambda ww: obj.value(ww, data))(x0)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_auto),
                               rtol=1e-3, atol=1e-4)
    asq = obj.hess_sqrt(x0, data)
    h_auto = jax.hessian(lambda ww: obj.value(ww, data))(x0)
    np.testing.assert_allclose(np.asarray(asq.T @ asq), np.asarray(h_auto),
                               rtol=1e-3, atol=1e-3)


def test_lasso_dual_gradient_and_hessian():
    key = jax.random.PRNGKey(6)
    n, d = 30, 50
    x = jax.random.normal(key, (n, d)) * 0.1
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    data = ob.Dataset(x=x, y=y)
    obj = ob.LassoDualIPM(lam=2.0, tau=3.0)
    z = jnp.zeros(n)
    g = obj.gradient(z, data)
    g_auto = jax.grad(lambda zz: obj.value(zz, data))(z)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_auto),
                               rtol=1e-3, atol=1e-4)
    asq = obj.hess_sqrt(z, data)
    h = asq.T @ asq + obj.hess_reg * jnp.eye(n)
    h_auto = jax.hessian(lambda zz: obj.value(zz, data))(z)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_auto),
                               rtol=1e-3, atol=1e-3)
