"""Pluggable sketching subsystem: registry round-trips, per-family
unbiasedness of the sketched Gram, survivor-subset rescaling, the SRHT
Pallas kernel vs its butterfly oracle, Marchenko-Pastur debiasing, and
end-to-end Newton convergence for every family (incl. distributed-avg)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sketching
from repro.core import (Dataset, LogisticRegression, NewtonConfig,
                        OverSketchConfig, oversketched_newton)
from repro.core.sketch import sketched_gram
from repro.kernels import ops, ref

FAMILIES = ("oversketch", "srht", "sjlt", "gaussian", "nystrom", "leverage")


def _cfg(m=256, b=64, zeta=0.25):
    return OverSketchConfig(m, b, zeta)


def _logistic(key, n=1200, d=20):
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d), minval=-1, maxval=1)
    wstar = jax.random.normal(kw, (d,))
    y = jnp.where(jax.random.uniform(ky, (n,)) < jax.nn.sigmoid(x @ wstar),
                  1.0, -1.0)
    return Dataset(x=x, y=y)


# ------------------------------------------------------------------ registry
def test_registry_round_trip():
    cfg = _cfg()
    for name in FAMILIES:
        fam = sketching.get(name, cfg)
        assert fam.name == name
        assert fam.cfg is cfg
    assert set(FAMILIES) <= set(sketching.available())


def test_registry_unknown_family_raises():
    with pytest.raises(KeyError, match="unknown sketch family"):
        sketching.get("fourier", _cfg())


def test_families_are_hashable_and_cacheable():
    """jit-closure caching in newton keys on family instances."""
    cfg = _cfg()
    for name in FAMILIES:
        assert sketching.get(name, cfg) == sketching.get(name, cfg)
        assert hash(sketching.get(name, cfg)) == hash(sketching.get(name, cfg))


# ------------------------------------------------------- per-family statistics
@pytest.mark.parametrize("name", FAMILIES)
def test_gram_unbiased(name):
    """E[A^T S S^T A] = A^T A per family, within Monte-Carlo tolerance."""
    key = jax.random.PRNGKey(3)
    n, d, reps = 300, 12, 60
    a = jax.random.normal(key, (n, d)) / np.sqrt(n)
    fam = sketching.get(name, _cfg(256, 64, 0.25))
    grams = []
    for r in range(reps):
        state = fam.sample(jax.random.fold_in(key, r), n)
        grams.append(fam.gram(state, a))
    avg = jnp.stack(grams).mean(axis=0)
    true = a.T @ a
    rel = float(jnp.linalg.norm(avg - true) / jnp.linalg.norm(true))
    assert rel < 0.08, f"{name}: mean sketched Gram off by {rel:.3f}"


@pytest.mark.parametrize("name", FAMILIES)
def test_survivor_subset_rescaling(name):
    """Masked gram == mean of the surviving per-block grams, exactly."""
    key = jax.random.PRNGKey(4)
    n, d = 200, 10
    a = jax.random.normal(key, (n, d))
    fam = sketching.get(name, _cfg(256, 64, 0.5))
    state = fam.sample(jax.random.fold_in(key, 1), n)
    a_t = fam.apply(state, a)                    # (K, b, d)
    surv = jnp.arange(fam.cfg.total_blocks) % 3 != 0
    got = fam.gram(state, a, surv)
    keep = np.asarray(a_t)[np.asarray(surv)]
    want = np.einsum("kbd,kbe->de", keep, keep) / keep.shape[0]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ("oversketch", "srht", "sjlt"))
def test_kernel_path_matches_reference(name):
    key = jax.random.PRNGKey(5)
    a = jax.random.normal(key, (200, 20))
    fam = sketching.get(name, _cfg(256, 64, 0.25))
    state = fam.sample(jax.random.fold_in(key, 2), 200)
    plain = fam.apply(state, a, use_kernels=False)
    kern = fam.apply(state, a, use_kernels=True)
    np.testing.assert_allclose(np.asarray(plain), np.asarray(kern),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", ("oversketch", "srht", "sjlt"))
def test_gram_fused_matches_gram(name):
    """Families with a fused streaming kernel: gram(use_kernels=True)
    (which prefers gram_fused) == the plain apply+gram path, under a
    partial survivor mask."""
    key = jax.random.PRNGKey(6)
    n = 300
    a = jax.random.normal(key, (n, 20)) / np.sqrt(n)
    fam = sketching.get(name, _cfg(256, 64, 0.25))
    state = fam.sample(jax.random.fold_in(key, 2), n)
    surv = jnp.arange(fam.cfg.total_blocks) % 2 == 0
    fused = fam.gram_fused(state, a, surv)
    assert fused is not None
    plain = fam.gram(state, a, surv, use_kernels=False)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(plain),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(fam.gram(state, a, surv, use_kernels=True)),
        np.asarray(fused), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", ("oversketch", "srht", "sjlt"))
def test_gram_fused_tiles_past_single_tile_budget(name):
    """Beyond the single-tile VMEM budget (the resident (d,d) output) the
    fused kernel d-tiles its output grid instead of declining: gram_fused
    never returns None and still matches the reference path.  (The old
    behavior — None past MAX_FUSED_VMEM_BYTES, silent unfused fallback —
    is exactly what the tiled grid deleted.)"""
    from repro.kernels.sketch_gram import fits_fused_vmem, pick_d_tile
    key = jax.random.PRNGKey(9)
    n, d = 64, 2048
    fam = sketching.get(name, _cfg(128, 64, 0.25))
    assert not fits_fused_vmem(fam.cfg.block_size, d)
    assert fits_fused_vmem(fam.cfg.block_size, 512)
    assert fam.fused_path(d) == "fused_tiled"
    assert pick_d_tile(fam.cfg.block_size, d) < d
    a = jax.random.normal(key, (n, d)) / np.sqrt(n)
    state = fam.sample(jax.random.fold_in(key, 1), n)
    surv = jnp.ones((fam.cfg.total_blocks,), bool)
    fused = fam.gram_fused(state, a, surv)
    assert fused is not None
    np.testing.assert_allclose(
        np.asarray(fused),
        np.asarray(fam.gram(state, a, surv, use_kernels=False)),
        rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ("gaussian", "nystrom", "leverage"))
def test_gram_kernel_fallback_without_fused(name):
    """Families without a fused kernel return None from gram_fused and the
    kernel path falls back to apply + masked-Gram kernel."""
    key = jax.random.PRNGKey(7)
    n = 200
    a = jax.random.normal(key, (n, 12))
    fam = sketching.get(name, _cfg(256, 64, 0.25))
    state = fam.sample(jax.random.fold_in(key, 3), n)
    surv = jnp.ones((fam.cfg.total_blocks,), bool).at[0].set(False)
    assert fam.gram_fused(state, a, surv) is None
    np.testing.assert_allclose(
        np.asarray(fam.gram(state, a, surv, use_kernels=True)),
        np.asarray(fam.gram(state, a, surv, use_kernels=False)),
        rtol=1e-4, atol=1e-4)


def test_core_oversketched_gram_fused_routing():
    """core.sketch.oversketched_gram(use_kernels=True) takes the fused
    kernel end-to-end and agrees with the reference composition."""
    from repro.core import sketch as core_sketch
    key = jax.random.PRNGKey(8)
    n = 400
    a = jax.random.normal(key, (n, 16)) / np.sqrt(n)
    cfg = _cfg(256, 64, 0.25)
    kf = jax.random.fold_in(key, 1)
    surv = jnp.ones((cfg.total_blocks,), bool).at[1].set(False)
    fused = core_sketch.oversketched_gram(kf, a, cfg, surv, use_kernels=True)
    plain = core_sketch.oversketched_gram(kf, a, cfg, surv)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(plain),
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- FWHT kernel
@pytest.mark.parametrize("k,n,d", [(2, 8, 5), (3, 256, 17), (1, 512, 130)])
def test_fwht_kernel_vs_butterfly_oracle(k, n, d):
    x = jax.random.normal(jax.random.PRNGKey(n), (k, n, d))
    np.testing.assert_allclose(np.asarray(ops.fwht(x)),
                               np.asarray(ref.fwht(x)),
                               rtol=1e-5, atol=1e-5)


def test_fwht_is_orthonormal_involution():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 128, 9))
    y = ref.fwht(x)
    # orthonormal: norms preserved; Sylvester H is symmetric: H^2 = I
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ref.fwht(y)), np.asarray(x),
                               rtol=1e-5, atol=1e-5)


def test_fwht_rejects_non_pow2():
    with pytest.raises(ValueError):
        ref.fwht(jnp.zeros((1, 100, 4)))


# ----------------------------------------------------------------- leverage
def test_leverage_beats_uniform_sampling_on_spiky_rows():
    """On a matrix whose mass sits in a few high-leverage rows, uniform
    Nystrom sampling mostly misses them; leverage-score sampling keeps
    them (Drineas-Mahoney-Muthukrishnan) at the same per-worker cost."""
    key = jax.random.PRNGKey(11)
    a = jax.random.normal(key, (400, 10)) * 0.05
    a = a.at[:8].mul(40.0)                  # 8 dominant rows
    cfg = _cfg(256, 64, 0.25)
    true = a.T @ a

    def mean_err(name):
        fam = sketching.get(name, cfg)
        errs = []
        for r in range(20):
            state = fam.sample(jax.random.fold_in(key, r), 400)
            g = fam.gram(state, a)
            errs.append(float(jnp.linalg.norm(g - true)
                              / jnp.linalg.norm(true)))
        return np.mean(errs)

    assert mean_err("leverage") < 0.5 * mean_err("nystrom")


# ------------------------------------------------------------------- debias
def test_mp_factor_values():
    assert float(sketching.mp_factor(20, 80)) == pytest.approx(0.75)
    # clamped far outside the m > d regime
    assert float(sketching.mp_factor(64, 4)) == pytest.approx(
        sketching.debias.MIN_FACTOR)


def test_debias_reduces_direction_bias():
    """E[gamma * H_hat^{-1} g] is much closer to H^{-1} g than the plain
    sketched direction (inverse-Wishart inflation m/(m-d-1) vs MP's 1-d/m)."""
    key = jax.random.PRNGKey(6)
    n, d, m, reps = 400, 20, 64, 200
    a = jax.random.normal(key, (n, d)) / np.sqrt(n)
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    h_true = a.T @ a
    p_exact = jnp.linalg.solve(h_true, g)
    fam = sketching.get("gaussian", OverSketchConfig(m, m, 0.0))

    def one(k):
        a_t = fam.apply(fam.sample(k, n), a)
        return jnp.linalg.solve(sketched_gram(a_t), g)

    p_all = jax.vmap(one)(jax.random.split(jax.random.fold_in(key, 2), reps))
    p_plain = p_all.mean(axis=0)
    p_deb = sketching.debias_direction(p_plain, d, m)
    err_plain = float(jnp.linalg.norm(p_plain - p_exact))
    err_deb = float(jnp.linalg.norm(p_deb - p_exact))
    assert err_deb < 0.35 * err_plain, (err_plain, err_deb)


# --------------------------------------------------------------- end to end
@pytest.mark.parametrize("name", FAMILIES)
def test_newton_converges_for_every_family(name):
    """Acceptance: all five families hit the same tolerance on logistic."""
    data = _logistic(jax.random.PRNGKey(7))
    obj = LogisticRegression(lam=1e-4)
    cfg = NewtonConfig(iters=10, sketch=_cfg(512, 64, 0.25),
                       coded_block_rows=128, sketch_family=name)
    res = oversketched_newton(obj, data, jnp.zeros(data.x.shape[1]), cfg)
    assert res.history["gnorm"][-1] < 1e-3


def test_debiased_beats_plain_unit_step_newton():
    """With unit steps and a tight sketch (m = 2d), the plain sketched
    direction is ~2x too long in expectation; MP debiasing restores
    convergence (Romanov-Zhang-Pilanci 2024 motivation)."""
    data = _logistic(jax.random.PRNGKey(8), n=1000, d=24)
    obj = LogisticRegression(lam=1e-3)
    base = dict(iters=8, sketch=OverSketchConfig(48, 48, 0.0),
                coded_block_rows=128, sketch_family="gaussian",
                unit_step=True)
    f_plain = oversketched_newton(
        obj, data, jnp.zeros(24), NewtonConfig(debias=False, **base),
        model=None).history["fval"][-1]
    f_deb = oversketched_newton(
        obj, data, jnp.zeros(24), NewtonConfig(debias=True, **base),
        model=None).history["fval"][-1]
    assert f_deb < f_plain


def test_distributed_avg_mode_converges():
    """Bartan-Pilanci direction averaging under the straggler clock."""
    data = _logistic(jax.random.PRNGKey(9))
    obj = LogisticRegression(lam=1e-4)
    cfg = NewtonConfig(iters=10, sketch=OverSketchConfig(512, 128, 0.25),
                       coded_block_rows=128, sketch_family="gaussian",
                       sketch_mode="distributed-avg", debias=True)
    res = oversketched_newton(obj, data, jnp.zeros(data.x.shape[1]), cfg)
    assert res.history["gnorm"][-1] < 1e-3
    assert res.history["time"] == sorted(res.history["time"])


def test_distavg_cg_agrees_with_dense_solve():
    """distavg_solver='cg' (matvec-only per-block solves, for d beyond
    master-factorization scale) must track the dense Cholesky path."""
    data = _logistic(jax.random.PRNGKey(12))
    obj = LogisticRegression(lam=1e-4)
    base = dict(iters=6, sketch=OverSketchConfig(512, 128, 0.25),
                coded_block_rows=128, sketch_family="gaussian",
                sketch_mode="distributed-avg", debias=True)
    r_chol = oversketched_newton(obj, data, jnp.zeros(data.x.shape[1]),
                                 NewtonConfig(distavg_solver="chol", **base))
    r_cg = oversketched_newton(obj, data, jnp.zeros(data.x.shape[1]),
                               NewtonConfig(distavg_solver="cg", **base))
    np.testing.assert_allclose(np.asarray(r_chol.w), np.asarray(r_cg.w),
                               rtol=1e-3, atol=1e-4)
    assert r_cg.history["gnorm"][-1] < 1e-3


def test_unknown_distavg_solver_raises():
    data = _logistic(jax.random.PRNGKey(14), n=200, d=8)
    with pytest.raises(ValueError, match="distavg_solver"):
        oversketched_newton(LogisticRegression(), data, jnp.zeros(8),
                            NewtonConfig(iters=1,
                                         sketch=_cfg(128, 64, 0.25),
                                         distavg_solver="qr"))


def test_distavg_requires_block_size_above_dim():
    data = _logistic(jax.random.PRNGKey(11), n=200, d=30)
    with pytest.raises(ValueError, match="block_size"):
        oversketched_newton(
            LogisticRegression(), data, jnp.zeros(30),
            NewtonConfig(iters=1, sketch=OverSketchConfig(64, 16, 0.25),
                         sketch_mode="distributed-avg"))
    with pytest.raises(ValueError, match="hessian_policy"):
        oversketched_newton(
            LogisticRegression(), data, jnp.zeros(30),
            NewtonConfig(iters=1, sketch=OverSketchConfig(128, 64, 0.25),
                         sketch_mode="distributed-avg",
                         hessian_policy="exact"))


def test_unknown_sketch_mode_raises():
    data = _logistic(jax.random.PRNGKey(10), n=200, d=8)
    with pytest.raises(ValueError, match="sketch_mode"):
        oversketched_newton(LogisticRegression(), data, jnp.zeros(8),
                            NewtonConfig(iters=1, sketch=_cfg(128, 64, 0.25),
                                         sketch_mode="bogus"))
