"""Windowed decode cache (local:global split, §Perf hillclimb C): must be
bit-consistent with full forward within bf16 noise, and strictly smaller."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import transformer
from repro.models.registry import ModelBundle


def _cfg():
    return smoke_config("gemma3-27b").scaled(windowed_decode_cache=True)


def test_cache_is_smaller():
    cfg = _cfg()
    # windowed_decode_cache is ON by default for gemma3 (§Perf C); compare
    # against the explicit full-cache baseline
    base = smoke_config("gemma3-27b").scaled(windowed_decode_cache=False)
    win_cache = transformer.init_cache(cfg, 2, 64)
    full_cache = transformer.init_cache(base, 2, 64)
    win_bytes = sum(a.size * a.dtype.itemsize
                    for a in jax.tree.leaves(win_cache))
    full_bytes = sum(a.size * a.dtype.itemsize
                     for a in jax.tree.leaves(full_cache))
    assert win_bytes < 0.6 * full_bytes


@pytest.mark.parametrize("seq", [24, 40])
def test_windowed_decode_matches_forward(seq):
    """window=16 smoke config: prefill+decode via split caches must match
    the full forward (the window semantics match because chunked_attention
    applies the same per-layer window masks in the full pass)."""
    cfg = _cfg()
    bundle = ModelBundle(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    rs = np.random.RandomState(0)
    toks = jnp.asarray(rs.randint(1, cfg.vocab_size - 1, (2, seq)))

    logits_full, _ = transformer.forward(cfg, params, toks, None,
                                         remat=False)
    cache = bundle.init_cache(2, 64)
    assert "kg" in cache
    _, cache = bundle.prefill(params, toks[:, :seq - 1], cache)
    lg_dec, cache2 = bundle.decode(params, cache, toks[:, seq - 1])
    assert int(cache2["pos"]) == seq
    err = float(jnp.abs(lg_dec.astype(jnp.float32) -
                        logits_full[:, -1].astype(jnp.float32)).max())
    assert err < 0.25, f"windowed decode drift {err}"


def test_multi_step_windowed_decode():
    cfg = _cfg()
    bundle = ModelBundle(cfg)
    params = bundle.init(jax.random.PRNGKey(2))
    rs = np.random.RandomState(1)
    toks = jnp.asarray(rs.randint(1, cfg.vocab_size - 1, (2, 30)))
    cache = bundle.init_cache(2, 64)
    _, cache = bundle.prefill(params, toks[:, :20], cache)
    # decode tokens 20..29 step by step; compare against full forward
    logits_full, _ = transformer.forward(cfg, params, toks, None,
                                         remat=False)
    for t in range(20, 30):
        lg, cache = bundle.decode(params, cache, toks[:, t])
    err = float(jnp.abs(lg.astype(jnp.float32) -
                        logits_full[:, -1].astype(jnp.float32)).max())
    assert err < 0.3, f"multi-step windowed drift {err}"
