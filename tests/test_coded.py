"""2-D product code: encode/decode round trips, peeling under erasures,
hypothesis property sweep over random decodable patterns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import coded as cd


def _setup(key, rows=500, cols=33, block=64):
    m = jax.random.normal(key, (rows, cols))
    v = jax.random.normal(jax.random.fold_in(key, 1), (cols,))
    code = cd.make_code(rows, block)
    enc = cd.encode_2d(m, code)
    return m, v, code, enc


def test_encode_shapes():
    key = jax.random.PRNGKey(0)
    m, v, code, enc = _setup(key)
    g = code.grid
    assert enc.shape == (g + 1, g + 1, code.block_rows, m.shape[1])
    # parity relations
    np.testing.assert_allclose(np.asarray(enc[:-1, -1]),
                               np.asarray(enc[:-1, :-1].sum(axis=1)),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(enc[-1]),
                               np.asarray(enc[:-1].sum(axis=0)),
                               rtol=1e-5, atol=1e-4)


def test_no_erasure_roundtrip():
    key = jax.random.PRNGKey(1)
    m, v, code, enc = _setup(key)
    y, ok = cd.coded_matvec(enc, v, code, m.shape[0])
    assert bool(ok)
    np.testing.assert_allclose(np.asarray(y), np.asarray(m @ v),
                               rtol=1e-4, atol=1e-4)


def test_single_erasure_per_line_decodes():
    key = jax.random.PRNGKey(2)
    m, v, code, enc = _setup(key)
    g = code.grid
    erased = jnp.zeros((g + 1, g + 1), bool)
    for i in range(g + 1):           # one erasure per row, distinct columns
        erased = erased.at[i, (i * 2) % (g + 1)].set(True)
    y, ok = cd.coded_matvec(enc, v, code, m.shape[0], erased)
    assert bool(ok)
    np.testing.assert_allclose(np.asarray(y), np.asarray(m @ v),
                               rtol=1e-4, atol=1e-4)


def test_multi_round_peeling():
    """A pattern needing >1 peel round (two erasures in a row, resolvable via
    columns first)."""
    key = jax.random.PRNGKey(3)
    m, v, code, enc = _setup(key)
    erased = jnp.zeros((code.grid + 1, code.grid + 1), bool)
    erased = erased.at[0, 0].set(True).at[0, 1].set(True)
    y, ok = cd.coded_matvec(enc, v, code, m.shape[0], erased)
    assert bool(ok)
    np.testing.assert_allclose(np.asarray(y), np.asarray(m @ v),
                               rtol=1e-4, atol=1e-4)


def test_undecodable_pattern_flags_failure():
    """A 2x2 erased square is a stopping set: decode must report failure."""
    key = jax.random.PRNGKey(4)
    m, v, code, enc = _setup(key)
    erased = jnp.zeros((code.grid + 1, code.grid + 1), bool)
    erased = erased.at[0, 0].set(True).at[0, 1].set(True)
    erased = erased.at[1, 0].set(True).at[1, 1].set(True)
    _, ok = cd.coded_matvec(enc, v, code, m.shape[0], erased)
    assert not bool(ok)


def test_ragged_rows_padding():
    """Row count not divisible by block size."""
    key = jax.random.PRNGKey(5)
    m, v, code, enc = _setup(key, rows=409, block=64)
    y, ok = cd.coded_matvec(enc, v, code, 409)
    assert bool(ok)
    np.testing.assert_allclose(np.asarray(y), np.asarray(m @ v),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_erase=st.integers(0, 5))
def test_random_erasures_property(seed, n_erase):
    """Random erasure sets: if peeling reports success the answer is exact;
    erasing entire rows' worth (> 2g+1) is not generated here."""
    key = jax.random.PRNGKey(seed)
    m, v, code, enc = _setup(key, rows=300, block=64)
    g1 = code.grid + 1
    idx = jax.random.choice(jax.random.fold_in(key, 2), g1 * g1,
                            (n_erase,), replace=False)
    erased = jnp.zeros((g1 * g1,), bool).at[idx].set(True).reshape(g1, g1)
    y, ok = cd.coded_matvec(enc, v, code, 300, erased)
    if bool(ok):
        np.testing.assert_allclose(np.asarray(y), np.asarray(m @ v),
                                   rtol=1e-3, atol=1e-3)
    else:
        # failure must only happen when some line has >= 2 erasures
        row_counts = np.asarray(erased).sum(axis=1)
        col_counts = np.asarray(erased).sum(axis=0)
        assert (row_counts >= 2).any() and (col_counts >= 2).any()


def test_distributed_matches_local():
    mesh = jax.make_mesh((1,), ("workers",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    key = jax.random.PRNGKey(6)
    m, v, code, enc = _setup(key, rows=256, block=64)
    g1 = code.grid + 1
    w = code.num_workers
    erased = jnp.zeros((g1, g1), bool).at[1, 1].set(True)
    y_local, ok_local = cd.coded_matvec(enc, v, code, 256, erased)
    enc_flat = enc.reshape(w, code.block_rows, -1)
    y_dist, ok_dist = cd.distributed_coded_matvec(
        enc_flat, v, erased.reshape(-1), code, 256, mesh=mesh,
        worker_axis="workers")
    assert bool(ok_local) and bool(ok_dist)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_dist),
                               rtol=1e-5, atol=1e-5)
