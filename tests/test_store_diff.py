"""Cross-run store round-trips and the noise-aware bench regression gate.

The diff half runs against two COMMITTED golden BENCH fixtures
(``tests/fixtures/bench_{base,head}_golden.json``) that seed exactly one
material regression (``kernel_gram_fused`` doubling its wall-clock) among
rows exercising every other verdict: a within-noise drift, an
abs-floor-suppressed jump on a trivial row, one added and one removed
row.  The gate must catch the seeded regression — and nothing else.
"""
import json
import pathlib

import jax
import pytest

from repro import obs
from repro.core.straggler import SimClock, StragglerModel
from repro.obs import diff as obs_diff
from repro.obs import store as obs_store
from repro.runtime import FleetConfig

FIXTURES = pathlib.Path(__file__).parent / "fixtures"
BASE = FIXTURES / "bench_base_golden.json"
HEAD = FIXTURES / "bench_head_golden.json"


def _load(path):
    with open(path) as f:
        return json.load(f)


# ------------------------------------------------------------- keys
def test_config_hash_canonical_and_order_insensitive():
    h1 = obs_store.config_hash({"module": "kernels_bench", "profile": "quick"})
    h2 = obs_store.config_hash({"profile": "quick", "module": "kernels_bench"})
    assert h1 == h2
    assert len(h1) == 12 and int(h1, 16) >= 0
    assert h1 != obs_store.config_hash({"module": "kernels_bench",
                                        "profile": "full"})


def test_git_sha_never_raises(tmp_path):
    assert obs_store.git_sha(str(tmp_path)) == "unknown"   # not a repo
    sha = obs_store.git_sha()
    assert sha and isinstance(sha, str)


def test_bench_record_backfills_legacy_meta():
    rec = obs_store.bench_record(
        {"meta": {"module": "kernels_bench", "backend": "cpu",
                  "jax_version": "0.4"},
         "rows": [{"name": "r", "us": 1.0}]})
    assert rec["git_sha"] == "unknown"
    assert rec["config_hash"] == "unknown"
    assert rec["rows"][0]["path"] == "unknown"
    assert rec["kind"] == "bench"


# ------------------------------------------------------------- store
def _bench_payload(sha, us):
    return {"meta": {"module": "kernels_bench", "backend": "cpu",
                     "jax_version": "0.4", "git_sha": sha,
                     "config_hash": "c" * 12, "profile": "quick",
                     "utc": "2026-08-07T00:00:00Z"},
            "rows": [{"name": "kernel_gram_fused", "us": us,
                      "path": "fused", "derived": "gflops=1"}]}


def test_store_append_query_roundtrip(tmp_path):
    store = obs_store.Store(tmp_path / "hist.jsonl")
    assert store.records() == []
    assert store.latest() is None
    assert store.last_two() is None
    store.append(obs_store.bench_record(_bench_payload("sha1", 100.0)))
    store.append(obs_store.bench_record(_bench_payload("sha2", 120.0)))
    recs = store.records(kind="bench", name="kernels_bench")
    assert [r["git_sha"] for r in recs] == ["sha1", "sha2"]
    assert store.latest()["git_sha"] == "sha2"
    prev, latest = store.last_two(kind="bench", name="kernels_bench")
    assert (prev["git_sha"], latest["git_sha"]) == ("sha1", "sha2")
    hist = store.history("kernel_gram_fused", name="kernels_bench")
    assert [h["us"] for h in hist] == [100.0, 120.0]
    assert store.kernel_path_table() == {
        "kernel_gram_fused": {"us": 120.0, "path": "fused"}}
    assert store.records(name="nonexistent") == []


def test_store_rejects_records_missing_key_fields(tmp_path):
    store = obs_store.Store(tmp_path / "hist.jsonl")
    with pytest.raises(ValueError, match="key fields"):
        store.append({"kind": "bench", "name": "x"})
    assert not store.path.exists()


def test_run_record_roundtrips_through_store(tmp_path):
    tel = obs.Telemetry(monitors=True)
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=FleetConfig(cold_start_prob=0.1), telemetry=tel)
    for r in range(3):
        clock.phase(jax.random.PRNGKey(r), 8, policy="k_of_n", k=6,
                    flops_per_worker=2e5, comm_units=1.0)
    rec = obs_store.run_record(
        "fleet_smoke", tel, backend="cpu", jax_version=jax.__version__,
        sha="deadbee", cfg_hash="c" * 12, extra={"note": "test"})
    assert rec["kind"] == "run" and rec["note"] == "test"
    tail = rec["straggler_tail"]
    assert tail["count"] == 24
    assert tail["p50"] <= tail["p95"] <= tail["p99"]
    assert {p["phase"] for p in rec["phases"]} == \
        {"phase0", "phase1", "phase2"}
    assert rec["health"]["alerts"] == len(rec.get("alerts", []))
    store = obs_store.Store(tmp_path / "hist.jsonl")
    store.append(rec)
    back = store.latest(kind="run", name="fleet_smoke")
    assert back["git_sha"] == "deadbee"
    assert back["straggler_tail"]["p95"] == pytest.approx(tail["p95"])


def test_store_cli_append_show_history(tmp_path, capsys):
    bench = tmp_path / "BENCH_x.json"
    bench.write_text(json.dumps(_bench_payload("sha9", 42.0)))
    store_path = str(tmp_path / "hist.jsonl")
    assert obs_store.main(["append", str(bench), "--store", store_path]) == 0
    assert obs_store.main(["show", "--store", store_path]) == 0
    assert obs_store.main(["history", "--store", store_path,
                           "--name", "kernels_bench",
                           "--row", "kernel_gram_fused"]) == 0
    out = capsys.readouterr().out
    assert "sha9" in out and "kernels_bench" in out
    assert "| 42" in out and "fused" in out     # the history row's timing


# ----------------------------------------------------------- diff unit
def test_diff_rows_sim_key_drift_overrides_quiet_wallclock():
    base = [{"name": "r", "us": 100.0, "derived": "sim_s=1.0;usd=0.010"}]
    worse = [{"name": "r", "us": 101.0, "derived": "sim_s=1.05;usd=0.010"}]
    rows = obs_diff.diff_rows(base, worse)
    assert rows[0].status == "regression"
    assert "sim_s" in rows[0].detail
    better = [{"name": "r", "us": 101.0, "derived": "sim_s=0.9;usd=0.010"}]
    assert obs_diff.diff_rows(base, better)[0].status == "improvement"


def test_diff_rows_abs_floor_and_per_row_override():
    base = [{"name": "tiny", "us": 40.0, "derived": ""},
            {"name": "noisy_row", "us": 1000.0, "derived": ""}]
    new = [{"name": "tiny", "us": 90.0, "derived": ""},
           {"name": "noisy_row", "us": 1900.0, "derived": ""}]
    rows = {r.name: r for r in obs_diff.diff_rows(base, new)}
    assert rows["tiny"].status == "ok"          # +50us == floor, not over
    assert rows["noisy_row"].status == "regression"
    rows2 = {r.name: r for r in obs_diff.diff_rows(
        base, new, per_row={"noisy_": 1.5})}
    assert rows2["noisy_row"].status == "ok"    # prefix override


# --------------------------------------------------------- diff golden
def test_diff_golden_catches_exactly_the_seeded_regression():
    report = obs_diff.diff_bench(_load(BASE), _load(HEAD))
    assert [r.name for r in report.regressions] == ["kernel_gram_fused"]
    seeded = report.regressions[0]
    assert seeded.ratio == pytest.approx(2.0)
    by_name = {r.name: r.status for r in report.rows}
    assert by_name == {"kernel_gram_fused": "regression",
                       "kernel_gram_unfused": "ok",       # +4% within noise
                       "sched_newton": "ok",              # sim keys steady
                       "kernel_tiny": "ok",               # abs floor
                       "kernel_retired_row": "removed",
                       "kernel_new_row": "added"}
    assert "aaaaaaa" in report.summary() and "bbbbbbb" in report.summary()
    assert "kernel_gram_fused" in report.table(only_changed=True)
    assert report.to_json()["regressions"] == ["kernel_gram_fused"]


def test_diff_cli_gate_exit_codes(tmp_path, capsys):
    # Report-only (first-landing CI mode): regressions print but exit 0.
    assert obs_diff.main([str(BASE), str(HEAD)]) == 0
    # Gate mode: the seeded regression flips the exit code to 2.
    verdict = tmp_path / "verdict.json"
    assert obs_diff.main([str(BASE), str(HEAD), "--gate",
                          "--json", str(verdict)]) == 2
    assert json.loads(verdict.read_text())["regressions"] == \
        ["kernel_gram_fused"]
    out = capsys.readouterr()
    assert "kernel_gram_fused" in out.out
    assert "GATE FAILED" in out.err


def test_diff_cli_store_mode(tmp_path, capsys):
    store_path = tmp_path / "hist.jsonl"
    store = obs_store.Store(store_path)
    # One record: nothing to diff, gate passes vacuously.
    store.append(obs_store.bench_record(_load(BASE)))
    assert obs_diff.main(["--store", str(store_path),
                          "--name", "kernels_bench", "--gate"]) == 0
    assert "vacuously" in capsys.readouterr().out
    # Two records: the seeded regression gates.
    store.append(obs_store.bench_record(_load(HEAD)))
    assert obs_diff.main(["--store", str(store_path),
                          "--name", "kernels_bench"]) == 0
    assert obs_diff.main(["--store", str(store_path),
                          "--name", "kernels_bench", "--gate"]) == 2


def test_make_report_diff_mode(tmp_path, capsys):
    from benchmarks import make_report
    assert make_report.main(["--diff", str(BASE), str(HEAD)]) == 0
    out = capsys.readouterr().out
    assert "Bench diff" in out and "kernel_gram_fused" in out
