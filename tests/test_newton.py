"""OverSketched Newton end-to-end behaviour: convergence on strongly and
weakly convex problems, straggler policies, theory-flavoured checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Dataset, LogisticRegression, NewtonConfig,
                        OverSketchConfig, RidgeRegression, SoftmaxRegression,
                        StragglerModel, oversketched_newton)


def _logistic(key, n=1500, d=30):
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d), minval=-1, maxval=1)
    wstar = jax.random.normal(kw, (d,))
    y = jnp.where(jax.random.uniform(ky, (n,)) < jax.nn.sigmoid(x @ wstar),
                  1.0, -1.0)
    return Dataset(x=x, y=y), wstar


def test_strongly_convex_converges_to_tolerance():
    data, _ = _logistic(jax.random.PRNGKey(0))
    obj = LogisticRegression(lam=1e-4)
    cfg = NewtonConfig(iters=10, sketch=OverSketchConfig(512, 64, 0.25),
                       coded_block_rows=128)
    res = oversketched_newton(obj, data, jnp.zeros(data.x.shape[1]), cfg)
    assert res.history["gnorm"][-1] < 1e-3
    # monotone decrease of f
    f = res.history["fval"]
    assert all(f[i + 1] <= f[i] + 1e-6 for i in range(len(f) - 1))


def test_matches_exact_newton_iterate_count():
    """Sketched Newton should need a similar number of iterations to exact
    Newton (paper Fig. 6 observation) on a well-conditioned problem."""
    data, _ = _logistic(jax.random.PRNGKey(1), n=1200, d=20)
    obj = LogisticRegression(lam=1e-3)
    common = dict(iters=8, coded_block_rows=128)
    sk_cfg = NewtonConfig(sketch=OverSketchConfig(1024, 128, 0.25), **common)
    ex_cfg = NewtonConfig(hessian_policy="exact",
                          sketch=OverSketchConfig(1024, 128, 0.25), **common)
    r_sk = oversketched_newton(obj, data, jnp.zeros(20), sk_cfg, model=None)
    r_ex = oversketched_newton(obj, data, jnp.zeros(20), ex_cfg, model=None)
    it_sk = next(i for i, g in enumerate(r_sk.history["gnorm"]) if g < 1e-4)
    it_ex = next(i for i, g in enumerate(r_ex.history["gnorm"]) if g < 1e-4)
    assert it_sk <= it_ex + 3


def test_weakly_convex_gradnorm_linear_decrease():
    """Thm 3.3: ||grad f||^2 decreases linearly for softmax (weakly convex)."""
    key = jax.random.PRNGKey(2)
    n, d, k = 900, 12, 4
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n, d))
    w = jax.random.normal(kw, (k, d))
    y = jax.nn.one_hot(jax.random.categorical(ky, x @ w.T), k)
    obj = SoftmaxRegression(num_classes=k)
    cfg = NewtonConfig(iters=7, sketch=OverSketchConfig(1024, 128, 0.25),
                       coded_block_rows=128, solver="pinv")
    res = oversketched_newton(obj, Dataset(x=x, y=y), jnp.zeros(k * d), cfg)
    g = res.history["gnorm"]
    assert g[-1] < 0.3 * g[0]
    assert all(g[i + 1] <= g[i] * 1.01 for i in range(len(g) - 1))


def test_straggler_sim_makes_coded_faster_than_wait_all():
    """Coded gradients must beat wait-all in simulated time (Fig. 6)."""
    data, _ = _logistic(jax.random.PRNGKey(3), n=2000, d=25)
    obj = LogisticRegression(lam=1e-4)
    # aggressive-but-decodable tail (the 2-D product code targets the
    # paper's ~2-5% straggler regime)
    model = StragglerModel(p_tail=0.08, tail_hi=3.0)
    base = dict(iters=5, sketch=OverSketchConfig(512, 64, 0.25),
                coded_block_rows=64)
    t_coded = oversketched_newton(
        obj, data, jnp.zeros(25),
        NewtonConfig(gradient_policy="coded", **base),
        model=model).history["time"][-1]
    t_wait = oversketched_newton(
        obj, data, jnp.zeros(25),
        NewtonConfig(gradient_policy="wait_all", **base),
        model=model).history["time"][-1]
    assert t_coded < t_wait


def test_unit_step_works():
    """Paper footnote 9: unit step-size suffices in practice."""
    data, _ = _logistic(jax.random.PRNGKey(4))
    obj = LogisticRegression(lam=1e-4)
    cfg = NewtonConfig(iters=8, unit_step=True,
                       sketch=OverSketchConfig(512, 64, 0.25),
                       coded_block_rows=128)
    res = oversketched_newton(obj, data, jnp.zeros(data.x.shape[1]), cfg,
                              model=None)
    assert res.history["gnorm"][-1] < 1e-3


def test_cg_solver_path():
    data, _ = _logistic(jax.random.PRNGKey(5), n=800, d=15)
    obj = LogisticRegression(lam=1e-3)
    cfg = NewtonConfig(iters=6, solver="cg", cg_iters=40,
                       sketch=OverSketchConfig(512, 64, 0.25),
                       coded_block_rows=128)
    res = oversketched_newton(obj, data, jnp.zeros(15), cfg, model=None)
    assert res.history["gnorm"][-1] < 1e-3


def test_ridge_closed_form_agreement():
    """Sketched Newton on ridge must land near the closed-form optimum."""
    key = jax.random.PRNGKey(6)
    n, d = 1000, 20
    x = jax.random.normal(key, (n, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    lam = 0.1
    data = Dataset(x=x, y=y)
    obj = RidgeRegression(lam=lam)
    cfg = NewtonConfig(iters=12, sketch=OverSketchConfig(2048, 256, 0.25),
                       coded_block_rows=128)
    res = oversketched_newton(obj, data, jnp.zeros(d), cfg, model=None)
    w_closed = jnp.linalg.solve(x.T @ x / n + lam * jnp.eye(d), x.T @ y / n)
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_closed),
                               rtol=2e-2, atol=2e-3)


def test_history_schema():
    data, _ = _logistic(jax.random.PRNGKey(7), n=400, d=10)
    obj = LogisticRegression()
    cfg = NewtonConfig(iters=3, sketch=OverSketchConfig(256, 64, 0.25),
                       coded_block_rows=64)
    res = oversketched_newton(obj, data, jnp.zeros(10), cfg)
    for k in ("iter", "fval", "gnorm", "step", "time"):
        assert len(res.history[k]) == 3
    assert res.history["time"] == sorted(res.history["time"])
