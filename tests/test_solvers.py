"""Solvers: CG vs direct, MINRES pseudo-inverse behaviour on singular PSD."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solvers


def _psd(key, d, rank=None):
    a = jax.random.normal(key, (d, d))
    h = a @ a.T / d
    if rank is not None:
        evals, evecs = jnp.linalg.eigh(h)
        evals = evals.at[:d - rank].set(0.0)
        h = (evecs * evals) @ evecs.T
    return h


def test_psd_solve():
    key = jax.random.PRNGKey(0)
    h = _psd(key, 12) + jnp.eye(12)
    g = jax.random.normal(jax.random.fold_in(key, 1), (12,))
    p = solvers.psd_solve(h, g)
    np.testing.assert_allclose(np.asarray(h @ p), np.asarray(g),
                               rtol=1e-4, atol=1e-5)


def test_cg_matches_direct():
    key = jax.random.PRNGKey(1)
    h = _psd(key, 20) + 0.5 * jnp.eye(20)
    g = jax.random.normal(jax.random.fold_in(key, 1), (20,))
    p_cg = solvers.conjugate_gradient(lambda v: h @ v, g, jnp.zeros(20),
                                      iters=60)
    p_direct = jnp.linalg.solve(h, g)
    np.testing.assert_allclose(np.asarray(p_cg), np.asarray(p_direct),
                               rtol=1e-3, atol=1e-4)


def test_pinv_solve_singular():
    key = jax.random.PRNGKey(2)
    d, rank = 15, 8
    h = _psd(key, d, rank=rank)
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    p = solvers.psd_pinv_solve(h, g)
    # Match the f32-appropriate cutoff; numpy's default rcond keeps noise
    # eigenvalues (~1e-7) and explodes.
    p_np = np.linalg.pinv(np.asarray(h), rcond=1e-6,
                          hermitian=True) @ np.asarray(g)
    np.testing.assert_allclose(np.asarray(p), p_np, rtol=1e-3, atol=1e-4)


def test_minres_consistent_system():
    key = jax.random.PRNGKey(3)
    h = _psd(key, 18) + 0.1 * jnp.eye(18)
    g = jax.random.normal(jax.random.fold_in(key, 1), (18,))
    p = solvers.minres(lambda v: h @ v, g, iters=40)
    np.testing.assert_allclose(np.asarray(h @ p), np.asarray(g),
                               rtol=1e-3, atol=1e-3)


def test_minres_singular_matches_pinv_on_range():
    """For b in range(H), MINRES converges to H^+ b (Newton-MR direction)."""
    key = jax.random.PRNGKey(4)
    d, rank = 16, 9
    h = _psd(key, d, rank=rank)
    raw = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    b = h @ raw                       # force b into range(H)
    p = solvers.minres(lambda v: h @ v, b, iters=40)
    p_pinv = np.linalg.pinv(np.asarray(h), rcond=1e-6,
                            hermitian=True) @ np.asarray(b)
    np.testing.assert_allclose(np.asarray(p), p_pinv, rtol=1e-2, atol=1e-3)
