"""Checkpoint manager: atomic save/restore, bf16 round-trip, GC, async."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16), jnp.bfloat16),
                   "b": jnp.zeros((16,), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state()
    mgr.save(5, state)
    restored = mgr.restore(5, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = _state(1)
    mgr.async_save(9, state)
    mgr.wait()
    restored = mgr.restore_latest(state)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"], np.float32),
        np.asarray(state["params"]["w"], np.float32))


def test_restore_empty_returns_none(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.restore_latest(_state()) is None


def test_leaf_count_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    with pytest.raises(AssertionError):
        mgr.restore(1, {"params": {"w": jnp.zeros((8, 16), jnp.bfloat16)}})


def test_partial_write_never_corrupts(tmp_path):
    """Only fully-renamed step dirs are visible."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state())
    # simulate a crashed writer: stray tmp dir must be ignored
    os.makedirs(os.path.join(str(tmp_path), ".tmp-2"))
    assert mgr.all_steps() == [1]
