"""Golden-trace regression: a committed JSONL fleet trace must replay to
bit-identical totals, forever.

``tests/fixtures/fleet_trace_golden.jsonl`` was recorded by this module's
``--regen`` entry point: a fixed five-row schedule (wait_all, an
OVERLAPPED k_of_n launched at t=0 — its row carries the ``advance`` field
— a hedged phase, a master charge, and a speculative phase) under a fleet
with failures and cold starts, with per-worker times attached.  The tests
pin three contracts the runtime refactors must not break:

1. Replaying the fixture through the same schedule reproduces, bit for
   bit, the totals implied by the raw rows (clock += advance-or-elapsed
   in row order; dollars from the summed ledger columns) — including the
   overlap accounting, which moves the clock by less than ``elapsed``.
2. Re-recording the schedule live matches the committed rows exactly
   (same jax version; across versions the schedule structure must still
   match), and a live record -> replay round trip is bit-identical.
3. ``calibrate_from_trace`` still accepts the fixture's worker_times.

A second fixture, ``dag_trace_golden.jsonl``, pins the scheduler-era
schema v2: the same contracts for a DAG-SCHEDULED, WARM-POOL, per-phase-
sized run (rows carry ``memory_gb``, ``pool``, ``retries``/``cold_delays``
and an overlapped phase's ``advance``) — and the v1 fixture above is the
standing proof that pre-v2 traces replay unchanged.

A third fixture, ``chaos_trace_golden.jsonl``, pins the chaos-era schema
v3: the same schedule shape recorded under a FULL fault plan (correlated
burst, concurrency throttle, S3 transients, silent corruption) with
lifecycle detail on.  Its rows carry the additive ``faults`` object —
kills, throttle rejections and waits, S3 retries, the ``corrupted`` hex
mask — and the v1/v2 fixtures above are the standing proof that pre-v3
traces replay unchanged.  Crucially the REPLAY clock gets no fault plan
at all: everything needed to reproduce a chaotic run bit-for-bit lives
in the trace.  A fourth contract rides along: ``calibrate_faults_from_-
trace`` must recover the plan's identifiable knobs from the fixture.

Regenerate (only after an INTENTIONAL engine/trace-format change):

    PYTHONPATH=src python tests/test_golden_trace.py --regen
    PYTHONPATH=src python tests/test_golden_trace.py --regen-dag
    PYTHONPATH=src python tests/test_golden_trace.py --regen-chaos
"""
import json
import pathlib

import jax
import pytest

from repro.core.straggler import SimClock, StragglerModel
from repro.runtime import (BurstSpec, CorruptionSpec, CostLedger, CostModel,
                           FaultPlan, FleetConfig, S3Spec, ThrottleSpec,
                           TraceRecorder, TraceReplayer,
                           calibrate_faults_from_trace, calibrate_from_trace)
from repro.scheduler import PhaseSpec, WarmPool, run_dag

FIXTURE = pathlib.Path(__file__).parent / "fixtures" / \
    "fleet_trace_golden.jsonl"
DAG_FIXTURE = pathlib.Path(__file__).parent / "fixtures" / \
    "dag_trace_golden.jsonl"
CHAOS_FIXTURE = pathlib.Path(__file__).parent / "fixtures" / \
    "chaos_trace_golden.jsonl"
_FLEET = FleetConfig(failure_rate=0.15, cold_start_prob=0.25)
_CHAOS_FLEET = FleetConfig(failure_rate=0.1, cold_start_prob=0.2)
#: Every fault axis at once, knobs picked so each one demonstrably fires
#: on the 16-worker schedule below (kills inside the window, >10
#: concurrent launches, fat S3 retry chains, a few corrupted results).
_CHAOS_PLAN = FaultPlan(
    burst=BurstSpec(t_start=0.3, t_end=1.5, kill_fraction=0.5),
    throttle=ThrottleSpec(max_concurrent=10),
    s3=S3Spec(get_fail_prob=0.3, put_fail_prob=0.15),
    corruption=CorruptionSpec(prob=0.15),
    seed=7)


def _drive(clock):
    """The golden schedule.  Phase 2 launches at t=0 (fully or partially
    hidden behind phase 1), so its recorded row carries ``advance``."""
    clock.phase(jax.random.PRNGKey(0), 12, policy="wait_all",
                flops_per_worker=3e5, comm_units=1.0)
    clock.phase(jax.random.PRNGKey(1), 12, policy="k_of_n", k=10,
                flops_per_worker=3e5, not_before=0.0)
    clock.phase(jax.random.PRNGKey(2), 8, policy="hedged",
                flops_per_worker=1e5)
    clock.charge(0.125)
    clock.phase(jax.random.PRNGKey(3), 6, policy="speculative",
                flops_per_worker=2e5)
    return clock


def _drive_dag(clock):
    """The golden DAG schedule: a gradient-shaped chain concurrent with a
    Hessian-shaped fan-out (whose row carries ``advance``), joined by a
    line-search phase, with per-phase Lambda sizes on two nodes."""
    run_dag(clock, jax.random.PRNGKey(42), [
        PhaseSpec("gx", 10, policy="k_of_n", k=8, flops_per_worker=3e5,
                  comm_units=1.0, memory_gb=0.5),
        PhaseSpec("gxt", 10, policy="k_of_n", k=8, flops_per_worker=3e5,
                  comm_units=1.0, deps=("gx",), memory_gb=0.5),
        PhaseSpec("hess", 16, policy="k_of_n", k=13, flops_per_worker=6e5,
                  comm_units=1.0, memory_gb=1.5),
        PhaseSpec("ls", 6, flops_per_worker=1e5, comm_units=0.5,
                  deps=("gxt", "hess")),
    ])
    clock.charge(0.0625)
    return clock


def _dag_pool():
    return WarmPool(ttl=20.0, prewarmed=4)


def _drive_chaos(clock):
    """The golden chaos schedule: a wait_all fan-out that eats the burst
    window and the throttle cap head-on, a partial-wait phase, a master
    charge, and a hedged phase — all under ``fail_open`` (default), so
    exhaustion degrades to partial masks rather than raising."""
    clock.phase(jax.random.PRNGKey(10), 16, policy="wait_all",
                flops_per_worker=3e5, comm_units=1.0)
    clock.phase(jax.random.PRNGKey(11), 16, policy="k_of_n", k=13,
                flops_per_worker=3e5, comm_units=1.0)
    clock.charge(0.1)
    clock.phase(jax.random.PRNGKey(12), 12, policy="hedged",
                flops_per_worker=2e5)
    return clock


def _load(fixture=FIXTURE):
    rows = [json.loads(line) for line in fixture.read_text().splitlines()
            if line.strip()]
    meta = rows[0]
    assert meta["kind"] == "meta"
    return meta, rows[1:]


def _assert_replay_matches_raw_rows(drive, rows):
    """Replay ``rows`` through ``drive`` and check the totals against
    independent arithmetic on the raw rows, in row order (same float
    accumulation order as the engine — equality is exact, not approx)."""
    replayed = drive(SimClock(StragglerModel(), replay=TraceReplayer(rows)))
    seconds = 0.0
    ledger = CostLedger()
    for r in rows:
        if r["kind"] == "phase":
            seconds += r.get("advance", r["elapsed"])
            ledger.add(CostLedger(gb_seconds=r["gb_seconds"],
                                  invocations=r["invocations"],
                                  s3_puts=r["s3_puts"],
                                  s3_gets=r["s3_gets"]))
        else:
            seconds += r["elapsed"]
    assert replayed.time == seconds
    assert replayed.dollars == ledger.dollars(CostModel())


def _assert_rerecord_matches(drive, rec, meta, rows, tmp_path, pool=None,
                             fleet=_FLEET, faults=None):
    """Re-drive ``drive`` live into ``rec``: the record -> replay round
    trip must be bit-identical in any version, the schedule structure must
    always match the committed ``rows``, and under the fixture's jax
    version the rows must be IDENTICAL (json round-trip normalizes float
    repr, mask hex, advance fields).  Only the LIVE clock gets ``faults``
    — the replay clock never needs the plan."""
    live = drive(SimClock(StragglerModel(), fleet=fleet, recorder=rec,
                          pool=pool, faults=faults))
    path = tmp_path / "rerecord.jsonl"
    rec.dump(path)
    from repro.runtime import load_trace
    replayed = drive(SimClock(StragglerModel(), replay=load_trace(path)))
    assert replayed.time == live.time
    assert replayed.dollars == live.dollars
    assert [(r["kind"], r.get("policy"), r.get("workers"), r.get("k"))
            for r in rec.rows] == \
        [(r["kind"], r.get("policy"), r.get("workers"), r.get("k"))
         for r in rows]
    if jax.__version__ != meta["jax_version"]:
        pytest.skip(f"fixture recorded under jax {meta['jax_version']}, "
                    f"running {jax.__version__}: structural check only")
    assert [json.loads(json.dumps(r)) for r in rec.rows] == rows


def test_golden_fixture_replays_bit_identical():
    _, rows = _load()
    assert any("advance" in r for r in rows), \
        "fixture must contain an overlapped phase"
    _assert_replay_matches_raw_rows(_drive, rows)


def test_golden_schedule_rerecord_matches_fixture(tmp_path):
    meta, rows = _load()
    _assert_rerecord_matches(_drive, TraceRecorder(worker_times=True),
                             meta, rows, tmp_path)


def test_golden_fixture_calibrates():
    model = calibrate_from_trace(FIXTURE)
    assert model.base_time > 0
    assert 0.0 <= model.p_tail <= 1.0


# ------------------------------------------------- scheduler-era DAG fixture
def test_dag_golden_fixture_replays_bit_identical():
    _, rows = _load(DAG_FIXTURE)
    phase_rows = [r for r in rows if r["kind"] == "phase"]
    assert any("advance" in r for r in phase_rows), \
        "fixture must contain an overlapped (DAG-concurrent) phase"
    assert any("memory_gb" in r for r in phase_rows), \
        "fixture must contain a per-phase-sized phase"
    assert all("pool" in r for r in phase_rows), \
        "fixture must be a warm-pool run"
    _assert_replay_matches_raw_rows(_drive_dag, rows)


def test_dag_golden_schedule_rerecord_matches_fixture(tmp_path):
    meta, rows = _load(DAG_FIXTURE)
    _assert_rerecord_matches(
        _drive_dag, TraceRecorder(worker_times=True, lifecycle=True),
        meta, rows, tmp_path, pool=_dag_pool())


def test_dag_golden_fixture_fleet_calibrates():
    from repro.runtime import calibrate_fleet_from_trace
    fleet = calibrate_fleet_from_trace(DAG_FIXTURE)
    assert 0.0 <= fleet.failure_rate <= 1.0
    assert fleet.cold_start_hi >= fleet.cold_start_lo > 0.0


# ------------------------------------------------- chaos-era fault fixture
def test_chaos_golden_fixture_replays_bit_identical():
    _, rows = _load(CHAOS_FIXTURE)
    phase_rows = [r for r in rows if r["kind"] == "phase"]
    assert all("faults" in r for r in phase_rows), \
        "every phase of the chaos fixture must carry the v3 faults object"
    seen = set()
    for r in phase_rows:
        seen.update(r["faults"])
    # Each plan axis left its signature somewhere in the trace.
    assert "burst_kills" in seen, "burst must have killed someone"
    assert "throttled" in seen, "the concurrency cap must have rejected"
    assert "s3_get_retries" in seen or "s3_put_retries" in seen
    assert "corrupted" in seen, "corruption must have tainted a result"
    # Replay needs NO fault plan: the drive below builds a plan-less clock.
    _assert_replay_matches_raw_rows(_drive_chaos, rows)


def test_chaos_golden_schedule_rerecord_matches_fixture(tmp_path):
    meta, rows = _load(CHAOS_FIXTURE)
    _assert_rerecord_matches(
        _drive_chaos, TraceRecorder(worker_times=True, lifecycle=True),
        meta, rows, tmp_path, fleet=_CHAOS_FLEET, faults=_CHAOS_PLAN)


def test_chaos_golden_fixture_fault_calibration_round_trips():
    """``calibrate_faults_from_trace`` recovers the plan's identifiable
    knobs from the committed fixture — the chaos analogue of the
    straggler/fleet calibrations above.  Windows and seeds are
    unidentifiable from a trace; rates and the cap are."""
    plan = calibrate_faults_from_trace(CHAOS_FIXTURE)
    # The saturated launch heap sits exactly at the cap: exact recovery.
    assert plan.throttle is not None
    assert plan.throttle.max_concurrent == \
        _CHAOS_PLAN.throttle.max_concurrent
    # First-rejection waits are backoff + U[0, jitter): the minimum
    # observed wait brackets the base backoff tightly from above.
    assert _CHAOS_PLAN.throttle.backoff <= plan.throttle.backoff < \
        _CHAOS_PLAN.throttle.backoff + _CHAOS_PLAN.throttle.jitter
    # Rate estimators: small-sample, so loose factor-of-two brackets.
    assert plan.burst is not None
    assert 0.5 * _CHAOS_PLAN.burst.kill_fraction <= \
        plan.burst.kill_fraction <= \
        min(1.0, 2.0 * _CHAOS_PLAN.burst.kill_fraction)
    assert plan.s3 is not None
    assert 0.5 * _CHAOS_PLAN.s3.get_fail_prob <= plan.s3.get_fail_prob <= \
        min(1.0, 2.0 * _CHAOS_PLAN.s3.get_fail_prob)


# ------------------------------------------- telemetry is observation-only
def _assert_telemetry_inert(drive, rows, *, want_phases):
    """Driving the golden schedule off the fixture with a LIVE telemetry
    recorder attached — and again with live HEALTH MONITORS watching the
    metric stream — must reproduce the exact totals the plain replay
    gives: the no-op default, the live recorder, and the recorder plus
    streaming anomaly detectors are all interchangeable as far as the
    simulation is concerned."""
    from repro import obs
    plain = drive(SimClock(StragglerModel(), replay=TraceReplayer(rows)))
    tel = obs.Telemetry()
    live = drive(SimClock(StragglerModel(), replay=TraceReplayer(rows),
                          telemetry=tel))
    assert live.time == plain.time
    assert live.dollars == plain.dollars
    phase_spans = tel.trace.by_kind("phase")
    assert len(phase_spans) == want_phases
    assert all(s.attrs.get("replayed") for s in phase_spans)
    monitored_tel = obs.Telemetry(monitors=True)
    monitored = drive(SimClock(StragglerModel(),
                               replay=TraceReplayer(rows),
                               telemetry=monitored_tel))
    assert monitored.time == plain.time
    assert monitored.dollars == plain.dollars
    # The listener really is wired into the registry (live-path coverage
    # of detector sampling is in test_health), and a healthy golden
    # replay stays silent.
    assert monitored_tel.metrics.listener is monitored_tel.health is not None
    assert monitored_tel.health.alerts == []
    # Incident attribution on top is observation-only too: running the
    # full alert->cause pipeline after the fact consumes only recorded
    # telemetry (no clock reads, no randomness), so the replayed totals
    # cannot move — and a second attribution of the same telemetry
    # yields identical incident rows (determinism of the attributor).
    first = [i.as_row() for i in obs.attribute(monitored_tel)]
    assert monitored.time == plain.time
    assert monitored.dollars == plain.dollars
    again = obs.attribute_rows(
        [s.as_row() for s in monitored_tel.trace.spans
         if s.kind != "incident"],
        [a.as_row() for a in monitored_tel.health.alerts])
    assert [i.as_row() for i in again] == first


def test_golden_fixture_replays_identically_with_telemetry():
    _, rows = _load()
    _assert_telemetry_inert(
        _drive, rows,
        want_phases=sum(r["kind"] == "phase" for r in rows))


def test_dag_golden_fixture_replays_identically_with_telemetry():
    _, rows = _load(DAG_FIXTURE)
    _assert_telemetry_inert(
        _drive_dag, rows,
        want_phases=sum(r["kind"] == "phase" for r in rows))


def test_chaos_golden_fixture_replays_identically_with_telemetry():
    """Replaying the CHAOTIC fixture under live health monitors stays
    alert-silent too: replay reproduces totals, not per-worker fault
    stats, so detectors see only the healthy-looking span stream."""
    _, rows = _load(CHAOS_FIXTURE)
    _assert_telemetry_inert(
        _drive_chaos, rows,
        want_phases=sum(r["kind"] == "phase" for r in rows))


def _regen():
    rec = TraceRecorder(worker_times=True)
    _drive(SimClock(StragglerModel(), fleet=_FLEET, recorder=rec))
    FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    with open(FIXTURE, "w") as f:
        f.write(json.dumps({"kind": "meta", "jax_version": jax.__version__,
                            "generator": "tests/test_golden_trace.py "
                                         "--regen"}) + "\n")
        for row in rec.rows:
            f.write(json.dumps(row) + "\n")
    print(f"wrote {FIXTURE} ({len(rec.rows)} rows)")


def _regen_dag():
    rec = TraceRecorder(worker_times=True, lifecycle=True)
    _drive_dag(SimClock(StragglerModel(), fleet=_FLEET, pool=_dag_pool(),
                        recorder=rec))
    DAG_FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    with open(DAG_FIXTURE, "w") as f:
        f.write(json.dumps({"kind": "meta", "jax_version": jax.__version__,
                            "generator": "tests/test_golden_trace.py "
                                         "--regen-dag"}) + "\n")
        for row in rec.rows:
            f.write(json.dumps(row) + "\n")
    print(f"wrote {DAG_FIXTURE} ({len(rec.rows)} rows)")


def _regen_chaos():
    rec = TraceRecorder(worker_times=True, lifecycle=True)
    _drive_chaos(SimClock(StragglerModel(), fleet=_CHAOS_FLEET,
                          recorder=rec, faults=_CHAOS_PLAN))
    CHAOS_FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    with open(CHAOS_FIXTURE, "w") as f:
        f.write(json.dumps({"kind": "meta", "jax_version": jax.__version__,
                            "generator": "tests/test_golden_trace.py "
                                         "--regen-chaos"}) + "\n")
        for row in rec.rows:
            f.write(json.dumps(row) + "\n")
    print(f"wrote {CHAOS_FIXTURE} ({len(rec.rows)} rows)")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    elif "--regen-dag" in sys.argv:
        _regen_dag()
    elif "--regen-chaos" in sys.argv:
        _regen_chaos()
    else:
        sys.exit("usage: python tests/test_golden_trace.py "
                 "[--regen | --regen-dag | --regen-chaos]")
