"""Trainer integration: fault tolerance + elastic rescale + resilient grads.

Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test process
keeps its single-device view (per the dry-run spec, the flag must never be
set globally)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh
from repro.training.trainer import Trainer, TrainerConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    return out.stdout


def test_loss_decreases_single_device(tmp_path):
    cfg = TrainerConfig(arch="qwen3-4b", steps=10, batch=4, seq=64,
                        ckpt_dir=str(tmp_path), ckpt_every=5, lr=1e-3)
    tr = Trainer(cfg, make_host_mesh())
    params, opt = tr.init_state()
    _, _, hist = tr.run(params, opt)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_failure_restart_resumes_from_checkpoint(tmp_path):
    cfg = TrainerConfig(arch="qwen3-4b", steps=12, batch=4, seq=64,
                        ckpt_dir=str(tmp_path), ckpt_every=4, lr=1e-3)
    tr = Trainer(cfg, make_host_mesh())
    hist = tr.run_with_restarts(fail_at=9)
    steps = [h["step"] for h in hist]
    assert steps[-1] == 11
    assert 8 in steps            # resumed from step-8 checkpoint
    # deterministic data => the post-restart loss at a step matches a
    # continuous run's trajectory direction (sanity: still decreasing)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_restart_determinism_same_data(tmp_path):
    """batch_at(step) is pure — restartability requires replay-identical
    batches."""
    cfg = TrainerConfig(arch="qwen3-4b", steps=4, batch=2, seq=32)
    tr = Trainer(cfg, make_host_mesh())
    b1 = tr.pipeline.batch_at(3)
    b2 = tr.pipeline.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_multidevice_train_and_elastic_restore():
    """8 devices: train on a (4,2) mesh, checkpoint, restore onto a (2,2)
    mesh (elastic rescale) and keep training."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, tempfile, os
        from repro.launch.mesh import make_mesh
        from repro.training.trainer import Trainer, TrainerConfig
        d = tempfile.mkdtemp()
        cfg = TrainerConfig(arch="qwen3-4b", steps=6, batch=8, seq=64,
                            ckpt_dir=d, ckpt_every=3, lr=1e-3)
        tr = Trainer(cfg, make_mesh((4, 2), ("data", "model")))
        p, o = tr.init_state()
        p, o, hist = tr.run(p, o)
        print("MESH1_LOSS", hist[0]["loss"], hist[-1]["loss"])

        # elastic: rebuild on a smaller mesh from the same checkpoint
        cfg2 = TrainerConfig(arch="qwen3-4b", steps=8, batch=8, seq=64,
                             ckpt_dir=d, ckpt_every=100, lr=1e-3)
        tr2 = Trainer(cfg2, make_mesh((2, 2), ("data", "model")))
        p2, o2 = tr2.init_state()
        from repro.distributed import opt_state_shardings
        state = tr2.ckpt.restore(
            tr2.ckpt.latest_step(),
            {"params": jax.eval_shape(lambda: p2),
             "opt": jax.eval_shape(lambda: o2)},
            {"params": tr2.p_shard,
             "opt": opt_state_shardings(tr2.p_shard, None)})
        p2, o2, hist2 = tr2.run(state["params"], state["opt"],
                                start_step=tr2.ckpt.latest_step())
        print("MESH2_LOSS", hist2[0]["loss"], hist2[-1]["loss"])
        assert hist2[-1]["loss"] < hist[0]["loss"]
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_multidevice_resilient_grads():
    """k-of-n resilient gradient reduction trains through stragglers."""
    out = _run_sub("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.training.trainer import Trainer, TrainerConfig
        from repro.core.straggler import StragglerModel
        cfg = TrainerConfig(arch="qwen3-4b", steps=8, batch=8, seq=64,
                            lr=1e-3, resilient_grads=True,
                            straggler=StragglerModel(p_tail=0.3))
        tr = Trainer(cfg, make_mesh((8,), ("data",)))
        p, o = tr.init_state()
        p, o, hist = tr.run(p, o)
        print("RES_LOSS", hist[0]["loss"], hist[-1]["loss"])
        assert hist[-1]["loss"] < hist[0]["loss"]
        print("RESILIENT_OK")
    """)
    assert "RESILIENT_OK" in out
