"""Incident attribution, per-tenant SLO budgets, and the fleet console.

Three planes, one contract each:

1. ``repro.obs.incident`` — alert windows correlated against declared
   ``FaultPlan`` events, recorded per-phase fault signatures, tenant
   dollar attribution and pool/CPM context must rank the *injected*
   cause first for every registered chaos scenario, and the whole
   pipeline must be deterministic down to the byte (the committed golden
   fixture ``tests/fixtures/incident_golden.jsonl``).
2. ``repro.obs.slo`` — multi-window burn rates and error budgets are
   pure arithmetic over recorded job completions; budget-aware admission
   sheds exactly the burning tenant.
3. ``repro.obs.console`` — the self-contained HTML console renders
   byte-identically from the same rows and carries the incident
   narratives, SLO burn charts and span timeline.

Regenerate the golden fixture only after an intentional engine /
attribution change:

    PYTHONPATH=src python tests/test_incident.py --regen
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro import obs, scheduler
from repro.core.straggler import SimClock, StragglerModel
from repro.obs.slo import SloPolicy, SloTracker
from repro.runtime import (FaultPlan, FleetConfig, available_scenarios,
                           get_scenario)
from repro.runtime.faults import (BurstSpec, CorruptionSpec, PoolDeathSpec,
                                  S3Spec, ThrottleSpec)
from repro.tenancy import (AdmissionPolicy, JobScheduler, TenancyConfig,
                           workload_from_trace)

FIXTURE = pathlib.Path(__file__).parent / "fixtures" / \
    "incident_golden.jsonl"


# --------------------------------------------------- shared fault drives
def _monitored_drive(faults=None, *, rounds=14, pool=None, schedule=None):
    """The test_faults fleet drive: 24 workers x N rounds with health
    monitors attached; ``schedule`` optionally varies memory pressure."""
    tel = obs.Telemetry(monitors=True)
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=FleetConfig(cold_start_prob=0.2),
                     pool=pool, faults=faults, telemetry=tel)
    for r in range(rounds):
        mem, ws = (schedule(r) if schedule is not None else (None, None))
        clock.phase(jax.random.PRNGKey(600 + r), 24, policy="wait_all",
                    flops_per_worker=3e5, comm_units=1.0,
                    memory_gb=mem, working_set_gb=ws)
    return tel, clock


def _healthy_midpoint(rounds=7, pool=False):
    p = scheduler.WarmPool(ttl=300.0, prewarmed=48) if pool else None
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=FleetConfig(cold_start_prob=0.2), pool=p)
    for r in range(rounds):
        clock.phase(jax.random.PRNGKey(600 + r), 24, policy="wait_all",
                    flops_per_worker=3e5, comm_units=1.0)
    return clock.time


def _newton_solve(faults=None, telemetry=None):
    from repro.core.newton import NewtonConfig, oversketched_newton
    from repro.core.objectives import Dataset, LogisticRegression
    from repro.core.sketch import OverSketchConfig
    key = jax.random.PRNGKey(0)
    n, d = 256, 8
    x = jax.random.normal(key, (n, d))
    y = jnp.sign(x @ jax.random.normal(jax.random.fold_in(key, 1), (d,)))
    cfg = NewtonConfig(iters=8,
                       sketch=OverSketchConfig(sketch_dim=64, block_size=16,
                                               straggler_tolerance=0.25),
                       coded_block_rows=32)
    clock = SimClock(StragglerModel(), faults=faults, telemetry=telemetry)
    oversketched_newton(LogisticRegression(lam=1e-3), Dataset(x=x, y=y),
                        jnp.zeros((d,)), cfg, clock)
    return clock


def _scenario_drive(scen: str):
    """A chaotic monitored run for ``scen`` plus the declared plan —
    each wired exactly like the corresponding test_faults scenario."""
    t_mid = _healthy_midpoint()
    if scen == "az_burst":
        plan = FaultPlan(burst=BurstSpec(t_start=t_mid, kill_fraction=0.9))
        return _monitored_drive(plan)[0], plan
    if scen == "throttle":
        plan = FaultPlan(throttle=ThrottleSpec(max_concurrent=4,
                                               t_start=t_mid))
        return _monitored_drive(plan)[0], plan
    if scen == "s3_transient":
        plan = FaultPlan(s3=S3Spec(get_fail_prob=0.7, put_fail_prob=0.3,
                                   retry_delay=0.2, t_start=t_mid))
        return _monitored_drive(plan)[0], plan
    if scen == "oom":
        plan = get_scenario("oom")
        tel, _ = _monitored_drive(
            plan, schedule=lambda r: ((1.0, 0.5) if r < 8 else (0.25, 0.5)))
        return tel, plan
    if scen == "pool_death":
        plan = FaultPlan(pool_death=PoolDeathSpec(
            t=_healthy_midpoint(pool=True), fraction=1.0))
        tel, _ = _monitored_drive(
            plan, pool=scheduler.WarmPool(ttl=300.0, prewarmed=48))
        return tel, plan
    if scen == "corruption":
        t2 = 0.5 * _newton_solve(None).time
        plan = FaultPlan(corruption=CorruptionSpec(prob=0.5, t_start=t2))
        tel = obs.Telemetry(monitors=True)
        _newton_solve(plan, telemetry=tel)
        return tel, plan
    raise ValueError(scen)


# ------------------------------------------- per-scenario cause ranking
@pytest.mark.parametrize("scen", available_scenarios())
def test_top_ranked_cause_matches_injected_fault(scen):
    """The attribution contract: for every registered chaos scenario the
    highest-scoring hypothesis is the fault that was actually injected."""
    tel, plan = _scenario_drive(scen)
    incidents = obs.attribute(tel, faults=plan)
    assert incidents, f"{scen}: chaotic monitored run raised no incident"
    top = incidents[0]
    assert top.cause == scen
    assert top.score > 0.0
    assert top.hypotheses[0][0] == scen
    # Every incident carries replayable evidence and a time window.
    for inc in incidents:
        assert inc.t_end >= inc.t_start
        assert inc.evidence and inc.n_alerts >= 1
        assert inc.cause in obs.CAUSES


def test_attribution_blames_declared_plan_window():
    """A declared FaultPlan window overlapping the alerts contributes
    plan-kind evidence (the strongest stream)."""
    tel, plan = _scenario_drive("az_burst")
    (inc, *_) = obs.attribute(tel, faults=plan)
    kinds = {e.kind for e in inc.evidence if e.cause == "az_burst"}
    assert "fault_plan" in kinds and "fault_stat" in kinds


def test_healthy_run_attributes_nothing():
    tel, _ = _monitored_drive(rounds=7)
    assert obs.attribute(tel) == []
    assert tel.incidents == []


def test_attribution_without_plan_still_finds_signature_cause():
    """Blind attribution (no FaultPlan handed over) still ranks the true
    cause first from recorded per-phase fault signatures alone."""
    t_mid = _healthy_midpoint()
    plan = FaultPlan(burst=BurstSpec(t_start=t_mid, kill_fraction=0.9))
    tel, _ = _monitored_drive(plan)
    incidents = obs.attribute(tel)          # note: faults=None
    assert incidents and incidents[0].cause == "az_burst"


def test_attribute_emits_incident_spans_and_rows():
    tel, plan = _scenario_drive("az_burst")
    incidents = obs.attribute(tel, faults=plan)
    spans = [s for s in tel.trace.spans if s.kind == "incident"]
    assert len(spans) == len(incidents)
    assert {s.name for s in spans} == \
        {f"incident:{i.cause}" for i in incidents}
    rows = obs.telemetry_rows(tel)
    inc_rows = [r for r in rows if r.get("kind") == "incident"]
    assert inc_rows == [i.as_row() for i in incidents]
    # JSONL round-trip preserves the rows bit-for-bit.
    assert [json.loads(json.dumps(r)) for r in inc_rows] == inc_rows


def test_chaotic_phases_record_fault_signatures_healthy_do_not():
    t_mid = _healthy_midpoint()
    plan = FaultPlan(burst=BurstSpec(t_start=t_mid, kill_fraction=0.9))
    chaotic, _ = _monitored_drive(plan)
    healthy, _ = _monitored_drive()
    def sigs(tel):
        return [s.attrs.get("faults") for s in tel.trace.spans
                if s.kind == "phase" and s.attrs.get("faults")]
    assert sigs(chaotic), "burst run must stamp per-phase fault attrs"
    assert any("burst_kills" in s for s in sigs(chaotic))
    assert not sigs(healthy)


def test_fault_plan_events_declares_every_armed_spec():
    assert FaultPlan().events() == []
    plan = get_scenario("az_burst", kill_fraction=0.85, t_start=1.0,
                        t_end=4.0)
    assert plan.events() == [{"cause": "az_burst", "t_start": 1.0,
                              "t_end": 4.0,
                              "detail": "kill_fraction=0.85"}]
    open_ended = FaultPlan(burst=BurstSpec(t_start=2.0, kill_fraction=0.9))
    (ev,) = open_ended.events()
    assert ev["t_end"] is None           # open window, JSON-safe
    causes = {e["cause"] for s in available_scenarios()
              for e in get_scenario(s).events()}
    assert causes == set(available_scenarios())


# ------------------------------------------ golden two-tenant fixture
def _golden_jobs():
    trace = [(0.2 * i, "matvec") for i in range(10)] + [(0.3, "giant")]
    return workload_from_trace(sorted(trace, key=lambda e: e[0]))


def _golden_drive(faults=None, telemetry=None):
    pool = scheduler.WarmPool(ttl=300.0, prewarmed=48)
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=FleetConfig(cold_start_prob=0.2), pool=pool,
                     faults=faults, telemetry=telemetry)
    cfg = TenancyConfig(slo={
        "serving": SloPolicy(latency_target_s=1.0, deadline_rate=0.9),
        "train": SloPolicy(latency_target_s=20.0, deadline_rate=0.5)})
    res = JobScheduler(clock, jax.random.PRNGKey(7), _golden_jobs(),
                       cfg).run()
    return clock, res


def _golden_incidents():
    plain, _ = _golden_drive()
    plan = FaultPlan(burst=BurstSpec(t_start=0.5 * plain.time,
                                     kill_fraction=0.9))
    tel = obs.Telemetry(monitors=True)
    _golden_drive(faults=plan, telemetry=tel)
    return obs.attribute(tel, faults=plan), tel, plan


def _load_fixture():
    lines = [ln for ln in FIXTURE.read_text().splitlines() if ln.strip()]
    meta = json.loads(lines[0])
    assert meta["kind"] == "meta"
    return meta, lines[1:]


def test_incident_golden_fixture_is_byte_identical(tmp_path):
    """The attribution pipeline end-to-end (two-tenant workload x
    az_burst chaos) reproduces the committed incident JSONL byte for
    byte — evidence lists, scores, blamed tenant/phase, impact."""
    meta, fixture_lines = _load_fixture()
    incidents, _, _ = _golden_incidents()
    assert incidents, "golden chaos drive must attribute >= 1 incident"
    assert incidents[0].cause == "az_burst"
    out = tmp_path / "incidents.jsonl"
    obs.dump_incidents(incidents, out)
    live_lines = [ln for ln in out.read_text().splitlines() if ln.strip()]
    # Structure must match under any jax version...
    assert [json.loads(ln)["cause"] for ln in live_lines] \
        == [json.loads(ln)["cause"] for ln in fixture_lines]
    if jax.__version__ != meta["jax_version"]:
        pytest.skip(f"fixture recorded under jax {meta['jax_version']}, "
                    f"running {jax.__version__}: structural check only")
    # ...and byte-for-byte under the recorded one.
    assert live_lines == fixture_lines


def test_golden_attribution_is_rerun_deterministic():
    a, tel, plan = _golden_incidents()
    b, _, _ = _golden_incidents()
    assert [i.as_row() for i in a] == [i.as_row() for i in b]
    # Offline replay from exported rows + declared events reproduces the
    # live result exactly.
    rows = [s.as_row() for s in tel.trace.spans if s.kind != "incident"]
    alerts = [al.as_row() for al in tel.health.alerts]
    again = obs.attribute_rows(rows, alerts, fault_events=plan.events())
    assert [i.as_row() for i in again] == [i.as_row() for i in a]


# --------------------------------------------------- SLO / error budgets
def _policy(**kw):
    kw.setdefault("latency_target_s", 1.0)
    kw.setdefault("deadline_rate", 0.9)
    return SloPolicy(**kw)


def test_slo_budget_burns_down_and_recovers_shape():
    tr = SloTracker({"t": _policy()})
    assert tr.budget_remaining("t") == 1.0
    for i in range(9):                         # 9 good jobs
        tr.record_job("t", 0.1 * i, 0.5, deadline_missed=False,
                      failed=False, dollars=0.01)
    assert tr.budget_remaining("t") == 1.0
    tr.record_job("t", 1.0, 5.0, deadline_missed=False, failed=False,
                  dollars=0.01)               # 1 bad of 10 == allowance
    assert tr.budget_remaining("t") == pytest.approx(0.0)
    tr.record_job("t", 1.1, 5.0, deadline_missed=False, failed=False,
                  dollars=0.01)               # over budget now
    assert tr.budget_remaining("t") < 0.0
    assert tr.should_shed("t", 1.2)


def test_slo_bad_job_definitions():
    """failed OR deadline_missed OR latency over target each count."""
    for kw in ({"failed": True, "deadline_missed": False, "latency_s": 0.1},
               {"failed": False, "deadline_missed": True, "latency_s": 0.1},
               {"failed": False, "deadline_missed": False,
                "latency_s": 9.0}):
        tr = SloTracker({"t": _policy(deadline_rate=0.99)})
        tr.record_job("t", 0.0, kw["latency_s"],
                      deadline_missed=kw["deadline_missed"],
                      failed=kw["failed"], dollars=0.0)
        assert tr.summary()["t"]["bad_jobs"] == 1


def test_slo_burn_rate_windows():
    pol = _policy(deadline_rate=0.9, fast_window_s=10.0,
                  slow_window_s=100.0)
    tr = SloTracker({"t": pol})
    # 5 bad jobs at t in [90, 94]: inside the fast window at t=95,
    # diluted in the slow one.
    for t in range(50):
        tr.record_job("t", float(t), 0.1, deadline_missed=False,
                      failed=False, dollars=0.0)
    for t in (90.0, 91.0, 92.0, 93.0, 94.0):
        tr.record_job("t", t, 9.0, deadline_missed=False, failed=False,
                      dollars=0.0)
    fast = tr.burn_rate("t", 95.0, pol.fast_window_s)
    slow = tr.burn_rate("t", 95.0, pol.slow_window_s)
    assert fast == pytest.approx((5 / 5) / pol.allowed_bad)  # all bad
    assert slow == pytest.approx((5 / 55) / pol.allowed_bad)
    assert fast > slow
    assert tr.burn_rate("t", 300.0, 10.0) == 0.0   # window slid past


def test_slo_shed_requires_both_windows_or_exhausted_budget():
    pol = _policy(deadline_rate=0.5, fast_window_s=10.0,
                  slow_window_s=1000.0, fast_burn=1.5, slow_burn=1.2)
    tr = SloTracker({"t": pol})
    for t in range(100):                       # long healthy history
        tr.record_job("t", float(t), 0.1, deadline_missed=False,
                      failed=False, dollars=0.0)
    # A recent burst of 30 bad jobs: the fast window pages (30 bad of 39
    # in-window => burn ~1.54 > 1.5) while the slow window — diluted by
    # the healthy history — stays calm, so no shed fires.
    for i in range(30):
        tr.record_job("t", 100.0 + 0.01 * i, 9.0, deadline_missed=False,
                      failed=False, dollars=0.0)
    now = 100.5
    assert tr.burn_rate("t", now, pol.fast_window_s) > pol.fast_burn
    assert tr.burn_rate("t", now, pol.slow_window_s) < pol.slow_burn
    assert tr.budget_remaining("t") > 0.0
    assert not tr.should_shed("t", now)


def test_slo_cost_ceiling_caps_budget():
    tr = SloTracker({"t": _policy(cost_ceiling_usd=1.0)})
    tr.record_job("t", 0.0, 0.1, deadline_missed=False, failed=False,
                  dollars=0.75)
    assert tr.budget_remaining("t") == pytest.approx(0.25)
    tr.record_job("t", 1.0, 0.1, deadline_missed=False, failed=False,
                  dollars=0.75)
    assert tr.budget_remaining("t") < 0.0      # cost axis exhausted
    assert tr.should_shed("t", 2.0)
    assert tr.summary()["t"]["dollars"] == pytest.approx(1.5)


def test_slo_unknown_tenant_is_untracked():
    tr = SloTracker({"t": _policy()})
    tr.record_job("other", 0.0, 99.0, deadline_missed=True, failed=True,
                  dollars=9.9)
    assert not tr.should_shed("other", 1.0)
    assert tr.budget_remaining("other") == 1.0
    assert "other" not in tr.summary()


def test_budget_aware_admission_sheds_only_burning_tenant():
    """matvec (serving) against an impossible 1 ms target sheds; the
    train tenant rides through untouched."""
    jobs = workload_from_trace(
        sorted([(0.05 * i, "matvec") for i in range(30)]
               + [(0.1, "giant")], key=lambda e: e[0]))
    slo = {"serving": SloPolicy(latency_target_s=0.001, deadline_rate=0.5,
                                fast_window_s=5.0, slow_window_s=20.0),
           "train": SloPolicy(latency_target_s=60.0, deadline_rate=0.5)}
    tel = obs.Telemetry()
    clock = SimClock(StragglerModel(), telemetry=tel)
    cfg = TenancyConfig(admission=AdmissionPolicy(
        max_inflight=256, queue=True, slo_aware=False, budget_aware=True),
        slo=slo)
    res = JobScheduler(clock, jax.random.PRNGKey(3), jobs, cfg).run()
    shed = {n: c.value for n, c in tel.metrics.counters.items()
            if n.endswith(".budget_shed")}
    assert shed.get("tenant.serving.budget_shed", 0) > 0
    assert "tenant.train.budget_shed" not in shed
    assert any(j.template == "giant" and j.completed for j in res.jobs)
    assert tel.slo.budget_remaining("serving") <= 0.0
    assert tel.slo.budget_remaining("train") == 1.0


def test_slo_tracking_alone_is_observation_only():
    """Policies attached but budget_aware off: totals bit-identical."""
    jobs = workload_from_trace([(0.2 * i, "matvec") for i in range(5)])
    def run(cfg):
        clock = SimClock(StragglerModel(), telemetry=obs.Telemetry())
        return JobScheduler(clock, jax.random.PRNGKey(2), jobs, cfg).run()
    plain = run(TenancyConfig())
    tracked = run(TenancyConfig(slo={"serving": _policy()}))
    assert (plain.seconds, plain.dollars) \
        == (tracked.seconds, tracked.dollars)
    assert plain.phase_log == tracked.phase_log


def test_slo_rows_export_series():
    tel = obs.Telemetry()
    tr = SloTracker({"t": _policy()}, telemetry=tel)
    tr.record_job("t", 1.0, 0.5, deadline_missed=False, failed=False,
                  dollars=0.1)
    assert tel.metrics.gauges["slo.t.budget_remaining"].value == 1.0
    assert "slo.t.bad_jobs" not in tel.metrics.counters  # no bad job yet
    tr.record_job("t", 2.0, 0.5, deadline_missed=False, failed=True,
                  dollars=0.1)
    assert tel.metrics.counters["slo.t.bad_jobs"].value == 1.0
    (row,) = tr.rows()
    assert row["kind"] == "slo" and row["tenant"] == "t"
    assert len(row["series"]) == 2 and row["jobs"] == 2


# -------------------------------------------------- perfetto counters
def test_counter_series_collects_timestamped_gauges():
    tel, _ = _monitored_drive(rounds=3)
    counters = obs.counter_series(tel)
    assert "worker.completion_s" not in counters      # histogram-only
    assert "phase.tail_p95_s" in counters             # opted-in histogram
    for name, pts in counters.items():
        assert pts == sorted(pts), name
        assert all(isinstance(t, float) and isinstance(v, float)
                   for t, v in pts)


def test_to_perfetto_counters_are_opt_in_and_valid():
    tel, _ = _monitored_drive(rounds=3)
    plain = obs.to_perfetto(tel.trace.spans)
    assert not any(e.get("ph") == "C" for e in plain["traceEvents"])
    counters = obs.counter_series(tel)
    trace = obs.to_perfetto(tel.trace.spans, counters=counters)
    cevents = [e for e in trace["traceEvents"] if e.get("ph") == "C"]
    assert cevents
    assert {e["pid"] for e in cevents} == {obs.perfetto.COUNTERS_PID}
    assert all("value" in e["args"] for e in cevents)
    obs.perfetto.validate_trace(
        trace, require_counters=tuple(sorted(counters)))
    with pytest.raises(ValueError, match="counter track"):
        obs.perfetto.validate_trace(plain,
                                    require_counters=("pool.hit_rate",))


# ------------------------------------------------------- fleet console
def _console_rows():
    _, tel, _ = _golden_incidents()
    return obs.telemetry_rows(tel)


def test_console_renders_all_sections_deterministically():
    rows = _console_rows()
    bench = [{"name": "sched_demo", "us": 1234.5, "derived": "sim_s=1.2",
              "path": "dag"}]
    html_a = obs.render_console(rows, bench=bench, title="fleet console")
    html_b = obs.render_console(rows, bench=bench, title="fleet console")
    assert html_a == html_b                    # byte-identical render
    assert html_a.lstrip().startswith("<!DOCTYPE html>")
    for needle in ("<svg", "incident-band-", "az_burst (score",
                   "budget", "burn", "sched_demo", "fleet console"):
        assert needle in html_a, needle
    # Evidence links anchor to real timeline spans.
    assert 'href="#span-' in html_a
    # Self-contained: nothing fetched from anywhere (the SVG xmlns is an
    # identifier, not a request).
    for banned in ("https://", "<script src", "<link ", "<img src"):
        assert banned not in html_a, banned


def test_write_console_and_empty_rows(tmp_path):
    out = tmp_path / "console.html"
    obs.write_console(out, [], title="empty run")
    text = out.read_text()
    assert "empty run" in text and "<!DOCTYPE html>" in text.lstrip()


# ---------------------------------------------------------------- regen
def _regen():
    incidents, _, _ = _golden_incidents()
    assert incidents and incidents[0].cause == "az_burst"
    FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    with open(FIXTURE, "w") as f:
        f.write(json.dumps({"kind": "meta",
                            "jax_version": jax.__version__,
                            "generator": "tests/test_incident.py "
                                         "--regen"}) + "\n")
        for inc in incidents:
            f.write(json.dumps(inc.as_row(), sort_keys=True) + "\n")
    print(f"wrote {FIXTURE} ({len(incidents)} incident(s))")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        sys.exit("usage: python tests/test_incident.py --regen")
