"""Chaos plane: seeded fault injection + graceful degradation.

Contracts pinned here, layer by layer:

1. Registry: every shipped scenario is constructible with knob overrides,
   specs validate their knobs, unknown names fail loudly.
2. Determinism: a fault plan's randomness comes from its own seeded
   stream — same plan, same drive => bit-identical totals; a plan whose
   windows never open leaves the healthy clock bit-identical (the
   fault plane cannot perturb the historical stream).
3. Trace: every scenario's signature lands in the additive v3 ``faults``
   row object, and a recorded chaotic run replays bit-identically with
   NO plan attached.
4. Billing honesty: throttle rejections, OOM escalations, burst retries,
   and hedged/speculative relaunches that die all bill; a truly
   exhausted phase (``fail_open=False``) raises a typed error AFTER
   billing every attempt, and the raise itself record/replays.
5. Detection: a corrupted coded-matvec product is localized by the
   parity checks and decoded EXACTLY; blind decode returns garbage.
6. Degradation: under every registry scenario (and a real retry budget)
   the Newton solve still converges; strict mode propagates the typed
   error instead.
7. Alerting: each scenario fires its expected ``obs.health`` metric
   while a healthy monitored drive stays silent, and the alerts render
   in the ``make_report --trace`` pipeline.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs, scheduler
from repro.core import coded
from repro.core.straggler import SimClock, StragglerModel
from repro.runtime import (FaultPlan, FleetConfig, PhaseExhaustedError,
                           S3Spec, ThrottleSpec, TraceRecorder,
                           available_scenarios, get_scenario, load_trace)
from repro.runtime.faults import BurstSpec, CorruptionSpec, PoolDeathSpec

ALL_SCENARIOS = ("az_burst", "corruption", "oom", "pool_death",
                 "s3_transient", "throttle")


def _drive(faults=None, *, rounds=6, workers=16, policy="wait_all", k=None,
           fleet=None, pool=None, recorder=None, replay=None, telemetry=None,
           memory_gb=None, working_set_gb=None, flops=3e5, key0=100):
    """The fixed chaos test workload: ``rounds`` identical fan-outs."""
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=fleet if fleet is not None
                     else FleetConfig(cold_start_prob=0.1),
                     pool=pool, faults=faults, recorder=recorder,
                     replay=replay, telemetry=telemetry)
    for r in range(rounds):
        clock.phase(jax.random.PRNGKey(key0 + r), workers, policy=policy,
                    k=k, flops_per_worker=flops, comm_units=1.0,
                    memory_gb=memory_gb, working_set_gb=working_set_gb)
    return clock


# --------------------------------------------------------------- registry
def test_registry_lists_every_scenario():
    assert tuple(available_scenarios()) == ALL_SCENARIOS


def test_scenario_knob_overrides():
    plan = get_scenario("az_burst", kill_fraction=0.9, t_end=3.0, seed=4)
    assert plan.burst.kill_fraction == 0.9
    assert plan.burst.t_end == 3.0
    assert plan.seed == 4
    assert plan.active()
    assert not FaultPlan().active()


def test_unknown_scenario_fails_loudly():
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("meteor_strike")


def test_spec_validation():
    with pytest.raises(ValueError):
        BurstSpec(kill_fraction=1.5)
    with pytest.raises(ValueError):
        BurstSpec(t_start=2.0, t_end=1.0)
    with pytest.raises(ValueError):
        ThrottleSpec(max_concurrent=0)
    with pytest.raises(ValueError):
        S3Spec(get_fail_prob=-0.1)
    with pytest.raises(ValueError):
        CorruptionSpec(prob=2.0)
    with pytest.raises(ValueError):
        PoolDeathSpec(fraction=1.5)


# ----------------------------------------------------------- determinism
def test_same_plan_is_bit_deterministic():
    a = _drive(get_scenario("az_burst"))
    b = _drive(get_scenario("az_burst"))
    assert a.time == b.time
    assert a.dollars == b.dollars


def test_plan_seed_changes_the_fault_stream():
    a = _drive(get_scenario("s3_transient", get_fail_prob=0.5))
    b = _drive(get_scenario("s3_transient", get_fail_prob=0.5, seed=1))
    assert a.time != b.time


def test_dormant_plan_leaves_healthy_clock_bit_identical():
    """A plan whose windows never open draws from its own stream only —
    the main lifecycle RNG never sees it, so totals are bit-identical
    to a plan-less run (pre-chaos traces replay unchanged for the same
    reason)."""
    healthy = _drive(None)
    dormant = _drive(FaultPlan(
        burst=BurstSpec(t_start=1e9, kill_fraction=1.0),
        throttle=ThrottleSpec(max_concurrent=1, t_start=1e9),
        s3=S3Spec(get_fail_prob=0.9, put_fail_prob=0.9, t_start=1e9),
        corruption=CorruptionSpec(prob=1.0, t_start=1e9)))
    assert dormant.time == healthy.time
    assert dormant.dollars == healthy.dollars


# ------------------------------------- per-scenario signature + replay
#: scenario -> (drive kwargs for its raw cell, fault-stat keys it must
#: leave in the trace's ``faults`` rows).
_SCENARIO_DRIVES = {
    "az_burst": (dict(), ("burst_kills", "burst_exposed")),
    "throttle": (dict(), ("throttled", "peak_concurrency")),
    "s3_transient": (dict(), ("s3_get_retries", "s3_put_retries")),
    "oom": (dict(memory_gb=0.25, working_set_gb=0.5),
            ("oom_kills", "oom_escalations")),
    "pool_death": (dict(pool=True), ("pool_killed",)),
}


def _scenario_drive(scen, faults, **kw):
    drive_kw, _ = _SCENARIO_DRIVES[scen]
    drive_kw = dict(drive_kw, **kw)
    if drive_kw.pop("pool", False):
        drive_kw["pool"] = scheduler.WarmPool(ttl=300.0, prewarmed=32)
    return _drive(faults, **drive_kw)


@pytest.mark.parametrize("scen", sorted(_SCENARIO_DRIVES))
def test_scenario_leaves_signature_and_replays(scen, tmp_path):
    rec = TraceRecorder(lifecycle=True)
    recorded = _scenario_drive(scen, get_scenario(scen), recorder=rec)
    totals: dict = {}
    for row in rec.rows:
        for key, v in (row.get("faults") or {}).items():
            if isinstance(v, (int, float)):
                totals[key] = totals.get(key, 0) + v
    _, want_keys = _SCENARIO_DRIVES[scen]
    for key in want_keys:
        assert totals.get(key, 0) > 0, \
            f"{scen} left no {key} in the trace: {totals}"
    path = tmp_path / f"{scen}.jsonl"
    rec.dump(path)
    # Replay with NO fault plan: the trace alone carries the chaos.
    replayed = _scenario_drive(scen, None, replay=load_trace(path))
    assert replayed.time == recorded.time
    assert replayed.dollars == recorded.dollars


# ------------------------------------------------------- billing honesty
def test_throttle_bills_rejected_invocations():
    healthy = _drive(None)
    throttled = _drive(FaultPlan(throttle=ThrottleSpec(max_concurrent=4)))
    assert throttled.ledger.invocations > healthy.ledger.invocations
    assert throttled.time > healthy.time


def test_oom_escalation_bills_bigger_lambdas_and_sizing_mitigates():
    plan = get_scenario("oom")
    plain = _drive(None, memory_gb=0.25, working_set_gb=0.5)
    oom = _drive(plan, memory_gb=0.25, working_set_gb=0.5)
    # Killed 90%-wasted attempts plus doubled-memory retries: strictly
    # more gb-seconds and wall time than the same drive without the plan.
    assert oom.ledger.gb_seconds > plain.ledger.gb_seconds
    assert oom.time > plain.time
    # The mitigation is sizing at the declared working set: the plan
    # stays attached but never fires.
    rec = TraceRecorder()
    sized = _drive(plan, memory_gb=0.5, working_set_gb=0.5, recorder=rec)
    assert all(not (r.get("faults") or {}).get("oom_kills")
               for r in rec.rows)
    assert sized.time < oom.time


@pytest.mark.parametrize("policy", ("hedged", "speculative"))
def test_relaunch_policies_bill_their_failures(policy, tmp_path):
    """Satellite: hedged/speculative duplicates are exposed to the same
    faults as first launches — dead duplicates and throttled relaunches
    still bill, and the billed totals record/replay bit-identically."""
    healthy = _drive(None, policy=policy, rounds=4)
    burst = get_scenario("az_burst", kill_fraction=0.8, t_end=30.0)
    burst_run = _drive(burst, policy=policy, rounds=4)
    assert burst_run.ledger.invocations > healthy.ledger.invocations
    assert burst_run.dollars > healthy.dollars
    throttled = _drive(FaultPlan(throttle=ThrottleSpec(max_concurrent=6)),
                       policy=policy, rounds=4)
    assert throttled.ledger.invocations > healthy.ledger.invocations
    rec = TraceRecorder()
    recorded = _drive(burst, policy=policy, rounds=4, recorder=rec)
    path = tmp_path / "relaunch.jsonl"
    rec.dump(path)
    replayed = _drive(None, policy=policy, rounds=4,
                      replay=load_trace(path))
    assert replayed.time == recorded.time
    assert replayed.dollars == recorded.dollars


# ----------------------------------------------------- typed exhaustion
_LETHAL = FaultPlan(burst=BurstSpec(t_start=0.0, kill_fraction=1.0))
_STRICT_FLEET = FleetConfig(fail_open=False, max_retries=1,
                            cold_start_prob=0.0)


def test_exhaustion_raises_typed_error_after_billing(tmp_path):
    rec = TraceRecorder()
    clock = SimClock(StragglerModel(), fleet=_STRICT_FLEET, recorder=rec,
                     faults=_LETHAL)
    with pytest.raises(PhaseExhaustedError) as ei:
        clock.phase(jax.random.PRNGKey(0), 8, policy="wait_all",
                    flops_per_worker=3e5, comm_units=1.0)
    e = ei.value
    assert e.num_workers == 8
    assert int(e.mask.sum()) == 0
    assert e.elapsed > 0.0
    # Every attempt billed (8 workers x 2 attempts), clock advanced to
    # the last observed event — the caller resumes on a consistent line.
    assert clock.ledger.invocations == 16.0
    assert clock.time == pytest.approx(e.elapsed)
    assert clock.dollars > 0.0
    row = rec.rows[-1]
    assert row["raised"]
    assert row["exhausted"] == 8
    # The raise itself replays: same error, same totals, no plan needed.
    path = tmp_path / "exhausted.jsonl"
    rec.dump(path)
    rclock = SimClock(StragglerModel(), replay=load_trace(path))
    with pytest.raises(PhaseExhaustedError) as rei:
        rclock.phase(jax.random.PRNGKey(0), 8, policy="wait_all",
                     flops_per_worker=3e5, comm_units=1.0)
    assert rei.value.elapsed == e.elapsed
    assert np.array_equal(rei.value.mask, e.mask)
    assert rclock.time == clock.time
    assert rclock.dollars == clock.dollars


def test_k_of_n_survives_partial_exhaustion():
    """A partial-wait phase under the same hard budget completes from
    survivors instead of raising — the paper's redundancy thesis applied
    to real (non-fail-open) retry budgets."""
    plan = FaultPlan(burst=BurstSpec(t_start=0.0, kill_fraction=0.5))
    clock = SimClock(StragglerModel(), fleet=_STRICT_FLEET, faults=plan)
    _, mask = clock.phase(jax.random.PRNGKey(1), 8, policy="k_of_n", k=4,
                          flops_per_worker=3e5, comm_units=1.0)
    assert int(np.asarray(mask).sum()) >= 4


def test_fail_open_default_never_raises():
    clock = SimClock(StragglerModel(),
                     fleet=FleetConfig(max_retries=1, cold_start_prob=0.0),
                     faults=_LETHAL)
    _, mask = clock.phase(jax.random.PRNGKey(0), 8, policy="wait_all",
                          flops_per_worker=3e5, comm_units=1.0)
    assert int(np.asarray(mask).sum()) == 8   # final attempts immune


# ------------------------------------------- corruption detect + decode
def _coded_setup(key=3, rows=32, cols=12, block=8):
    k = jax.random.PRNGKey(key)
    a = jax.random.normal(k, (rows, cols))
    v = jax.random.normal(jax.random.fold_in(k, 1), (cols,))
    code = coded.make_code(rows, block)
    prods = coded.coded_block_products(coded.encode_2d(a, code), v)
    return a @ v, prods, code, rows


# make_code(32, 8) -> 4 blocks on a 2x2 systematic grid; row/col index 2
# are the parity lines of the 3x3 worker grid.
@pytest.mark.parametrize("cell", [(1, 1), (2, 1), (1, 2)],
                         ids=["systematic", "col_parity", "row_parity"])
def test_corrupted_cell_detected_and_decoded_exactly(cell):
    exact, prods, code, rows = _coded_setup()
    g1 = code.grid + 1
    known = jnp.ones((g1, g1), bool)
    bad = prods.at[cell[0], cell[1]].add(7.5)
    flagged = coded.detect_corrupted(bad, known, code)
    assert bool(flagged[cell])
    y, ok, n_flagged = coded.verified_decode(bad, known, code, rows)
    assert n_flagged >= 1
    assert bool(ok)
    np.testing.assert_allclose(np.asarray(y), np.asarray(exact),
                               rtol=1e-4, atol=1e-4)


def test_blind_decode_returns_the_corruption():
    exact, prods, code, rows = _coded_setup()
    g1 = code.grid + 1
    known = jnp.ones((g1, g1), bool)
    bad = prods.at[1, 1].add(7.5)   # a systematic cell
    y, ok = coded.decode_matvec(bad, known, code, rows)
    assert bool(ok)
    assert not np.allclose(np.asarray(y), np.asarray(exact),
                           rtol=1e-4, atol=1e-4)


def test_clean_grid_flags_nothing():
    _, prods, code, _ = _coded_setup()
    g1 = code.grid + 1
    known = jnp.ones((g1, g1), bool)
    assert not bool(coded.detect_corrupted(prods, known, code).any())


# ------------------------------------------------ end-to-end degradation
def _newton_solve(faults=None, *, fleet=None, pool=None, telemetry=None,
                  detection=True, fallback="degrade", iters=8):
    from repro.core.newton import NewtonConfig, oversketched_newton
    from repro.core.objectives import Dataset, LogisticRegression
    from repro.core.sketch import OverSketchConfig

    key = jax.random.PRNGKey(0)
    n, d = 256, 8
    x = jax.random.normal(key, (n, d))
    y = jnp.sign(x @ jax.random.normal(jax.random.fold_in(key, 1), (d,)))
    cfg = NewtonConfig(iters=iters,
                       sketch=OverSketchConfig(sketch_dim=64, block_size=16,
                                               straggler_tolerance=0.25),
                       coded_block_rows=32, corruption_detection=detection,
                       fault_fallback=fallback)
    clock = SimClock(StragglerModel(), fleet=fleet, pool=pool, faults=faults,
                     telemetry=telemetry)
    res = oversketched_newton(LogisticRegression(lam=1e-3),
                              Dataset(x=x, y=y), jnp.zeros((d,)), cfg, clock)
    return float(res.history["gnorm"][-1]), clock


@pytest.mark.parametrize("scen", ALL_SCENARIOS)
def test_newton_converges_under_every_scenario(scen):
    """Graceful degradation, end to end: each registry scenario under a
    REAL retry budget still reaches a converged solve (the corruption
    scenario additionally needs the parity-check detection on, which is
    the default)."""
    gn, clock = _newton_solve(
        get_scenario(scen),
        fleet=FleetConfig(cold_start_prob=0.1, fail_open=False,
                          max_retries=2),
        pool=scheduler.WarmPool(ttl=300.0, prewarmed=32))
    assert np.isfinite(gn)
    assert gn < 1e-2
    assert np.isfinite(clock.time) and np.isfinite(clock.dollars)


def test_corruption_detection_recovers_what_blind_decode_loses():
    plan = get_scenario("corruption", prob=0.3)
    gn_healthy, _ = _newton_solve(None)
    gn_blind, _ = _newton_solve(plan, detection=False)
    gn_detected, _ = _newton_solve(plan, detection=True)
    assert gn_healthy < 1e-3
    assert gn_detected < 1e-3
    assert gn_blind > 10.0 * gn_detected


def test_strict_mode_propagates_exhaustion():
    with pytest.raises(PhaseExhaustedError):
        _newton_solve(_LETHAL, fleet=_STRICT_FLEET, fallback="raise",
                      iters=2)


def test_degrade_mode_survives_what_strict_mode_raises_on():
    gn, clock = _newton_solve(
        FaultPlan(burst=BurstSpec(t_start=0.5, t_end=2.0,
                                  kill_fraction=0.9)),
        fleet=FleetConfig(fail_open=False, max_retries=1), iters=6)
    assert np.isfinite(gn)
    assert np.isfinite(clock.time) and clock.dollars > 0.0


# ------------------------------------------------------- health alerting
def _monitored_drive(faults=None, *, rounds=14, pool=None,
                     schedule=None):
    """The alert-test workload: enough healthy rounds to freeze every
    detector baseline before any fault window opens."""
    tel = obs.Telemetry(monitors=True)
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=FleetConfig(cold_start_prob=0.2),
                     pool=pool, faults=faults, telemetry=tel)
    for r in range(rounds):
        mem, ws = (schedule(r) if schedule is not None else (None, None))
        clock.phase(jax.random.PRNGKey(600 + r), 24, policy="wait_all",
                    flops_per_worker=3e5, comm_units=1.0,
                    memory_gb=mem, working_set_gb=ws)
    return tel, clock


def _healthy_midpoint(rounds=7, pool=False):
    p = scheduler.WarmPool(ttl=300.0, prewarmed=48) if pool else None
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=FleetConfig(cold_start_prob=0.2), pool=p)
    for r in range(rounds):
        clock.phase(jax.random.PRNGKey(600 + r), 24, policy="wait_all",
                    flops_per_worker=3e5, comm_units=1.0)
    return clock.time


def test_healthy_monitored_drive_stays_silent():
    tel, _ = _monitored_drive(None)
    assert tel.health.alerts == []
    tel, _ = _monitored_drive(
        None, pool=scheduler.WarmPool(ttl=300.0, prewarmed=48))
    assert tel.health.alerts == []


def _fleet_alert_plan(scen, t_mid):
    """The scenario windowed to open only after the detector baselines
    froze on healthy samples."""
    if scen == "az_burst":
        return FaultPlan(burst=BurstSpec(t_start=t_mid,
                                         kill_fraction=0.9))
    if scen == "throttle":
        return FaultPlan(throttle=ThrottleSpec(max_concurrent=4,
                                               t_start=t_mid))
    if scen == "s3_transient":
        return FaultPlan(s3=S3Spec(get_fail_prob=0.7, put_fail_prob=0.3,
                                   retry_delay=0.2, t_start=t_mid))
    raise KeyError(scen)


@pytest.mark.parametrize("scen", ("az_burst", "throttle", "s3_transient"))
def test_scenario_fires_straggler_alerts(scen):
    """Bursts, throttling, and S3 retry chains all fatten the completion
    stream mid-run — the straggler detectors must notice."""
    plan = _fleet_alert_plan(scen, _healthy_midpoint())
    tel, _ = _monitored_drive(plan)
    metrics = {a.metric for a in tel.health.alerts}
    assert metrics & {"worker.completion_s", "phase.tail_p95_s"}, \
        f"{scen} fired no straggler alert (got {metrics})"


def test_oom_fires_straggler_alerts():
    """Right-sized early rounds freeze the baseline; undersized later
    rounds OOM at 90% of the run and retry escalated — roughly doubled
    completions, a textbook drift."""
    tel, _ = _monitored_drive(
        get_scenario("oom"),
        schedule=lambda r: ((1.0, 0.5) if r < 8 else (0.25, 0.5)))
    metrics = {a.metric for a in tel.health.alerts}
    assert metrics & {"worker.completion_s", "phase.tail_p95_s"}, \
        f"oom fired no straggler alert (got {metrics})"


def test_pool_death_fires_hit_rate_alert():
    plan = FaultPlan(pool_death=PoolDeathSpec(
        t=_healthy_midpoint(pool=True), fraction=1.0))
    tel, _ = _monitored_drive(
        plan, pool=scheduler.WarmPool(ttl=300.0, prewarmed=48))
    metrics = {a.metric for a in tel.health.alerts}
    assert "pool.phase_hit_rate" in metrics, \
        f"pool death fired no hit-rate alert (got {metrics})"


def test_corruption_fires_block_error_rate_alert():
    """The coded engine publishes a per-phase block error rate whenever a
    CorruptionSpec is attached (0.0 on clean phases) — a mid-solve
    corruption window must drift the CUSUM off that exact baseline."""
    _, healthy_clock = _newton_solve(None)
    t_mid = 0.5 * healthy_clock.time
    tel = obs.Telemetry(monitors=True)
    _newton_solve(FaultPlan(corruption=CorruptionSpec(prob=0.5,
                                                      t_start=t_mid)),
                  telemetry=tel)
    metrics = {a.metric for a in tel.health.alerts}
    assert "coded.block_error_rate" in metrics, \
        f"corruption fired no block-error alert (got {metrics})"
    # And the healthy solve's stream holds the zero baseline silently.
    tel_h = obs.Telemetry(monitors=True)
    _newton_solve(None, telemetry=tel_h)
    assert not any(a.metric == "coded.block_error_rate"
                   for a in tel_h.health.alerts)


def test_alerts_render_in_trace_report(tmp_path):
    """The chaos alerts survive the export pipeline: JSONL dump ->
    ``make_report --trace`` tables (what CI renders per push)."""
    from benchmarks.make_report import trace_report
    plan = _fleet_alert_plan("az_burst", _healthy_midpoint())
    tel, _ = _monitored_drive(plan)
    assert tel.health.alerts
    path = tmp_path / "chaos_run.jsonl"
    obs.dump_jsonl(tel, path)
    rows = obs.load_jsonl(path)
    assert obs.alerts_from_rows(rows)
    report = trace_report(rows)
    assert "Health monitors" in report
    assert any(a.metric in report for a in tel.health.alerts)
