"""Sharding policy unit tests (1-device mesh: spec resolution logic only)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import resolve_pspec, _zero1_spec
from jax.sharding import NamedSharding


def _mesh(shape, axes):
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


# The policy logic is mesh-shape arithmetic; fake a 16x16 mesh via the
# abstract mesh API is overkill — use a real 1-device mesh reshaped.
def test_resolve_divisibility():
    mesh = _mesh((1,), ("model",))
    # dim not divisible by axis (1 divides everything) => sharded
    spec = resolve_pspec((64, 128), ("embed", "ffn"), mesh)
    assert spec == P(None, "model")


def test_resolve_no_duplicate_axes():
    mesh = _mesh((1,), ("model",))
    spec = resolve_pspec((64, 64), ("rnn", "rnn"), mesh)
    # "model" may appear only once
    flat = [e for e in spec if e is not None]
    assert flat.count("model") <= 1


def test_resolve_expert_ffn_uses_data():
    mesh = _mesh((1, 1), ("data", "model"))
    spec = resolve_pspec((128, 64, 96), ("experts", "embed", "expert_ffn"),
                         mesh)
    assert spec == P("model", None, "data")


def test_zero1_adds_data_axis():
    mesh = _mesh((1, 1), ("data", "model"))
    base = NamedSharding(mesh, P(None, None, "model"))
    out = _zero1_spec(base, (36, 2560, 9728))
    # first free dim divisible by the data size (1 here) gets "data"
    assert out.spec == P("data", None, "model")


def test_zero1_skips_when_data_used():
    mesh = _mesh((1, 1), ("data", "model"))
    base = NamedSharding(mesh, P("model", None, "data"))
    out = _zero1_spec(base, (128, 64, 96))
    assert out.spec == base.spec


def test_param_shardings_cover_tree():
    from repro.distributed import param_shardings
    from repro.models.registry import get_bundle
    mesh = _mesh((1, 1), ("data", "model"))
    b = get_bundle("qwen3-32b")
    ps = param_shardings(b, mesh)
    specs = b.specs()
    assert jax.tree.structure(ps, is_leaf=lambda x: isinstance(
        x, NamedSharding)) == jax.tree.structure(
            specs, is_leaf=lambda x: hasattr(x, "axes"))
