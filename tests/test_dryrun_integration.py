"""Dry-run machinery on a reduced mesh (8 placeholder devices, subprocess so
the main process never sets the device-count flag).  Exercises the same
lower+compile+analyze path as the production 16x16 / 2x16x16 runs."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    return out.stdout


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-4b", "train_4k"),
    ("qwen3-moe-30b-a3b", "decode_32k"),
    ("mamba2-780m", "long_500k"),
    ("recurrentgemma-2b", "prefill_32k"),
])
def test_reduced_mesh_cell(arch, shape):
    """lower+compile succeeds on a (4,2) mesh with reduced model dims; the
    analyzer returns all roofline fields."""
    out = _run_sub(f"""
        import jax
        from repro.launch import dryrun
        from repro.launch.mesh import make_mesh
        from repro.models.registry import _REGISTRY
        import repro.configs
        from repro.configs import smoke_config
        # swap in the smoke config under the same name (full dims would
        # compile too, but slowly at 8 devices); capture it BEFORE replacing
        # the registry entry
        cfg = smoke_config("{arch}").scaled(
            max_seq=40_000 if "{shape}" != "long_500k" else 600_000)
        _REGISTRY["{arch}"] = lambda cfg=cfg: cfg
        mesh = make_mesh((4, 2), ("data", "model"))
        lowered, info = dryrun.lower_cell("{arch}", "{shape}", mesh=mesh)
        assert lowered is not None, info
        info = dryrun.analyze(lowered, info)
        for k in ("hlo_flops_per_chip", "collective_bytes_per_chip",
                  "roofline_seconds", "bottleneck", "memory"):
            assert k in info, k
        assert info["memory"]["temp_bytes"] >= 0
        print("CELL_OK", info["bottleneck"])
    """)
    assert "CELL_OK" in out


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
    %all-reduce.1 = f32[16,256] all-reduce(%x), replica_groups=[2,4]<=[8]
    %all-gather.2 = bf16[8,128] all-gather(%y), dimensions={1}
    %add.3 = f32[4] add(%a, %b)
    %reduce-scatter.9 = f32[2,2] reduce-scatter(%z)
    """
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 256 * 4
    assert out["all-gather"] == 8 * 128 * 2
    assert out["reduce-scatter"] == 16
    assert "add" not in out


def test_skip_rules():
    from repro.launch.dryrun import lower_cell
    lowered, info = lower_cell("qwen3-32b", "long_500k")
    assert lowered is None
    assert "skipped" in info
    lowered, info2 = lower_cell("qwen2-7b", "long_500k")
    assert lowered is None


def test_active_params_moe():
    from repro.launch.dryrun import active_param_count
    from repro.models.registry import get_bundle
    b = get_bundle("qwen3-moe-235b-a22b")
    total = b.param_count()
    active = active_param_count(b)
    assert total > 200e9
    assert 15e9 < active < 30e9      # ~22B active


def test_production_mesh_shapes():
    """make_production_mesh is importable without touching devices; shape
    contract per the spec."""
    import repro.launch.mesh as m
    import inspect
    src = inspect.getsource(m)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src
