"""Telemetry subsystem tests: tracer, metrics, critical path, Perfetto.

The load-bearing contracts:

1. The no-op default (``obs.NULL``) and a live ``obs.Telemetry`` are
   interchangeable: attaching a recorder to any simulated run changes no
   ``(seconds, dollars)`` total and no iterate (telemetry draws no
   randomness and never moves the clock).
2. The critical-path analysis matches hand-computed CPM values and the
   binding chain of a real dispatched DAG.
3. The Perfetto export is byte-stable: a committed golden file built from
   a synthetic span set (no RNG, no jax sampling — deterministic under
   any jax version) must match ``dumps_stable`` forever.

Regenerate the golden export (only after an INTENTIONAL format change):

    PYTHONPATH=src python tests/test_obs.py --regen
"""
import math
import pathlib

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.core.straggler import SimClock, StragglerModel
from repro.runtime import FleetConfig
from repro.scheduler import PhaseSpec, WarmPool, run_dag

PERFETTO_GOLDEN = pathlib.Path(__file__).parent / "fixtures" / \
    "perfetto_golden.json"


# ------------------------------------------------------------------ tracer
def test_tracer_hierarchy_and_rows():
    tr = obs.SpanTracer()
    run = tr.begin("newton", "run", 0.0, schedule="dag")
    it = tr.begin("iter0", "iteration", 0.0)
    ph = tr.emit("grad", "phase", 0.0, 0.5, policy="wait_all")
    att = tr.emit("run", "attempt", 0.0, 0.4, track="grad/w0")
    tr.end(it, 0.5)
    after = tr.emit("post", "charge", 0.5, 0.625)
    tr.end(run, 0.625)

    spans = {s.span_id: s for s in tr.spans}
    assert spans[ph].parent_id == it
    assert spans[att].parent_id == it
    assert spans[after].parent_id == run      # iteration already closed
    assert spans[run].parent_id == 0
    assert spans[it].end == 0.5 and spans[run].end == 0.625
    assert [s.name for s in tr.children(it)] == ["grad", "run"]
    assert [s.name for s in tr.by_kind("phase")] == ["grad"]
    row = spans[att].as_row()
    assert row["kind"] == "span" and row["track"] == "grad/w0"
    assert spans[ph].duration == 0.5


def test_tracer_out_of_order_end_unwinds():
    tr = obs.SpanTracer()
    a = tr.begin("a", "run", 0.0)
    b = tr.begin("b", "iteration", 0.0)
    tr.end(a, 1.0)                 # closes b too
    spans = {s.span_id: s for s in tr.spans}
    assert spans[b].end == 1.0 and spans[a].end == 1.0
    assert tr.current == 0
    with pytest.raises(KeyError):
        tr.end(999, 1.0)


def test_tracer_set_attrs_and_open_end_is_nan():
    tr = obs.SpanTracer()
    sid = tr.begin("r", "run", 0.0)
    assert math.isnan(tr.spans[0].end)
    tr.set_attrs(sid, makespan=2.0)
    assert tr.spans[0].attrs["makespan"] == 2.0


def test_null_telemetry_is_inert():
    tel = obs.NULL
    assert not tel.enabled
    assert tel.trace.begin("x", "run", 0.0) == 0
    assert tel.trace.emit("x", "phase", 0.0, 1.0) == 0
    tel.trace.end(0, 1.0)
    tel.trace.set_attrs(0, a=1)
    assert tel.trace.spans == [] and tel.trace.by_kind("phase") == []
    c = tel.metrics.counter("n")
    c.inc()
    g = tel.metrics.gauge("g")
    g.set(3.0)
    tel.metrics.histogram("h").observe(1.0)
    assert tel.metrics.snapshot() == \
        {"counters": {}, "gauges": {}, "histograms": {}}


# ----------------------------------------------------------------- metrics
def test_metrics_registry():
    reg = obs.MetricsRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(2.5)
    reg.gauge("b").set(1.0)
    reg.gauge("b").set(4.0)
    h = reg.histogram("c")
    for v in (5.0, 1.0, 3.0, 2.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["a"] == 3.5
    assert snap["gauges"]["b"] == {"n": 2, "value": 4.0}
    assert reg.gauge("b").series == [1.0, 4.0]
    assert h.count == 5 and h.total == 15.0
    assert h.percentile(50) == 3.0
    assert h.percentile(100) == 5.0
    assert snap["histograms"]["c"]["p50"] == 3.0
    assert snap["histograms"]["c"]["max"] == 5.0


# ----------------------------------------------------------- critical path
def test_critical_path_hand_computed():
    # A and B are roots; C joins both (B binds: finish 3 == C's start);
    # D hangs off A with room to slip.  Makespan 5.
    rep = obs.critical_path({
        "A": (0.0, 2.0, ()),
        "B": (0.0, 3.0, ()),
        "C": (3.0, 5.0, ("A", "B")),
        "D": (2.0, 4.0, ("A",)),
    })
    assert rep.makespan == 5.0
    assert rep.critical_path == ("B", "C")
    assert rep.critical_seconds == 5.0
    slacks = {n: p.slack for n, p in rep.phases.items()}
    assert slacks == {"A": 1.0, "B": 0.0, "C": 0.0, "D": 1.0}
    assert rep.phases["B"].on_critical_path
    assert not rep.phases["D"].on_critical_path
    rows = rep.rows()
    assert [r["phase"] for r in rows[:2]] == ["B", "C"]   # chain first


def test_critical_path_validates():
    with pytest.raises(ValueError):
        obs.critical_path({})
    with pytest.raises(ValueError):
        obs.critical_path({"a": (0.0, 1.0, ("ghost",))})
    with pytest.raises(ValueError):
        obs.critical_path({"a": (2.0, 1.0, ())})


def test_critical_path_from_real_dag():
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0))
    res = run_dag(clock, jax.random.PRNGKey(7), [
        PhaseSpec("gx", 8, policy="wait_all", flops_per_worker=2e5),
        PhaseSpec("gxt", 8, policy="wait_all", flops_per_worker=2e5,
                  deps=("gx",)),
        PhaseSpec("hess", 8, policy="wait_all", flops_per_worker=6e5),
        PhaseSpec("ls", 8, policy="wait_all", flops_per_worker=1e5,
                  deps=("gxt", "hess")),
    ])
    rep = res.critical_path()
    assert rep.critical_path[-1] == "ls"
    assert rep.makespan == res.makespan
    # Every phase is either on the chain (slack 0) or strictly off it.
    for name, p in rep.phases.items():
        assert (p.slack == 0.0) == p.on_critical_path or p.slack == 0.0
    # The chain is connected: each member's start is its predecessor's
    # finish, and the last member finishes at the makespan.
    for a, b in zip(rep.critical_path, rep.critical_path[1:]):
        assert rep.phases[b].start == rep.phases[a].finish
    assert rep.phases[rep.critical_path[-1]].finish - rep.start \
        == rep.makespan


# ---------------------------------------------------------------- perfetto
def _synthetic_spans():
    """A deterministic span tree (no RNG, exact binary floats) shaped like
    one DAG-scheduled Newton iteration — the golden export's source."""
    tr = obs.SpanTracer()
    run = tr.begin("newton", "run", 0.0, schedule="dag")
    it = tr.begin("iter0", "iteration", 0.0)
    tr.emit("grad/0:X", "phase", 0.0, 0.25, policy="k_of_n", workers=2,
            deps=[], dollars=0.000125, gb_seconds=1.5)
    tr.emit("hessian", "phase", 0.0, 0.1875, policy="k_of_n", workers=2,
            deps=[], dollars=0.00025, gb_seconds=3.0)
    tr.emit("grad/1:XT", "phase", 0.25, 0.5, policy="k_of_n", workers=2,
            deps=["grad/0:X"], dollars=0.000125, gb_seconds=1.5)
    tr.emit("linesearch", "phase", 0.5, 0.625, policy="wait_all", workers=2,
            deps=["grad/1:XT", "hessian"], dollars=0.0000625,
            gb_seconds=0.75)
    tr.emit("cold", "attempt", 0.0, 0.0625, track="grad/0:X/w0")
    tr.emit("run", "attempt", 0.0625, 0.25, track="grad/0:X/w0", attempt=0)
    tr.emit("run", "attempt", 0.0, 0.125, track="grad/0:X/w1", attempt=0)
    tr.emit("failed", "attempt", 0.0, 0.0625, track="hessian/w0", attempt=0)
    tr.emit("retry", "attempt", 0.0625, 0.1875, track="hessian/w0",
            attempt=1)
    tr.end(it, 0.625)
    tr.end(run, 0.625)
    return tr.spans


def test_perfetto_layout():
    trace = obs.to_perfetto(_synthetic_spans())
    evs = trace["traceEvents"]
    slices = [e for e in evs if e["ph"] == "X"]
    metas = [e for e in evs if e["ph"] == "M"]
    # run + iteration nest on the master tid.
    master = [e for e in slices if e["pid"] == obs.perfetto.MASTER_PID
              and e["tid"] == obs.perfetto.MASTER_TID]
    assert {e["name"] for e in master} == {"newton", "iter0"}
    # Overlapping phases land on distinct lanes; the serialized chain
    # member reuses lane 0.
    by_name = {e["name"]: e for e in slices if e["cat"] == "phase"}
    assert by_name["grad/0:X"]["tid"] != by_name["hessian"]["tid"]
    assert by_name["grad/1:XT"]["tid"] == by_name["grad/0:X"]["tid"]
    # One worker tid per track label, under the workers pid.
    wslices = [e for e in slices if e["pid"] == obs.perfetto.WORKERS_PID]
    tids = {}
    for e in wslices:
        tids.setdefault(e["tid"], []).append(e["name"])
    assert len(tids) == 3
    assert sorted(tids[1]) == ["cold", "run"]         # grad/0:X/w0
    track_names = {m["args"]["name"] for m in metas
                   if m["pid"] == obs.perfetto.WORKERS_PID
                   and m["name"] == "thread_name"}
    assert track_names == {"grad/0:X/w0", "grad/0:X/w1", "hessian/w0"}
    # Timestamps are simulated microseconds.
    assert by_name["linesearch"]["ts"] == 0.5e6
    assert by_name["linesearch"]["dur"] == 0.125e6
    obs.validate_trace(trace, require_phases=("hessian", "linesearch"))


def test_perfetto_golden_bytes():
    got = obs.dumps_stable(obs.to_perfetto(_synthetic_spans()))
    assert PERFETTO_GOLDEN.exists(), \
        "run: PYTHONPATH=src python tests/test_obs.py --regen"
    assert got == PERFETTO_GOLDEN.read_text()
    # And the committed bytes are themselves a valid trace.
    obs.validate_file(PERFETTO_GOLDEN,
                      require_phases=("grad/0:X", "hessian", "linesearch"))


def test_validate_trace_rejects():
    with pytest.raises(ValueError):
        obs.validate_trace({"traceEvents": []})
    ok = obs.to_perfetto(_synthetic_spans())
    with pytest.raises(ValueError, match="ghost"):
        obs.validate_trace(ok, require_phases=("ghost",))
    bad = {"traceEvents": [{"name": "x", "cat": "phase", "ph": "X",
                            "ts": 0.0, "dur": -1.0, "pid": 1, "tid": 1}]}
    with pytest.raises(ValueError, match="negative dur"):
        obs.validate_trace(bad, require_worker_tracks=False)
    with pytest.raises(ValueError, match="pid 2 is empty"):
        obs.validate_trace({"traceEvents": [
            {"name": "x", "cat": "phase", "ph": "X", "ts": 0.0, "dur": 1.0,
             "pid": 1, "tid": 1}]})


# ------------------------------------------------------------------ export
def test_jsonl_round_trip_and_tables(tmp_path):
    tel = obs.Telemetry()
    for s in _synthetic_spans():
        tel.trace.spans.append(s)
    tel.metrics.counter("fleet.phases").inc(4)
    path = tmp_path / "run.jsonl"
    obs.dump_jsonl(tel, path)
    rows = obs.load_jsonl(path)
    assert rows[-1]["kind"] == "metrics"
    assert rows[-1]["counters"]["fleet.phases"] == 4.0
    assert sum(r.get("span_kind") == "phase" for r in rows) == 4

    summary = obs.phase_summary_rows(rows)
    by_phase = {r["phase"]: r for r in summary}
    assert by_phase["grad/0:X"]["seconds"] == 0.25
    assert by_phase["hessian"]["dollars"] == 0.00025
    table = obs.phase_table(rows)
    assert "TOTAL" in table and "linesearch" in table

    reports = obs.dag_reports_from_rows(rows)
    assert len(reports) == 1
    assert reports[0].critical_path == ("grad/0:X", "grad/1:XT",
                                        "linesearch")
    assert reports[0].phases["hessian"].slack == 0.3125
    cp_table = obs.critical_path_table(reports[0])
    assert "critical path: grad/0:X -> grad/1:XT -> linesearch" in cp_table


def test_bench_rows_table_shared_formatter():
    from benchmarks.common import json_row
    rows = [json_row("a", 12.5, sim_s=1.25, usd=0.5),
            json_row("b", 7.5, sim_s=0.5, warm=3)]
    table = obs.bench_rows_table(rows)
    lines = table.splitlines()
    assert [c.strip() for c in lines[0].split("|")[1:6]] == \
        ["name", "us_per_call", "sim_s", "usd", "warm"]
    assert "12.5" in table and "0.5" in table


# ----------------------------------------------- attach points / inertness
def _fleet_drive(telemetry=None):
    clock = SimClock(StragglerModel(p_tail=0.1, tail_hi=3.0),
                     fleet=FleetConfig(failure_rate=0.2,
                                       cold_start_prob=0.3),
                     pool=WarmPool(ttl=5.0, prewarmed=2),
                     telemetry=telemetry)
    for r in range(3):
        clock.phase(jax.random.PRNGKey(r), 6, policy="k_of_n", k=4,
                    flops_per_worker=2e5, comm_units=1.0,
                    phase_name=f"p{r}")
    clock.charge(0.125, phase_name="decode")
    return clock


def test_fleet_telemetry_is_observation_only():
    plain = _fleet_drive()
    tel = obs.Telemetry()
    live = _fleet_drive(tel)
    assert live.time == plain.time
    assert live.dollars == plain.dollars

    phases = tel.trace.by_kind("phase")
    assert [s.name for s in phases] == ["p0", "p1", "p2"]
    assert all(s.end == pytest.approx(s.start + s.duration) for s in phases)
    assert tel.trace.by_kind("charge")[0].name == "decode"
    attempts = tel.trace.by_kind("attempt")
    assert attempts and all(a.track for a in attempts)
    # Worker slices sit inside their phase's interval.
    for a in attempts:
        ph = next(p for p in phases if a.track.startswith(p.name + "/"))
        assert ph.start <= a.start <= a.end

    snap = tel.metrics.snapshot()
    assert snap["counters"]["fleet.phases"] == 3.0
    assert snap["counters"]["fleet.attempts"] >= 18.0
    assert snap["counters"]["fleet.cold_starts"] \
        + snap["counters"]["fleet.warm_hits"] > 0
    assert snap["histograms"]["phase.elapsed_s"]["count"] == 3
    assert snap["gauges"]["pool.warm_hits_total"]["value"] \
        == snap["counters"]["fleet.warm_hits"]


def test_pool_snapshot():
    pool = WarmPool(ttl=10.0, prewarmed=3)
    assert pool.snapshot(0.0) == {"warm_hits": 0, "cold_starts": 0,
                                  "killed": 0, "free": 3, "containers": 3}
    pool.acquire(1.0)
    snap = pool.snapshot(1.0)
    assert snap["warm_hits"] == 1 and snap["free"] == 2


def _tiny_newton(telemetry=None, schedule="dag"):
    from repro.core import newton, sketch
    from repro.core.objectives import Dataset, LogisticRegression
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (128, 8))
    y = jnp.sign(x @ jax.random.normal(jax.random.PRNGKey(1), (8,)))
    cfg = newton.NewtonConfig(
        iters=2, schedule=schedule,
        sketch=sketch.OverSketchConfig(sketch_dim=64, block_size=16,
                                       straggler_tolerance=0.25))
    model = StragglerModel(p_tail=0.05, tail_hi=3.0)
    clock = SimClock(model, telemetry=telemetry) \
        if telemetry is not None else model
    return newton.oversketched_newton(
        LogisticRegression(), Dataset(x=x, y=y), jnp.zeros(8), cfg,
        model=clock)


def test_newton_telemetry_is_observation_only():
    plain = _tiny_newton()
    tel = obs.Telemetry()
    live = _tiny_newton(tel)
    assert live.history["time"] == plain.history["time"]
    assert live.history["cost"] == plain.history["cost"]
    assert live.history["fval"] == plain.history["fval"]

    runs = tel.trace.by_kind("run")
    assert len(runs) == 1 and runs[0].name == "newton"
    iters = tel.trace.by_kind("iteration")
    assert len(iters) == 2
    # Every iteration carries the DAG critical-path decomposition, and
    # the recorded chain reaches the joining line search.
    for s in iters:
        assert s.attrs["critical_path"][-1] == "linesearch"
        assert s.attrs["dag_makespan"] > 0
        assert set(s.attrs["slack"]) >= {"hessian", "linesearch"}
    snap = tel.metrics.snapshot()
    kernel_paths = [k for k in snap["counters"] if k.startswith("kernel.path.")]
    assert kernel_paths, "hessian phase must log the kernel path taken"
    assert sum(snap["counters"][k] for k in kernel_paths) == 2.0
    assert snap["gauges"]["sketch.m_eff"]["value"] > 0
    assert 0.0 <= snap["gauges"]["sketch.mp_debias"]["value"] < 1.0

    trace = obs.to_perfetto(tel.trace.spans)
    obs.validate_trace(trace, require_phases=("hessian", "linesearch"))


def test_giant_telemetry_is_observation_only():
    from repro.core.objectives import Dataset, LogisticRegression
    from repro.optim.giant import GiantConfig, giant
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (96, 6))
    y = jnp.sign(x @ jax.random.normal(jax.random.PRNGKey(4), (6,)))
    data = Dataset(x=x, y=y)
    cfg = GiantConfig(iters=2, num_workers=8)

    def go(telemetry=None):
        model = StragglerModel(p_tail=0.05, tail_hi=3.0)
        clock = SimClock(model, telemetry=telemetry) \
            if telemetry is not None else model
        return giant(LogisticRegression(), data, jnp.zeros(6), cfg,
                     model=clock)

    plain = go()
    tel = obs.Telemetry()
    live = go(tel)
    assert live["time"] == plain["time"]
    assert live["cost"] == plain["cost"]
    assert tel.trace.by_kind("run")[0].name == "giant"
    assert len(tel.trace.by_kind("iteration")) == 2
    names = {s.name for s in tel.trace.by_kind("phase")}
    assert {"grad", "local-newton"} <= names


# ------------------------------------------------------- kernel profiling
def test_ops_profiler_hook():
    from repro.kernels import ops
    x = jnp.ones((1, 8, 4), jnp.float32)
    assert ops.get_profiler() is None
    baseline = ops.fwht(x)                      # unprofiled path
    reg = obs.MetricsRegistry()
    ops.set_profiler(reg)
    try:
        profiled = ops.fwht(x)
        snap = reg.snapshot()
        assert snap["counters"]["kernel.fwht.calls"] == 1.0
        assert snap["histograms"]["kernel.fwht.us"]["count"] == 1
        assert snap["histograms"]["kernel.fwht.us"]["max"] > 0
    finally:
        ops.set_profiler(None)
    assert ops.get_profiler() is None
    assert jnp.array_equal(baseline, profiled)


def _regen():
    PERFETTO_GOLDEN.parent.mkdir(parents=True, exist_ok=True)
    PERFETTO_GOLDEN.write_text(
        obs.dumps_stable(obs.to_perfetto(_synthetic_spans())))
    print(f"wrote {PERFETTO_GOLDEN}")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        sys.exit("usage: python tests/test_obs.py --regen")
