"""Substrate coverage: MoE routing invariants (hypothesis), data pipeline
determinism, resilient-psum semantics, batched server, analytic model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import smoke_config
from repro.models import moe
from repro.models.registry import SHAPES, ModelBundle, get_config


# ------------------------------------------------------------------- MoE ----
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 500), seq=st.integers(8, 40))
def test_moe_routing_properties(seed, seq):
    """Gates renormalize to 1; output is finite; capacity bounds respected
    (dropping tokens must not produce NaNs or blowups)."""
    cfg = smoke_config("qwen3-moe-30b-a3b").scaled(moe_capacity_factor=1.0)
    key = jax.random.PRNGKey(seed)
    from repro.models.common import materialize
    p = materialize(moe.moe_specs(cfg), key, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, seq, cfg.d_model))
    y, aux = moe.moe_ffn(cfg, p, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0


def test_moe_aux_loss_detects_imbalance():
    """A router biased hard to one expert must score a larger aux loss than
    a random (roughly balanced) router."""
    cfg = smoke_config("qwen3-moe-30b-a3b").scaled(moe_capacity_factor=8.0)
    from repro.models.common import materialize
    p = materialize(moe.moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    # positive activations so a positive column weight => always-top logit
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1),
                                  (2, 64, cfg.d_model)))
    _, aux_rand = moe.moe_ffn(cfg, p, x)
    p_bad = dict(p)
    bias = jnp.zeros_like(p["router"]).at[:, 0].set(50.0)
    p_bad["router"] = bias                      # everything -> expert 0
    _, aux_bad = moe.moe_ffn(cfg, p_bad, x)
    assert float(aux_bad) > 2.0 * float(aux_rand)


# --------------------------------------------------------------- pipeline ----
def test_pipeline_determinism_and_shapes():
    from repro.data.pipeline import TokenPipeline
    p = TokenPipeline(vocab_size=100, batch=4, seq=16, seed=7)
    b1, b2 = p.batch_at(12), p.batch_at(12)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert (b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all()
    assert (b1["labels"][:, -1] == -1).all()
    assert b1["tokens"].max() < 100
    b3 = p.batch_at(13)
    assert not (b1["tokens"] == b3["tokens"]).all()


def test_pipeline_prefetch_thread():
    from repro.data.pipeline import TokenPipeline
    p = TokenPipeline(vocab_size=50, batch=2, seq=8, seed=1)
    p.start(first_step=5)
    step, batch = p.next()
    assert step == 5
    np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                  p.batch_at(5)["tokens"])
    p.stop()


# ------------------------------------------------------------ collectives ----
def test_resilient_psum_semantics():
    """Mean over live shards only (the k-of-n reduction)."""
    from repro.distributed import resilient_psum
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    def run(live_val):
        def local(x, live):
            return resilient_psum({"v": x}, live[0], "data")["v"]
        from jax.sharding import PartitionSpec as P
        return jax.shard_map(local, mesh=mesh,
                             in_specs=(P("data"), P("data")),
                             out_specs=P("data"),
                             check_vma=False)(
            jnp.asarray([[3.0]]), jnp.asarray([live_val]))

    np.testing.assert_allclose(np.asarray(run(1.0)), [[3.0]])
    # dead shard: contribution zeroed, denominator floor of 1
    np.testing.assert_allclose(np.asarray(run(0.0)), [[0.0]])


# ---------------------------------------------------------------- serving ----
def test_batched_server_waves_and_eos():
    from repro.launch.serve import BatchedServer
    cfg = smoke_config("qwen3-4b")
    bundle = ModelBundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rs = np.random.RandomState(0)
    prompts = [rs.randint(3, cfg.vocab_size - 1, rs.randint(4, 10))
               for _ in range(5)]
    server = BatchedServer(bundle, params, batch=2, max_seq=64)
    outs = server.generate(prompts, max_new=6)
    assert len(outs) == 5
    for o in outs:
        assert 1 <= len(o) <= 6
        for t in o:
            assert 0 <= t < cfg.vocab_size


# ---------------------------------------------------------------- analytic ----
@pytest.mark.parametrize("arch", ["qwen3-32b", "qwen3-moe-235b-a22b",
                                  "mamba2-780m", "recurrentgemma-2b",
                                  "whisper-large-v3"])
def test_analytic_costs_positive_and_scaled(arch):
    from repro.launch import analytic
    cfg = get_config(arch)
    bundle = ModelBundle(cfg)
    for shape_name in ("train_4k", "decode_32k"):
        shape = SHAPES[shape_name]
        if not bundle.supports(shape)[0]:
            continue
        c = analytic.cell_costs(cfg, shape, 256)
        assert c.flops_per_chip > 0
        assert c.hbm_bytes_per_chip > 0
        # train is vastly more compute-heavy than one decode step
    train = analytic.cell_costs(cfg, SHAPES["train_4k"], 256)
    dec = analytic.cell_costs(cfg, SHAPES["decode_32k"], 256)
    assert train.flops_per_chip > 100 * dec.flops_per_chip


def test_analytic_moe_cheaper_than_dense_equivalent():
    """Active-params accounting: the 235B MoE trains with ~22B-active flops,
    far less than a hypothetical dense 235B."""
    from repro.launch.dryrun import active_param_count
    from repro.models.registry import get_bundle
    b = get_bundle("qwen3-moe-235b-a22b")
    assert active_param_count(b) < 0.15 * b.param_count()
