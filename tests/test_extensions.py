"""Beyond-paper extensions: adaptive sketch growth (Thm 3.2 remark) and
int8-compressed resilient gradient reduction."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Dataset, LogisticRegression, NewtonConfig,
                        OverSketchConfig, oversketched_newton)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _logistic(key, n=1500, d=40):
    kx, kw, ky = jax.random.split(key, 3)
    x = jax.random.uniform(kx, (n, d), minval=-1, maxval=1)
    wstar = jax.random.normal(kw, (d,))
    y = jnp.where(jax.random.uniform(ky, (n,)) < jax.nn.sigmoid(x @ wstar),
                  1.0, -1.0)
    return Dataset(x=x, y=y)


def test_adaptive_sketch_grows_on_stall():
    """With a deliberately tiny sketch the eps-linear tail stalls; adaptive
    mode must grow the sketch dim and reach a better gradient norm than the
    fixed-dim run in the same iteration budget."""
    data = _logistic(jax.random.PRNGKey(0))
    obj = LogisticRegression(lam=1e-4)
    tiny = OverSketchConfig(sketch_dim=64, block_size=32,
                            straggler_tolerance=0.25)
    base = dict(iters=12, coded_block_rows=128, unit_step=True)
    fixed = oversketched_newton(obj, data, jnp.zeros(40),
                                NewtonConfig(sketch=tiny, **base),
                                model=None)
    adapt = oversketched_newton(obj, data, jnp.zeros(40),
                                NewtonConfig(sketch=tiny,
                                             adaptive_sketch=True, **base),
                                model=None)
    assert max(adapt.history["sketch_dim"]) > 64          # grew
    assert max(adapt.history["sketch_dim"]) <= 64 * 4     # capped
    assert adapt.history["gnorm"][-1] < fixed.history["gnorm"][-1]


def test_adaptive_sketch_untouched_when_progress_is_fine():
    data = _logistic(jax.random.PRNGKey(1))
    obj = LogisticRegression(lam=1e-4)
    cfg = NewtonConfig(iters=5, sketch=OverSketchConfig(1024, 128, 0.25),
                       adaptive_sketch=True, coded_block_rows=128,
                       unit_step=True)
    res = oversketched_newton(obj, data, jnp.zeros(40), cfg, model=None)
    # quadratic-phase progress every iteration: no growth triggered
    assert res.history["sketch_dim"][-1] <= 2048


def test_compressed_psum_close_to_exact():
    from repro.distributed.collectives import compressed_resilient_psum
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import PartitionSpec as P

    x = jnp.linspace(-3.0, 5.0, 64).reshape(1, 64)

    def local(xl, live):
        return compressed_resilient_psum({"g": xl}, live[0], "data")["g"]

    out = jax.shard_map(local, mesh=mesh, in_specs=(P("data"), P("data")),
                        out_specs=P("data"), check_vma=False)(
        x, jnp.ones((1,)))
    # int8 quantization noise <= scale/127
    np.testing.assert_allclose(np.asarray(out), np.asarray(x),
                               atol=float(jnp.abs(x).max()) / 127 + 1e-6)


def test_compressed_training_converges():
    """8-way DP with int8 gradient wire format + 10% dropped shards still
    trains (subprocess: 8 placeholder devices)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import jax
        from repro.launch.mesh import make_mesh
        from repro.training.trainer import Trainer, TrainerConfig
        from repro.core.straggler import StragglerModel
        cfg = TrainerConfig(arch="qwen3-4b", steps=8, batch=8, seq=64,
                            lr=1e-3, resilient_grads=True,
                            grad_compression=True,
                            straggler=StragglerModel(p_tail=0.3))
        tr = Trainer(cfg, make_mesh((8,), ("data",)))
        p, o = tr.init_state()
        p, o, hist = tr.run(p, o)
        assert hist[-1]["loss"] < hist[0]["loss"], hist
        print("COMPRESSED_OK", hist[0]["loss"], "->", hist[-1]["loss"])
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    assert "COMPRESSED_OK" in out.stdout
