"""Online health monitors: hand-computed detector fixtures, listener
wiring, alert emission, and the observation-only contract on a live
fleet drive.

The detector tests pin exact arithmetic (median/MAD z-scores, frozen
-baseline CUSUM accumulation) against values computed by hand in the
test, so a refactor that changes the statistics — not just the API —
fails loudly.
"""
import math

import jax
import pytest

from repro import obs
from repro.core.straggler import SimClock, StragglerModel
from repro.obs.health import Alert, Cusum, HealthMonitors, RobustZScore, Rule
from repro.runtime import FleetConfig


# ------------------------------------------------------- RobustZScore
def test_zscore_hand_computed_spike():
    # Window [10, 12, 11, 13, 9, 11, 12, 10]: median 11, absolute
    # deviations sorted [0,0,1,1,1,1,2,2] -> MAD 1, scale 1.4826.
    det = RobustZScore(window=8, z=4.0, min_samples=8)
    for x in (10, 12, 11, 13, 9, 11, 12, 10):
        assert det.update(x) is None          # warming up
    fired = det.update(20.0)
    assert fired is not None
    score, threshold, direction = fired
    assert score == pytest.approx((20.0 - 11.0) / 1.4826)
    assert threshold == 4.0 and direction == "high"


def test_zscore_scores_against_prior_window_and_low_side():
    det = RobustZScore(window=8, z=4.0, min_samples=8)
    for x in (10, 12, 11, 13, 9, 11, 12, 10):
        det.update(x)
    # In-band sample: |10.5 - 11| / 1.4826 << 4 -> silent.
    assert det.update(10.5) is None
    det2 = RobustZScore(window=8, z=4.0, min_samples=8)
    for x in (10, 12, 11, 13, 9, 11, 12, 10):
        det2.update(x)
    score, _, direction = det2.update(1.0)
    assert direction == "low" and score < 0
    assert score == pytest.approx((1.0 - 11.0) / 1.4826)


def test_zscore_rel_floor_suppresses_tight_stream_wobble():
    # A statistically tight stream (MAD ~ 0 around 100): without a floor,
    # a 3% wobble is a 20-sigma event; with rel_floor=0.25 the scale is
    # clamped to 25 and the wobble scores 0.12.
    loose = RobustZScore(window=8, z=4.0, min_samples=8, rel_floor=0.25)
    tight = RobustZScore(window=8, z=4.0, min_samples=8)
    stream = (100.0, 100.1, 99.9, 100.0, 100.05, 99.95, 100.0, 100.1)
    for x in stream:
        loose.update(x)
        tight.update(x)
    assert tight.update(103.0) is not None     # fires without the floor
    assert loose.update(103.0) is None         # floored scale: silent
    assert loose.last_score == pytest.approx((103.0 - 100.0) / 25.0)


def test_cusum_hand_computed_drift():
    # Baseline [9, 11] x 4: mean 10, population std 1.  Then two samples
    # of 14 at k=0.5: s_pos = 0 + 4 - 0.5 = 3.5, then 3.5 + 4 - 0.5 = 7,
    # which crosses h=5 and fires with the accumulated score.
    det = Cusum(k=0.5, h=5.0, min_samples=8)
    for x in (9, 11) * 4:
        assert det.update(x) is None
    assert det.mean == pytest.approx(10.0)
    assert det.std == pytest.approx(1.0)
    assert det.update(14.0) is None
    assert det.s_pos == pytest.approx(3.5)
    fired = det.update(14.0)
    assert fired is not None
    score, threshold, direction = fired
    assert score == pytest.approx(7.0)
    assert threshold == 5.0 and direction == "high"
    # Firing resets both accumulators (bounded re-alert rate).
    assert det.s_pos == 0.0 and det.s_neg == 0.0


def test_cusum_low_side_and_body_decay():
    det = Cusum(k=0.5, h=5.0, min_samples=4)
    for x in (10.0, 10.0, 9.0, 11.0):
        det.update(x)
    # Downward shift accumulates s_neg: z = -4 each -> s_neg += 3.5.
    assert det.update(6.8) is None
    fired = det.update(6.8)
    assert fired is not None and fired[2] == "low" and fired[0] < 0
    # An in-baseline sample decays the accumulator by k.
    det2 = Cusum(k=0.5, h=5.0, min_samples=4)
    for x in (10.0, 10.0, 9.0, 11.0):
        det2.update(x)
    det2.update(12.0)
    high_water = det2.s_pos
    det2.update(10.0)
    assert det2.s_pos == pytest.approx(max(0.0, high_water - 0.5))


def test_detectors_reject_tiny_min_samples():
    with pytest.raises(ValueError):
        RobustZScore(min_samples=1)
    with pytest.raises(ValueError):
        Cusum(min_samples=0)


# -------------------------------------------------- listener wiring
def test_monitors_watch_registry_stream_and_emit_alert_spans():
    # Baseline (10, 10, 9, 11): mean 10, population std sqrt(0.5).  Each
    # 14 contributes z - k = 4/sqrt(0.5) - 0.5 ~ 5.157 of CUSUM mass, so
    # h=12 is crossed exactly on the third one (s_pos ~ 15.47).
    rules = (Rule("lat", lambda: Cusum(k=0.5, h=12.0, min_samples=4),
                  kinds=("hist",)),)
    tel = obs.Telemetry(monitors=HealthMonitors(rules))
    hist = tel.metrics.histogram("lat")
    for x in (10.0, 10.0, 9.0, 11.0, 14.0, 14.0, 14.0):
        hist.observe(x)
    assert len(tel.health.alerts) == 1
    a = tel.health.alerts[0]
    assert isinstance(a, Alert)
    assert a.metric == "lat" and a.detector == "cusum"
    assert a.sample == 7 and a.direction == "high"
    assert a.score == pytest.approx(3 * (4.0 / math.sqrt(0.5) - 0.5))
    # The alert also landed in the span tree as a zero-duration marker...
    spans = tel.trace.by_kind("alert")
    assert len(spans) == 1
    assert spans[0].name == "alert:lat"
    assert spans[0].start == spans[0].end
    # ...and in the JSONL rows, next to a health-state row.
    rows = obs.telemetry_rows(tel)
    assert [r["metric"] for r in obs.alerts_from_rows(rows)] == ["lat"]
    health = next(r for r in rows if r.get("kind") == "health")
    assert health["alerts"] == 1
    assert health["detectors"][0]["metric"] == "lat"


def test_monitors_rule_kinds_filter_and_unwatched_metrics():
    rules = (Rule("only.gauge", lambda: Cusum(min_samples=2),
                  kinds=("gauge",)),)
    tel = obs.Telemetry(monitors=HealthMonitors(rules))
    tel.metrics.histogram("only.gauge").observe(1.0)   # wrong kind
    tel.metrics.counter("unrelated").inc()             # unwatched name
    assert tel.health.detectors == {}
    tel.metrics.gauge("only.gauge").set(1.0)
    assert ("only.gauge", 0) in tel.health.detectors


def test_alerts_stamped_with_tracer_high_water_mark():
    rules = (Rule("lat", lambda: Cusum(k=0.5, h=5.0, min_samples=4),
                  kinds=("hist",)),)
    tel = obs.Telemetry(monitors=HealthMonitors(rules))
    tel.trace.emit("phase/x", "phase", 3.25, 7.5)
    for x in (10.0, 10.0, 9.0, 11.0, 14.0, 14.0, 14.0):
        tel.metrics.histogram("lat").observe(x)
    assert tel.health.alerts[0].t == 7.5


def test_telemetry_monitors_true_uses_default_rules():
    tel = obs.Telemetry(monitors=True)
    assert tel.health is not None
    assert tel.metrics.listener is tel.health
    assert {r.metric for r in tel.health.rules} >= {
        "worker.completion_s", "phase.tail_p95_s", "sketch.mp_debias"}


# -------------------------------- observation-only + default tuning
def _fleet_drive(telemetry=None, shift=False):
    """Twelve 32-worker rounds; with ``shift`` the per-worker work jumps
    4x at the halfway mark (the tail the straggler monitors watch)."""
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=FleetConfig(cold_start_prob=0.1),
                     telemetry=telemetry)
    for r in range(12):
        flops = 8e5 if (shift and r >= 6) else 2e5
        clock.phase(jax.random.PRNGKey(7000 + r), 32, policy="k_of_n",
                    k=25, flops_per_worker=flops, comm_units=1.0)
    return clock


def test_monitored_fleet_drive_is_observation_only():
    plain = _fleet_drive()
    tel = obs.Telemetry(monitors=True)
    monitored = _fleet_drive(telemetry=tel)
    assert monitored.time == plain.time
    assert monitored.dollars == plain.dollars


def test_default_rules_quiet_on_healthy_drive_loud_on_shift():
    healthy = obs.Telemetry(monitors=True)
    _fleet_drive(telemetry=healthy)
    assert healthy.health.alerts == []
    shifted = obs.Telemetry(monitors=True)
    _fleet_drive(telemetry=shifted, shift=True)
    completion_alerts = [a for a in shifted.health.alerts
                         if a.metric == "worker.completion_s"]
    assert completion_alerts, "4x work shift must trip the straggler cusum"
    # 6 rounds x 32 workers = 192 pre-shift samples: every firing is
    # attributable to the shift, none to healthy straggler tails.
    assert all(a.sample > 192 for a in completion_alerts)
    assert all(a.direction == "high" for a in completion_alerts)


def test_monitor_summary_counts_by_metric():
    rules = (Rule("a", lambda: Cusum(k=0.5, h=5.0, min_samples=2),
                  kinds=("gauge",)),)
    tel = obs.Telemetry(monitors=HealthMonitors(rules))
    g = tel.metrics.gauge("a")
    for x in (10.0, 10.0, 20.0, 20.0, 20.0, 20.0):
        g.set(x)
    s = tel.health.summary()
    assert s["alerts"] == len(tel.health.alerts) >= 1
    assert s["by_metric"]["a"] == s["alerts"]
    assert s["metrics_watched"] == 1


def test_alert_and_detector_tables_render():
    rules = (Rule("lat", lambda: Cusum(k=0.5, h=5.0, min_samples=4),
                  kinds=("hist",)),)
    tel = obs.Telemetry(monitors=HealthMonitors(rules))
    for x in (10.0, 10.0, 9.0, 11.0, 14.0, 14.0, 14.0):
        tel.metrics.histogram("lat").observe(x)
    rows = obs.telemetry_rows(tel)
    alert_tbl = obs.alert_table(rows)
    assert "lat" in alert_tbl and "cusum" in alert_tbl
    det_tbl = obs.detector_table(rows)
    assert "lat" in det_tbl and "cusum" in det_tbl


def test_zscore_nan_free_on_constant_stream():
    det = RobustZScore(window=8, z=4.0, min_samples=4)
    for _ in range(10):
        det.update(5.0)
    assert math.isfinite(det.last_score)
