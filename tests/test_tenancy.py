"""Multi-tenant fleet plane: warm-pool bugfix regressions, workload
determinism, shared-pool scheduling, SLO admission, provisioned billing,
and a committed two-tenant golden trace.

The three pool regressions pin PR 9's bugfixes:

1. Prewarmed containers are pinned to first use — a run whose first
   dispatch lands after ``ttl`` simulated seconds still gets its full
   prewarm (they used to be seeded idle-since-0.0 and lazily expired).
2. ``WarmPool.killed`` exists from construction and ``snapshot()``
   reports it (it used to appear only after the first ``cull``).
3. The engine emits both ``pool.phase_hit_rate`` (per-phase) and a true
   cumulative ``pool.hit_rate`` from the pool's own counters (the old
   ``pool.hit_rate`` was per-phase despite the cumulative-sounding name).

The golden fixture ``tests/fixtures/tenancy_trace_golden.jsonl`` is a
small two-tenant run (serving/matvec + train/giant) recorded through the
SHARED engine with a shared warm pool.  Regenerate only after an
intentional engine/trace/scheduler change:

    PYTHONPATH=src python tests/test_tenancy.py --regen
"""
import json
import pathlib

import jax
import pytest
from _hypothesis_compat import given, settings, st

from repro import obs
from repro.core.straggler import SimClock, StragglerModel
from repro.runtime import (CostLedger, CostModel, FleetConfig,
                           TraceRecorder, TraceReplayer)
from repro.scheduler import PhaseSpec, WarmPool
from repro.tenancy import (AdmissionPolicy, Autoscaler, JobScheduler,
                           JobTemplate, TenancyConfig, WorkloadConfig,
                           generate_workload, get_template,
                           workload_from_trace)

MODEL = StragglerModel()
TEN_FIXTURE = pathlib.Path(__file__).parent / "fixtures" / \
    "tenancy_trace_golden.jsonl"
_TEN_FLEET = FleetConfig(failure_rate=0.05, cold_start_prob=0.2)


# ----------------------------------------------- pool bugfix regressions
def test_prewarmed_pool_survives_late_first_dispatch():
    """Bugfix 1: a first acquire at t >> ttl must still hit the prewarm
    (provisioned containers are pinned warm until first use)."""
    pool = WarmPool(ttl=10.0, prewarmed=4)
    assert pool.free_at(300.0) == 4
    for _ in range(4):
        assert pool.acquire(300.0)       # all four land warm
    assert not pool.acquire(300.0)       # reserve drained: cold
    assert pool.warm_hits == 4 and pool.cold_starts == 1
    # Once USED, a container joins the TTL pool like any other.
    pool.release(301.0)
    assert not pool.acquire(320.0)       # idle 19 s > ttl: expired


def test_prewarmed_is_drained_after_released_containers():
    """MRU contract: released (hot) containers outrank the pinned
    reserve, so steady traffic never touches the provisioned spares."""
    pool = WarmPool(ttl=10.0, prewarmed=1)
    pool.release(5.0)
    assert pool.acquire(6.0)
    assert pool.fresh == 1               # the reserve was not consumed
    assert pool.acquire(6.1)             # now it is
    assert pool.fresh == 0


def test_cull_killed_counter_initialized_and_snapshotted():
    """Bugfix 2: ``killed`` exists before any cull and shows up in
    ``snapshot()`` — including kills from the pinned prewarm reserve."""
    pool = WarmPool(ttl=50.0, prewarmed=8)
    assert pool.killed == 0
    assert pool.snapshot(0.0)["killed"] == 0
    import numpy as np
    n = pool.cull(0.5, np.random.default_rng(3))
    assert n == 4 and pool.killed == 4
    snap = pool.snapshot(0.0)
    assert snap["killed"] == 4 and snap["containers"] == 4


def test_engine_emits_phase_and_cumulative_hit_rates_and_killed():
    """Bugfix 3: ``pool.phase_hit_rate`` is the per-phase ratio,
    ``pool.hit_rate`` is cumulative from the pool's own counters, and
    ``pool.killed_total`` is always published."""
    pool = WarmPool(ttl=100.0, prewarmed=6)
    tel = obs.Telemetry()
    clock = SimClock(MODEL, pool=pool, telemetry=tel)
    clock.phase(jax.random.PRNGKey(0), 6, flops_per_worker=1e5)
    g = tel.metrics.gauges
    assert g["pool.phase_hit_rate"].value == 1.0     # all 6 prewarmed
    assert g["pool.hit_rate"].value == 1.0
    assert g["pool.killed_total"].value == 0.0
    # Phase 2: 12 workers against ~6 warm containers — the phase ratio
    # collapses while the cumulative one averages both phases.
    clock.phase(jax.random.PRNGKey(1), 12, flops_per_worker=1e5)
    phase_rate = g["pool.phase_hit_rate"].value
    cum_rate = g["pool.hit_rate"].value
    assert phase_rate < 1.0
    assert cum_rate == pool.warm_hits / (pool.warm_hits
                                         + pool.cold_starts)
    assert cum_rate > phase_rate


def test_pool_earliest_fit_waits_for_warm_capacity():
    pool = WarmPool(ttl=100.0)
    for t in (2.0, 2.0, 3.0):
        pool.release(t)
    # At t=0 nothing is warm; by t=3 all three are.  Within a deadline of
    # 5 the best launch is the earliest candidate covering the need.
    assert pool.earliest_fit(0.0, 2, 5.0) == 2.0
    assert pool.earliest_fit(0.0, 3, 5.0) == 3.0
    # Deadline too tight to reach capacity: take the best reachable.
    assert pool.earliest_fit(0.0, 3, 2.5) == 2.0
    # Nothing to gain: launch immediately.
    assert pool.earliest_fit(4.0, 2, 9.0) == 4.0


# ------------------------------------------------------------- workload
def test_workload_generation_is_seed_deterministic():
    cfg = WorkloadConfig(seed=11, rate=5.0, n_jobs=50)
    a, b = generate_workload(cfg), generate_workload(cfg)
    assert [(j.id, j.template.name, j.t_arrival) for j in a] \
        == [(j.id, j.template.name, j.t_arrival) for j in b]
    c = generate_workload(WorkloadConfig(seed=12, rate=5.0, n_jobs=50))
    assert [(j.template.name, j.t_arrival) for j in a] \
        != [(j.template.name, j.t_arrival) for j in c]
    assert all(x.t_arrival <= y.t_arrival for x, y in zip(a, a[1:]))


def test_template_estimates_and_slack():
    tpl = get_template("newton_small")
    est = tpl.expected_makespan(MODEL)
    assert est > 0
    slack = tpl.phase_slack(MODEL)
    # hess (0.3 s) dominates grad (0.25 s); linesearch joins both.
    assert slack["hess"] == 0.0 and slack["linesearch"] == 0.0
    assert slack["grad"] == pytest.approx(0.05)
    assert tpl.expected_peak_workers(MODEL) == 16   # grad + hess overlap


def test_job_deadline_is_arrival_relative():
    job = workload_from_trace([(3.0, "matvec")])[0]
    assert job.deadline == pytest.approx(3.0 + 2.0)
    assert job.tenant == "serving"


# ----------------------------------------------------------- scheduling
def _run(jobs, pool=None, config=None, telemetry=None, fleet=None,
         key=0):
    clock = SimClock(MODEL, fleet=fleet, pool=pool, telemetry=telemetry)
    sched = JobScheduler(clock, jax.random.PRNGKey(key), jobs,
                         config or TenancyConfig())
    return sched.run(), clock


def test_shared_pool_spans_jobs():
    """Job B (arriving after job A finished) reuses A's containers —
    the whole point of sharing one pool across runs."""
    jobs = workload_from_trace([(0.0, "matvec"), (5.0, "matvec")])
    pool = WarmPool(ttl=60.0)
    res, _ = _run(jobs, pool=pool)
    warm_by_job = {jid: warm for jid, _, _, _, warm, _ in res.phase_log}
    assert warm_by_job[0] == 0            # cold fleet: A starts cold
    assert warm_by_job[1] == 8            # B fully warm off A's releases
    assert pool.warm_hits == 8 and pool.cold_starts == 8


def test_admission_cap_queues_then_drains():
    jobs = workload_from_trace([(0.0, "matvec"), (0.0, "matvec"),
                                (0.0, "matvec")])
    cfg = TenancyConfig(admission=AdmissionPolicy(max_inflight=1,
                                                  queue=True,
                                                  slo_aware=False))
    res, _ = _run(jobs, config=cfg)
    assert len(res.completed) == 3 and not res.rejected
    assert res.peak_inflight == 1
    waits = sorted(j.queue_wait for j in res.jobs)
    assert waits[0] == 0.0 and waits[1] > 0.0 and waits[2] > waits[1]


def test_admission_cap_rejects_without_queue():
    jobs = workload_from_trace([(0.0, "matvec"), (0.0, "matvec")])
    cfg = TenancyConfig(admission=AdmissionPolicy(max_inflight=1,
                                                  queue=False,
                                                  slo_aware=False))
    res, _ = _run(jobs, config=cfg)
    assert len(res.completed) == 1 and len(res.rejected) == 1
    assert res.jobs[1].rejected and res.jobs[1].t_finish is None


def test_slo_aware_admission_rejects_infeasible_jobs():
    """A job whose estimated makespan already exceeds its deadline is
    refused at arrival instead of admitted to fail."""
    from repro.tenancy import register
    register(JobTemplate(
        name="_test_tight", tenant="t", deadline_s=0.05,
        specs=(PhaseSpec("p", workers=2, flops_per_worker=4e5),)),
        overwrite=True)
    jobs = workload_from_trace([(0.0, "_test_tight")])
    res, _ = _run(jobs, config=TenancyConfig(
        admission=AdmissionPolicy(slo_aware=True)))
    assert res.jobs[0].rejected
    # Same job, SLO gate off: admitted (and counted as an SLO miss).
    res2, _ = _run(jobs, config=TenancyConfig(
        admission=AdmissionPolicy(slo_aware=False)))
    assert res2.jobs[0].completed and res2.slo_misses == 1


def test_pool_aware_dispatch_spends_slack_to_convert_colds():
    """With warm containers becoming free shortly after a slack-bearing
    phase's ready time, pool-aware dispatch waits and lands warm."""
    from repro.tenancy import register
    register(JobTemplate(
        # 'long' (0.5 s median) dominates; 'short' (0.2 s) has 0.3 s of
        # CPM slack — enough to wait for the t=0.25 releases below.
        name="_test_slack", tenant="t",
        specs=(PhaseSpec("long", workers=2, flops_per_worker=8e5),
               PhaseSpec("short", workers=4, flops_per_worker=2e5))),
        overwrite=True)
    jobs = workload_from_trace([(0.0, "_test_slack")])

    def colds(pool_aware):
        pool = WarmPool(ttl=60.0)
        for _ in range(4):
            pool.release(0.25)
        res, _ = _run(jobs, pool=pool,
                      config=TenancyConfig(pool_aware=pool_aware))
        return sum(c for *_, c in res.phase_log), res
    naive_colds, _ = colds(False)
    aware_colds, aware_res = colds(True)
    assert aware_colds < naive_colds
    # The delayed phase launched at the release time, not its ready time.
    launches = {name: t for _, _, name, t, _, _ in aware_res.phase_log}
    assert launches["short"] == 0.25 and launches["long"] == 0.0


def test_multi_tenant_run_is_bit_deterministic():
    jobs = generate_workload(WorkloadConfig(seed=5, rate=6.0, n_jobs=30))
    cfg = TenancyConfig(pool_aware=True,
                        autoscaler=Autoscaler(max_provisioned=64))
    runs = [_run(jobs, pool=WarmPool(ttl=60.0, prewarmed=8), config=cfg,
                 fleet=_TEN_FLEET)[0] for _ in range(2)]
    assert runs[0].seconds == runs[1].seconds
    assert runs[0].dollars == runs[1].dollars
    assert runs[0].phase_log == runs[1].phase_log
    assert [j.t_finish for j in runs[0].jobs] \
        == [j.t_finish for j in runs[1].jobs]


def test_telemetry_is_observation_only_for_tenancy_runs():
    jobs = generate_workload(WorkloadConfig(seed=9, rate=8.0, n_jobs=15))
    tel = obs.Telemetry(monitors=True)
    plain, _ = _run(jobs, pool=WarmPool(ttl=60.0, prewarmed=8))
    seen, _ = _run(jobs, pool=WarmPool(ttl=60.0, prewarmed=8),
                   telemetry=tel)
    assert (plain.seconds, plain.dollars) == (seen.seconds, seen.dollars)
    assert plain.phase_log == seen.phase_log
    snap = tel.metrics.snapshot()
    assert snap["counters"]["jobs.arrived"] == 15.0
    assert snap["counters"]["jobs.completed"] == 15.0
    assert snap["histograms"]["job.latency_s"]["count"] == 15
    assert any(s.kind == "job" for s in tel.trace.spans)
    # Per-tenant attribution adds up to the whole bill (minus any
    # provisioned accrual, which lands on the _platform tenant).
    model = CostModel()
    total = sum(led.dollars(model) for led in seen.tenants.values())
    assert total == pytest.approx(seen.dollars)


def test_store_run_record_captures_fleet_job_aggregates():
    from repro.obs.store import run_record
    jobs = generate_workload(WorkloadConfig(seed=2, rate=8.0, n_jobs=10))
    tel = obs.Telemetry()
    _run(jobs, telemetry=tel)
    rec = run_record("tenancy_test", tel)
    assert rec["fleet_jobs"]["arrived"] == 10.0
    assert rec["fleet_jobs"]["completed"] == 10.0
    assert rec["fleet_jobs"]["latency"]["count"] == 10


# ------------------------------------------------- provisioned billing
def test_static_prewarm_bills_provisioned_gb_seconds():
    jobs = workload_from_trace([(0.0, "matvec")])
    res, clock = _run(jobs, pool=WarmPool(ttl=60.0, prewarmed=10))
    model = clock.engine.cost_model
    # Billed by configured target over the whole horizon, idle or not.
    assert res.provisioned_gb_seconds == \
        pytest.approx(10 * model.memory_gb * res.seconds)
    assert clock.engine.ledger.provisioned_gb_seconds \
        == res.provisioned_gb_seconds
    bare, _ = _run(jobs, pool=WarmPool(ttl=60.0))
    assert bare.provisioned_gb_seconds == 0.0
    # The total bill decomposes into execution + provisioned-idle terms.
    led = clock.engine.ledger
    execution = CostLedger(gb_seconds=led.gb_seconds,
                           invocations=led.invocations,
                           s3_puts=led.s3_puts, s3_gets=led.s3_gets)
    assert res.dollars == pytest.approx(
        execution.dollars(model) + res.provisioned_gb_seconds
        * model.usd_per_provisioned_gb_second)
    assert "_platform" in res.tenants


def test_autoscaler_tracks_arrival_rate():
    jobs = generate_workload(WorkloadConfig(seed=4, rate=20.0, n_jobs=40))
    pool = WarmPool(ttl=60.0)
    res, _ = _run(jobs, pool=pool,
                  config=TenancyConfig(autoscaler=Autoscaler(
                      max_provisioned=100)))
    # The reserve scaled up from zero and billed its idle time.
    assert res.provisioned_gb_seconds > 0.0
    assert pool.fresh + pool.warm_hits > 0
    lo = _run(generate_workload(WorkloadConfig(seed=4, rate=2.0,
                                               n_jobs=40)),
              pool=WarmPool(ttl=60.0),
              config=TenancyConfig(autoscaler=Autoscaler(
                  max_provisioned=100)))[0]
    # 10x the arrival rate => a (much) bigger provisioned-seconds bill
    # per simulated second.
    assert res.provisioned_gb_seconds / res.seconds \
        > lo.provisioned_gb_seconds / lo.seconds


# ------------------------------------ hypothesis: order determinism
@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.floats(0.0, 4.0),
                          st.sampled_from(["matvec", "giant",
                                           "newton_small"])),
                min_size=1, max_size=5),
       st.integers(0, 3))
def test_interleaved_acquire_release_is_order_deterministic(trace, seed):
    """Same seed + same arrival trace => bit-identical warm/cold
    assignment across the whole interleaved multi-job run."""
    jobs = workload_from_trace(trace)
    cfg = TenancyConfig(pool_aware=bool(seed % 2))
    outs = []
    for _ in range(2):
        res, clock = _run(jobs, pool=WarmPool(ttl=30.0, prewarmed=4),
                          config=cfg, fleet=_TEN_FLEET, key=seed)
        outs.append((res.phase_log, res.seconds, res.dollars,
                     clock.engine.pool.warm_hits,
                     clock.engine.pool.cold_starts))
    assert outs[0] == outs[1]


# ------------------------------------------------- two-tenant golden trace
def _golden_jobs():
    return workload_from_trace([(0.0, "matvec"), (0.1, "giant")])


def _golden_pool():
    # prewarmed=0: the fixture pins shared-pool REUSE dynamics without a
    # provisioned-billing term, so a pool-less replay reproduces the
    # dollars from the recorded ledger columns alone.
    return WarmPool(ttl=30.0)


def _drive_tenancy(clock):
    JobScheduler(clock, jax.random.PRNGKey(99), _golden_jobs(),
                 TenancyConfig()).run()
    return clock


def _load_fixture():
    rows = [json.loads(line)
            for line in TEN_FIXTURE.read_text().splitlines()
            if line.strip()]
    assert rows[0]["kind"] == "meta"
    return rows[0], rows[1:]


def test_tenancy_golden_fixture_replays_bit_identical():
    _, rows = _load_fixture()
    phase_rows = [r for r in rows if r["kind"] == "phase"]
    assert len(phase_rows) == 5          # matvec(1) + giant(2 x 2 iters)
    assert all("pool" in r for r in phase_rows), \
        "fixture must be a shared warm-pool run"
    replayed = _drive_tenancy(
        SimClock(StragglerModel(), replay=TraceReplayer(rows)))
    seconds, ledger = 0.0, CostLedger()
    for r in rows:
        seconds += r.get("advance", r["elapsed"])
        ledger.add(CostLedger(gb_seconds=r["gb_seconds"],
                              invocations=r["invocations"],
                              s3_puts=r["s3_puts"], s3_gets=r["s3_gets"]))
    assert replayed.time == seconds
    assert replayed.dollars == ledger.dollars(CostModel())


def test_tenancy_golden_rerecord_matches_fixture(tmp_path):
    meta, rows = _load_fixture()
    rec = TraceRecorder(worker_times=True, lifecycle=True)
    live = _drive_tenancy(SimClock(StragglerModel(), fleet=_TEN_FLEET,
                                   recorder=rec, pool=_golden_pool()))
    path = tmp_path / "rerecord.jsonl"
    rec.dump(path)
    from repro.runtime import load_trace
    replayed = _drive_tenancy(SimClock(StragglerModel(),
                                       replay=load_trace(path)))
    assert replayed.time == live.time
    assert replayed.dollars == live.dollars
    assert [(r["kind"], r.get("policy"), r.get("workers"), r.get("k"))
            for r in rec.rows] == \
        [(r["kind"], r.get("policy"), r.get("workers"), r.get("k"))
         for r in rows]
    if jax.__version__ != meta["jax_version"]:
        pytest.skip(f"fixture recorded under jax {meta['jax_version']}, "
                    f"running {jax.__version__}: structural check only")
    assert [json.loads(json.dumps(r)) for r in rec.rows] == rows


def _regen():
    rec = TraceRecorder(worker_times=True, lifecycle=True)
    _drive_tenancy(SimClock(StragglerModel(), fleet=_TEN_FLEET,
                            recorder=rec, pool=_golden_pool()))
    TEN_FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    with open(TEN_FIXTURE, "w") as f:
        f.write(json.dumps({"kind": "meta",
                            "jax_version": jax.__version__,
                            "generator": "tests/test_tenancy.py "
                                         "--regen"}) + "\n")
        for row in rec.rows:
            f.write(json.dumps(row) + "\n")
    print(f"wrote {TEN_FIXTURE} ({len(rec.rows)} rows)")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        sys.exit("usage: python tests/test_tenancy.py --regen")
