"""Per-architecture smoke tests (reduced configs of the same family):
one train step on CPU asserting output shapes + no NaNs, plus the strong
serving invariant  full-forward(t) == prefill(t-1) + decode  per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, smoke_config
from repro.models import encdec, transformer
from repro.models.registry import ModelBundle
from repro.optim import adamw

DECODE_TOL = 0.2   # bf16 logit noise at scale ~3.5


def _batch(cfg, bsz=2, seq=24, seed=0):
    rs = np.random.RandomState(seed)
    toks = jnp.asarray(rs.randint(1, cfg.vocab_size - 1, (bsz, seq)))
    batch = {"tokens": toks, "labels": toks}
    extra = None
    if cfg.family == "encdec":
        extra = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                  (bsz, cfg.encoder_seq, cfg.d_model),
                                  cfg.compute_dtype)
        batch["frame_embeds"] = extra
    elif cfg.frontend == "patch_stub":
        extra = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                  (bsz, cfg.num_patches, cfg.d_model),
                                  cfg.compute_dtype)
        batch["patch_embeds"] = extra
        batch["labels"] = jnp.asarray(rs.randint(
            1, cfg.vocab_size - 1, (bsz, seq + cfg.num_patches)))
    return batch, extra


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    """Reduced config, one forward+backward+AdamW step: shapes + no NaNs."""
    cfg = smoke_config(arch)
    bundle = ModelBundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch, _ = _batch(cfg)

    loss, grads = jax.value_and_grad(
        lambda p: bundle.loss(p, batch))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gn = jnp.sqrt(sum(jnp.vdot(g, g).real for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0, f"{arch}: bad grads"

    ocfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = adamw.init(params)
    new_params, _ = adamw.apply(ocfg, grads, state, params)
    # shapes preserved, values changed, still finite
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape and a.dtype == b.dtype
    loss2 = bundle.loss(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch):
    """prefill(S-1 tokens) + decode(1) must reproduce full forward's last
    logits (the serving-correctness invariant)."""
    cfg = smoke_config(arch)
    bundle = ModelBundle(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    seq = 24
    batch, extra = _batch(cfg, seq=seq, seed=2)
    toks = batch["tokens"]

    if cfg.family == "encdec":
        logits_full, _ = encdec.forward(cfg, params, toks, extra)
    else:
        logits_full, _ = transformer.forward(cfg, params, toks, extra,
                                             remat=False)

    cache = bundle.init_cache(2, 64)
    _, cache = bundle.prefill(params, toks[:, :seq - 1], cache, extra)
    lg_dec, cache2 = bundle.decode(params, cache, toks[:, seq - 1])
    expect_pos = seq + (cfg.num_patches if cfg.frontend == "patch_stub" else 0)
    assert int(cache2["pos"]) == expect_pos
    ref = logits_full[:, -1].astype(jnp.float32)
    err = float(jnp.abs(lg_dec.astype(jnp.float32) - ref).max())
    assert err < DECODE_TOL, f"{arch}: decode drift {err}"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_multi_token_decode_stays_finite(arch):
    cfg = smoke_config(arch)
    bundle = ModelBundle(cfg)
    params = bundle.init(jax.random.PRNGKey(3))
    batch, extra = _batch(cfg, seq=8, seed=4)
    cache = bundle.init_cache(2, 64)
    _, cache = bundle.prefill(params, batch["tokens"], cache, extra)
    tok = jnp.zeros((2,), jnp.int32)
    dec = jax.jit(bundle.decode)
    for _ in range(4):
        logits, cache = dec(params, cache, tok)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    from repro.models.registry import get_config
    c = get_config("qwen3-32b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (64, 5120, 64, 8, 25600, 151936)
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.num_layers, c.d_model, c.num_experts, c.experts_per_token) == \
        (94, 4096, 128, 8)
    c = get_config("gemma3-27b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size,
            c.local_global_pattern) == (62, 5376, 21504, 262144, 5)
    c = get_config("recurrentgemma-2b")
    assert (c.num_layers, c.d_model, c.attn_every) == (26, 2560, 3)
    c = get_config("mamba2-780m")
    assert (c.num_layers, c.d_model, c.ssm_state) == (48, 1536, 128)
    c = get_config("whisper-large-v3")
    assert (c.num_layers, c.encoder_layers, c.d_model, c.vocab_size) == \
        (32, 32, 1280, 51866)
    c = get_config("llava-next-34b")
    assert (c.num_layers, c.d_model, c.num_heads, c.vocab_size) == \
        (60, 7168, 56, 64000)
    c = get_config("qwen2-7b")
    assert c.qkv_bias and (c.num_layers, c.d_model) == (28, 3584)
    c = get_config("qwen3-4b")
    assert c.qk_norm and (c.num_layers, c.d_ff) == (36, 9728)
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.num_layers, c.d_model, c.d_ff) == (48, 2048, 768)


def test_param_counts_plausible():
    """Full-config parameter counts are in the right ballpark (catches
    transposed dims / missing factors).  Counted from specs, no allocation."""
    from repro.models.registry import get_bundle
    expect = {
        "qwen3-32b": (30e9, 36e9),
        "qwen3-4b": (3.5e9, 5e9),
        "qwen2-7b": (7e9, 8.5e9),
        "gemma3-27b": (26e9, 30e9),
        "qwen3-moe-235b-a22b": (220e9, 250e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "mamba2-780m": (0.7e9, 0.9e9),
        "recurrentgemma-2b": (2.3e9, 3.3e9),
        "whisper-large-v3": (1.4e9, 1.9e9),
        "llava-next-34b": (33e9, 36e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_bundle(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params not in [{lo/1e9}, {hi/1e9}]B"
