"""OverSketched Newton inside interior-point methods (paper Sec. 4.3):
(a) a linear program  min c.x  s.t. Ax <= b, and (b) the Lasso dual.
Both solve a sequence of barrier subproblems with the sketched Hessian.

  PYTHONPATH=src python examples/interior_point.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Dataset, LassoDualIPM, LinearProgramIPM,
                        NewtonConfig, OverSketchConfig, oversketched_newton)

key = jax.random.PRNGKey(0)

# ---------------------------------------------------------------- LP --------
n, m = 400, 40
a_mat = jax.random.normal(key, (n, m))
x_feasible = jnp.zeros(m)
b = a_mat @ x_feasible + 1.0 + jax.random.uniform(jax.random.fold_in(key, 1),
                                                  (n,))
c = jax.random.normal(jax.random.fold_in(key, 2), (m,))
data = Dataset(x=a_mat, y=b)

x = jnp.zeros(m)
tau = 2.0
print("LP interior point (barrier stages with OverSketched Newton):")
for stage in range(4):
    obj = LinearProgramIPM(c=c, tau=tau)
    cfg = NewtonConfig(iters=6, sketch=OverSketchConfig(512, 64, 0.25),
                       coded_block_rows=64, beta=0.1)
    res = oversketched_newton(obj, data, x, cfg, model=None)
    x = res.w
    gap = n / tau          # duality-gap bound for the log barrier
    print(f"  tau={tau:7.1f}  c.x={float(c @ x):+.4f}  gap<={gap:.3f}  "
          f"feasible={bool((a_mat @ x < b).all())}")
    tau *= 8.0

# ------------------------------------------------------------- Lasso dual ---
n2, d2 = 60, 200
x_mat = jax.random.normal(jax.random.fold_in(key, 3), (n2, d2)) * 0.2
y = jax.random.normal(jax.random.fold_in(key, 4), (n2,))
lam = 1.5
ldata = Dataset(x=x_mat, y=y)
z = jnp.zeros(n2)
tau = 4.0
print("\nLasso dual interior point:")
for stage in range(3):
    obj = LassoDualIPM(lam=lam, tau=tau)
    cfg = NewtonConfig(iters=6, sketch=OverSketchConfig(256, 64, 0.25),
                       coded_block_rows=32, beta=0.1)
    res = oversketched_newton(obj, ldata, z, cfg, model=None)
    z = res.w
    viol = float(jnp.abs(x_mat.T @ z).max())
    print(f"  tau={tau:6.1f}  0.5||y-z||^2={float(0.5*jnp.sum((y-z)**2)):.4f}"
          f"  max|X^T z|={viol:.4f} (lam={lam})")
    tau *= 10.0
print("dual feasibility approached: max|X^T z| <= lam at optimum")
