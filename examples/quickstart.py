"""Quickstart: OverSketched Newton on logistic regression in ~20 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (LogisticRegression, NewtonConfig, OverSketchConfig,
                        oversketched_newton)
from repro.data import make_logistic_dataset

# a synthetic classification problem (paper Sec. 5.1 generative model)
data = make_logistic_dataset(jax.random.PRNGKey(0), n=4000, d=150,
                             n_test=1000)
objective = LogisticRegression(lam=1e-4)

config = NewtonConfig(
    iters=10,
    # OverSketch: sketch dim 10*d, 128-wide Count-Sketch blocks, 25% extra
    # blocks so up to 1-in-4 straggling workers cost nothing (Alg. 2)
    sketch=OverSketchConfig(sketch_dim=1536, block_size=128,
                            straggler_tolerance=0.25),
    gradient_policy="coded",       # 2D-product-coded exact gradients (Alg. 1)
    track_test_error=True,
)

result = oversketched_newton(objective, data, jnp.zeros(150), config)

print("iter    f(w)        ||grad||     sim_time  test_err")
for i in range(len(result.history["fval"])):
    h = result.history
    print(f"{h['iter'][i]:3d}  {h['fval'][i]:.6f}  {h['gnorm'][i]:.2e}"
          f"  {h['time'][i]:8.2f}  {h['test_error'][i]:.4f}")
