"""End-to-end training driver: train a reduced qwen3-family LM for a few
hundred steps with the production code path — pjit train step, AdamW,
deterministic data pipeline, async checkpointing, and a mid-run simulated
chip failure with automatic restart.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import tempfile

from repro.launch.mesh import make_host_mesh
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", type=str, default="qwen3-4b")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro-lm-")
    cfg = TrainerConfig(
        arch=args.arch, smoke=True, steps=args.steps, batch=8, seq=128,
        lr=1e-3, warmup_steps=20, ckpt_dir=ckpt_dir, ckpt_every=50)
    trainer = Trainer(cfg, make_host_mesh())
    print(f"arch={args.arch} (reduced) params={trainer.bundle.param_count():,}"
          f" ckpt={ckpt_dir}")

    fail_at = args.fail_at if args.fail_at is not None else args.steps // 2
    hist = trainer.run_with_restarts(fail_at=fail_at)
    for rec in hist[:: max(1, len(hist) // 20)]:
        print(f"step {rec['step']:5d}  loss {rec['loss']:.4f}  "
              f"gnorm {rec['grad_norm']:.3f}")
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(from {hist[0]['loss']:.4f}; survived a simulated failure at "
          f"step {fail_at})")


if __name__ == "__main__":
    main()
