"""Serving example: batched prefill+decode with continuous-batching waves.

  PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import BatchedServer
from repro.models.registry import ModelBundle

cfg = smoke_config("qwen3-4b")
bundle = ModelBundle(cfg)
params = bundle.init(jax.random.PRNGKey(0))

rs = np.random.RandomState(0)
prompts = [rs.randint(1, cfg.vocab_size - 1, rs.randint(4, 16))
           for _ in range(10)]

server = BatchedServer(bundle, params, batch=4, max_seq=128)
outs = server.generate(prompts, max_new=12)
for i, (p, o) in enumerate(zip(prompts, outs)):
    print(f"req{i}: prompt_len={len(p)} -> {o}")
