"""The paper's technique applied to the assigned-architecture pool: train a
softmax-regression readout head on frozen LM-backbone features with
OverSketched Newton (weakly convex => Newton-MR update, Thm 3.3 regime).

This is exactly the paper's Sec. 4.2 workload, with the feature matrix
produced by one of the pool architectures instead of raw pixels.

  PYTHONPATH=src python examples/osn_lm_head.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models.registry import ModelBundle
from repro.training.osn_head import extract_features, train_osn_head

K = 4                      # synthetic downstream classes
N = 1200                   # probe training examples

cfg = smoke_config("qwen3-4b")
bundle = ModelBundle(cfg)
params = bundle.init(jax.random.PRNGKey(0))

# synthetic "documents": class-conditioned token distributions
rs = np.random.RandomState(0)
labels = rs.randint(0, K, N)
tokens = (rs.randint(1, cfg.vocab_size // K - 1, (N, 32)) +
          labels[:, None] * (cfg.vocab_size // K)).astype(np.int32)

feats = []
for i in range(0, N, 64):
    feats.append(extract_features(bundle, params,
                                  jnp.asarray(tokens[i:i + 64])))
features = jnp.concatenate(feats)
onehot = jax.nn.one_hot(labels, K)

w, hist = train_osn_head(features, onehot, num_classes=K, iters=8)
pred = jnp.argmax(features @ w.reshape(K, -1).T, axis=1)
acc = float((pred == jnp.asarray(labels)).mean())
print("iter  f(W)      ||grad||   sim_time")
for i in range(len(hist["fval"])):
    print(f"{i:3d}  {hist['fval'][i]:.5f}  {hist['gnorm'][i]:.2e}"
          f"  {hist['time'][i]:7.2f}")
print(f"probe train accuracy: {acc:.3f} (chance {1/K:.3f})")
