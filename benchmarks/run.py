"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig6,fig9]

Prints ``name,us_per_call,derived`` CSV rows.  Default is the quick profile
(CPU-scaled dataset sizes, same generative models and worker ratios as the
paper's experiments; see repro/configs/paper.py).

Modules listed in ``PERSIST_JSON`` additionally write their rows (plus
backend / jax-version metadata) to a ``BENCH_*.json`` file at the repo
root — the persistent perf trajectory CI archives per push, so kernel
regressions have a baseline to diff against (see kernels/README.md).
"""
from __future__ import annotations

import argparse
import inspect
import json
import pathlib
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# module -> repo-root JSON file persisting its rows as a perf baseline
PERSIST_JSON = {
    "kernels_bench": "BENCH_kernels.json",
    "scheduler_bench": "BENCH_fleet.json",
}

MODULES = [
    "fig1_stragglers",
    "fig6_logistic_synthetic",
    "fig7_epsilon",
    "fig8_small_datasets",
    "fig9_softmax",
    "fig10_coded_vs_spec",
    "fig11_first_order",
    "fig12_serverful",
    "fleet_bench",
    "kernels_bench",
    "roofline",
    "scheduler_bench",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger problem sizes (slower)")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated module prefixes")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write a Perfetto trace of an instrumented run "
                         "here (modules whose run() accepts trace_out; "
                         "a .jsonl sibling feeds make_report --trace)")
    args = ap.parse_args(argv)

    mods = MODULES
    if args.only:
        keys = args.only.split(",")
        mods = [m for m in MODULES if any(m.startswith(k) for k in keys)]

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in mods:
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t0 = time.time()
        kwargs = {}
        if args.trace_out and \
                "trace_out" in inspect.signature(mod.run).parameters:
            kwargs["trace_out"] = args.trace_out
        try:
            rows = mod.run(quick=not args.full, **kwargs)
        except Exception as e:   # noqa: BLE001 — surface and continue
            print(f"{mod_name},NaN,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
            failures += 1
            continue
        for r in rows:
            print(f"{r['name']},{r['us']:.1f},{r['derived']}")
        if mod_name in PERSIST_JSON:
            import jax
            # Every persisted row carries a ``path`` field naming what
            # actually executed (fused | fused_tiled | unfused | ref |
            # pallas) so the perf trajectory is attributable; backfill
            # rows from modules that predate the field.
            for r in rows:
                r.setdefault("path", "unknown")
            payload = {
                "meta": {
                    "module": mod_name,
                    "profile": "full" if args.full else "quick",
                    "backend": jax.default_backend(),
                    "jax_version": jax.__version__,
                    "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                         time.gmtime()),
                },
                "rows": rows,
            }
            path = REPO_ROOT / PERSIST_JSON[mod_name]
            path.write_text(json.dumps(payload, indent=1) + "\n")
            print(f"# wrote {path}", file=sys.stderr)
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
