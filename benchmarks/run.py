"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig6,fig9]

Prints ``name,us_per_call,derived`` CSV rows.  Default is the quick profile
(CPU-scaled dataset sizes, same generative models and worker ratios as the
paper's experiments; see repro/configs/paper.py).

Modules listed in ``PERSIST_JSON`` additionally write their rows (plus
backend / jax-version / git-sha / config-hash metadata) to a
``BENCH_*.json`` file at the repo root — the persistent perf trajectory CI
archives per push, so kernel regressions have a baseline to diff against
(see kernels/README.md).  Before overwriting a prior BENCH file the driver
prints a report-only noise-aware diff against it (``repro.obs.diff``), and
``--store`` appends the fresh payload to a cross-run JSONL warehouse
(``repro.obs.store``) for history-aware gating.

Trace/report artifacts default into the git-ignored ``artifacts/``
directory: a bare ``--trace-out run.perfetto.json`` lands at
``artifacts/run.perfetto.json`` (explicit directories are honored).
"""
from __future__ import annotations

import argparse
import inspect
import json
import pathlib
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
ARTIFACTS = REPO_ROOT / "artifacts"

# module -> repo-root JSON file persisting its rows as a perf baseline.
# Two modules may share one file (fleet_bench + scheduler_bench both feed
# BENCH_fleet.json): within one invocation their rows are merged by name
# (later module wins on collision) so the second write doesn't clobber
# the first; ``--store`` still appends each module's own payload
# separately, keyed by its module name.
PERSIST_JSON = {
    "fleet_bench": "BENCH_fleet.json",
    "kernels_bench": "BENCH_kernels.json",
    "scheduler_bench": "BENCH_fleet.json",
    "tenancy_bench": "BENCH_fleet.json",
}

MODULES = [
    "fig1_stragglers",
    "fig6_logistic_synthetic",
    "fig7_epsilon",
    "fig8_small_datasets",
    "fig9_softmax",
    "fig10_coded_vs_spec",
    "fig11_first_order",
    "fig12_serverful",
    "fleet_bench",
    "kernels_bench",
    "roofline",
    "scheduler_bench",
    "tenancy_bench",
]


def _artifact_path(name: str) -> pathlib.Path:
    """Bare filenames land in the git-ignored ``artifacts/`` directory;
    paths with an explicit directory component are honored as-is."""
    p = pathlib.Path(name)
    if p.parent == pathlib.Path("."):
        p = ARTIFACTS / p
    p.parent.mkdir(parents=True, exist_ok=True)
    return p


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger problem sizes (slower)")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated module prefixes")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write a Perfetto trace of an instrumented run "
                         "here (modules whose run() accepts trace_out; "
                         "a .jsonl sibling feeds make_report --trace; "
                         "bare filenames go under artifacts/)")
    ap.add_argument("--store", type=str, default=None,
                    help="append each persisted BENCH payload to this "
                         "cross-run JSONL store (repro.obs.store)")
    ap.add_argument("--console-out", type=str, default=None,
                    help="render the --trace-out run's telemetry (its "
                         ".jsonl sibling) plus the written BENCH rows "
                         "into a self-contained HTML fleet console "
                         "(repro.obs.console); bare filenames go under "
                         "artifacts/")
    args = ap.parse_args(argv)

    if args.console_out and not args.trace_out:
        ap.error("--console-out needs --trace-out (the console renders "
                 "the trace's .jsonl sibling)")

    mods = MODULES
    if args.only:
        keys = args.only.split(",")
        mods = [m for m in MODULES if any(m.startswith(k) for k in keys)]

    print("name,us_per_call,derived")
    failures = 0
    written: dict = {}   # BENCH file -> payload written this invocation
    for mod_name in mods:
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t0 = time.time()
        kwargs = {}
        if args.trace_out and \
                "trace_out" in inspect.signature(mod.run).parameters:
            kwargs["trace_out"] = str(_artifact_path(args.trace_out))
        try:
            rows = mod.run(quick=not args.full, **kwargs)
        except Exception as e:   # noqa: BLE001 — surface and continue
            print(f"{mod_name},NaN,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
            failures += 1
            continue
        for r in rows:
            print(f"{r['name']},{r['us']:.1f},{r['derived']}")
        if mod_name in PERSIST_JSON:
            import jax

            from repro.obs import diff as obs_diff
            from repro.obs import store as obs_store

            # Every persisted row carries a ``path`` field naming what
            # actually executed (fused | fused_tiled | unfused | ref |
            # pallas) so the perf trajectory is attributable; backfill
            # rows from modules that predate the field.
            for r in rows:
                r.setdefault("path", "unknown")
            payload = {
                "meta": {
                    "module": mod_name,
                    "profile": "full" if args.full else "quick",
                    "backend": jax.default_backend(),
                    "jax_version": jax.__version__,
                    "git_sha": obs_store.git_sha(REPO_ROOT),
                    "config_hash": obs_store.config_hash(
                        {"module": mod_name,
                         "profile": "full" if args.full else "quick"}),
                    "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                         time.gmtime()),
                },
                "rows": rows,
            }
            file_rel = PERSIST_JSON[mod_name]
            file_payload = payload
            prior_merge = written.get(file_rel)
            path = REPO_ROOT / file_rel
            if (prior_merge is None and path.exists()
                    and sum(f == file_rel
                            for f in PERSIST_JSON.values()) > 1):
                # Shared BENCH file, first writer this invocation: seed
                # the merge from the rows already on disk so a partial
                # run (e.g. --only tenancy) keeps the other modules'
                # rows instead of clobbering them.  Renamed/removed rows
                # of THIS module are replaced wholesale by name below;
                # stale rows only linger if a module itself is dropped.
                try:
                    prior_merge = json.loads(path.read_text())
                except Exception:   # noqa: BLE001 — corrupt prior file
                    prior_merge = None
            if prior_merge is not None:
                # Another module already wrote this file (this invocation
                # or a prior one): merge by row name instead of
                # clobbering; meta.module tracks every contributor.
                names = {r["name"] for r in rows}
                prior_mods = prior_merge["meta"].get(
                    "module", "unknown").split("+")
                merged_mods = "+".join(
                    [m for m in prior_mods if m != mod_name] + [mod_name])
                file_payload = {
                    "meta": {**payload["meta"], "module": merged_mods},
                    "rows": [r for r in prior_merge["rows"]
                             if r["name"] not in names] + rows,
                }
            if path.exists():
                # Report-only noise-aware diff vs the file being replaced
                # (CI gates via `repro.obs.diff --gate`; here we only warn).
                try:
                    prior = json.loads(path.read_text())
                    rep = obs_diff.diff_bench(prior, file_payload)
                    print(f"# diff vs previous {path.name}: {rep.summary()}",
                          file=sys.stderr)
                    for row in rep.regressions:
                        print(f"#   regression: {row.name}: {row.detail}",
                              file=sys.stderr)
                except Exception as e:  # noqa: BLE001 — diff is best-effort
                    print(f"# diff vs previous {path.name} failed: {e}",
                          file=sys.stderr)
            path.write_text(json.dumps(file_payload, indent=1) + "\n")
            written[file_rel] = file_payload
            print(f"# wrote {path}", file=sys.stderr)
            if args.store:
                store = obs_store.Store(_artifact_path(args.store))
                store.append(obs_store.bench_record(payload))
                print(f"# appended {mod_name} to {store.path}",
                      file=sys.stderr)
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if args.console_out:
        from repro.obs import console as obs_console
        from repro.obs import export as obs_export

        trace_path = str(_artifact_path(args.trace_out))
        jsonl = (trace_path[:-5] if trace_path.endswith(".json")
                 else trace_path) + ".jsonl"
        try:
            rows = obs_export.load_jsonl(jsonl)
        except OSError as e:
            print(f"# console: no trace JSONL at {jsonl} ({e})",
                  file=sys.stderr)
            rows = []
        bench_rows = [r for payload in written.values()
                      for r in payload["rows"]]
        out = _artifact_path(args.console_out)
        obs_console.write_console(out, rows, bench=bench_rows or None,
                                  title="fleet console")
        print(f"# wrote console {out}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
