"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig6,fig9]

Prints ``name,us_per_call,derived`` CSV rows.  Default is the quick profile
(CPU-scaled dataset sizes, same generative models and worker ratios as the
paper's experiments; see repro/configs/paper.py).
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "fig1_stragglers",
    "fig6_logistic_synthetic",
    "fig7_epsilon",
    "fig8_small_datasets",
    "fig9_softmax",
    "fig10_coded_vs_spec",
    "fig11_first_order",
    "fig12_serverful",
    "fleet_bench",
    "kernels_bench",
    "roofline",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger problem sizes (slower)")
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated module prefixes")
    args = ap.parse_args(argv)

    mods = MODULES
    if args.only:
        keys = args.only.split(",")
        mods = [m for m in MODULES if any(m.startswith(k) for k in keys)]

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in mods:
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run(quick=not args.full)
        except Exception as e:   # noqa: BLE001 — surface and continue
            print(f"{mod_name},NaN,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
            failures += 1
            continue
        for r in rows:
            print(f"{r['name']},{r['us']:.1f},{r['derived']}")
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
