"""Fig. 7: EPSILON-profile logistic regression, train AND test error vs
simulated time.  Paper headline: OverSketched Newton >= 46% faster than the
best baseline; gradient coding loses to uncoded due to replication comm.

Extended with a sketch-family sweep (repro.sketching registry): the same
Newton loop is scored per family in simulated wall-clock and solution
quality, one JSON row each."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import best_f, json_row, time_to_target
from repro.sketching import available as sketch_families
from repro.core import (LogisticRegression, NewtonConfig, OverSketchConfig,
                        oversketched_newton)
from repro.core.straggler import StragglerModel
from repro.data import profile_dataset
from repro.optim import GiantConfig, exact_newton, giant


def run(quick: bool = True):
    data = profile_dataset("epsilon", jax.random.PRNGKey(1))
    d = data.x.shape[1]
    obj = LogisticRegression(lam=1e-5)
    w0 = jnp.zeros(d)
    model = StragglerModel()
    iters = 8 if quick else 14

    sk = OverSketchConfig(((15 * d) // 256 + 1) * 256, 256, 0.25)
    osn = oversketched_newton(
        obj, data, w0,
        NewtonConfig(iters=iters, sketch=sk, unit_step=False,
                     coded_block_rows=256, track_test_error=True),
        model=model).history
    exact = exact_newton(obj, data, w0, iters=iters, model=model,
                         unit_step=False, track_test_error=True)
    g_wait = giant(obj, data, w0,
                   GiantConfig(iters=iters + 6, num_workers=100,
                               policy="wait_all", unit_step=False,
                               track_test_error=True),
                   model=model)
    g_code = giant(obj, data, w0,
                   GiantConfig(iters=iters + 6, num_workers=100,
                               policy="gcode", gcode_redundancy=4, unit_step=False,
                               track_test_error=True), model=model)

    target = best_f(osn, exact, g_wait, g_code)
    rows = []
    for name, h in [("osn", osn), ("exact_newton", exact),
                    ("giant_waitall", g_wait), ("giant_gcode", g_code)]:
        t = time_to_target(h, target)
        rows.append({
            "name": f"fig7_{name}",
            "us": (t if t != float("inf") else h["time"][-1]) * 1e6,
            "derived": (f"t_to_target={t:.2f};"
                        f"test_err={h['test_error'][-1]:.4f};"
                        f"final_f={h['fval'][-1]:.5f}"),
        })
    # paper observation: gcode slower than wait-all per-iteration on EPSILON
    rows.append({
        "name": "fig7_gcode_vs_waitall_periter", "us": 0.0,
        "derived": (f"gcode_t={g_code['time'][-1]:.1f};"
                    f"waitall_t={g_wait['time'][-1]:.1f}"),
    })

    # --- sketch-family sweep: head-to-head simulated time + quality --------
    fam_iters = 6 if quick else 10
    for fam in sketch_families():
        h = oversketched_newton(
            obj, data, w0,
            NewtonConfig(iters=fam_iters, sketch=sk, unit_step=False,
                         coded_block_rows=256, sketch_family=fam,
                         track_test_error=True),
            model=model).history
        rows.append(json_row(
            f"fig7_family_{fam}", h["time"][-1] * 1e6,
            family=fam, sim_t=h["time"][-1], final_f=h["fval"][-1],
            gnorm=h["gnorm"][-1], test_err=h["test_error"][-1]))
    return rows
