"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from sweep JSONs,
render telemetry tables from an obs JSONL export, or diff two BENCH files.

  PYTHONPATH=src python -m benchmarks.make_report \
      --single sweep_single_pod.json --multi sweep_multi_pod.json
  PYTHONPATH=src python -m benchmarks.make_report \
      --trace artifacts/run.perfetto.jsonl
  PYTHONPATH=src python -m benchmarks.make_report \
      --diff BENCH_kernels.prev.json BENCH_kernels.json
  PYTHONPATH=src python -m benchmarks.make_report \
      --console artifacts/run.perfetto.jsonl --bench BENCH_fleet.json \
      --out artifacts/console.html

``--trace`` takes the JSONL sibling that ``benchmarks.run --trace-out``
writes next to the Perfetto file, and renders the per-phase time/dollar
breakdown, a critical-path/slack table per recorded iteration DAG, and —
when health monitors were attached — the alert log and per-detector state
(via ``repro.obs``; same formatter the benchmark summaries share).
Incident rows (``repro.obs.incident``) get their own narrative section.

``--console`` takes the same JSONL and renders the self-contained HTML
fleet console (``repro.obs.console``): span timeline, incident
narratives with evidence links, per-tenant SLO burn charts, and — with
``--bench`` — the benchmark row table.  No external assets; CI archives
the file as a build artifact.

``--diff`` renders the noise-aware row-by-row comparison from
``repro.obs.diff`` (report-only; CI gates via ``repro.obs.diff --gate``).
"""
from __future__ import annotations

import argparse
import json


def _fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_table(cells):
    lines = [
        "| arch | shape | mesh | status | args GB/chip | temps GB/chip | "
        "HLO coll GB/chip | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        mesh = "x".join(str(v) for v in c.get("mesh", {}).values()) or "-"
        if "skipped" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | {mesh} | SKIP "
                         f"({c['skipped'][:40]}...) | - | - | - | - |")
            continue
        if "error" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | {mesh} | "
                         f"FAIL {c['error'][:60]} | - | - | - | - |")
            continue
        mem = c["memory"]
        colls = ",".join(f"{k.split('-')[-1][:3]}:{v/1e9:.1f}G"
                         for k, v in sorted(c.get("collectives", {}).items()))
        lines.append(
            f"| {c['arch']} | {c['shape']} | {mesh} | ok | "
            f"{_fmt_bytes(mem['argument_bytes'])} | "
            f"{_fmt_bytes(mem['temp_bytes'])} | "
            f"{_fmt_bytes(c['collective_bytes_per_chip'])} | {colls} |")
    return "\n".join(lines)


def roofline_table(cells):
    lines = [
        "| arch | shape | c (ms) | m (ms) | x (ms) | bound | "
        "MODEL_FLOPs/chip | useful/HLO | MFU bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if "skipped" in c or "error" in c or "analytic" not in c:
            continue
        a = c["analytic"]
        t = a["roofline_seconds"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {1e3*t['compute']:.2f} | "
            f"{1e3*t['memory']:.2f} | {1e3*t['collective']:.2f} | "
            f"{a['bottleneck']} | {c['model_flops_per_chip']:.2e} | "
            f"{c['useful_flop_fraction']:.2f} | {a['mfu_bound']:.3f} |")
    return "\n".join(lines)


def summarize(cells):
    ok = [c for c in cells if "skipped" not in c and "error" not in c]
    skip = [c for c in cells if "skipped" in c]
    fail = [c for c in cells if "error" in c]
    return ok, skip, fail


def trace_report(rows):
    """Per-phase breakdown + per-DAG critical-path tables from obs rows,
    plus alert/detector tables when health monitors were attached."""
    from repro import obs
    out = ["### Per-phase breakdown\n", obs.phase_table(rows)]
    reports = obs.dag_reports_from_rows(rows)
    for i, rep in enumerate(reports):
        out.append(f"\n### Iteration DAG {i}: critical path\n")
        out.append(obs.critical_path_table(rep))
    if not reports:
        out.append("\n(no DAG-dispatched phases with recorded deps)")
    health = next((r for r in rows if r.get("kind") == "health"), None)
    if health is not None:
        alerts = obs.alerts_from_rows(rows)
        out.append(f"\n### Health monitors: {len(alerts)} alert(s)\n")
        if alerts:
            out.append(obs.alert_table(rows))
            out.append("")
        out.append(obs.detector_table(rows))
    incidents = [r for r in rows if r.get("kind") == "incident"]
    if incidents:
        out.append(f"\n### Incidents: {len(incidents)} attributed\n")
        out.append(obs.incident_table(incidents))
    return "\n".join(out)


def diff_report(base_path, new_path):
    from repro.obs import diff as obs_diff
    with open(base_path) as f:
        base = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    rep = obs_diff.diff_bench(base, new)
    return "### Bench diff: " + rep.summary() + "\n\n" + rep.table()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--single", type=str, default=None)
    ap.add_argument("--multi", type=str, default=None)
    ap.add_argument("--trace", type=str, default=None,
                    help="obs JSONL export (from benchmarks.run --trace-out)")
    ap.add_argument("--diff", type=str, nargs=2, default=None,
                    metavar=("BASE", "NEW"),
                    help="render a noise-aware diff of two BENCH_*.json")
    ap.add_argument("--console", type=str, default=None,
                    help="obs JSONL export -> self-contained HTML fleet "
                         "console (span timeline, incidents, SLO burn)")
    ap.add_argument("--bench", type=str, default=None,
                    help="BENCH_*.json whose rows the console tabulates "
                         "(only with --console)")
    ap.add_argument("--out", type=str, default=None)
    args = ap.parse_args(argv)
    modes = sum(bool(m) for m in (args.single, args.trace, args.diff,
                                  args.console))
    if modes != 1:
        ap.error("pass exactly one of --single / --trace / --diff / "
                 "--console")

    if args.console:
        from repro import obs
        rows = obs.load_jsonl(args.console)
        bench_rows = None
        if args.bench:
            with open(args.bench) as f:
                bench_rows = json.load(f).get("rows", [])
        text = obs.render_console(rows, bench=bench_rows,
                                  title="fleet console")
        if args.out:
            with open(args.out, "w") as f:
                f.write(text)
        else:
            print(text)
        return 0

    if args.trace or args.diff:
        if args.trace:
            from repro import obs
            text = trace_report(obs.load_jsonl(args.trace))
        else:
            text = diff_report(*args.diff)
        if args.out:
            with open(args.out, "w") as f:
                f.write(text)
        else:
            print(text)
        return 0

    with open(args.single) as f:
        single = json.load(f)
    out = []
    ok, skip, fail = summarize(single)
    out.append(f"### Single-pod (16x16): {len(ok)} ok, {len(skip)} skipped "
               f"(documented), {len(fail)} failed\n")
    out.append(dryrun_table(single))
    out.append("\n### Roofline (single-pod, analytic terms)\n")
    out.append(roofline_table(single))
    if args.multi:
        with open(args.multi) as f:
            multi = json.load(f)
        ok, skip, fail = summarize(multi)
        out.append(f"\n### Multi-pod (2x16x16): {len(ok)} ok, {len(skip)} "
                   f"skipped, {len(fail)} failed\n")
        out.append(dryrun_table(multi))
    text = "\n".join(out)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
