"""Fleet-engine sweep: fleet size x failure rate x termination policy.

Every cell runs the same fixed workload (a few distributed rounds at a
fixed per-worker flop count) through ``repro.runtime.FleetEngine`` via the
SimClock facade and reports simulated seconds *and* simulated dollars —
the time-vs-cost Pareto data that the fig10/fig12 comparisons sit on.
One extra row self-checks trace record/replay bit-exactness; another runs
a two-regime fleet (per-worker work jumps 4x mid-run) under live health
monitors and reports that the straggler detectors fired on the shift
while attaching them changed no simulated totals.
"""
from __future__ import annotations

import os
import sys
import tempfile

import jax

from benchmarks.common import json_row
from repro import obs
from repro.core.straggler import SimClock, StragglerModel
from repro.runtime import (FleetConfig, TraceRecorder, available_policies,
                           load_trace)

ROUNDS = 5
FLOPS_PER_WORKER = 4e5        # ~0.2 s of work at the default throughput


def _run_cell(num_workers: int, failure_rate: float, policy: str,
              recorder=None, replay=None) -> SimClock:
    fleet = FleetConfig(failure_rate=failure_rate, cold_start_prob=0.1)
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0), fleet=fleet,
                     recorder=recorder, replay=replay)
    k = max(1, int(0.8 * num_workers))
    for r in range(ROUNDS):
        clock.phase(jax.random.PRNGKey(1000 * num_workers + r), num_workers,
                    policy=policy, k=k,
                    flops_per_worker=FLOPS_PER_WORKER, comm_units=1.0)
    return clock


def _two_regime_cell(telemetry=None) -> SimClock:
    """A fleet whose per-worker work jumps 2e5 -> 8e5 flops mid-run: the
    completion tail shifts 4x, exactly what the straggler monitors watch."""
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=FleetConfig(cold_start_prob=0.1),
                     telemetry=telemetry)
    for r in range(12):
        clock.phase(jax.random.PRNGKey(7000 + r), 32, policy="k_of_n",
                    k=25, flops_per_worker=2e5 if r < 6 else 8e5,
                    comm_units=1.0)
    return clock


def run(quick: bool = True):
    sizes = (32, 128) if quick else (32, 128, 512)
    failure_rates = (0.0, 0.05) if quick else (0.0, 0.05, 0.2)
    rows = []
    for n in sizes:
        for f in failure_rates:
            for policy in available_policies():
                clock = _run_cell(n, f, policy)
                rows.append(json_row(
                    f"fleet_n{n}_fail{int(100 * f)}_{policy}",
                    clock.time * 1e6,
                    sim_s=clock.time, usd=clock.dollars,
                    invocations=clock.ledger.invocations,
                    gb_s=clock.ledger.gb_seconds))

    # Record/replay self-check: one cell recorded, replayed, compared.
    rec = TraceRecorder()
    recorded = _run_cell(64, 0.1, "k_of_n", recorder=rec)
    with tempfile.NamedTemporaryFile("w", suffix=".jsonl",
                                     delete=False) as tmp:
        path = tmp.name
    try:
        rec.dump(path)
        replayed = _run_cell(64, 0.1, "k_of_n", replay=load_trace(path))
        exact = int(replayed.time == recorded.time
                    and replayed.dollars == recorded.dollars)
    finally:
        os.unlink(path)
    rows.append(json_row("fleet_trace_replay", recorded.time * 1e6,
                         sim_s=recorded.time, usd=recorded.dollars,
                         replay_exact=exact))

    # Health-monitor self-check: the 4x work shift must alert, and the
    # monitored run must land on the exact same simulated totals.
    plain = _two_regime_cell()
    tel = obs.Telemetry(monitors=True)
    monitored = _two_regime_cell(telemetry=tel)
    shift_alerts = [a for a in tel.health.alerts
                    if a.metric in ("worker.completion_s",
                                    "phase.tail_p95_s")]
    rows.append(json_row(
        "fleet_two_regime_monitored", monitored.time * 1e6,
        sim_s=monitored.time, usd=monitored.dollars,
        alerts=len(tel.health.alerts), shift_alerts=len(shift_alerts),
        monitor_inert=int(monitored.time == plain.time
                          and monitored.dollars == plain.dollars)))
    print(obs.bench_rows_table(rows), file=sys.stderr)
    return rows
