"""Fleet-engine sweep: fleet size x failure rate x termination policy.

Every cell runs the same fixed workload (a few distributed rounds at a
fixed per-worker flop count) through ``repro.runtime.FleetEngine`` via the
SimClock facade and reports simulated seconds *and* simulated dollars —
the time-vs-cost Pareto data that the fig10/fig12 comparisons sit on.
One extra row self-checks trace record/replay bit-exactness; another runs
a two-regime fleet (per-worker work jumps 4x mid-run) under live health
monitors and reports that the straggler detectors fired on the shift
while attaching them changed no simulated totals.

The chaos sweep at the end drives the same fixed workload through every
registered fault scenario (``repro.runtime.faults``), raw and with its
scenario-specific mitigation, and prices each against one shared healthy
baseline (``overhead_s`` / ``overhead_usd`` ratios) — what each failure
mode costs and what its mitigation buys back.  The ``corruption``
scenario is scored on an end-to-end coded Newton solve instead (the
generic drive never decodes anything, so silent corruption is free
there): detection off shows the poisoned solve stalling, detection on
recovers the healthy optimum and pays for it in relaunches.
"""
from __future__ import annotations

import os
import sys
import tempfile

import jax

from benchmarks.common import json_row
from repro import obs, scheduler
from repro.core.straggler import SimClock, StragglerModel
from repro.runtime import (FleetConfig, TraceRecorder, available_policies,
                           available_scenarios, get_scenario, load_trace)

ROUNDS = 5
FLOPS_PER_WORKER = 4e5        # ~0.2 s of work at the default throughput

#: Chaos drive geometry: one shared healthy baseline, every scenario cell
#: a one-knob delta from it.
CHAOS_WORKERS = 32
CHAOS_ROUNDS = 8

#: scenario -> non-default fault knobs for its raw chaos cell.  The
#: registry defaults stay mild; the burst cell turns the dial to where
#: the failure mode is actually worth mitigating (the default AZ event
#: barely dents the drive — the engine's fast per-worker retries absorb
#: it at ~1.06x).
CHAOS_KNOBS = {
    "az_burst": dict(kill_fraction=0.85, t_end=6.0),
}

#: scenario -> the drive-knob delta that mitigates it.  ``run()`` iterates
#: ``available_scenarios()`` against this table, so registering a new
#: scenario without deciding its mitigation fails the bench loudly
#: instead of silently losing chaos coverage.
CHAOS_MITIGATIONS = {
    # Correlated burst deaths: the paper's own answer — provisioned
    # redundancy plus a partial wait, so the phase never needs the killed
    # workers' serial retry chains.  (Hedged duplicates do NOT help here:
    # the duplicates are exposed to the same burst window.)
    "az_burst": dict(policy="k_of_n", k=26),
    # Concurrency cap of 8: size the fleet under the cap and give each
    # worker 4x the work instead of paying rejection/backoff storms.
    "throttle": dict(num_workers=8, flops=4 * FLOPS_PER_WORKER),
    # Transient S3 errors fatten the per-attempt tail: the same
    # redundancy margin absorbs the unlucky GET/PUT retry chains
    # completely (the k-th arrival never sits in the retried tail).
    "s3_transient": dict(policy="k_of_n", k=26),
    # OOM kills fire iff memory < working set: provision at the declared
    # working set (costlier gb-seconds, no 90%-wasted killed runs).
    "oom": dict(memory_gb=1.0),
    # Idle-container cull: prewarm enough spares that the surviving 25%
    # still covers the fleet.
    "pool_death": dict(prewarmed=160),
}


def _run_cell(num_workers: int, failure_rate: float, policy: str,
              recorder=None, replay=None) -> SimClock:
    fleet = FleetConfig(failure_rate=failure_rate, cold_start_prob=0.1)
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0), fleet=fleet,
                     recorder=recorder, replay=replay)
    k = max(1, int(0.8 * num_workers))
    for r in range(ROUNDS):
        clock.phase(jax.random.PRNGKey(1000 * num_workers + r), num_workers,
                    policy=policy, k=k,
                    flops_per_worker=FLOPS_PER_WORKER, comm_units=1.0)
    return clock


def _two_regime_cell(telemetry=None) -> SimClock:
    """A fleet whose per-worker work jumps 2e5 -> 8e5 flops mid-run: the
    completion tail shifts 4x, exactly what the straggler monitors watch."""
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=FleetConfig(cold_start_prob=0.1),
                     telemetry=telemetry)
    for r in range(12):
        clock.phase(jax.random.PRNGKey(7000 + r), 32, policy="k_of_n",
                    k=25, flops_per_worker=2e5 if r < 6 else 8e5,
                    comm_units=1.0)
    return clock


def _chaos_drive(faults=None, *, policy="wait_all", num_workers=CHAOS_WORKERS,
                 flops=FLOPS_PER_WORKER, k=None, memory_gb=0.5,
                 prewarmed=CHAOS_WORKERS, telemetry=None) -> SimClock:
    """The fixed chaos workload: CHAOS_ROUNDS phases on a warm-pooled
    fleet.  Every phase declares a 1 GB working set against a 0.5 GB
    Lambda — inert unless an OomSpec is in the plan, exactly the trap the
    ``oom`` scenario springs."""
    pool = scheduler.WarmPool(ttl=300.0, prewarmed=prewarmed)
    clock = SimClock(StragglerModel(p_tail=0.05, tail_hi=3.0),
                     fleet=FleetConfig(cold_start_prob=0.3),
                     pool=pool, faults=faults, telemetry=telemetry)
    for r in range(CHAOS_ROUNDS):
        clock.phase(jax.random.PRNGKey(9000 + r), num_workers,
                    policy=policy, k=k, flops_per_worker=flops,
                    comm_units=1.0, memory_gb=memory_gb,
                    working_set_gb=1.0)
    return clock


def _corruption_newton(faults=None, detection=True):
    """Small coded Newton solve (the corruption scenario's scoreboard):
    returns (final gnorm, clock)."""
    import jax.numpy as jnp

    from repro.core.newton import NewtonConfig, oversketched_newton
    from repro.core.objectives import Dataset, LogisticRegression
    from repro.core.sketch import OverSketchConfig

    key = jax.random.PRNGKey(0)
    n, d = 256, 8
    x = jax.random.normal(key, (n, d))
    y = jnp.sign(x @ jax.random.normal(jax.random.fold_in(key, 1), (d,)))
    cfg = NewtonConfig(iters=8,
                       sketch=OverSketchConfig(sketch_dim=64, block_size=16,
                                               straggler_tolerance=0.25),
                       coded_block_rows=32, corruption_detection=detection)
    clock = SimClock(StragglerModel(), faults=faults)
    res = oversketched_newton(LogisticRegression(lam=1e-3),
                              Dataset(x=x, y=y), jnp.zeros((d,)), cfg, clock)
    return res.history["gnorm"][-1], clock


def run(quick: bool = True):
    sizes = (32, 128) if quick else (32, 128, 512)
    failure_rates = (0.0, 0.05) if quick else (0.0, 0.05, 0.2)
    rows = []
    for n in sizes:
        for f in failure_rates:
            for policy in available_policies():
                clock = _run_cell(n, f, policy)
                rows.append(json_row(
                    f"fleet_n{n}_fail{int(100 * f)}_{policy}",
                    clock.time * 1e6,
                    sim_s=clock.time, usd=clock.dollars,
                    invocations=clock.ledger.invocations,
                    gb_s=clock.ledger.gb_seconds))

    # Record/replay self-check: one cell recorded, replayed, compared.
    rec = TraceRecorder()
    recorded = _run_cell(64, 0.1, "k_of_n", recorder=rec)
    with tempfile.NamedTemporaryFile("w", suffix=".jsonl",
                                     delete=False) as tmp:
        path = tmp.name
    try:
        rec.dump(path)
        replayed = _run_cell(64, 0.1, "k_of_n", replay=load_trace(path))
        exact = int(replayed.time == recorded.time
                    and replayed.dollars == recorded.dollars)
    finally:
        os.unlink(path)
    rows.append(json_row("fleet_trace_replay", recorded.time * 1e6,
                         sim_s=recorded.time, usd=recorded.dollars,
                         replay_exact=exact))

    # Health-monitor self-check: the 4x work shift must alert, and the
    # monitored run must land on the exact same simulated totals.
    plain = _two_regime_cell()
    tel = obs.Telemetry(monitors=True)
    monitored = _two_regime_cell(telemetry=tel)
    shift_alerts = [a for a in tel.health.alerts
                    if a.metric in ("worker.completion_s",
                                    "phase.tail_p95_s")]
    rows.append(json_row(
        "fleet_two_regime_monitored", monitored.time * 1e6,
        sim_s=monitored.time, usd=monitored.dollars,
        alerts=len(tel.health.alerts), shift_alerts=len(shift_alerts),
        monitor_inert=int(monitored.time == plain.time
                          and monitored.dollars == plain.dollars)))
    # ---------------------------------------------------------- chaos sweep
    # One shared healthy baseline; every registered fault scenario runs
    # raw and mitigated against it.  Ratios > 1 are the price of the
    # failure mode (or of its mitigation — OOM-safe sizing and extra
    # prewarm cost real gb-seconds, reported honestly).
    healthy = _chaos_drive()
    rows.append(json_row("chaos_healthy", healthy.time * 1e6,
                         sim_s=healthy.time, usd=healthy.dollars,
                         invocations=healthy.ledger.invocations))

    def chaos_row(nm, clock, **extra):
        rows.append(json_row(
            nm, clock.time * 1e6, sim_s=clock.time, usd=clock.dollars,
            invocations=clock.ledger.invocations,
            overhead_s=clock.time / healthy.time,
            overhead_usd=clock.dollars / healthy.dollars, **extra))

    for scen in available_scenarios():
        if scen == "corruption":
            continue   # scored on the coded Newton solve below
        if scen not in CHAOS_MITIGATIONS:
            raise KeyError(
                f"scenario {scen!r} has no entry in CHAOS_MITIGATIONS — "
                "decide its mitigation to keep chaos coverage total")
        plan = get_scenario(scen, **CHAOS_KNOBS.get(scen, {}))
        chaos_row(f"chaos_{scen}", _chaos_drive(plan))
        chaos_row(f"chaos_{scen}_mitigated",
                  _chaos_drive(plan, **CHAOS_MITIGATIONS[scen]))

    # Incident-attribution smoke (repro.obs.incident): a mid-run AZ burst
    # under live monitors must attribute back to az_burst, and running
    # the attribution pipeline must change no simulated totals.  CI's
    # bench-smoke asserts cause_match and attribution_inert off this row.
    def _burst_plan():
        return get_scenario("az_burst", kill_fraction=0.85,
                            t_start=0.5 * healthy.time,
                            t_end=0.5 * healthy.time + 3.0)

    atel = obs.Telemetry(monitors=True)
    attributed = _chaos_drive(_burst_plan(), telemetry=atel)
    incidents = obs.attribute(atel, faults=_burst_plan())
    plain_burst = _chaos_drive(_burst_plan())
    top = incidents[0].cause if incidents else "none"
    chaos_row("chaos_attributed", attributed,
              incidents=len(incidents), top_cause=top,
              cause_match=int(top == "az_burst"),
              attribution_inert=int(attributed.time == plain_burst.time
                                    and attributed.dollars
                                    == plain_burst.dollars))

    # Corruption: silent wrong results only matter where something decodes
    # them, so this cell is an end-to-end coded Newton solve.  Blind
    # (detection off) converges to the wrong place for free; detection
    # pays relaunches/full-arrival waits to recover the healthy optimum.
    gn_h, ck_h = _corruption_newton()
    gtol = 1e-3
    rows.append(json_row("chaos_newton_healthy", ck_h.time * 1e6,
                         sim_s=ck_h.time, usd=ck_h.dollars,
                         converged=int(gn_h < gtol)))
    gn_b, ck_b = _corruption_newton(get_scenario("corruption"),
                                    detection=False)
    rows.append(json_row("chaos_corruption", ck_b.time * 1e6,
                         sim_s=ck_b.time, usd=ck_b.dollars,
                         overhead_s=ck_b.time / ck_h.time,
                         overhead_usd=ck_b.dollars / ck_h.dollars,
                         converged=int(gn_b < gtol)))
    gn_m, ck_m = _corruption_newton(get_scenario("corruption"),
                                    detection=True)
    rows.append(json_row("chaos_corruption_mitigated", ck_m.time * 1e6,
                         sim_s=ck_m.time, usd=ck_m.dollars,
                         overhead_s=ck_m.time / ck_h.time,
                         overhead_usd=ck_m.dollars / ck_h.dollars,
                         converged=int(gn_m < gtol)))

    print(obs.bench_rows_table(rows), file=sys.stderr)
    return rows
