"""Fig. 1: straggler tail of a 3600-worker distributed job.

Paper: median ~135 s, ~2% of workers up to ~180 s.  We sample the calibrated
straggler model at the paper's scale and report the median, the tail
fraction and the p99/median ratio.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core.straggler import StragglerModel


def run(quick: bool = True):
    model = StragglerModel(base_time=135.0, invoke_overhead=0.0)
    times = np.asarray(model.sample_times(jax.random.PRNGKey(0), 3600))
    med = float(np.median(times))
    frac_tail = float((times > 1.25 * med).mean())
    p99 = float(np.percentile(times, 99))
    mx = float(times.max())
    return [{
        "name": "fig1_straggler_tail",
        "us": med * 1e6,
        "derived": (f"median_s={med:.1f};tail_frac={frac_tail:.3f};"
                    f"p99_s={p99:.1f};max_s={mx:.1f}"),
    }]
