"""Multi-tenant frontier sweep: throughput vs latency vs dollars at scale.

Every cell pushes the same seeded workload (1k jobs quick / 10k full,
heterogeneous Newton/GIANT/matvec templates from ``repro.tenancy``)
through one shared discrete-event fleet under a different platform
policy, and reports the three axes the paper's economics live on —
completed-jobs-per-second, job latency tail, and total dollars
(provisioned-concurrency idle billing included):

  - ``nopool_open``: no warm pool, admit everything — the baseline where
    every phase pays i.i.d. cold-start odds and the platform is free of
    provisioned cost.
  - ``shared_pool``: one ``WarmPool`` shared by every tenant, statically
    provisioned; idle reserve bills real provisioned-concurrency
    GB-seconds.
  - ``pool_aware``: same pool, plus slack-spending dispatch (delay an
    off-critical-path phase within its CPM slack to land on warm
    containers).
  - ``autoscale``: empty reserve at t=0, arrival-rate autoscaler sizes it
    (Little's-law target, EWMA-smoothed) — dollars follow load.
  - ``slo_admission``: SLO-aware admission on top — infeasible jobs are
    refused at arrival instead of admitted to fail.
  - ``burst``: the whole workload arrives in ~1 simulated second — peak
    in-flight concurrency ~= the full job count, the "thousands of
    concurrent jobs" regime of the ROADMAP item.

A final self-check row re-runs one policy cell twice and reports
bit-identity of (seconds, dollars, warm/cold phase log) — the tenancy
determinism contract, continuously measured.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import json_row
from repro.core.straggler import SimClock, StragglerModel
from repro.runtime import FleetConfig
from repro.scheduler.pool import WarmPool
from repro.tenancy import (AdmissionPolicy, Autoscaler, JobScheduler,
                           TenancyConfig, WorkloadConfig,
                           generate_workload)

SEED = 0
POOL_TTL = 120.0
POOL_PREWARMED = 200
#: Cold starts are real in every cell: without a pool each attempt flips
#: this i.i.d. coin; with a pool the coin is replaced by actual container
#: reuse — that substitution is the frontier being measured.
FLEET = FleetConfig(cold_start_prob=0.3)

#: Admit-everything policy for the open cells: the cap is never the
#: binding constraint, so the frontier isolates pool + dispatch effects.
OPEN = AdmissionPolicy(max_inflight=1_000_000, queue=True, slo_aware=False)


def _drive(jobs, pool=None, config=TenancyConfig(admission=OPEN)):
    clock = SimClock(StragglerModel(), fleet=FLEET, pool=pool)
    sched = JobScheduler(clock, jax.random.PRNGKey(SEED), jobs, config)
    return sched.run()


def _row(name: str, wall_s: float, res, pool=None) -> dict:
    s = res.summary()
    warm_rate = 0.0
    if pool is not None and (pool.warm_hits + pool.cold_starts):
        warm_rate = pool.warm_hits / (pool.warm_hits + pool.cold_starts)
    return json_row(
        name, s["seconds"] * 1e6,
        sim_s=s["seconds"], usd=s["dollars"],
        jobs=s["jobs"], completed=s["completed"],
        rejected=s["rejected"], slo_miss=s["slo_misses"],
        throughput=s["throughput"], peak_inflight=s["peak_inflight"],
        lat_p50=s["latency_p50"], lat_p95=s["latency_p95"],
        prov_gb_s=s["provisioned_gb_seconds"], warm_rate=warm_rate,
        wall_s=wall_s)


def run(quick: bool = True):
    n_jobs = 1_000 if quick else 10_000
    rate = 60.0 if quick else 150.0
    jobs = generate_workload(WorkloadConfig(seed=SEED, rate=rate,
                                            n_jobs=n_jobs))
    rows = []

    def cell(name, pool=None, config=TenancyConfig(admission=OPEN)):
        t0 = time.time()
        res = _drive(jobs, pool=pool, config=config)
        rows.append(_row(f"tenancy_{name}", time.time() - t0, res,
                         pool=pool))
        return res

    cell("nopool_open")
    cell("shared_pool", pool=WarmPool(ttl=POOL_TTL,
                                      prewarmed=POOL_PREWARMED))
    cell("pool_aware", pool=WarmPool(ttl=POOL_TTL,
                                     prewarmed=POOL_PREWARMED),
         config=TenancyConfig(admission=OPEN, pool_aware=True))
    cell("autoscale", pool=WarmPool(ttl=POOL_TTL, prewarmed=0),
         config=TenancyConfig(admission=OPEN, pool_aware=True,
                              autoscaler=Autoscaler(max_provisioned=400)))
    cell("slo_admission", pool=WarmPool(ttl=POOL_TTL,
                                        prewarmed=POOL_PREWARMED),
         config=TenancyConfig(
             admission=AdmissionPolicy(max_inflight=256, queue=True,
                                       slo_aware=True),
             pool_aware=True))

    # The "thousands of concurrent jobs" regime: the same job count
    # compressed into ~1 simulated second of arrivals, open admission —
    # peak_inflight approaches n_jobs.
    burst = generate_workload(WorkloadConfig(seed=SEED, rate=float(n_jobs),
                                             n_jobs=n_jobs))
    burst_pool = WarmPool(ttl=POOL_TTL, prewarmed=POOL_PREWARMED)
    t0 = time.time()
    res = _drive(burst, pool=burst_pool)
    rows.append(_row("tenancy_burst", time.time() - t0, res,
                     pool=burst_pool))

    # Determinism self-check: same seed + same trace, twice, smaller run
    # (the contract is bit-identity, not speed).
    small = generate_workload(WorkloadConfig(seed=SEED, rate=rate,
                                             n_jobs=min(200, n_jobs)))
    cfg = TenancyConfig(admission=OPEN, pool_aware=True)
    a = _drive(small, pool=WarmPool(ttl=POOL_TTL, prewarmed=32),
               config=cfg)
    b = _drive(small, pool=WarmPool(ttl=POOL_TTL, prewarmed=32),
               config=cfg)
    exact = int(a.seconds == b.seconds and a.dollars == b.dollars
                and a.phase_log == b.phase_log)
    rows.append(json_row("tenancy_determinism", a.seconds * 1e6,
                         sim_s=a.seconds, usd=a.dollars, exact=exact))
    return rows
