"""Multi-tenant frontier sweep: throughput vs latency vs dollars at scale.

Every cell pushes the same seeded workload (1k jobs quick / 10k full,
heterogeneous Newton/GIANT/matvec templates from ``repro.tenancy``)
through one shared discrete-event fleet under a different platform
policy, and reports the three axes the paper's economics live on —
completed-jobs-per-second, job latency tail, and total dollars
(provisioned-concurrency idle billing included):

  - ``nopool_open``: no warm pool, admit everything — the baseline where
    every phase pays i.i.d. cold-start odds and the platform is free of
    provisioned cost.
  - ``shared_pool``: one ``WarmPool`` shared by every tenant, statically
    provisioned; idle reserve bills real provisioned-concurrency
    GB-seconds.
  - ``pool_aware``: same pool, plus slack-spending dispatch (delay an
    off-critical-path phase within its CPM slack to land on warm
    containers).
  - ``autoscale``: empty reserve at t=0, arrival-rate autoscaler sizes it
    (Little's-law target, EWMA-smoothed) — dollars follow load.
  - ``slo_admission``: SLO-aware admission on top — infeasible jobs are
    refused at arrival instead of admitted to fail.
  - ``burst``: the whole workload arrives in ~1 simulated second — peak
    in-flight concurrency ~= the full job count, the "thousands of
    concurrent jobs" regime of the ROADMAP item.
  - ``budget_slo``: per-tenant error budgets (``repro.obs.slo``) with
    budget-aware admission — a tenant whose SLO burn pages sheds *its
    own* arrivals while every other tenant rides undisturbed; a sibling
    check shows tracking alone (``budget_aware=False``) is pure
    observation (bit-identical totals with and without SLO policies).

A final self-check row re-runs one policy cell twice and reports
bit-identity of (seconds, dollars, warm/cold phase log) — the tenancy
determinism contract, continuously measured.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import json_row
from repro import obs
from repro.core.straggler import SimClock, StragglerModel
from repro.obs.slo import SloPolicy
from repro.runtime import FleetConfig
from repro.scheduler.pool import WarmPool
from repro.tenancy import (AdmissionPolicy, Autoscaler, JobScheduler,
                           TenancyConfig, WorkloadConfig,
                           generate_workload)

SEED = 0
POOL_TTL = 120.0
POOL_PREWARMED = 200
#: Cold starts are real in every cell: without a pool each attempt flips
#: this i.i.d. coin; with a pool the coin is replaced by actual container
#: reuse — that substitution is the frontier being measured.
FLEET = FleetConfig(cold_start_prob=0.3)

#: Admit-everything policy for the open cells: the cap is never the
#: binding constraint, so the frontier isolates pool + dispatch effects.
OPEN = AdmissionPolicy(max_inflight=1_000_000, queue=True, slo_aware=False)


def _drive(jobs, pool=None, config=TenancyConfig(admission=OPEN)):
    clock = SimClock(StragglerModel(), fleet=FLEET, pool=pool)
    sched = JobScheduler(clock, jax.random.PRNGKey(SEED), jobs, config)
    return sched.run()


def _row(name: str, wall_s: float, res, pool=None) -> dict:
    s = res.summary()
    warm_rate = 0.0
    if pool is not None and (pool.warm_hits + pool.cold_starts):
        warm_rate = pool.warm_hits / (pool.warm_hits + pool.cold_starts)
    return json_row(
        name, s["seconds"] * 1e6,
        sim_s=s["seconds"], usd=s["dollars"],
        jobs=s["jobs"], completed=s["completed"],
        rejected=s["rejected"], slo_miss=s["slo_misses"],
        throughput=s["throughput"], peak_inflight=s["peak_inflight"],
        lat_p50=s["latency_p50"], lat_p95=s["latency_p95"],
        prov_gb_s=s["provisioned_gb_seconds"], warm_rate=warm_rate,
        wall_s=wall_s)


def run(quick: bool = True):
    n_jobs = 1_000 if quick else 10_000
    rate = 60.0 if quick else 150.0
    jobs = generate_workload(WorkloadConfig(seed=SEED, rate=rate,
                                            n_jobs=n_jobs))
    rows = []

    def cell(name, pool=None, config=TenancyConfig(admission=OPEN)):
        t0 = time.time()
        res = _drive(jobs, pool=pool, config=config)
        rows.append(_row(f"tenancy_{name}", time.time() - t0, res,
                         pool=pool))
        return res

    cell("nopool_open")
    cell("shared_pool", pool=WarmPool(ttl=POOL_TTL,
                                      prewarmed=POOL_PREWARMED))
    cell("pool_aware", pool=WarmPool(ttl=POOL_TTL,
                                     prewarmed=POOL_PREWARMED),
         config=TenancyConfig(admission=OPEN, pool_aware=True))
    cell("autoscale", pool=WarmPool(ttl=POOL_TTL, prewarmed=0),
         config=TenancyConfig(admission=OPEN, pool_aware=True,
                              autoscaler=Autoscaler(max_provisioned=400)))
    cell("slo_admission", pool=WarmPool(ttl=POOL_TTL,
                                        prewarmed=POOL_PREWARMED),
         config=TenancyConfig(
             admission=AdmissionPolicy(max_inflight=256, queue=True,
                                       slo_aware=True),
             pool_aware=True))

    # The "thousands of concurrent jobs" regime: the same job count
    # compressed into ~1 simulated second of arrivals, open admission —
    # peak_inflight approaches n_jobs.
    burst = generate_workload(WorkloadConfig(seed=SEED, rate=float(n_jobs),
                                             n_jobs=n_jobs))
    burst_pool = WarmPool(ttl=POOL_TTL, prewarmed=POOL_PREWARMED)
    t0 = time.time()
    res = _drive(burst, pool=burst_pool)
    rows.append(_row("tenancy_burst", time.time() - t0, res,
                     pool=burst_pool))

    # Error-budget plane (repro.obs.slo): a deliberately-tight serving
    # objective burns its budget; budget-aware admission sheds exactly
    # that tenant's arrivals once fast+slow burn both page.
    slo_policies = {
        "serving": SloPolicy(latency_target_s=0.15, deadline_rate=0.9,
                             fast_window_s=10.0, slow_window_s=40.0),
        "batch": SloPolicy(latency_target_s=60.0, deadline_rate=0.5),
        "train": SloPolicy(latency_target_s=60.0, deadline_rate=0.5),
    }
    budget_adm = AdmissionPolicy(max_inflight=256, queue=True,
                                 slo_aware=False, budget_aware=True)
    tel = obs.Telemetry()
    pool = WarmPool(ttl=POOL_TTL, prewarmed=POOL_PREWARMED)
    t0 = time.time()
    clock = SimClock(StragglerModel(), fleet=FLEET, pool=pool,
                     telemetry=tel)
    res = JobScheduler(clock, jax.random.PRNGKey(SEED), jobs,
                       TenancyConfig(admission=budget_adm, pool_aware=True,
                                     slo=slo_policies)).run()
    shed = sum(c.value for n, c in tel.metrics.counters.items()
               if n.endswith(".budget_shed"))
    summ = tel.slo.summary()
    row = _row("tenancy_budget_slo", time.time() - t0, res, pool=pool)
    row["derived"] += (f";budget_shed={int(shed)}"
                       + "".join(f";{t}_budget="
                                 f"{summ[t]['budget_remaining']:.3f}"
                                 for t in sorted(summ)))
    rows.append(row)

    # Determinism self-check: same seed + same trace, twice, smaller run
    # (the contract is bit-identity, not speed).
    small = generate_workload(WorkloadConfig(seed=SEED, rate=rate,
                                             n_jobs=min(200, n_jobs)))
    cfg = TenancyConfig(admission=OPEN, pool_aware=True)
    a = _drive(small, pool=WarmPool(ttl=POOL_TTL, prewarmed=32),
               config=cfg)
    b = _drive(small, pool=WarmPool(ttl=POOL_TTL, prewarmed=32),
               config=cfg)
    exact = int(a.seconds == b.seconds and a.dollars == b.dollars
                and a.phase_log == b.phase_log)
    # SLO tracking alone must be pure observation: attach the policies
    # with budget_aware off and nothing simulated may move.
    c = _drive(small, pool=WarmPool(ttl=POOL_TTL, prewarmed=32),
               config=dataclasses.replace(cfg, slo=slo_policies))
    slo_inert = int(c.seconds == a.seconds and c.dollars == a.dollars
                    and c.phase_log == a.phase_log)
    rows.append(json_row("tenancy_determinism", a.seconds * 1e6,
                         sim_s=a.seconds, usd=a.dollars, exact=exact,
                         slo_inert=slo_inert))
    return rows
