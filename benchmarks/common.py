"""Shared benchmark harness utilities."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall-clock microseconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def time_to_target(history: Dict[str, List[float]], f_target: float) -> float:
    """Simulated seconds until fval <= target (inf if never)."""
    for f, t in zip(history["fval"], history["time"]):
        if f <= f_target:
            return t
    return float("inf")


def best_f(*histories, rel: float = 0.01) -> float:
    """A common reachable target: rel-relative above the best final value
    (1% default — the accuracy regime the paper's figures compare at)."""
    best = min(h["fval"][-1] for h in histories)
    return best * (1.0 + rel) + 1e-6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def json_row(name: str, us: float, **payload) -> Dict[str, object]:
    """One JSON-ready benchmark row; payload keys land in ``derived`` as
    ``k=v`` pairs (CSV-safe, no commas) so BENCH_*.json trajectories can
    track each key — e.g. one row per sketch family in the fig7 sweep."""
    def fmt(v):
        return f"{v:.4g}" if isinstance(v, float) else str(v)
    return {"name": name, "us": us,
            "derived": ";".join(f"{k}={fmt(v)}" for k, v in payload.items())}
