"""Kernel microbenchmarks: Pallas (interpret on CPU) vs pure-jnp reference.

On this container the interpreter dominates wall-clock, so the *reference*
implementations provide the meaningful CPU numbers and the Pallas variants
are validated for correctness+shape coverage; on TPU the same harness times
the compiled kernels.  Derived column reports achieved GFLOP/s of the ref.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels import ops, ref


def run(quick: bool = True):
    key = jax.random.PRNGKey(0)
    rows = []

    # count-sketch apply
    k, n, d, b = (8, 4096, 256, 256) if quick else (10, 20_000, 1000, 512)
    kh, ks, ka = jax.random.split(key, 3)
    h = jax.random.randint(kh, (k, n), 0, b, dtype=jnp.int32)
    sg = jax.random.rademacher(ks, (k, n), dtype=jnp.float32)
    a = jax.random.normal(ka, (n, d))
    f_ref = jax.jit(lambda: ref.count_sketch_apply(h, sg, a, b))
    us = time_fn(f_ref)
    flops = 2.0 * k * n * d
    rows.append({"name": "kernel_count_sketch_ref", "us": us,
                 "derived": f"gflops={flops/us/1e3:.2f};shape=({k},{n},{d})"})
    out_p = ops.count_sketch_apply(h, sg, a, b)
    out_r = f_ref()
    err = float(jnp.abs(out_p - out_r).max())
    rows.append({"name": "kernel_count_sketch_pallas_check", "us": 0.0,
                 "derived": f"max_err={err:.2e}"})

    # oversketch gram
    a_t = jax.random.normal(key, (k, b, d))
    surv = jnp.ones((k,), bool).at[0].set(False)
    f_ref2 = jax.jit(lambda: ref.oversketch_gram(a_t, surv))
    us2 = time_fn(f_ref2)
    flops2 = 2.0 * k * b * d * d
    rows.append({"name": "kernel_oversketch_gram_ref", "us": us2,
                 "derived": f"gflops={flops2/us2/1e3:.2f}"})
    err2 = float(jnp.abs(ops.oversketch_gram(a_t, surv) - f_ref2()).max())
    rows.append({"name": "kernel_oversketch_gram_pallas_check", "us": 0.0,
                 "derived": f"max_err={err2:.2e}"})

    # fused sketch->gram streaming kernel vs unfused apply+gram (the
    # two-HBM-round-trip baseline it replaces).  The 1/sqrt(n) row scale
    # keeps Gram entries O(1) so max_err is an absolute float32 figure.
    kg, ng, dg, bg = (6, 4096, 256, 256) if quick else (10, 20_000, 512, 512)
    kh2, ks2, ka2, kr2 = jax.random.split(jax.random.fold_in(key, 2), 4)
    h2 = jax.random.randint(kh2, (kg, ng), 0, bg, dtype=jnp.int32)
    sg2 = jax.random.rademacher(ks2, (kg, ng), dtype=jnp.float32)
    a2 = jax.random.normal(ka2, (ng, dg)) / math.sqrt(ng)
    surv = jnp.ones((kg,), bool).at[0].set(False)
    gram_fl = 2.0 * kg * bg * dg * dg
    # Per-row flop counts match what each implementation actually executes:
    # fused kernel = dense encode matmul + gram; scatter-style count ref =
    # one signed add per element; FWHT ref = butterfly.
    flops_fused = 2.0 * kg * ng * bg * dg + gram_fl
    flops_count_ref = 2.0 * kg * ng * dg + gram_fl
    n_pad_s = 1 << (ng - 1).bit_length()
    flops_srht_ref = kg * n_pad_s * math.log2(n_pad_s) * dg + gram_fl
    f_unf = jax.jit(lambda: ref.sketch_gram_count(h2, sg2, a2, bg, surv))
    us_unf = time_fn(f_unf)
    rows.append({"name": "kernel_sketch_gram_count_unfused_ref",
                 "us": us_unf,
                 "derived": (f"gflops={flops_count_ref/us_unf/1e3:.2f};"
                             f"shape=({kg},{ng},{dg},{bg})")})
    f_fus = lambda: ops.sketch_gram_count(h2, sg2, a2, bg, surv)
    us_fus = time_fn(f_fus, iters=3, warmup=1)
    err_f = float(jnp.abs(f_fus() - f_unf()).max())
    rows.append({"name": "kernel_sketch_gram_count_fused", "us": us_fus,
                 "derived": (f"gflops={flops_fused/us_fus/1e3:.2f};"
                             f"max_err={err_f:.2e}")})

    rws = jax.random.randint(kr2, (kg, bg), 0, n_pad_s, dtype=jnp.int32)
    f_unf_s = jax.jit(lambda: ref.sketch_gram_srht(rws, sg2, a2, surv))
    us_unf_s = time_fn(f_unf_s)
    rows.append({"name": "kernel_sketch_gram_srht_unfused_ref",
                 "us": us_unf_s,
                 "derived": (f"gflops={flops_srht_ref/us_unf_s/1e3:.2f};"
                             f"shape=({kg},{ng},{dg},{bg})")})
    f_fus_s = lambda: ops.sketch_gram_srht(rws, sg2, a2, surv)
    us_fus_s = time_fn(f_fus_s, iters=3, warmup=1)
    err_s = float(jnp.abs(f_fus_s() - f_unf_s()).max())
    rows.append({"name": "kernel_sketch_gram_srht_fused", "us": us_fus_s,
                 "derived": (f"gflops={flops_fused/us_fus_s/1e3:.2f};"
                             f"max_err={err_s:.2e}")})

    # srht fwht (blocked Kronecker-matmul kernel vs butterfly oracle)
    kf, nf, df = (4, 1024, 256) if quick else (8, 8192, 1000)
    xf = jax.random.normal(ks, (kf, nf, df))
    f_ref_f = jax.jit(lambda: ref.fwht(xf))
    usf = time_fn(f_ref_f)
    flopsf = kf * nf * math.log2(nf) * df
    rows.append({"name": "kernel_fwht_ref", "us": usf,
                 "derived": f"gflops={flopsf/usf/1e3:.2f};shape=({kf},{nf},{df})"})
    errf = float(jnp.abs(ops.fwht(xf) - f_ref_f()).max())
    rows.append({"name": "kernel_fwht_pallas_check", "us": 0.0,
                 "derived": f"max_err={errf:.2e}"})

    # two-pass tiled fwht (streams O(sqrt(n)) VMEM panels; the compile
    # path for n beyond the monolithic kernel's panel budget)
    k2p, n2p, d2p = (2, 4096, 256) if quick else (4, 16384, 256)
    x2p = jax.random.normal(jax.random.fold_in(ks, 3), (k2p, n2p, d2p))
    f_2p = lambda: ops.fwht_two_pass(x2p)
    us2p = time_fn(f_2p, iters=3, warmup=1)
    err2p = float(jnp.abs(f_2p() - ref.fwht(x2p)).max())
    rows.append({"name": "kernel_fwht_two_pass", "us": us2p,
                 "derived": (f"max_err={err2p:.2e};"
                             f"shape=({k2p},{n2p},{d2p})")})

    # coded matvec
    w, bb, s = (25, 128, 2048) if quick else (64, 256, 8192)
    enc = jax.random.normal(key, (w, bb, s))
    x = jax.random.normal(kh, (s,))
    er = jnp.zeros((w,), bool).at[3].set(True)
    f_ref3 = jax.jit(lambda: ref.coded_block_matvec(enc, x, er))
    us3 = time_fn(f_ref3)
    gb = enc.size * 4 / 1e9
    rows.append({"name": "kernel_coded_matvec_ref", "us": us3,
                 "derived": f"gbps={gb/(us3/1e6):.2f}"})
    err3 = float(jnp.abs(ops.coded_block_matvec(enc, x, er) - f_ref3()).max())
    rows.append({"name": "kernel_coded_matvec_pallas_check", "us": 0.0,
                 "derived": f"max_err={err3:.2e}"})
    return rows
