"""Kernel microbenchmarks: Pallas (interpret on CPU) vs pure-jnp reference.

On this container the interpreter dominates wall-clock, so the *reference*
implementations provide the meaningful CPU numbers and the Pallas variants
are validated for correctness+shape coverage; on TPU the same harness times
the compiled kernels.  Derived column reports achieved GFLOP/s of the ref.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels import ops, ref


def run(quick: bool = True):
    key = jax.random.PRNGKey(0)
    rows = []

    # count-sketch apply
    k, n, d, b = (8, 4096, 256, 256) if quick else (10, 20_000, 1000, 512)
    kh, ks, ka = jax.random.split(key, 3)
    h = jax.random.randint(kh, (k, n), 0, b, dtype=jnp.int32)
    sg = jax.random.rademacher(ks, (k, n), dtype=jnp.float32)
    a = jax.random.normal(ka, (n, d))
    f_ref = jax.jit(lambda: ref.count_sketch_apply(h, sg, a, b))
    us = time_fn(f_ref)
    flops = 2.0 * k * n * d
    rows.append({"name": "kernel_count_sketch_ref", "us": us,
                 "derived": f"gflops={flops/us/1e3:.2f};shape=({k},{n},{d})"})
    out_p = ops.count_sketch_apply(h, sg, a, b)
    out_r = f_ref()
    err = float(jnp.abs(out_p - out_r).max())
    rows.append({"name": "kernel_count_sketch_pallas_check", "us": 0.0,
                 "derived": f"max_err={err:.2e}"})

    # oversketch gram
    a_t = jax.random.normal(key, (k, b, d))
    surv = jnp.ones((k,), bool).at[0].set(False)
    f_ref2 = jax.jit(lambda: ref.oversketch_gram(a_t, surv))
    us2 = time_fn(f_ref2)
    flops2 = 2.0 * k * b * d * d
    rows.append({"name": "kernel_oversketch_gram_ref", "us": us2,
                 "derived": f"gflops={flops2/us2/1e3:.2f}"})
    err2 = float(jnp.abs(ops.oversketch_gram(a_t, surv) - f_ref2()).max())
    rows.append({"name": "kernel_oversketch_gram_pallas_check", "us": 0.0,
                 "derived": f"max_err={err2:.2e}"})

    # srht fwht (blocked Kronecker-matmul kernel vs butterfly oracle)
    kf, nf, df = (4, 1024, 256) if quick else (8, 8192, 1000)
    xf = jax.random.normal(ks, (kf, nf, df))
    f_ref_f = jax.jit(lambda: ref.fwht(xf))
    usf = time_fn(f_ref_f)
    flopsf = kf * nf * math.log2(nf) * df
    rows.append({"name": "kernel_fwht_ref", "us": usf,
                 "derived": f"gflops={flopsf/usf/1e3:.2f};shape=({kf},{nf},{df})"})
    errf = float(jnp.abs(ops.fwht(xf) - f_ref_f()).max())
    rows.append({"name": "kernel_fwht_pallas_check", "us": 0.0,
                 "derived": f"max_err={errf:.2e}"})

    # coded matvec
    w, bb, s = (25, 128, 2048) if quick else (64, 256, 8192)
    enc = jax.random.normal(key, (w, bb, s))
    x = jax.random.normal(kh, (s,))
    er = jnp.zeros((w,), bool).at[3].set(True)
    f_ref3 = jax.jit(lambda: ref.coded_block_matvec(enc, x, er))
    us3 = time_fn(f_ref3)
    gb = enc.size * 4 / 1e9
    rows.append({"name": "kernel_coded_matvec_ref", "us": us3,
                 "derived": f"gbps={gb/(us3/1e6):.2f}"})
    err3 = float(jnp.abs(ops.coded_block_matvec(enc, x, er) - f_ref3()).max())
    rows.append({"name": "kernel_coded_matvec_pallas_check", "us": 0.0,
                 "derived": f"max_err={err3:.2e}"})
    return rows
