"""Kernel microbenchmarks: Pallas (interpret on CPU) vs pure-jnp reference.

On this container the interpreter dominates wall-clock, so the *reference*
implementations provide the meaningful CPU numbers and the Pallas variants
are validated for correctness+shape coverage; on TPU the same harness times
the compiled kernels.  Derived column reports achieved GFLOP/s of the ref.

Every row carries a ``path`` field naming what actually executed, so the
persisted BENCH_kernels.json trajectory is attributable row-by-row:

  ref         pure-jnp oracle timing
  pallas      Pallas entry point checked against the oracle (no timing)
  unfused     the two-kernel apply+gram baseline the fusion replaces
  fused       fused sketch->Gram, single resident output tile
  fused_tiled fused sketch->Gram, d-tiled (d_i, d_j) output grid

Pre-path-field BENCH files (before the d-tiled kernel) labelled the
``*_fused`` rows by entry point alone; see kernels/README.md ("Reading
BENCH_kernels.json") for the discontinuity note.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels import ops, ref


def _fused_inputs(key, kg, ng, dg, bg, s=None):
    """Shared draw for the fused sketch->Gram rows; the 1/sqrt(n) row scale
    keeps Gram entries O(1) so max_err is an absolute float32 figure."""
    kh, ks, ka, kr, kj = jax.random.split(key, 5)
    h = jax.random.randint(kh, (kg, ng), 0, bg, dtype=jnp.int32)
    sg = jax.random.rademacher(ks, (kg, ng), dtype=jnp.float32)
    a = jax.random.normal(ka, (ng, dg)) / math.sqrt(ng)
    n_pad = 1 << (ng - 1).bit_length()
    rows = jax.random.randint(kr, (kg, bg), 0, n_pad, dtype=jnp.int32)
    sjlt = None
    if s is not None:
        hj = jax.random.randint(kj, (kg, s, ng), 0, bg, dtype=jnp.int32)
        sj = jax.random.rademacher(jax.random.fold_in(kj, 1), (kg, s, ng),
                                   dtype=jnp.float32)
        sjlt = (hj, sj)
    surv = jnp.ones((kg,), bool).at[0].set(False)
    return h, sg, a, rows, sjlt, surv, n_pad


def _fused_rows(rows, tag, key, kg, ng, dg, bg, s, iters):
    """Unfused-ref + fused rows for all three encode families at one shape.

    Flop counts match what each implementation actually executes: fused
    kernel = dense encode matmul + gram, recomputed once per output
    row/column of d tiles; scatter-style count ref = one signed add per
    element; FWHT ref = butterfly.
    """
    h, sg, a, rws, (hj, sj), surv, n_pad = _fused_inputs(
        key, kg, ng, dg, bg, s=s)
    gram_fl = 2.0 * kg * bg * dg * dg
    d_tile = ops.pick_d_tile(bg, dg)
    d_tiles = -(-dg // d_tile)
    path = ops.fused_path(bg, dg)
    # Tiled grid recomputes the encode matmul once per off-diagonal panel:
    # (2*d_tiles - 1) x the single-tile encode work (see kernels/README.md).
    flops_fused = 2.0 * kg * ng * bg * dg * (2.0 * d_tiles - 1.0) + gram_fl
    shape = f"shape=({kg},{ng},{dg},{bg})"

    cases = [
        ("count", lambda: ref.sketch_gram_count(h, sg, a, bg, surv),
         lambda: ops.sketch_gram_count(h, sg, a, bg, surv),
         2.0 * kg * ng * dg + gram_fl),
        ("srht", lambda: ref.sketch_gram_srht(rws, sg, a, surv),
         lambda: ops.sketch_gram_srht(rws, sg, a, surv),
         kg * n_pad * math.log2(n_pad) * dg + gram_fl),
        ("sjlt", lambda: ref.sketch_gram_sjlt(hj, sj, a, bg, surv),
         lambda: ops.sketch_gram_sjlt(hj, sj, a, bg, surv),
         2.0 * kg * s * ng * dg + gram_fl),
    ]
    for fam, f_ref, f_fus, flops_ref in cases:
        f_unf = jax.jit(f_ref)
        us_unf = time_fn(f_unf)
        rows.append({"name": f"kernel_sketch_gram_{fam}_unfused_ref{tag}",
                     "us": us_unf, "path": "unfused",
                     "derived": (f"gflops={flops_ref/us_unf/1e3:.2f};"
                                 f"{shape}")})
        us_fus = time_fn(f_fus, iters=iters, warmup=1)
        err = float(jnp.abs(f_fus() - f_unf()).max())
        rows.append({"name": f"kernel_sketch_gram_{fam}_fused{tag}",
                     "us": us_fus, "path": path,
                     "derived": (f"gflops={flops_fused/us_fus/1e3:.2f};"
                                 f"max_err={err:.2e};d_tile={d_tile};"
                                 f"{shape}")})


def run(quick: bool = True):
    key = jax.random.PRNGKey(0)
    rows = []

    # count-sketch apply
    k, n, d, b = (8, 4096, 256, 256) if quick else (10, 20_000, 1000, 512)
    kh, ks, ka = jax.random.split(key, 3)
    h = jax.random.randint(kh, (k, n), 0, b, dtype=jnp.int32)
    sg = jax.random.rademacher(ks, (k, n), dtype=jnp.float32)
    a = jax.random.normal(ka, (n, d))
    f_ref = jax.jit(lambda: ref.count_sketch_apply(h, sg, a, b))
    us = time_fn(f_ref)
    flops = 2.0 * k * n * d
    rows.append({"name": "kernel_count_sketch_ref", "us": us, "path": "ref",
                 "derived": f"gflops={flops/us/1e3:.2f};shape=({k},{n},{d})"})
    out_p = ops.count_sketch_apply(h, sg, a, b)
    out_r = f_ref()
    err = float(jnp.abs(out_p - out_r).max())
    rows.append({"name": "kernel_count_sketch_pallas_check", "us": 0.0,
                 "path": "pallas", "derived": f"max_err={err:.2e}"})

    # oversketch gram
    a_t = jax.random.normal(key, (k, b, d))
    surv = jnp.ones((k,), bool).at[0].set(False)
    f_ref2 = jax.jit(lambda: ref.oversketch_gram(a_t, surv))
    us2 = time_fn(f_ref2)
    flops2 = 2.0 * k * b * d * d
    rows.append({"name": "kernel_oversketch_gram_ref", "us": us2,
                 "path": "ref", "derived": f"gflops={flops2/us2/1e3:.2f}"})
    err2 = float(jnp.abs(ops.oversketch_gram(a_t, surv) - f_ref2()).max())
    rows.append({"name": "kernel_oversketch_gram_pallas_check", "us": 0.0,
                 "path": "pallas", "derived": f"max_err={err2:.2e}"})

    # fused sketch->gram streaming kernel vs unfused apply+gram (the
    # two-HBM-round-trip baseline it replaces), all three encode families.
    # First shape fits one resident output tile (path=fused); the second
    # puts d above the old single-tile budget so the d-tiled grid runs
    # (path=fused_tiled) — pre-tiling code silently never fused there.
    s = 4
    if quick:
        _fused_rows(rows, "", jax.random.fold_in(key, 2),
                    6, 4096, 256, 256, s, iters=3)
        _fused_rows(rows, "_bigd", jax.random.fold_in(key, 3),
                    2, 1024, 1536, 128, s, iters=2)
    else:
        _fused_rows(rows, "", jax.random.fold_in(key, 2),
                    10, 20_000, 512, 512, s, iters=3)
        _fused_rows(rows, "_bigd", jax.random.fold_in(key, 3),
                    4, 4096, 2048, 256, s, iters=2)

    # srht fwht (blocked Kronecker-matmul kernel vs butterfly oracle)
    kf, nf, df = (4, 1024, 256) if quick else (8, 8192, 1000)
    xf = jax.random.normal(ks, (kf, nf, df))
    f_ref_f = jax.jit(lambda: ref.fwht(xf))
    usf = time_fn(f_ref_f)
    flopsf = kf * nf * math.log2(nf) * df
    rows.append({"name": "kernel_fwht_ref", "us": usf, "path": "ref",
                 "derived": f"gflops={flopsf/usf/1e3:.2f};shape=({kf},{nf},{df})"})
    errf = float(jnp.abs(ops.fwht(xf) - f_ref_f()).max())
    rows.append({"name": "kernel_fwht_pallas_check", "us": 0.0,
                 "path": "pallas", "derived": f"max_err={errf:.2e}"})

    # two-pass tiled fwht (streams O(sqrt(n)) VMEM panels; the compile
    # path for n beyond the monolithic kernel's panel budget)
    k2p, n2p, d2p = (2, 4096, 256) if quick else (4, 16384, 256)
    x2p = jax.random.normal(jax.random.fold_in(ks, 3), (k2p, n2p, d2p))
    f_2p = lambda: ops.fwht_two_pass(x2p)
    us2p = time_fn(f_2p, iters=3, warmup=1)
    err2p = float(jnp.abs(f_2p() - ref.fwht(x2p)).max())
    rows.append({"name": "kernel_fwht_two_pass", "us": us2p,
                 "path": "pallas",
                 "derived": (f"max_err={err2p:.2e};"
                             f"shape=({k2p},{n2p},{d2p})")})

    # coded matvec
    w, bb, ss = (25, 128, 2048) if quick else (64, 256, 8192)
    enc = jax.random.normal(key, (w, bb, ss))
    x = jax.random.normal(kh, (ss,))
    er = jnp.zeros((w,), bool).at[3].set(True)
    f_ref3 = jax.jit(lambda: ref.coded_block_matvec(enc, x, er))
    us3 = time_fn(f_ref3)
    gb = enc.size * 4 / 1e9
    rows.append({"name": "kernel_coded_matvec_ref", "us": us3, "path": "ref",
                 "derived": f"gbps={gb/(us3/1e6):.2f}"})
    err3 = float(jnp.abs(ops.coded_block_matvec(enc, x, er) - f_ref3()).max())
    rows.append({"name": "kernel_coded_matvec_pallas_check", "us": 0.0,
                 "path": "pallas", "derived": f"max_err={err3:.2e}"})

    # Measured per-op wall-clock through the ops profiler hook — the same
    # ``kernel.<op>.us`` table ``obs.store.run_record`` persists for the
    # ROADMAP's measured kernel auto-router; here it lands in the BENCH
    # trajectory so the router's data source is itself regression-gated.
    from repro import obs
    reg = obs.MetricsRegistry()
    ops.set_profiler(reg)
    try:
        ops.oversketch_gram(a_t, surv)
        ops.fwht(xf)
        ops.coded_block_matvec(enc, x, er)
    finally:
        ops.set_profiler(None)
    measured = {n: h.percentile(50) for n, h in sorted(reg.histograms.items())
                if n.startswith("kernel.") and n.endswith(".us")}
    rows.append({"name": "kernel_profiled_us",
                 "us": sum(measured.values()), "path": "pallas",
                 "derived": ";".join(f"{n.split('.')[1]}={v:.0f}"
                                     for n, v in measured.items())})
    return rows
