"""Fig. 9: softmax regression (weakly convex, EMNIST profile) — gradient
descent vs exact Newton vs OverSketched Newton with the Newton-MR update.
Paper headline: OSN ~75% faster than GD, ~50% faster than exact Newton."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_to_target
from repro.core import (NewtonConfig, OverSketchConfig, SoftmaxRegression,
                        oversketched_newton)
from repro.core.straggler import StragglerModel
from repro.data import profile_dataset
from repro.optim import FirstOrderConfig, exact_newton, first_order


def run(quick: bool = True):
    from repro.data import make_softmax_dataset
    # EMNIST stand-in with the paper's n >> sketch-dim regime
    data = make_softmax_dataset(jax.random.PRNGKey(3), 6000, 98, 10)
    d = data.x.shape[1]
    k = 10
    obj = SoftmaxRegression(num_classes=k)
    w0 = jnp.zeros(k * d)
    model = StragglerModel()
    iters = 6 if quick else 10

    dk = d * k
    sk = OverSketchConfig(((6 * dk) // 256 + 1) * 256, 256, 0.25)
    osn = oversketched_newton(
        obj, data, w0, NewtonConfig(iters=iters, sketch=sk, solver="pinv",
                                    unit_step=False, coded_block_rows=256),
        model=model).history
    exact = exact_newton(obj, data, w0, iters=iters, model=model,
                         solver="pinv", unit_step=False)
    gd = first_order(obj, data, w0,
                     FirstOrderConfig(iters=30 if quick else 60, method="gd",
                                      policy="ignore", num_workers=60),
                     model=model)

    # fixed moderate gradient-norm target (the paper plots ||grad f||; the
    # sketch's eps-noise floor sits well below this threshold)
    g_target = 3e-2
    rows = []
    for name, h in [("osn_newton_mr", osn), ("exact_newton", exact),
                    ("gradient_descent", gd)]:
        t = float("inf")
        for g, tt in zip(h["gnorm"], h["time"]):
            if g <= g_target:
                t = tt
                break
        rows.append({
            "name": f"fig9_{name}",
            "us": (t if t != float("inf") else h["time"][-1]) * 1e6,
            "derived": (f"t_to_gtarget={t:.2f};"
                        f"final_gnorm={h['gnorm'][-1]:.2e};"
                        f"final_f={h['fval'][-1]:.5f}"),
        })
    return rows
