"""Fig. 6: logistic regression on the synthetic dataset — OverSketched Newton
vs GIANT (wait-all / gradient-coding / ignore-stragglers) vs exact Newton
with speculative execution.  Scored in simulated wall-clock (same straggler
model for every scheme); the paper's qualitative result to reproduce:

  uncoded (wait-all) worst;  mini-batch beats gradient coding;  exact Newton
  beats GIANT;  OverSketched Newton fastest overall (~2x vs exact Newton).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import best_f, time_to_target
from repro.core import (LogisticRegression, NewtonConfig, OverSketchConfig,
                        oversketched_newton)
from repro.core.straggler import StragglerModel
from repro.data import make_logistic_dataset
from repro.optim import GiantConfig, exact_newton, giant


def run(quick: bool = True):
    n, d = (12_000, 400) if quick else (40_000, 1000)
    data = make_logistic_dataset(jax.random.PRNGKey(0), n, d, n_test=1000,
                                 cond=10.0, sorted_layout=True)
    obj = LogisticRegression(lam=1e-5)
    w0 = jnp.zeros(d)
    model = StragglerModel()
    iters = 8 if quick else 12

    sk = OverSketchConfig(sketch_dim=((10 * d) // 256 + 1) * 256,
                          block_size=256, straggler_tolerance=0.25)
    osn = oversketched_newton(
        obj, data, w0, NewtonConfig(iters=iters, sketch=sk, unit_step=False,
                                    coded_block_rows=256),
        model=model).history
    exact = exact_newton(obj, data, w0, iters=iters, model=model,
                         unit_step=False)
    g_wait = giant(obj, data, w0,
                   GiantConfig(iters=iters + 6, num_workers=60,
                               policy="wait_all", unit_step=False), model=model)
    g_code = giant(obj, data, w0,
                   GiantConfig(iters=iters + 6, num_workers=60,
                               policy="gcode", unit_step=False), model=model)
    g_ign = giant(obj, data, w0,
                  GiantConfig(iters=iters + 6, num_workers=60,
                              policy="ignore", unit_step=False), model=model)

    target = best_f(osn, exact, g_wait, g_code, g_ign)
    out = []
    for name, h in [("osn", osn), ("exact_newton_spec", exact),
                    ("giant_waitall", g_wait), ("giant_gcode", g_code),
                    ("giant_minibatch", g_ign)]:
        t = time_to_target(h, target)
        out.append({
            "name": f"fig6_{name}",
            "us": (t if t != float("inf") else h["time"][-1]) * 1e6,
            "derived": (f"t_to_target={t:.2f};final_f={h['fval'][-1]:.5f};"
                        f"final_gnorm={h['gnorm'][-1]:.2e}"),
        })
    # headline check: osn faster than exact newton to the common target
    t_osn = time_to_target(osn, target)
    t_ex = time_to_target(exact, target)
    out.append({"name": "fig6_speedup_osn_vs_exact", "us": 0.0,
                "derived": f"ratio={t_ex / max(t_osn, 1e-9):.2f}x"})
    return out
