"""Fig. 8: WEBPAGE and a9a profiles — OverSketched Newton vs exact Newton vs
GIANT.  Paper headline: OSN >= ~25% faster than exact Newton, ~75% vs GIANT."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import best_f, time_to_target
from repro.core import (LogisticRegression, NewtonConfig, OverSketchConfig,
                        oversketched_newton)
from repro.core.straggler import StragglerModel
from repro.data import profile_dataset
from repro.optim import GiantConfig, exact_newton, giant


def _one(profile: str, quick: bool):
    data = profile_dataset(profile, jax.random.PRNGKey(2))
    d = data.x.shape[1]
    obj = LogisticRegression(lam=1e-5)
    w0 = jnp.zeros(d)
    model = StragglerModel()
    iters = 7 if quick else 12

    sk = OverSketchConfig(((10 * d) // 128 + 1) * 128, 128, 0.25)
    osn = oversketched_newton(
        obj, data, w0, NewtonConfig(iters=iters, sketch=sk, unit_step=False,
                                    coded_block_rows=128),
        model=model).history
    exact = exact_newton(obj, data, w0, iters=iters, model=model,
                         unit_step=False)
    g = giant(obj, data, w0, GiantConfig(iters=iters + 5, num_workers=30, unit_step=False),
              model=model)
    target = best_f(osn, exact, g)
    rows = []
    for name, h in [("osn", osn), ("exact_newton", exact), ("giant", g)]:
        t = time_to_target(h, target)
        rows.append({
            "name": f"fig8_{profile}_{name}",
            "us": (t if t != float("inf") else h["time"][-1]) * 1e6,
            "derived": f"t_to_target={t:.2f};final_f={h['fval'][-1]:.5f}",
        })
    return rows


def run(quick: bool = True):
    return _one("webpage", quick) + _one("a9a", quick)
