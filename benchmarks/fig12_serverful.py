"""Fig. 12: serverless OverSketched Newton vs serverful (EC2/MPI-style)
GIANT.  The serverful clock has much lower invocation overhead and faster
communication but far fewer, fixed workers; OSN exploits the serverless
scale for a better global second-order update — the paper's (surprising)
result is OSN winning by >= 30%."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import best_f, time_to_target
from repro.core import (LogisticRegression, NewtonConfig, OverSketchConfig,
                        oversketched_newton)
from repro.core.straggler import SimClock, StragglerModel
from repro.data import make_logistic_dataset
from repro.optim import GiantConfig, giant
from repro.runtime import CostModel


def run(quick: bool = True):
    n, d = (40_000, 400) if quick else (80_000, 1000)
    data = make_logistic_dataset(jax.random.PRNGKey(6), n, d,
                                 cond=10.0, sorted_layout=True)
    obj = LogisticRegression(lam=1e-5)
    w0 = jnp.zeros(d)

    # serverless: high invoke overhead, heavy tail, thousands of workers
    serverless = StragglerModel(invoke_overhead=0.10, comm_per_unit=0.05,
                                p_tail=0.02)
    # serverful MPI: negligible overhead, fast interconnect, mild noise,
    # but capped at 60 fixed t2.medium workers (1 burstable vCPU — about
    # half a Lambda 3GB worker's throughput) holding 1/60th of the data each
    serverful = StragglerModel(invoke_overhead=0.005, comm_per_unit=0.01,
                               p_tail=0.005, tail_hi=0.5,
                               flops_per_second=1e6)
    # EC2-style meters for the fixed cluster: t2.medium-ish per-GB-second
    # rate, reserved billing (all 60 nodes bill phase wall-clock, idle
    # included), no per-invocation or per-S3-op charges (MPI interconnect).
    ec2_meters = CostModel(memory_gb=4.0, billing="reserved",
                           usd_per_gb_second=3.22e-6,
                           usd_per_invocation=0.0, usd_per_s3_put=0.0,
                           usd_per_s3_get=0.0)

    sk = OverSketchConfig(((10 * d) // 256 + 1) * 256, 256, 0.25)
    osn = oversketched_newton(
        obj, data, w0, NewtonConfig(iters=8 if quick else 12, sketch=sk,
                                    unit_step=False,
                                    coded_block_rows=max(32, d // 7)),
        model=serverless).history
    g_mpi = giant(obj, data, w0,
                  GiantConfig(iters=14 if quick else 20, num_workers=60,
                              policy="wait_all", unit_step=False),
                  model=SimClock(serverful, cost=ec2_meters))

    target = best_f(osn, g_mpi)
    rows = []
    for name, h in [("osn_serverless", osn), ("giant_serverful_mpi", g_mpi)]:
        t = time_to_target(h, target)
        rows.append({
            "name": f"fig12_{name}",
            "us": (t if t != float("inf") else h["time"][-1]) * 1e6,
            "derived": (f"t_to_target={t:.2f};final_f={h['fval'][-1]:.5f};"
                        f"usd={h['cost'][-1]:.4f}"),
        })
    return rows
