"""Phase-DAG scheduler sweep: DAG-vs-sequential makespan x warm-pool TTL x
per-phase Lambda sizing.

Three questions, one grid (written to ``BENCH_fleet.json`` — the fleet-side
perf trajectory next to the kernel one):

  1. How much makespan does DAG dispatch buy?  A Newton-iteration-shaped
     DAG (gradient matvec chain || Hessian-sketch fan-out -> line search)
     under nonzero straggler tails: the DAG makespan must be strictly
     below sequential, and a fully serialized chain must equal it
     bit-for-bit.  A real ``oversketched_newton`` run (schedule="dag" vs
     "sequential") repeats the comparison end-to-end.
  2. What do bursty schedules pay in cold starts?  The same DAG under a
     ``WarmPool`` across TTLs: the DAG's concurrent fan-outs need more
     containers at once than the steady sequential schedule, so its cold
     count is never lower.
  3. What does per-phase sizing save?  The same workload billed at the
     paper's fleet-wide 3 GB vs each phase's declared ``memory_gb``.

One extra row self-checks that a DAG-scheduled, pool-enabled, per-phase-
sized trace replays to bit-identical ``(seconds, dollars)``.

Every row carries a ``path`` field (``dag`` | ``seq`` | ``pool`` |
``replay``) naming which dispatch mode produced it, mirroring the kernel
baseline's attribution convention.
"""
from __future__ import annotations

import os
import sys
import tempfile

import jax
import jax.numpy as jnp

from benchmarks.common import json_row
from repro import obs
from repro.core.straggler import SimClock, StragglerModel
from repro.runtime import TraceRecorder, load_trace
from repro.scheduler import PhaseSpec, WarmPool, lambda_memory_gb, run_dag

MODEL = StragglerModel(p_tail=0.05, tail_hi=3.0)


def _newton_shaped_specs(workers: int, sized: bool):
    """One Newton-iteration-shaped DAG: a two-matvec gradient chain in
    parallel with a Hessian-sketch fan-out, joined by a line search."""
    mem = (lambda: lambda_memory_gb(256 * 64 * 4)) if sized else (lambda: None)
    return [
        PhaseSpec("grad/0:X", workers, policy="k_of_n",
                  k=max(1, int(0.8 * workers)), flops_per_worker=3e5,
                  comm_units=1.0, memory_gb=mem()),
        PhaseSpec("grad/1:XT", workers, policy="k_of_n",
                  k=max(1, int(0.8 * workers)), flops_per_worker=3e5,
                  comm_units=1.0, deps=("grad/0:X",), memory_gb=mem()),
        PhaseSpec("hessian", 2 * workers, policy="k_of_n",
                  k=max(1, int(0.8 * 2 * workers)), flops_per_worker=6e5,
                  comm_units=1.0,
                  memory_gb=lambda_memory_gb(256 * 256 * 8) if sized
                  else None),
        PhaseSpec("linesearch", workers, policy="wait_all",
                  flops_per_worker=1e5, comm_units=0.5,
                  deps=("grad/1:XT", "hessian"), memory_gb=mem()),
    ]


def _chain_specs(workers: int):
    names = ["a", "b", "c", "d"]
    return [PhaseSpec(n, workers, policy="wait_all", flops_per_worker=2e5,
                      deps=(names[i - 1],) if i else ())
            for i, n in enumerate(names)]


def _run(specs, *, sequential=False, pool=None, recorder=None, replay=None
         ) -> SimClock:
    clock = SimClock(MODEL, pool=pool, recorder=recorder, replay=replay)
    run_dag(clock, jax.random.PRNGKey(7), specs, sequential=sequential)
    return clock


def _newton_end_to_end(schedule: str, iters: int, telemetry=None):
    from repro.core import newton, sketch
    from repro.core.objectives import Dataset, LogisticRegression

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1024, 16))
    y = jnp.sign(x @ jax.random.normal(jax.random.PRNGKey(1), (16,)))
    cfg = newton.NewtonConfig(
        iters=iters, schedule=schedule,
        sketch=sketch.OverSketchConfig(sketch_dim=256, block_size=64,
                                       straggler_tolerance=0.25))
    model = (SimClock(MODEL, telemetry=telemetry)
             if telemetry is not None else MODEL)
    res = newton.oversketched_newton(
        LogisticRegression(), Dataset(x=x, y=y), jnp.zeros(16), cfg,
        model=model)
    return res.history["time"][-1], res.history["cost"][-1]


def _traced_newton_row(trace_out: str, iters: int):
    """The ``--trace-out`` path: re-run the DAG-scheduled Newton with live
    telemetry AND health monitors, export + validate a Perfetto trace
    (gradient chain || Hessian-sketch overlap with per-worker lifecycle
    slices), dump the JSONL sibling for ``benchmarks.make_report
    --trace``, and self-check that attaching the recorder + monitors
    changed nothing."""
    t_plain, c_plain = _newton_end_to_end("dag", iters)
    tel = obs.Telemetry(monitors=True)
    t_dag, c_dag = _newton_end_to_end("dag", iters, telemetry=tel)
    # Attribute any alerts before export so incident rows land in the
    # JSONL (and thus in make_report --trace / the HTML console), and
    # ship the timestamped gauge streams as Perfetto counter tracks.
    incidents = obs.attribute(tel)
    counters = obs.counter_series(tel)
    trace = obs.to_perfetto(tel.trace.spans, counters=counters)
    obs.perfetto.validate_trace(
        trace, require_phases=("hessian", "linesearch", "grad/0:X"),
        require_counters=tuple(sorted(counters))[:1])
    obs.dump_perfetto(trace, trace_out)
    jsonl = (trace_out[:-5] if trace_out.endswith(".json") else trace_out) \
        + ".jsonl"
    obs.dump_jsonl(tel, jsonl)
    print(f"# wrote {trace_out} + {jsonl}", file=sys.stderr)
    print(obs.phase_table(obs.telemetry_rows(tel)), file=sys.stderr)
    return json_row(
        "sched_newton_traced", t_dag * 1e6, sim_s=t_dag, usd=c_dag,
        spans=len(tel.trace.spans),
        events=len(trace["traceEvents"]),
        alerts=len(tel.health.alerts), incidents=len(incidents),
        counter_tracks=len(counters),
        recorder_inert=int(t_dag == t_plain and c_dag == c_plain)) \
        | {"path": "dag"}


def run(quick: bool = True, trace_out=None):
    rows = []
    sizes = (16, 64) if quick else (16, 64, 256)

    # --- 1. DAG vs sequential makespan --------------------------------
    for w in sizes:
        specs = _newton_shaped_specs(w, sized=False)
        dag = _run(specs)
        seq = _run(specs, sequential=True)
        rows.append(json_row(
            f"sched_dag_vs_seq_w{w}", dag.time * 1e6, sim_s=dag.time,
            seq_s=seq.time, speedup=seq.time / dag.time, usd=dag.dollars)
            | {"path": "dag"})
        assert dag.time < seq.time, "DAG makespan must beat sequential"
        assert dag.dollars == seq.dollars, "billing is schedule-invariant"
        chain = _chain_specs(w)
        cd, cs = _run(chain), _run(chain, sequential=True)
        rows.append(json_row(
            f"sched_chain_eq_w{w}", cd.time * 1e6, sim_s=cd.time,
            exact=int(cd.time == cs.time and cd.dollars == cs.dollars))
            | {"path": "seq"})

    # --- 2. warm-pool TTL sweep ---------------------------------------
    # Phase durations here are O(0.3 s) with straggler tails to ~1 s, so
    # ttl=0.05 expires containers released early behind a straggling
    # phase, 1.0 keeps intra-schedule reuse, 300 never expires.
    for ttl in (0.05, 1.0, 300.0):
        for label, sequential in (("dag", False), ("seq", True)):
            pool = WarmPool(ttl=ttl)
            clock = _run(_newton_shaped_specs(64, sized=False),
                         sequential=sequential, pool=pool)
            rows.append(json_row(
                f"sched_pool_ttl{ttl:g}_{label}", clock.time * 1e6,
                sim_s=clock.time, usd=clock.dollars, warm=pool.warm_hits,
                cold=pool.cold_starts) | {"path": "pool"})

    # --- 3. per-phase Lambda sizing -----------------------------------
    fixed = _run(_newton_shaped_specs(64, sized=False))
    sized = _run(_newton_shaped_specs(64, sized=True))
    rows.append(json_row(
        "sched_mem_fixed3gb", fixed.time * 1e6, usd=fixed.dollars,
        gb_s=fixed.ledger.gb_seconds) | {"path": "dag"})
    rows.append(json_row(
        "sched_mem_sized", sized.time * 1e6, usd=sized.dollars,
        gb_s=sized.ledger.gb_seconds,
        saving=1.0 - sized.dollars / fixed.dollars) | {"path": "dag"})

    # --- 4. Newton end-to-end, DAG vs sequential dispatch -------------
    iters = 3 if quick else 8
    t_dag, c_dag = _newton_end_to_end("dag", iters)
    t_seq, c_seq = _newton_end_to_end("sequential", iters)
    rows.append(json_row(
        "sched_newton_dag_vs_seq", t_dag * 1e6, sim_s=t_dag, seq_s=t_seq,
        speedup=t_seq / t_dag, usd=c_dag,
        cost_equal=int(c_dag == c_seq)) | {"path": "dag"})

    # --- 5. DAG + pool + sizing trace replay self-check ---------------
    rec = TraceRecorder(lifecycle=True)
    recorded = _run(_newton_shaped_specs(32, sized=True),
                    pool=WarmPool(ttl=30.0), recorder=rec)
    with tempfile.NamedTemporaryFile("w", suffix=".jsonl",
                                     delete=False) as tmp:
        path = tmp.name
    try:
        rec.dump(path)
        replayed = _run(_newton_shaped_specs(32, sized=True),
                        replay=load_trace(path))
        exact = int(replayed.time == recorded.time
                    and replayed.dollars == recorded.dollars)
    finally:
        os.unlink(path)
    rows.append(json_row("sched_trace_replay", recorded.time * 1e6,
                         sim_s=recorded.time, usd=recorded.dollars,
                         replay_exact=exact) | {"path": "replay"})

    # --- 6. telemetry export (opt-in via --trace-out) -----------------
    if trace_out:
        rows.append(_traced_newton_row(trace_out, iters))
    print(obs.bench_rows_table(rows), file=sys.stderr)
    return rows
