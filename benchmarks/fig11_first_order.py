"""Fig. 11: OverSketched Newton (unit step) vs gradient descent and NAG with
backtracking line search, EPSILON profile.  Paper headline: >= 9x faster than
first-order methods in simulated end-to-end time."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import best_f, time_to_target
from repro.core import (LogisticRegression, NewtonConfig, OverSketchConfig,
                        oversketched_newton)
from repro.core.straggler import StragglerModel
from repro.optim import FirstOrderConfig, first_order


def run(quick: bool = True):
    from repro.data import make_logistic_dataset
    # ill-conditioned features: the regime where Newton's advantage is ~10x
    data = make_logistic_dataset(jax.random.PRNGKey(5), 12_000, 100,
                                 n_test=1000, cond=100.0)
    d = data.x.shape[1]
    obj = LogisticRegression(lam=1e-5)
    w0 = jnp.zeros(d)
    model = StragglerModel()

    sk = OverSketchConfig(((15 * d) // 256 + 1) * 256, 256, 0.25)
    osn = oversketched_newton(
        obj, data, w0, NewtonConfig(iters=8 if quick else 12, sketch=sk,
                                    unit_step=False, coded_block_rows=256),
        model=model).history
    fo_iters = 150 if quick else 300
    gd = first_order(obj, data, w0,
                     FirstOrderConfig(iters=fo_iters, method="gd",
                                      policy="ignore", num_workers=100,
                                      backtracking=True), model=model)
    nag = first_order(obj, data, w0,
                      FirstOrderConfig(iters=fo_iters, method="nag",
                                       policy="ignore", num_workers=100,
                                       backtracking=True), model=model)
    sgd = first_order(obj, data, w0,
                      FirstOrderConfig(iters=fo_iters, method="sgd",
                                       batch_fraction=0.2, lr=0.5,
                                       backtracking=False,
                                       num_workers=100), model=model)

    target = best_f(osn)   # the Newton optimum is the bar (paper's framing)
    rows = []
    for name, h in [("osn", osn), ("gd_backtrack", gd),
                    ("nag_backtrack", nag), ("sgd20", sgd)]:
        t = time_to_target(h, target)
        rows.append({
            "name": f"fig11_{name}",
            "us": (t if t != float("inf") else h["time"][-1]) * 1e6,
            "derived": (f"t_to_target={t if t != float('inf') else -1:.2f};"
                        f"final_f={h['fval'][-1]:.5f}"),
        })
    t_osn = time_to_target(osn, target)
    t_best_fo = min(time_to_target(gd, target), time_to_target(nag, target))
    ratio = (t_best_fo / max(t_osn, 1e-9)) if t_best_fo != float("inf") \
        else float(gd["time"][-1] / max(t_osn, 1e-9))
    rows.append({"name": "fig11_speedup_vs_first_order", "us": 0.0,
                 "derived": f"ratio>={ratio:.1f}x"})
    return rows
