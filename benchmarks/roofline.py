"""Roofline extraction for the benchmark run: a reduced-mesh dry-run cell
(per-arch smoke at 8 placeholder devices in a subprocess keeps this fast and
keeps the main process single-device) + the analytic full-mesh terms for
every (arch x shape) cell — the full table lives in EXPERIMENTS.md and the
sweep JSON produced by `python -m repro.launch.dryrun --all`."""
from __future__ import annotations

from repro.launch import analytic
from repro.launch.dryrun import PEAK_FLOPS, HBM_BW, ICI_BW
from repro.models.registry import SHAPES, get_bundle, get_config


def run(quick: bool = True):
    rows = []
    archs = ["qwen3-4b", "qwen3-moe-235b-a22b", "mamba2-780m"] if quick else \
        None
    if archs is None:
        from repro.configs import ASSIGNED_ARCHS
        archs = list(ASSIGNED_ARCHS)
    for arch in archs:
        cfg = get_config(arch)
        bundle = get_bundle(arch)
        for shape_name, shape in SHAPES.items():
            ok, _ = bundle.supports(shape)
            if not ok:
                continue
            costs = analytic.cell_costs(cfg, shape, 256)
            terms = {
                "c": costs.flops_per_chip / PEAK_FLOPS,
                "m": costs.hbm_bytes_per_chip / HBM_BW,
                "x": costs.coll_bytes_per_chip / ICI_BW,
            }
            bound = max(terms, key=terms.get)
            step = max(terms.values())
            rows.append({
                "name": f"roofline_{arch}_{shape_name}",
                "us": step * 1e6,
                "derived": (f"bound={bound};c_ms={terms['c']*1e3:.2f};"
                            f"m_ms={terms['m']*1e3:.2f};"
                            f"x_ms={terms['x']*1e3:.2f}"),
            })
    return rows
