"""Roofline extraction for the benchmark run: a reduced-mesh dry-run cell
(per-arch smoke at 8 placeholder devices in a subprocess keeps this fast and
keeps the main process single-device) + the analytic full-mesh terms for
every (arch x shape) cell — the full table lives in EXPERIMENTS.md and the
sweep JSON produced by `python -m repro.launch.dryrun --all`.

Also reports the sketch->Gram hot path's arithmetic intensity, fused
(``kernels/sketch_gram.py``, A streams once and A_tilde stays in VMEM)
next to the unfused two-pass pipeline it replaces (apply writes A_tilde to
HBM, Gram reads it back) — the HBM-traffic delta is the whole point of the
fusion, so it belongs on the roofline."""
from __future__ import annotations

from repro.launch import analytic
from repro.launch.dryrun import PEAK_FLOPS, HBM_BW, ICI_BW
from repro.models.registry import SHAPES, get_bundle, get_config


def sketch_gram_intensity(k: int, n: int, d: int, b: int):
    """Analytic per-path (flops, hbm_bytes) for the sketch->Gram hot path.

    Returns ``{"fused": (flops, bytes), "unfused": (flops, bytes),
    "d_tiles": t}`` for the d-tiled fused kernel vs the two-kernel
    apply+gram pipeline.  Both build on the same MXU primitives — encode
    matmul 2*K*n*b*d (one-hot / Hadamard mix columns are materialized in
    VMEM, not read from HBM) plus Gram 2*K*b*d^2 — but trade opposite
    resources:

    * unfused reads A once per block, writes the (K, b, d) A_tilde to HBM
      and reads it back for the Gram pass (2 extra round-trips).
    * fused never materializes A_tilde; with t = ceil(d_pad / d_tile)
      output tiles it recomputes the encode matmul (2t - 1)x (diagonal
      programs contract one panel with itself) but re-reads A's column
      panels 2t x — the diagonal programs still FETCH both panel blocks
      even though the second matmul is skipped (t = 1, the single-tile
      grid, recovers read-once / compute-once exactly).
    """
    from repro.kernels.sketch_gram import pick_d_tile

    d_pad = d + ((-d) % 128)
    t = max(1, -(-d_pad // pick_d_tile(b, d)))
    recompute = 2.0 * t - 1.0
    reread = 1.0 if t == 1 else 2.0 * t
    encode_fl, gram_fl = 2.0 * k * n * b * d, 2.0 * k * b * d * d
    a_read = 4.0 * k * n * d
    gram_out = 4.0 * d * d
    return {
        "fused": (encode_fl * recompute + gram_fl,
                  a_read * reread + gram_out),
        "unfused": (encode_fl + gram_fl,
                    a_read + 2.0 * 4.0 * k * b * d + gram_out),
        "d_tiles": t,
    }


def run(quick: bool = True):
    rows = []
    # sketch->gram hot path (paper Alg. 2): fused vs unfused AI at the
    # kernels_bench full shape (single-tile regime) AND at a d past the
    # single-tile VMEM budget, where the d-tiled grid trades encode
    # recompute + A re-reads against A_tilde round-trips.  Analytic, so
    # quick == full.
    ridge = PEAK_FLOPS / HBM_BW
    for kk, nn, dd, bb, suffix in ((10, 20_000, 512, 512, ""),
                                   (10, 20_000, 4096, 512, "_bigd")):
        cell = sketch_gram_intensity(kk, nn, dd, bb)
        tiles = cell["d_tiles"]
        for tag in ("fused", "unfused"):
            flops, byts = cell[tag]
            ai = flops / byts
            bound = "compute" if ai >= ridge else "memory"
            t_hbm = byts / HBM_BW
            t_mxu = flops / PEAK_FLOPS
            path = ("fused_tiled" if tiles > 1 else "fused") \
                if tag == "fused" else "unfused"
            rows.append({
                "name": f"roofline_sketch_gram_{tag}{suffix}",
                "us": max(t_hbm, t_mxu) * 1e6,
                "path": path,
                "derived": (f"bound={bound};ai={ai:.1f};ridge={ridge:.1f};"
                            f"hbm_mb={byts/1e6:.1f};gflop={flops/1e9:.1f};"
                            f"d_tiles={tiles};"
                            f"shape=({kk},{nn},{dd},{bb})"),
            })
    archs = ["qwen3-4b", "qwen3-moe-235b-a22b", "mamba2-780m"] if quick else \
        None
    if archs is None:
        from repro.configs import ASSIGNED_ARCHS
        archs = list(ASSIGNED_ARCHS)
    for arch in archs:
        cfg = get_config(arch)
        bundle = get_bundle(arch)
        for shape_name, shape in SHAPES.items():
            ok, _ = bundle.supports(shape)
            if not ok:
                continue
            costs = analytic.cell_costs(cfg, shape, 256)
            terms = {
                "c": costs.flops_per_chip / PEAK_FLOPS,
                "m": costs.hbm_bytes_per_chip / HBM_BW,
                "x": costs.coll_bytes_per_chip / ICI_BW,
            }
            bound = max(terms, key=terms.get)
            step = max(terms.values())
            rows.append({
                "name": f"roofline_{arch}_{shape_name}",
                "us": step * 1e6,
                "derived": (f"bound={bound};c_ms={terms['c']*1e3:.2f};"
                            f"m_ms={terms['m']*1e3:.2f};"
                            f"x_ms={terms['x']*1e3:.2f}"),
            })
    return rows
