"""Fig. 10: straggler mitigation schemes head-to-head — coded computing vs
speculative execution, applied independently to the gradient phase and the
Hessian phase (2x2 grid like the paper's figure)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import best_f, time_to_target
from repro.core import (LogisticRegression, NewtonConfig, OverSketchConfig,
                        oversketched_newton)
from repro.core.straggler import StragglerModel
from repro.data import make_logistic_dataset


def run(quick: bool = True):
    n, d = (12_000, 400) if quick else (30_000, 1000)
    data = make_logistic_dataset(jax.random.PRNGKey(4), n, d,
                                 cond=10.0, sorted_layout=True)
    obj = LogisticRegression(lam=1e-5)
    w0 = jnp.zeros(d)
    model = StragglerModel()
    iters = 7 if quick else 10
    sk = OverSketchConfig(((10 * d) // 256 + 1) * 256, 256, 0.25)

    cases = {
        "grad_coded_hess_sketch": dict(gradient_policy="coded",
                                       hessian_policy="oversketch"),
        "grad_spec_hess_sketch": dict(gradient_policy="speculative",
                                      hessian_policy="oversketch"),
        "grad_coded_hess_exact_spec": dict(gradient_policy="coded",
                                           hessian_policy="exact_speculative"),
        "grad_spec_hess_exact_spec": dict(gradient_policy="speculative",
                                          hessian_policy="exact_speculative"),
    }
    hists = {}
    for name, kw in cases.items():
        cfg = NewtonConfig(iters=iters, sketch=sk, unit_step=False,
                           coded_block_rows=256, **kw)
        hists[name] = oversketched_newton(obj, data, w0, cfg,
                                          model=model).history
    target = best_f(*hists.values())
    rows = []
    for name, h in hists.items():
        t = time_to_target(h, target)
        rows.append({
            "name": f"fig10_{name}",
            "us": (t if t != float("inf") else h["time"][-1]) * 1e6,
            "derived": (f"t_to_target={t:.2f};final_f={h['fval'][-1]:.5f};"
                        f"usd={h['cost'][-1]:.4f}"),
        })
    return rows
