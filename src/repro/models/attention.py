"""Grouped-query attention with RoPE, sliding windows, qk-norm, QKV bias,
logit softcap, KV caches, cross-attention — the attention substrate for every
assigned architecture.

Memory-efficient by construction: full-sequence attention is computed with an
online-softmax scan over key/value chunks (flash-attention structure in pure
JAX), so the O(S^2) score matrix is never materialized — required for the
32k-prefill dry-run cells to fit HBM.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import ModelConfig, Spec

NEG_INF = -1e30


# ------------------------------------------------------------------ specs ----
def attn_specs(cfg: ModelConfig, stacked: int = 0, *,
               cross: bool = False) -> Dict[str, Spec]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    lead: Tuple[int, ...] = (stacked,) if stacked else ()
    lax_: Tuple[Optional[str], ...] = ("layers",) if stacked else ()
    sp = {
        "wq": Spec(lead + (d, h, hd), lax_ + ("embed", "heads", "head_dim"),
                   fan_in_dims=(len(lead),)),
        "wk": Spec(lead + (d, kv, hd), lax_ + ("embed", "kv_heads",
                                               "head_dim"),
                   fan_in_dims=(len(lead),)),
        "wv": Spec(lead + (d, kv, hd), lax_ + ("embed", "kv_heads",
                                               "head_dim"),
                   fan_in_dims=(len(lead),)),
        "wo": Spec(lead + (h, hd, d), lax_ + ("heads", "head_dim", "embed"),
                   fan_in_dims=(len(lead), len(lead) + 1)),
    }
    if cfg.qkv_bias and not cross:
        sp["bq"] = Spec(lead + (h, hd), lax_ + ("heads", "head_dim"),
                        init="zeros")
        sp["bk"] = Spec(lead + (kv, hd), lax_ + ("kv_heads", "head_dim"),
                        init="zeros")
        sp["bv"] = Spec(lead + (kv, hd), lax_ + ("kv_heads", "head_dim"),
                        init="zeros")
    if cfg.qk_norm and not cross:
        sp["q_norm"] = Spec(lead + (hd,), lax_ + ("head_dim",), init="zeros")
        sp["k_norm"] = Spec(lead + (hd,), lax_ + ("head_dim",), init="zeros")
    return sp


# ------------------------------------------------------------- projections ---
def project_qkv(cfg: ModelConfig, p: Dict[str, jax.Array], xq: jax.Array,
                xkv: Optional[jax.Array] = None):
    """xq (B,S,d) [, xkv (B,T,d) for cross-attention] -> q,k,v."""
    xkv = xq if xkv is None else xkv
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", xkv, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", xkv, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if "q_norm" in p:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    return q, k, v


def out_proj(p: Dict[str, jax.Array], attn: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", attn, p["wo"])


# --------------------------------------------------- chunked online softmax --
def _chunk_scores(q, k, scale, softcap):
    """q (B,Sq,KV,G,hd), k (B,Ck,KV,hd) -> scores (B,KV,G,Sq,Ck) in f32."""
    s = jnp.einsum("bskgh,bckh->bkgsc", q, k).astype(jnp.float32) * scale
    return common.softcap(s, softcap)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, window, softcap: float = 0.0,
                      q_offset: int = 0, kv_len: Optional[jax.Array] = None,
                      chunk: int = 512, repeat_kv: bool = False) -> jax.Array:
    """Online-softmax attention over KV chunks (flash structure).

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); GQA via H = KV * G.
    window: ints or traced scalar; 0/None => unlimited.  q_offset: the
    absolute position of q[0] (for decode/prefill continuation).
    kv_len: optional valid-length mask bound (decode caches are allocated at
    max length).  Returns (B, Sq, H, hd).
    """
    b, sq, h, hd = q.shape
    if repeat_kv and k.shape[2] != h:
        # TP-friendly GQA: repeat KV to full heads so the head dim stays
        # shardable on "model" even when kv_heads < mesh model size.
        rep = h // k.shape[2]
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    skv, kv_heads = k.shape[1], k.shape[2]
    g = h // kv_heads
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kv_heads, g, hd)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, kv_heads, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kv_heads, hd).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m_run, l_run, acc = carry
        c_idx, k_blk, v_blk = inp
        scores = _chunk_scores(qg, k_blk, scale, softcap)   # (B,KV,G,Sq,C)
        k_pos = c_idx * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            w_ok = jnp.asarray(window) <= 0
            mask &= w_ok | (q_pos[:, None] - k_pos[None, :] <
                            jnp.maximum(jnp.asarray(window), 1))
        if kv_len is not None:
            mask &= k_pos[None, :] < kv_len
        if pad:
            mask &= k_pos[None, :] < skv
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m_run, scores.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        prob = jnp.exp(scores - m_new[..., None])
        l_new = l_run * alpha + prob.sum(axis=-1)
        pv = jnp.einsum("bkgsc,bckh->bkgsh", prob.astype(v_blk.dtype), v_blk)
        acc = acc * alpha[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kv_heads, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv_heads, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, kv_heads, g, sq, hd), q.dtype)
    # Remat the chunk body: backward recomputes scores/probs per chunk
    # instead of saving the (B, KV, G, Sq, C) tensors for every chunk.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-30).astype(acc.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)


# ------------------------------------------------------------------ decode ---
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window=None,
                     softcap: float = 0.0) -> jax.Array:
    """One-token attention against a preallocated cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, S_max, KV, hd); pos: scalar —
    the index of the *current* token (cache valid through pos inclusive).
    """
    b, _, h, hd = q.shape
    s_max, kv_heads = k_cache.shape[1], k_cache.shape[2]
    g = h // kv_heads
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, kv_heads, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg,
                        k_cache).astype(jnp.float32) * scale
    scores = common.softcap(scores, softcap)
    k_pos = jnp.arange(s_max)
    mask = k_pos <= pos
    if window is not None:
        w_ok = jnp.asarray(window) <= 0
        mask &= w_ok | (pos - k_pos < jnp.maximum(jnp.asarray(window), 1))
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    prob = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", prob.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, hd)


def update_cache(k_cache: jax.Array, v_cache: jax.Array, k_new: jax.Array,
                 v_new: jax.Array, pos) -> Tuple[jax.Array, jax.Array]:
    """Write S_new tokens at position ``pos`` (dynamic)."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), pos, axis=1)
    return k_cache, v_cache
