"""Model substrate shared pieces: the architecture config, parameter spec
trees (shape + logical sharding axes, materialized lazily so 235B-parameter
configs never allocate), norms, embeddings and activation helpers.

Logical axis names used throughout (mapped to mesh axes by
``repro.distributed.sharding``):
  embed, heads, kv_heads, head_dim, ffn, vocab, experts, layers, rnn, state,
  conv, classes
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0              # 0 => d_model // num_heads
    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    global_rope_theta: float = 0.0   # gemma3 uses a larger theta globally
    window_size: int = 0             # sliding-window size for local layers
    local_global_pattern: int = 0    # N => N local layers per 1 global
    logit_softcap: float = 0.0
    # norm / mlp flavour
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    mlp_type: str = "swiglu"         # swiglu | gelu
    pos_embed: str = "rope"          # rope | sinusoidal | learned
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 256
    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # recurrent (RG-LRU)
    rnn_width: int = 0
    attn_every: int = 0              # hybrid: 1 attention per `attn_every`
    # encoder-decoder
    encoder_layers: int = 0
    encoder_seq: int = 0
    # multimodal stub frontends
    frontend: Optional[str] = None   # audio_stub | patch_stub
    num_patches: int = 0
    max_seq: int = 131_072
    dtype: str = "bfloat16"
    # perf knobs (EXPERIMENTS.md §Perf iterates these)
    attn_chunk: int = 512            # KV chunk for online-softmax attention
    ce_chunk: int = 1024             # sequence chunk for fused CE loss
    repeat_kv: bool = True           # repeat GQA KV to full heads (TP-friendly)
    windowed_decode_cache: bool = False  # local layers: ring-buffer KV cache
    #   bounded by window_size instead of full context (5:1 gemma3 pattern
    #   cuts decode cache bytes ~4.8x; see EXPERIMENTS.md §Perf)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class Spec:
    """Declarative parameter: shape + logical axes + init recipe."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"            # normal | zeros | ones
    fan_in_dims: Tuple[int, ...] = ()   # dims whose product scales init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def materialize(specs: Pytree, key: jax.Array, dtype) -> Pytree:
    """Build real parameters from a spec tree (smoke-test scale only)."""
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            fan_in = 1
            for dim in spec.fan_in_dims:
                fan_in *= spec.shape[dim]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            out.append(scale * jax.random.normal(k, spec.shape, dtype))
    return jax.tree.unflatten(treedef, out)


def abstract(specs: Pytree, dtype) -> Pytree:
    """ShapeDtypeStruct tree — the dry-run path, zero allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
        is_leaf=lambda x: isinstance(x, Spec))


def spec_axes(specs: Pytree) -> Pytree:
    """Tree of logical-axis tuples, aligned with the param tree."""
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, Spec))


def param_count(specs: Pytree) -> int:
    return sum(math.prod(s.shape) for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, Spec)))


# ----------------------------------------------------------------- layers ----
def maybe_constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the ambient mesh; no-op when tracing
    outside any mesh context (unit tests, single-device paths)."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (ValueError, TypeError, RuntimeError):
        return x


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def apply_norm(cfg: ModelConfig, x: jax.Array, p: Dict[str, jax.Array]
               ) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def norm_spec(cfg: ModelConfig, dim: int, stacked: int = 0) -> Dict[str, Spec]:
    shape = (stacked, dim) if stacked else (dim,)
    axes = (("layers", "embed") if stacked else ("embed",))
    out = {"scale": Spec(shape, axes, init="zeros" if cfg.norm_type ==
                         "rmsnorm" else "ones")}
    if cfg.norm_type == "layernorm":
        out["bias"] = Spec(shape, axes, init="zeros")
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def embed_lookup(embed: jax.Array, tokens: jax.Array,
                 grad_chunk: int = 512) -> jax.Array:
    """Token embedding with a sharding-aware backward.

    Forward is a plain gather.  The *default* gather-VJP is a scatter-add
    whose accumulator GSPMD keeps replicated — a full (V, d) f32 buffer per
    chip (2.5 GB at 152k x 4096).  The custom backward instead accumulates
    chunked one-hot matmuls with the vocab dim constrained to "model", so the
    gradient is born sharded.
    """
    return jnp.take(embed, tokens, axis=0)


def _embed_lookup_fwd(embed, tokens, grad_chunk):
    # zero-size sentinel carries the param dtype through the residuals
    # (raw dtypes are not valid JAX residual types)
    return jnp.take(embed, tokens, axis=0), (
        tokens, embed.shape[0], jnp.zeros((0,), embed.dtype))


def _embed_lookup_bwd(grad_chunk, res, g):
    tokens, vocab, dtype_probe = res
    dtype = dtype_probe.dtype
    b, s = tokens.shape
    cs = min(grad_chunk, s)
    n_chunks = -(-s // cs)
    pad = n_chunks * cs - s
    if pad:
        tokens = jnp.pad(tokens, ((0, 0), (0, pad)), constant_values=0)
        g = jnp.pad(g, ((0, 0), (0, pad), (0, 0)))
    # dynamic_slice chunking (NOT reshape) — preserves batch sharding.

    def body(acc, i):
        tk = jax.lax.dynamic_slice_in_dim(tokens, i * cs, cs, axis=1)
        gk = jax.lax.dynamic_slice_in_dim(g, i * cs, cs, axis=1)
        onehot = jax.nn.one_hot(tk, vocab, dtype=gk.dtype)     # (B, cs, V)
        onehot = maybe_constrain(onehot, None, None, "model")
        part = jnp.einsum("bsv,bsd->vd", onehot, gk)
        part = maybe_constrain(part, "model")
        return acc + part, None

    acc0 = maybe_constrain(
        jnp.zeros((vocab, g.shape[-1]), jnp.float32), "model")
    grad_embed, _ = jax.lax.scan(jax.checkpoint(body), acc0,
                                 jnp.arange(n_chunks))
    return (grad_embed.astype(dtype), None)


embed_lookup.defvjp(_embed_lookup_fwd, _embed_lookup_bwd)


def sinusoidal_positions(num: int, dim: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(num)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2) * (-math.log(10_000.0) / dim))
    pe = jnp.zeros((num, dim))
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """Rotary embedding.  x: (..., seq, heads, head_dim); positions: (seq,)
    or (batch, seq); theta may be a traced scalar (per-layer theta)."""
    hd = x.shape[-1]
    freq = jnp.exp(jnp.arange(0, hd // 2, dtype=jnp.float32) *
                   (-2.0 / hd) * jnp.log(theta))
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freq[None, :]
        ang = ang[None, :, None, :]              # (1, seq, 1, hd/2)
    else:
        ang = positions[..., None].astype(jnp.float32) * freq
        ang = ang[:, :, None, :]                 # (batch, seq, 1, hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       ignore_id: int = -1) -> jax.Array:
    """Mean token NLL.  logits (B, S, V) any float dtype; labels (B, S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_cross_entropy(h: jax.Array, head: jax.Array, labels: jax.Array,
                          *, transpose_head: bool = False,
                          chunk: int = 1024, ignore_id: int = -1
                          ) -> jax.Array:
    """Mean token NLL with the vocab projection fused per sequence chunk, so
    the full (B, S, V) logits tensor is never materialized — required for the
    256k-vocab training cells to fit HBM.

    h (B, S, d); head (d, V), or (V, d) with transpose_head=True (tied).
    """
    b, s, _ = h.shape
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=ignore_id)

    # NOTE: chunks are carved with dynamic_slice, NOT reshape+transpose —
    # reshaping a batch-sharded (B, S, d) into (B, nc, c, d) makes GSPMD drop
    # the batch sharding and gather the full global batch (observed: a
    # 5 GB/chip f32 logits chunk).  Slices preserve operand sharding.
    def body(carry, i):
        nll_sum, count = carry
        h_blk = jax.lax.dynamic_slice_in_dim(h, i * chunk, chunk, axis=1)
        l_blk = jax.lax.dynamic_slice_in_dim(labels, i * chunk, chunk,
                                             axis=1)
        eq = "bsd,vd->bsv" if transpose_head else "bsd,dv->bsv"
        logits = jnp.einsum(eq, h_blk, head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l_blk, 0)[..., None], axis=-1)[..., 0]
        mask = (l_blk != ignore_id).astype(jnp.float32)
        return (nll_sum + ((lse - gold) * mask).sum(),
                count + mask.sum()), None

    (nll_sum, count), _ = jax.lax.scan(
        jax.checkpoint(body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n_chunks))
    return nll_sum / jnp.maximum(count, 1.0)
