"""Mamba-2 SSD (state-space duality, arXiv:2405.21060) layer.

Training/prefill uses the chunked SSD algorithm: within a chunk the
contribution is computed as a masked quadratic form (the "attention-like"
dual); across chunks a short linear recurrence carries the (H, P, N) state.
Decode is the O(1) recurrent update.  Pure JAX, scan-friendly, shards with
heads on the "model" axis.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Spec


def ssd_specs(cfg: ModelConfig, stacked: int = 0) -> Dict[str, Spec]:
    d = cfg.d_model
    din = cfg.ssm_inner
    h = cfg.ssm_heads
    n = cfg.ssm_state
    conv_dim = din + 2 * n                      # x, B, C share the conv
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    return {
        # fused input projection: [z (din), x (din), B (n), C (n), dt (h)]
        "w_in": Spec(lead + (d, 2 * din + 2 * n + h),
                     lax_ + ("embed", "rnn"), fan_in_dims=(len(lead),)),
        "conv_w": Spec(lead + (cfg.ssm_conv, conv_dim),
                       lax_ + ("conv", "rnn")),
        "conv_b": Spec(lead + (conv_dim,), lax_ + ("rnn",), init="zeros"),
        "a_log": Spec(lead + (h,), lax_ + ("heads",), init="zeros"),
        "dt_bias": Spec(lead + (h,), lax_ + ("heads",), init="zeros"),
        "d_skip": Spec(lead + (h,), lax_ + ("heads",), init="ones"),
        "norm": Spec(lead + (din,), lax_ + ("rnn",), init="zeros"),
        "w_out": Spec(lead + (din, d), lax_ + ("rnn", "embed"),
                      fan_in_dims=(len(lead),)),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    din, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :din]
    x = proj[..., din:2 * din]
    b_mat = proj[..., 2 * din:2 * din + n]
    c_mat = proj[..., 2 * din + n:2 * din + 2 * n]
    dt = proj[..., 2 * din + 2 * n:]
    return z, x, b_mat, c_mat, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, x (B, S, C), w (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def ssd_forward(cfg: ModelConfig, p: Dict[str, jax.Array], x_in: jax.Array,
                ) -> jax.Array:
    """Full-sequence SSD.  x_in (B, S, d) -> (B, S, d)."""
    bsz, s_orig, _ = x_in.shape
    din, n, h, hp = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s_orig)
    s_pad = (-s_orig) % q
    if s_pad:   # causal => zero right-padding never affects real positions
        x_in = jnp.pad(x_in, ((0, 0), (0, s_pad), (0, 0)))
    s = s_orig + s_pad
    nc = s // q

    proj = x_in @ p["w_in"]
    z, xr, b_mat, c_mat, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xr, b_mat, c_mat], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xr, b_mat, c_mat = (conv_out[..., :din], conv_out[..., din:din + n],
                        conv_out[..., din + n:])

    xh = xr.reshape(bsz, s, h, hp)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                   # (H,)
    da = dt * a                                                    # (B,S,H)

    # chunked views
    xc = xh.reshape(bsz, nc, q, h, hp)
    bc = b_mat.reshape(bsz, nc, q, n)
    cc = c_mat.reshape(bsz, nc, q, n)
    dtc = dt.reshape(bsz, nc, q, h)
    dac = da.reshape(bsz, nc, q, h)

    cum = jnp.cumsum(dac, axis=2)                                  # (B,Nc,Q,H)
    # intra-chunk (dual/quadratic) term
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]            # (B,Nc,Q,Q,H)
    idx = jnp.arange(q)
    causal = idx[:, None] >= idx[None, :]
    l_mat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)                     # (B,Nc,Q,Q)
    w_ij = cb[..., None] * l_mat * dtc[:, :, None, :, :]           # (B,Nc,Q,Q,H)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", w_ij.astype(xc.dtype), xc)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                # (B,Nc,Q,H)
    sb = (decay_to_end * dtc)[..., None] * bc[:, :, :, None, :]    # (B,Nc,Q,H,N)
    states = jnp.einsum("bcqhn,bcqhp->bchpn", sb.astype(xc.dtype), xc)

    # inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                        # (B,Nc,H)

    def carry_fn(hprev, inp):
        st, dec = inp
        hnew = hprev * dec[..., None, None].astype(hprev.dtype) + st
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, hp, n), xc.dtype)
    _, h_before = jax.lax.scan(
        carry_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)                   # (B,Nc,H,P,N)

    # inter-chunk contribution: C_i exp(cum_i) h_{c-1}
    in_decay = jnp.exp(cum)                                        # (B,Nc,Q,H)
    y_off = jnp.einsum("bcqn,bchpn->bcqhp", cc.astype(xc.dtype), h_before)
    y_off = y_off * in_decay[..., None].astype(xc.dtype)

    y = (y_diag + y_off).reshape(bsz, s, h, hp)
    y = y + xh * p["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(bsz, s, din)
    if s_pad:
        y = y[:, :s_orig]
        z = z[:, :s_orig]
    # gated RMSNorm then output projection (mamba2 block structure)
    from repro.models import common as cm
    y = cm.rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["w_out"]


def ssd_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    din, n, h, hp = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    conv_dim = din + 2 * n
    return {
        "ssm": jnp.zeros((batch, h, hp, n), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def ssd_decode_step(cfg: ModelConfig, p: Dict[str, jax.Array],
                    state: Dict[str, jax.Array], x_tok: jax.Array
                    ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """One-token recurrent update.  x_tok (B, d) -> (new_state, y (B, d))."""
    din, n, h, hp = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    proj = x_tok @ p["w_in"]
    z, xr, b_mat, c_mat, dt = _split_proj(cfg, proj[:, None, :])
    conv_in = jnp.concatenate([xr, b_mat, c_mat], axis=-1)         # (B,1,C)
    hist = jnp.concatenate([state["conv"], conv_in], axis=1)       # (B,K,C)
    conv_out = jax.nn.silu((hist * p["conv_w"]).sum(axis=1) + p["conv_b"])
    new_conv = hist[:, 1:]
    xr = conv_out[:, :din].reshape(-1, h, hp)
    b_t = conv_out[:, din:din + n]
    c_t = conv_out[:, din + n:]

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                        # (B,H)
    db = dt[..., None] * b_t[:, None, :]                           # (B,H,N)
    upd = xr[..., None] * db[:, :, None, :]                        # (B,H,P,N)
    ssm = state["ssm"] * decay[..., None, None].astype(state["ssm"].dtype) \
        + upd.astype(state["ssm"].dtype)
    y = jnp.einsum("bhpn,bn->bhp", ssm, c_t.astype(ssm.dtype))
    y = y + xr * p["d_skip"][None, :, None].astype(xr.dtype)
    y = y.reshape(-1, din)
    from repro.models import common as cm
    y = cm.rms_norm(y * jax.nn.silu(z[:, 0]), p["norm"])
    return {"ssm": ssm, "conv": new_conv}, y @ p["w_out"]
