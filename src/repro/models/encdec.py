"""Encoder-decoder transformer (Whisper-large-v3 backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, d) directly to the encoder.
Encoder: bidirectional self-attention layers (layernorm + gelu MLP).
Decoder: causal self-attention + cross-attention to the encoder memory.
Serving: prefill caches both self-attn KV and the (static) cross-attn KV.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common
from repro.models.common import ModelConfig, Spec

Pytree = Any


def encdec_specs(cfg: ModelConfig) -> Dict[str, Any]:
    ne, nd = cfg.encoder_layers, cfg.num_layers
    return {
        "embed": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                      fan_in_dims=(1,)),
        "pos_dec": Spec((cfg.max_seq, cfg.d_model), (None, "embed"),
                        fan_in_dims=(1,)),
        "enc": {
            "attn": attn.attn_specs(cfg, stacked=ne),
            "ln1": common.norm_spec(cfg, cfg.d_model, stacked=ne),
            "ffn": _gelu_mlp_specs(cfg, ne),
            "ln2": common.norm_spec(cfg, cfg.d_model, stacked=ne),
        },
        "enc_norm": common.norm_spec(cfg, cfg.d_model),
        "dec": {
            "self_attn": attn.attn_specs(cfg, stacked=nd),
            "ln1": common.norm_spec(cfg, cfg.d_model, stacked=nd),
            "cross_attn": attn.attn_specs(cfg, stacked=nd, cross=True),
            "ln_x": common.norm_spec(cfg, cfg.d_model, stacked=nd),
            "ffn": _gelu_mlp_specs(cfg, nd),
            "ln2": common.norm_spec(cfg, cfg.d_model, stacked=nd),
        },
        "final_norm": common.norm_spec(cfg, cfg.d_model),
    }


def _gelu_mlp_specs(cfg: ModelConfig, stacked: int) -> Dict[str, Spec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_up": Spec((stacked, d, f), ("layers", "embed", "ffn"),
                     fan_in_dims=(1,)),
        "b_up": Spec((stacked, f), ("layers", "ffn"), init="zeros"),
        "w_down": Spec((stacked, f, d), ("layers", "ffn", "embed"),
                       fan_in_dims=(1,)),
        "b_down": Spec((stacked, d), ("layers", "embed"), init="zeros"),
    }


def _gelu_mlp(p, x):
    return (jax.nn.gelu(x @ p["w_up"] + p["b_up"])) @ p["w_down"] + p["b_down"]


def encode(cfg: ModelConfig, params: Pytree,
           frame_embeds: jax.Array) -> jax.Array:
    """(B, T_enc, d) precomputed frontend embeddings -> encoder memory."""
    h = frame_embeds.astype(cfg.compute_dtype)
    h = h + common.sinusoidal_positions(h.shape[1], cfg.d_model,
                                        h.dtype)[None]

    def body(hc, lp):
        x = common.apply_norm(cfg, hc, lp["ln1"])
        q, k, v = attn.project_qkv(cfg, lp["attn"], x)
        o = attn.chunked_attention(q, k, v, causal=False, window=None,
                                   chunk=cfg.attn_chunk)
        hc = hc + attn.out_proj(lp["attn"], o)
        x = common.apply_norm(cfg, hc, lp["ln2"])
        return hc + _gelu_mlp(lp["ffn"], x), None

    from repro.models.transformer import _two_level_scan
    h, _ = _two_level_scan(lambda hc, lp: (body(hc, lp)[0],
                                           jnp.zeros((), jnp.float32)),
                           h, params["enc"], cfg.encoder_layers, True)
    return common.apply_norm(cfg, h, params["enc_norm"])


def _decoder_pass(cfg: ModelConfig, params: Pytree, h: jax.Array,
                  memory: jax.Array, *,
                  cache: Optional[Pytree] = None, pos=None):
    """Shared decoder stack.  Full-seq when cache is None (train) or
    cache-filling prefill / single-token decode otherwise."""
    decoding = cache is not None and pos is not None and h.shape[1] == 1

    def body(hc, xs):
        if cache is None:
            lp = xs
            kc = vc = mk = mv = None
        else:
            lp, kc, vc, mk, mv = xs
        x = common.apply_norm(cfg, hc, lp["ln1"])
        q, k, v = attn.project_qkv(cfg, lp["self_attn"], x)
        if decoding:
            kc, vc = attn.update_cache(kc, vc, k, v, pos)
            o = attn.decode_attention(q, kc, vc, pos)
        else:
            if cache is not None:
                kc, vc = attn.update_cache(kc, vc, k, v, 0)
            o = attn.chunked_attention(q, k, v, causal=True, window=None,
                                       chunk=cfg.attn_chunk)
        hc = hc + attn.out_proj(lp["self_attn"], o)
        # cross attention (memory KV cached at prefill)
        x = common.apply_norm(cfg, hc, lp["ln_x"])
        if cache is not None and decoding:
            qx = jnp.einsum("bsd,dhk->bshk", x, lp["cross_attn"]["wq"])
            ox = attn.chunked_attention(qx, mk, mv, causal=False, window=None)
        else:
            qx, mk_new, mv_new = attn.project_qkv(cfg, lp["cross_attn"], x,
                                                  memory)
            if cache is not None:
                mk, mv = mk_new.astype(mk.dtype), mv_new.astype(mv.dtype)
            ox = attn.chunked_attention(qx, mk_new if cache is None else mk,
                                        mv_new if cache is None else mv,
                                        causal=False, window=None)
        hc = hc + attn.out_proj(lp["cross_attn"], ox)
        x = common.apply_norm(cfg, hc, lp["ln2"])
        hc = hc + _gelu_mlp(lp["ffn"], x)
        out = None if cache is None else (kc, vc, mk, mv)
        return hc, out

    if cache is None:
        from repro.models.transformer import _two_level_scan
        h, _ = _two_level_scan(lambda hc, lp: (body(hc, lp)[0],
                                               jnp.zeros((), jnp.float32)),
                               h, params["dec"], cfg.num_layers, True)
        return h, None
    h, new = jax.lax.scan(body, h, (params["dec"], cache["k"], cache["v"],
                                    cache["mk"], cache["mv"]))
    return h, new


def forward(cfg: ModelConfig, params: Pytree, tokens: jax.Array,
            frame_embeds: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Training pass -> (logits (B,S,V), aux=0)."""
    memory = encode(cfg, params, frame_embeds)
    s = tokens.shape[1]
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    h = h + params["pos_dec"][:s].astype(h.dtype)[None]
    h, _ = _decoder_pass(cfg, params, h, memory)
    h = common.apply_norm(cfg, h, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: Pytree,
            batch: Dict[str, jax.Array], constrain=None) -> jax.Array:
    memory = encode(cfg, params, batch["frame_embeds"])
    tokens = batch["tokens"]
    s = tokens.shape[1]
    h = common.embed_lookup(params["embed"],
                            tokens).astype(cfg.compute_dtype)
    h = h + params["pos_dec"][:s].astype(h.dtype)[None]
    h, _ = _decoder_pass(cfg, params, h, memory)
    h = common.apply_norm(cfg, h, params["final_norm"])
    return common.chunked_cross_entropy(h, params["embed"], batch["labels"],
                                        transpose_head=True,
                                        chunk=cfg.ce_chunk)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> Pytree:
    dtype = dtype or cfg.compute_dtype
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    nd = cfg.num_layers
    t_enc = cfg.encoder_seq
    return {
        "k": jnp.zeros((nd, batch, max_seq, kv, hd), dtype),
        "v": jnp.zeros((nd, batch, max_seq, kv, hd), dtype),
        "mk": jnp.zeros((nd, batch, t_enc, kv, hd), dtype),
        "mv": jnp.zeros((nd, batch, t_enc, kv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Pytree, tokens: jax.Array,
            cache: Pytree, frame_embeds: jax.Array
            ) -> Tuple[jax.Array, Pytree]:
    memory = encode(cfg, params, frame_embeds)
    s = tokens.shape[1]
    h = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    h = h + params["pos_dec"][:s].astype(h.dtype)[None]
    h, new = _decoder_pass(cfg, params, h, memory, cache=cache)
    kc, vc, mk, mv = new
    cache = {"k": kc, "v": vc, "mk": mk, "mv": mv,
             "pos": jnp.asarray(s, jnp.int32)}
    h = common.apply_norm(cfg, h[:, -1:], params["final_norm"])
    return jnp.einsum("bsd,vd->bsv", h, params["embed"]), cache


def decode_step(cfg: ModelConfig, params: Pytree, cache: Pytree,
                token: jax.Array) -> Tuple[jax.Array, Pytree]:
    pos = cache["pos"]
    h = jnp.take(params["embed"], token[:, None],
                 axis=0).astype(cfg.compute_dtype)
    pe = jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1, axis=0)
    h = h + pe[None].astype(h.dtype)
    h, new = _decoder_pass(cfg, params, h, memory=None, cache=cache, pos=pos)
    kc, vc, mk, mv = new
    new_cache = {"k": kc, "v": vc, "mk": mk, "mv": mv, "pos": pos + 1}
    h = common.apply_norm(cfg, h, params["final_norm"])
    return jnp.einsum("bsd,vd->bsv", h, params["embed"])[:, 0], new_cache
