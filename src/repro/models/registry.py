"""Model bundle: a uniform interface over all families, consumed by the
trainer, the server and the dry-run launcher.

Every architecture exposes:
  specs()                -> param Spec tree (shapes + logical sharding axes)
  init(key)              -> real params (reduced/smoke scale only)
  abstract()             -> ShapeDtypeStruct params (dry-run, no allocation)
  loss(params, batch)    -> scalar train loss
  init_cache(batch, s)   -> serving cache
  prefill(params, ...)   -> (logits, cache)
  decode(params, cache, token) -> (logits, cache)
  input_specs(shape)     -> ShapeDtypeStruct batch for the dry-run
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common, encdec, transformer
from repro.models.common import ModelConfig

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


class ModelBundle:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.is_encdec = cfg.family == "encdec"
        self._mod = encdec if self.is_encdec else transformer

    # ------------------------------------------------------------- params --
    def specs(self) -> Pytree:
        if self.is_encdec:
            return encdec.encdec_specs(self.cfg)
        return transformer.decoder_specs(self.cfg)

    def init(self, key: jax.Array) -> Pytree:
        return common.materialize(self.specs(), key, self.cfg.compute_dtype)

    def abstract(self) -> Pytree:
        return common.abstract(self.specs(), self.cfg.compute_dtype)

    def logical_axes(self) -> Pytree:
        return common.spec_axes(self.specs())

    def param_count(self) -> int:
        return common.param_count(self.specs())

    # --------------------------------------------------------------- steps --
    def loss(self, params: Pytree, batch: Dict[str, jax.Array],
             constrain=None) -> jax.Array:
        return self._mod.loss_fn(self.cfg, params, batch, constrain)

    def init_cache(self, batch: int, max_seq: int, dtype=None) -> Pytree:
        return self._mod.init_cache(self.cfg, batch, max_seq, dtype)

    def prefill(self, params: Pytree, tokens: jax.Array, cache: Pytree,
                extra: Optional[jax.Array] = None):
        if self.is_encdec:
            return encdec.prefill(self.cfg, params, tokens, cache, extra)
        return transformer.prefill(self.cfg, params, tokens, cache, extra)

    def decode(self, params: Pytree, cache: Pytree, token: jax.Array):
        return self._mod.decode_step(self.cfg, params, cache, token)

    # --------------------------------------------------------- input specs --
    def input_specs(self, shape: ShapeSpec, *, reduced: bool = False
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of one cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            if self.is_encdec:
                return {
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32),
                    "frame_embeds": jax.ShapeDtypeStruct(
                        (b, cfg.encoder_seq, cfg.d_model), cfg.compute_dtype),
                }
            out = {
                "tokens": jax.ShapeDtypeStruct((b, s - cfg.num_patches), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
            if cfg.frontend == "patch_stub":
                out["patch_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.num_patches, cfg.d_model), cfg.compute_dtype)
            return out
        if shape.kind == "prefill":
            out = {"tokens": jax.ShapeDtypeStruct(
                (b, s - cfg.num_patches), i32)}
            if self.is_encdec:
                out["frame_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.encoder_seq, cfg.d_model), cfg.compute_dtype)
            elif cfg.frontend == "patch_stub":
                out["patch_embeds"] = jax.ShapeDtypeStruct(
                    (b, cfg.num_patches, cfg.d_model), cfg.compute_dtype)
            return out
        # decode: one new token against a seq_len cache
        return {"token": jax.ShapeDtypeStruct((b,), i32)}

    def supports(self, shape: ShapeSpec) -> Tuple[bool, str]:
        """Cell applicability (DESIGN.md §Arch-applicability)."""
        if shape.name == "long_500k" and self.cfg.family not in ("ssm",
                                                                 "hybrid"):
            return False, ("full-attention architecture: 500k decode needs "
                           "sub-quadratic attention (skip per assignment)")
        return True, ""


# --------------------------------------------------------------- registry ----
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        from repro import configs  # noqa: F401 — populate registry
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def get_bundle(name: str) -> ModelBundle:
    return ModelBundle(get_config(name))


def list_archs():
    if not _REGISTRY:
        from repro import configs  # noqa: F401
    return sorted(_REGISTRY)
