"""Decoder-only LM covering the dense / MoE / hybrid (RG-LRU) / SSM (SSD) /
VLM-backbone families.  Layers are scanned (`jax.lax.scan` over stacked
params) so the HLO stays small for 94-layer configs, with per-layer scalars
(sliding window, rope theta) carried as scan inputs — this is how gemma3's
5:1 local:global pattern and recurrentgemma's 2:1 recurrent:attention pattern
compile to a single compact program.  Each layer body is rematerialized
(jax.checkpoint) on the training path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import common, moe, rglru, ssd
from repro.models.common import ModelConfig, Spec

Pytree = Any
MOE_AUX_WEIGHT = 0.01


# ------------------------------------------------------------------ specs ----
def mlp_specs(cfg: ModelConfig, stacked: int = 0) -> Dict[str, Spec]:
    d, f = cfg.d_model, cfg.d_ff
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": Spec(lead + (d, f), lax_ + ("embed", "ffn"),
                           fan_in_dims=(len(lead),)),
            "w_up": Spec(lead + (d, f), lax_ + ("embed", "ffn"),
                         fan_in_dims=(len(lead),)),
            "w_down": Spec(lead + (f, d), lax_ + ("ffn", "embed"),
                           fan_in_dims=(len(lead),)),
        }
    return {   # gelu MLP with biases (whisper style)
        "w_up": Spec(lead + (d, f), lax_ + ("embed", "ffn"),
                     fan_in_dims=(len(lead),)),
        "b_up": Spec(lead + (f,), lax_ + ("ffn",), init="zeros"),
        "w_down": Spec(lead + (f, d), lax_ + ("ffn", "embed"),
                       fan_in_dims=(len(lead),)),
        "b_down": Spec(lead + (d,), lax_ + ("embed",), init="zeros"),
    }


def mlp_forward(cfg: ModelConfig, p: Dict[str, jax.Array],
                x: jax.Array) -> jax.Array:
    if cfg.mlp_type == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return (jax.nn.gelu(x @ p["w_up"] + p["b_up"])) @ p["w_down"] + p["b_down"]


def _uniform_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    n = cfg.num_layers
    sp: Dict[str, Any] = {
        "ln1": common.norm_spec(cfg, cfg.d_model, stacked=n),
        "ln2": common.norm_spec(cfg, cfg.d_model, stacked=n),
    }
    if cfg.family == "ssm":
        sp.pop("ln2")
        sp["mix"] = ssd.ssd_specs(cfg, stacked=n)
    else:
        sp["attn"] = attn.attn_specs(cfg, stacked=n)
        if cfg.family == "moe":
            sp["ffn"] = moe.moe_specs(cfg, stacked=n)
        else:
            sp["ffn"] = mlp_specs(cfg, stacked=n)
    return sp


def _hybrid_layer_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """RecurrentGemma: pattern (rec, rec, attn); every layer has an MLP."""
    n = cfg.num_layers
    n_attn = n // cfg.attn_every
    n_rec = n - n_attn
    return {
        "rec": rglru.rglru_specs(cfg, stacked=n_rec),
        "rec_ln": common.norm_spec(cfg, cfg.d_model, stacked=n_rec),
        "rec_mlp": mlp_specs(cfg, stacked=n_rec),
        "rec_mlp_ln": common.norm_spec(cfg, cfg.d_model, stacked=n_rec),
        "attn": attn.attn_specs(cfg, stacked=n_attn),
        "attn_ln": common.norm_spec(cfg, cfg.d_model, stacked=n_attn),
        "attn_mlp": mlp_specs(cfg, stacked=n_attn),
        "attn_mlp_ln": common.norm_spec(cfg, cfg.d_model, stacked=n_attn),
    }


def decoder_specs(cfg: ModelConfig) -> Dict[str, Any]:
    sp: Dict[str, Any] = {
        "embed": Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                      fan_in_dims=(1,)),
        "final_norm": common.norm_spec(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        sp["lm_head"] = Spec((cfg.d_model, cfg.vocab_size),
                             ("embed", "vocab"), fan_in_dims=(0,))
    if cfg.family == "hybrid":
        sp["layers"] = _hybrid_layer_specs(cfg)
    else:
        sp["layers"] = _uniform_layer_specs(cfg)
    return sp


# --------------------------------------------------------- layer schedules ---
def layer_schedule(cfg: ModelConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Per-layer (window, rope_theta) for uniform attention stacks.
    window 0 => unlimited (global)."""
    n = cfg.num_layers
    windows = np.zeros(n, np.int32)
    thetas = np.full(n, cfg.rope_theta, np.float32)
    if cfg.local_global_pattern and cfg.window_size:
        pat = cfg.local_global_pattern + 1
        for i in range(n):
            if (i + 1) % pat != 0:            # local layer
                windows[i] = cfg.window_size
            else:                             # global layer
                thetas[i] = cfg.global_rope_theta or cfg.rope_theta
    elif cfg.window_size and not cfg.local_global_pattern:
        windows[:] = cfg.window_size
    return windows, thetas


# ------------------------------------------------------------- embeddings ----
def embed_tokens(cfg: ModelConfig, params: Pytree, tokens: jax.Array,
                 extra_embeds: Optional[jax.Array]) -> jax.Array:
    h = common.embed_lookup(params["embed"],
                            tokens).astype(cfg.compute_dtype)
    if extra_embeds is not None:   # VLM / audio stub: prepend frontier embeds
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    if cfg.pos_embed == "sinusoidal":
        pe = common.sinusoidal_positions(h.shape[1], cfg.d_model, h.dtype)
        h = h + pe[None]
    return h


def lm_logits(cfg: ModelConfig, params: Pytree, h: jax.Array) -> jax.Array:
    h = common.apply_norm(cfg, h, params["final_norm"])
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"])
    return jnp.einsum("bsd,dv->bsv", h, params["lm_head"])


# -------------------------------------------------------------- full pass ----
def _uniform_block(cfg: ModelConfig, lp: Pytree, h: jax.Array,
                   positions: jax.Array, window, theta,
                   constrain=None) -> jax.Array:
    inner = (lambda x: constrain(x, "inner")) if constrain is not None \
        else (lambda x: x)
    if cfg.family == "ssm":
        return h + ssd.ssd_forward(cfg, lp["mix"],
                                   inner(common.apply_norm(cfg, h,
                                                           lp["ln1"])))
    x = inner(common.apply_norm(cfg, h, lp["ln1"]))
    q, k, v = attn.project_qkv(cfg, lp["attn"], x)
    if cfg.pos_embed == "rope":
        q = common.rope(q, positions, theta)
        k = common.rope(k, positions, theta)
    o = attn.chunked_attention(q, k, v, causal=True, window=window,
                               softcap=cfg.logit_softcap,
                               chunk=cfg.attn_chunk, repeat_kv=cfg.repeat_kv)
    h = h + attn.out_proj(lp["attn"], o)
    x = inner(common.apply_norm(cfg, h, lp["ln2"]))
    if cfg.family == "moe":
        y, aux = moe.moe_ffn(cfg, lp["ffn"], x)
        _moe_aux_store.append(aux)
    else:
        y = mlp_forward(cfg, lp["ffn"], x)
    return h + y


_moe_aux_store = []


def forward_hidden(cfg: ModelConfig, params: Pytree, tokens: jax.Array,
                   extra_embeds: Optional[jax.Array] = None, *,
                   remat: bool = True,
                   constrain=None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence pass -> (hidden (B,S,d), moe_aux scalar).

    ``constrain`` is an optional h -> h sharding-constraint hook applied to
    the residual stream between layers (sequence-parallel activations)."""
    h = embed_tokens(cfg, params, tokens, extra_embeds)
    if constrain is not None:
        h = constrain(h, "carry")
    s = h.shape[1]
    positions = jnp.arange(s)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        h = _hybrid_forward(cfg, params["layers"], h, positions, remat,
                            constrain)
    else:
        windows, thetas = layer_schedule(cfg)

        def body(hc, xs):
            lp, w, th = xs
            del _moe_aux_store[:]
            out = _uniform_block(cfg, lp, hc, positions, w, th, constrain)
            if constrain is not None:
                out = constrain(out, "carry")
            aux = _moe_aux_store[0] if _moe_aux_store else \
                jnp.zeros((), jnp.float32)
            return out, aux

        h, aux_total = _two_level_scan(body, h, (params["layers"],
                                                 jnp.asarray(windows),
                                                 jnp.asarray(thetas)),
                                       cfg.num_layers, remat)
    return h, aux_total


def _two_level_scan(body, h, xs, num_layers: int, remat: bool):
    """sqrt(L) rematerialization: scan groups of ~sqrt(L) layers, remat at
    BOTH levels.  The backward pass then keeps ~2*sqrt(L) residual-stream
    carries live instead of L — the difference between 6.3 GB and 0.8 GB of
    saved activations per chip on the 94-layer MoE config."""
    if not remat:
        h, auxes = jax.lax.scan(body, h, xs)
        return h, auxes.sum()
    import math as _m
    k = max(1, int(_m.ceil(_m.sqrt(num_layers))))
    g = num_layers // k
    r = num_layers - g * k
    take = lambda sl: jax.tree.map(lambda a: a[sl], xs)
    aux_total = jnp.zeros((), jnp.float32)

    inner = jax.checkpoint(body)

    def group_body(hc, gxs):
        hc, auxes = jax.lax.scan(inner, hc, gxs)
        return hc, auxes.sum()

    if g > 0:
        main = jax.tree.map(
            lambda a: a[:g * k].reshape((g, k) + a.shape[1:]), xs)
        h, aux1 = jax.lax.scan(jax.checkpoint(group_body), h, main)
        aux_total = aux_total + aux1.sum()
    if r > 0:
        h, aux2 = jax.lax.scan(inner, h, take(slice(g * k, None)))
        aux_total = aux_total + aux2.sum()
    return h, aux_total


def forward(cfg: ModelConfig, params: Pytree, tokens: jax.Array,
            extra_embeds: Optional[jax.Array] = None, *,
            remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence pass -> (logits (B,S,V), moe_aux scalar)."""
    h, aux_total = forward_hidden(cfg, params, tokens, extra_embeds,
                                  remat=remat)
    return lm_logits(cfg, params, h), aux_total


def _hybrid_forward(cfg: ModelConfig, lp: Pytree, h: jax.Array,
                    positions: jax.Array, remat: bool,
                    constrain=None) -> jax.Array:
    """(rec, rec, attn) groups scanned; remainder rec layers appended."""
    n = cfg.num_layers
    n_attn = n // cfg.attn_every
    per_group_rec = cfg.attn_every - 1
    n_group_rec = n_attn * per_group_rec
    n_rec_total = n - n_attn
    rem = n_rec_total - n_group_rec
    inner = (lambda x: constrain(x, "inner")) if constrain is not None \
        else (lambda x: x)
    carry = (lambda x: constrain(x, "carry")) if constrain is not None \
        else (lambda x: x)

    def rec_block(hc, p_rec, p_ln, p_mlp, p_mlp_ln):
        x = inner(common.apply_norm(cfg, hc, p_ln))
        hc = hc + rglru.rglru_forward(cfg, p_rec, x)
        x = inner(common.apply_norm(cfg, hc, p_mlp_ln))
        return carry(hc + mlp_forward(cfg, p_mlp, x))

    def attn_block(hc, p_attn, p_ln, p_mlp, p_mlp_ln):
        x = inner(common.apply_norm(cfg, hc, p_ln))
        q, k, v = attn.project_qkv(cfg, p_attn, x)
        q = common.rope(q, positions, cfg.rope_theta)
        k = common.rope(k, positions, cfg.rope_theta)
        o = attn.chunked_attention(q, k, v, causal=True,
                                   window=cfg.window_size,
                                   chunk=cfg.attn_chunk,
                                   repeat_kv=cfg.repeat_kv)
        hc = hc + attn.out_proj(p_attn, o)
        x = inner(common.apply_norm(cfg, hc, p_mlp_ln))
        return carry(hc + mlp_forward(cfg, p_mlp, x))

    take = lambda tree, sl: jax.tree.map(lambda a: a[sl], tree)
    group_slice = slice(0, n_group_rec)
    reshape_g = lambda tree: jax.tree.map(
        lambda a: a.reshape((n_attn, per_group_rec) + a.shape[1:]),
        take(tree, group_slice))

    rec_g = {k: reshape_g(lp[k]) for k in ("rec", "rec_ln", "rec_mlp",
                                           "rec_mlp_ln")}
    attn_g = {k: lp[k] for k in ("attn", "attn_ln", "attn_mlp",
                                 "attn_mlp_ln")}

    def group(hc, xs):
        rg, ag = xs
        for j in range(per_group_rec):
            hc = rec_block(hc, take(rg["rec"], j), take(rg["rec_ln"], j),
                           take(rg["rec_mlp"], j), take(rg["rec_mlp_ln"], j))
        hc = attn_block(hc, ag["attn"], ag["attn_ln"], ag["attn_mlp"],
                        ag["attn_mlp_ln"])
        return hc, None

    fn = jax.checkpoint(group) if remat else group
    h, _ = jax.lax.scan(fn, h, (rec_g, attn_g))

    if rem:   # trailing recurrent layers
        tail = lambda tree: take(lp[tree], slice(n_group_rec, None))

        def tail_fn(hc, xs):
            return rec_block(hc, xs[0], xs[1], xs[2], xs[3]), None

        fn_t = jax.checkpoint(tail_fn) if remat else tail_fn
        h, _ = jax.lax.scan(fn_t, h, (tail("rec"), tail("rec_ln"),
                                      tail("rec_mlp"), tail("rec_mlp_ln")))
    return h


# -------------------------------------------------------------- train loss ---
def loss_fn(cfg: ModelConfig, params: Pytree, batch: Dict[str, jax.Array],
            constrain=None) -> jax.Array:
    """Train loss; the vocab projection is fused chunk-by-chunk so (B,S,V)
    logits are never materialized (256k-vocab configs)."""
    h, aux = forward_hidden(cfg, params, batch["tokens"],
                            batch.get("patch_embeds"), constrain=constrain)
    h = common.apply_norm(cfg, h, params["final_norm"])
    if cfg.tie_embeddings:
        ce = common.chunked_cross_entropy(h, params["embed"],
                                          batch["labels"],
                                          transpose_head=True,
                                          chunk=cfg.ce_chunk)
    else:
        ce = common.chunked_cross_entropy(h, params["lm_head"],
                                          batch["labels"],
                                          chunk=cfg.ce_chunk)
    return ce + MOE_AUX_WEIGHT * aux


# ------------------------------------------------------------------ caches ---
def _pattern_counts(cfg: ModelConfig):
    """(n_global, n_local) for local:global patterned stacks."""
    windows, _ = layer_schedule(cfg)
    n_local = int((windows > 0).sum())
    return cfg.num_layers - n_local, n_local


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> Pytree:
    dtype = dtype or cfg.compute_dtype
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.windowed_decode_cache and cfg.window_size and \
            cfg.family in ("dense", "moe"):
        n_g, n_l = _pattern_counts(cfg)
        win = min(cfg.window_size, max_seq)
        return {
            "kg": jnp.zeros((max(n_g, 1), batch, max_seq, kv, hd), dtype),
            "vg": jnp.zeros((max(n_g, 1), batch, max_seq, kv, hd), dtype),
            "kl": jnp.zeros((max(n_l, 1), batch, win, kv, hd), dtype),
            "vl": jnp.zeros((max(n_l, 1), batch, win, kv, hd), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "ssm":
        per = ssd.ssd_init_state(cfg, batch, dtype)
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape),
            per), "pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.attn_every
        n_rec = cfg.num_layers - n_attn
        rec = rglru.rglru_init_state(cfg, batch, dtype)
        win = cfg.window_size or max_seq
        return {
            "rec": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_rec,) + a.shape), rec),
            "k": jnp.zeros((n_attn, batch, min(win, max_seq), kv, hd), dtype),
            "v": jnp.zeros((n_attn, batch, min(win, max_seq), kv, hd), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
    return {
        "k": jnp.zeros((cfg.num_layers, batch, max_seq, kv, hd), dtype),
        "v": jnp.zeros((cfg.num_layers, batch, max_seq, kv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ------------------------------------------------------------------ prefill --
def prefill(cfg: ModelConfig, params: Pytree, tokens: jax.Array,
            cache: Pytree, extra_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Pytree]:
    """Process the prompt, fill the cache, return last-position logits."""
    h = embed_tokens(cfg, params, tokens, extra_embeds)
    s = h.shape[1]
    positions = jnp.arange(s)

    if "kg" in cache:   # windowed-cache layout (local:global pattern)
        return _windowed_prefill(cfg, params, h, positions, cache)

    if cfg.family == "ssm":
        # Run the chunked form for outputs, then recompute the final state
        # per layer via a scan (state = suffix of recurrence).
        def body(hc, xs):
            lp, st = xs
            x = common.apply_norm(cfg, hc, lp["ln1"])
            y = ssd.ssd_forward(cfg, lp["mix"], x)
            # final state: step through the last ssm tokens sequentially is
            # O(S); instead reuse decode on the last conv window + full scan
            # is unnecessary for the dry-run/serving path: we recompute the
            # state with a lightweight scan over chunks (already computed
            # inside ssd_forward); for simplicity re-run a recurrent pass.
            new_st = _ssd_final_state(cfg, lp["mix"], x, st)
            return hc + y, new_st

        h, new_states = jax.lax.scan(body, h,
                                     (params["layers"], cache["layers"]))
        cache = {"layers": new_states, "pos": jnp.asarray(s, jnp.int32)}
        return lm_logits(cfg, params, h[:, -1:]), cache

    if cfg.family == "hybrid":
        return _hybrid_prefill(cfg, params, h, positions, cache)

    windows, thetas = layer_schedule(cfg)

    def body(carry, xs):
        hc, k_all, v_all, idx = carry
        lp, w, th = xs
        x = common.apply_norm(cfg, hc, lp["ln1"])
        q, k, v = attn.project_qkv(cfg, lp["attn"], x)
        if cfg.pos_embed == "rope":
            q = common.rope(q, positions, th)
            k = common.rope(k, positions, th)
        zero = jnp.zeros((), jnp.int32)
        pad = k_all.shape[2] - k.shape[1]
        k_w = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_w = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_all = jax.lax.dynamic_update_slice(
            k_all, k_w[None].astype(k_all.dtype),
            (idx, zero, zero, zero, zero))
        v_all = jax.lax.dynamic_update_slice(
            v_all, v_w[None].astype(v_all.dtype),
            (idx, zero, zero, zero, zero))
        o = attn.chunked_attention(q, k, v, causal=True, window=w,
                                   softcap=cfg.logit_softcap,
                                   chunk=cfg.attn_chunk,
                                   repeat_kv=cfg.repeat_kv)
        hc = hc + attn.out_proj(lp["attn"], o)
        x = common.apply_norm(cfg, hc, lp["ln2"])
        if cfg.family == "moe":
            y, _ = moe.moe_ffn(cfg, lp["ffn"], x)
        else:
            y = mlp_forward(cfg, lp["ffn"], x)
        return (hc + y, k_all, v_all, idx + 1), None

    (h, k_new, v_new, _), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"], jnp.zeros((), jnp.int32)),
        (params["layers"], jnp.asarray(windows), jnp.asarray(thetas)))
    cache = {"k": k_new, "v": v_new, "pos": jnp.asarray(s, jnp.int32)}
    return lm_logits(cfg, params, h[:, -1:]), cache


def _ssd_final_state(cfg, p, x_in, st):
    """Recompute the post-prefill SSD recurrent state (conv tail + ssm)."""
    din, n = cfg.ssm_inner, cfg.ssm_state
    proj = x_in @ p["w_in"]
    z, xr, b_mat, c_mat, dt = ssd._split_proj(cfg, proj)
    conv_in = jnp.concatenate([xr, b_mat, c_mat], axis=-1)
    new_conv = conv_in[:, -(cfg.ssm_conv - 1):, :].astype(st["conv"].dtype)
    conv_out = jax.nn.silu(ssd._causal_conv(conv_in, p["conv_w"],
                                            p["conv_b"]))
    xr = conv_out[..., :din].reshape(x_in.shape[0], x_in.shape[1],
                                     cfg.ssm_heads, cfg.ssm_head_dim)
    b_mat = conv_out[..., din:din + n]
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = dtv * a

    def step(hprev, inp):
        x_t, b_t, dt_t, da_t = inp
        decay = jnp.exp(da_t)
        upd = (dt_t[..., None] * b_t[:, None, :])[:, :, None, :] * \
            x_t[..., None]
        return hprev * decay[..., None, None].astype(hprev.dtype) + \
            upd.astype(hprev.dtype), None

    hs, _ = jax.lax.scan(step, st["ssm"],
                         (xr.transpose(1, 0, 2, 3),
                          b_mat.transpose(1, 0, 2),
                          dtv.transpose(1, 0, 2), da.transpose(1, 0, 2)))
    return {"ssm": hs, "conv": new_conv}


def _hybrid_prefill(cfg, params, h, positions, cache):
    lp = params["layers"]
    n = cfg.num_layers
    n_attn = n // cfg.attn_every
    s = h.shape[1]
    win = cache["k"].shape[2]
    take = lambda tree, i: jax.tree.map(lambda a: a[i], tree)

    rec_states, k_caches, v_caches = [], [], []
    ri, ai = 0, 0
    for i in range(n):
        is_attn = (i + 1) % cfg.attn_every == 0 and ai < n_attn
        if is_attn:
            x = common.apply_norm(cfg, h, take(lp["attn_ln"], ai))
            pa = take(lp["attn"], ai)
            q, k, v = attn.project_qkv(cfg, pa, x)
            q = common.rope(q, positions, cfg.rope_theta)
            k = common.rope(k, positions, cfg.rope_theta)
            o = attn.chunked_attention(q, k, v, causal=True,
                                       window=cfg.window_size,
                                       chunk=cfg.attn_chunk,
                                       repeat_kv=cfg.repeat_kv)
            h = h + attn.out_proj(pa, o)
            x = common.apply_norm(cfg, h, take(lp["attn_mlp_ln"], ai))
            h = h + mlp_forward(cfg, take(lp["attn_mlp"], ai), x)
            tail_k = k[:, -win:].astype(cache["k"].dtype)
            tail_v = v[:, -win:].astype(cache["v"].dtype)
            pad = win - tail_k.shape[1]
            if pad > 0:
                tail_k = jnp.pad(tail_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                tail_v = jnp.pad(tail_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            else:
                # Ring-buffer layout: token t lives at slot t % win, so that
                # decode's write at pos % win evicts exactly the oldest token.
                tail_k = jnp.roll(tail_k, s % win, axis=1)
                tail_v = jnp.roll(tail_v, s % win, axis=1)
            k_caches.append(tail_k)
            v_caches.append(tail_v)
            ai += 1
        else:
            x = common.apply_norm(cfg, h, take(lp["rec_ln"], ri))
            pr = take(lp["rec"], ri)
            # full recurrence for outputs + final state
            gate_branch = jax.nn.gelu(x @ pr["w_gate"])
            u = rglru._causal_conv(x @ pr["w_x"], pr["conv_w"], pr["conv_b"])
            a_g, b_g = rglru._gates(pr, u)

            def combine(l, r):
                return l[0] * r[0], r[0] * l[1] + r[1]

            _, hseq = jax.lax.associative_scan(
                combine, (a_g, b_g.astype(jnp.float32)), axis=1)
            h = h + (hseq.astype(h.dtype) * gate_branch) @ pr["w_out"]
            x2 = common.apply_norm(cfg, h, take(lp["rec_mlp_ln"], ri))
            h = h + mlp_forward(cfg, take(lp["rec_mlp"], ri), x2)
            conv_tail = (x @ pr["w_x"])[:, -3:, :]
            rec_states.append({"h": hseq[:, -1].astype(jnp.float32),
                               "conv": conv_tail.astype(cache["rec"]["conv"].dtype)})
            ri += 1

    cache = {
        "rec": jax.tree.map(lambda *xs: jnp.stack(xs), *rec_states),
        "k": jnp.stack(k_caches), "v": jnp.stack(v_caches),
        "pos": jnp.asarray(s, jnp.int32),
    }
    return lm_logits(cfg, params, h[:, -1:]), cache


# --------------------------------------------------------------- decode ------
def decode_step(cfg: ModelConfig, params: Pytree, cache: Pytree,
                token: jax.Array) -> Tuple[jax.Array, Pytree]:
    """One decode step for the whole batch.  token (B,) -> logits (B, V)."""
    pos = cache["pos"]
    h = jnp.take(params["embed"], token[:, None],
                 axis=0).astype(cfg.compute_dtype)      # (B, 1, d)

    if "kg" in cache:   # windowed-cache layout (local:global pattern)
        return _windowed_decode(cfg, params, cache, h)

    if cfg.family == "ssm":
        def body(hc, xs):
            lp, st = xs
            x = common.apply_norm(cfg, hc, lp["ln1"])
            st2, y = ssd.ssd_decode_step(cfg, lp["mix"], st, x[:, 0])
            return hc + y[:, None], st2

        h, new_states = jax.lax.scan(body, h,
                                     (params["layers"], cache["layers"]))
        new_cache = {"layers": new_states, "pos": pos + 1}
        return lm_logits(cfg, params, h)[:, 0], new_cache

    if cfg.family == "hybrid":
        return _hybrid_decode(cfg, params, cache, h)

    windows, thetas = layer_schedule(cfg)
    positions = pos[None]                          # shape (1,)

    # The cache rides in the scan CARRY and is updated in place with a
    # layer-indexed dynamic_update_slice: carry-in/carry-out buffers alias in
    # the compiled while loop, so one cache copy lives in HBM (the scan
    # xs->ys formulation keeps two).
    def body(carry, xs):
        hc, k_all, v_all, idx = carry
        lp, w, th = xs
        x = common.apply_norm(cfg, hc, lp["ln1"])
        q, k, v = attn.project_qkv(cfg, lp["attn"], x)
        if cfg.pos_embed == "rope":
            q = common.rope(q, positions, th)
            k = common.rope(k, positions, th)
        zero = jnp.zeros((), jnp.int32)
        k_all = jax.lax.dynamic_update_slice(
            k_all, k[None].astype(k_all.dtype), (idx, zero, pos, zero, zero))
        v_all = jax.lax.dynamic_update_slice(
            v_all, v[None].astype(v_all.dtype), (idx, zero, pos, zero, zero))
        kc = jax.lax.dynamic_index_in_dim(k_all, idx, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, idx, 0, keepdims=False)
        o = attn.decode_attention(q, kc, vc, pos, window=w,
                                  softcap=cfg.logit_softcap)
        hc = hc + attn.out_proj(lp["attn"], o)
        x = common.apply_norm(cfg, hc, lp["ln2"])
        if cfg.family == "moe":
            y, _ = moe.moe_ffn(cfg, lp["ffn"], x)
        else:
            y = mlp_forward(cfg, lp["ffn"], x)
        return (hc + y, k_all, v_all, idx + 1), None

    (h, k_new, v_new, _), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"], jnp.zeros((), jnp.int32)),
        (params["layers"], jnp.asarray(windows), jnp.asarray(thetas)))
    new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}
    return lm_logits(cfg, params, h)[:, 0], new_cache


def _hybrid_decode(cfg, params, cache, h):
    lp = params["layers"]
    pos = cache["pos"]
    n = cfg.num_layers
    n_attn = n // cfg.attn_every
    win = cache["k"].shape[2]
    slot = pos % win                               # ring-buffer local cache
    take = lambda tree, i: jax.tree.map(lambda a: a[i], tree)

    new_rec, new_k, new_v = [], [], []
    ri, ai = 0, 0
    for i in range(n):
        is_attn = (i + 1) % cfg.attn_every == 0 and ai < n_attn
        if is_attn:
            x = common.apply_norm(cfg, h, take(lp["attn_ln"], ai))
            pa = take(lp["attn"], ai)
            q, k, v = attn.project_qkv(cfg, pa, x)
            q = common.rope(q, pos[None], cfg.rope_theta)
            k = common.rope(k, pos[None], cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["k"][ai], k.astype(cache["k"].dtype), slot, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["v"][ai], v.astype(cache["v"].dtype), slot, axis=1)
            valid = jnp.minimum(pos + 1, win)
            o = _ring_decode_attn(q, kc, vc, valid)
            h = h + attn.out_proj(pa, o)
            x = common.apply_norm(cfg, h, take(lp["attn_mlp_ln"], ai))
            h = h + mlp_forward(cfg, take(lp["attn_mlp"], ai), x)
            new_k.append(kc)
            new_v.append(vc)
            ai += 1
        else:
            x = common.apply_norm(cfg, h, take(lp["rec_ln"], ri))
            st, y = rglru.rglru_decode_step(
                cfg, take(lp["rec"], ri), take(cache["rec"], ri), x[:, 0])
            h = h + y[:, None]
            x2 = common.apply_norm(cfg, h, take(lp["rec_mlp_ln"], ri))
            h = h + mlp_forward(cfg, take(lp["rec_mlp"], ri), x2)
            new_rec.append(st)
            ri += 1

    new_cache = {
        "rec": jax.tree.map(lambda *xs: jnp.stack(xs), *new_rec),
        "k": jnp.stack(new_k), "v": jnp.stack(new_v), "pos": pos + 1,
    }
    return lm_logits(cfg, params, h)[:, 0], new_cache


# ----------------------------------------------- windowed (5:1) cache paths --
def _windowed_prefill(cfg: ModelConfig, params: Pytree, h: jax.Array,
                      positions: jax.Array, cache: Pytree):
    """Prefill with split caches: global layers keep the full context,
    local layers keep only the last `window` tokens in ring layout."""
    windows, thetas = layer_schedule(cfg)
    s = h.shape[1]
    win = cache["kl"].shape[2]
    is_local = jnp.asarray(windows > 0)
    # per-layer slot within its own stack
    l_idx = jnp.cumsum(is_local.astype(jnp.int32)) - is_local.astype(jnp.int32)
    g_idx = jnp.cumsum((~is_local).astype(jnp.int32)) - \
        (~is_local).astype(jnp.int32)

    def body(carry, xs):
        hc, kg, vg, kl, vl = carry
        lp, w, th, loc, li, gi = xs
        x = common.apply_norm(cfg, hc, lp["ln1"])
        q, k, v = attn.project_qkv(cfg, lp["attn"], x)
        if cfg.pos_embed == "rope":
            q = common.rope(q, positions, th)
            k = common.rope(k, positions, th)
        zero = jnp.zeros((), jnp.int32)

        def write_local(ops):
            kg_, vg_, kl_, vl_ = ops
            tail_k = k[:, -win:].astype(kl_.dtype)
            tail_v = v[:, -win:].astype(vl_.dtype)
            pad = win - tail_k.shape[1]
            if pad > 0:
                tail_k = jnp.pad(tail_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                tail_v = jnp.pad(tail_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            else:
                tail_k = jnp.roll(tail_k, s % win, axis=1)
                tail_v = jnp.roll(tail_v, s % win, axis=1)
            kl_ = jax.lax.dynamic_update_slice(
                kl_, tail_k[None], (li, zero, zero, zero, zero))
            vl_ = jax.lax.dynamic_update_slice(
                vl_, tail_v[None], (li, zero, zero, zero, zero))
            return kg_, vg_, kl_, vl_

        def write_global(ops):
            kg_, vg_, kl_, vl_ = ops
            pad = kg_.shape[2] - k.shape[1]
            k_w = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_w = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kg_ = jax.lax.dynamic_update_slice(
                kg_, k_w[None].astype(kg_.dtype),
                (gi, zero, zero, zero, zero))
            vg_ = jax.lax.dynamic_update_slice(
                vg_, v_w[None].astype(vg_.dtype),
                (gi, zero, zero, zero, zero))
            return kg_, vg_, kl_, vl_

        kg, vg, kl, vl = jax.lax.cond(loc, write_local, write_global,
                                      (kg, vg, kl, vl))
        o = attn.chunked_attention(q, k, v, causal=True, window=w,
                                   softcap=cfg.logit_softcap,
                                   chunk=cfg.attn_chunk,
                                   repeat_kv=cfg.repeat_kv)
        hc = hc + attn.out_proj(lp["attn"], o)
        x = common.apply_norm(cfg, hc, lp["ln2"])
        if cfg.family == "moe":
            y, _ = moe.moe_ffn(cfg, lp["ffn"], x)
        else:
            y = mlp_forward(cfg, lp["ffn"], x)
        return (hc + y, kg, vg, kl, vl), None

    (h, kg, vg, kl, vl), _ = jax.lax.scan(
        body, (h, cache["kg"], cache["vg"], cache["kl"], cache["vl"]),
        (params["layers"], jnp.asarray(windows), jnp.asarray(thetas),
         is_local, l_idx, g_idx))
    new_cache = {"kg": kg, "vg": vg, "kl": kl, "vl": vl,
                 "pos": jnp.asarray(s, jnp.int32)}
    return lm_logits(cfg, params, h[:, -1:]), new_cache


def _windowed_decode(cfg: ModelConfig, params: Pytree, cache: Pytree,
                     h: jax.Array):
    windows, thetas = layer_schedule(cfg)
    pos = cache["pos"]
    win = cache["kl"].shape[2]
    slot = pos % win
    is_local = jnp.asarray(windows > 0)
    l_idx = jnp.cumsum(is_local.astype(jnp.int32)) - is_local.astype(jnp.int32)
    g_idx = jnp.cumsum((~is_local).astype(jnp.int32)) - \
        (~is_local).astype(jnp.int32)
    positions = pos[None]

    def body(carry, xs):
        hc, kg, vg, kl, vl = carry
        lp, w, th, loc, li, gi = xs
        x = common.apply_norm(cfg, hc, lp["ln1"])
        q, k, v = attn.project_qkv(cfg, lp["attn"], x)
        if cfg.pos_embed == "rope":
            q = common.rope(q, positions, th)
            k = common.rope(k, positions, th)
        zero = jnp.zeros((), jnp.int32)

        def local_branch(ops):
            hc_, kg_, vg_, kl_, vl_ = ops
            kl_ = jax.lax.dynamic_update_slice(
                kl_, k[None].astype(kl_.dtype), (li, zero, slot, zero, zero))
            vl_ = jax.lax.dynamic_update_slice(
                vl_, v[None].astype(vl_.dtype), (li, zero, slot, zero, zero))
            kc = jax.lax.dynamic_index_in_dim(kl_, li, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vl_, li, 0, keepdims=False)
            valid = jnp.minimum(pos + 1, win)
            o = _ring_decode_attn(q, kc, vc, valid,
                                  softcap=cfg.logit_softcap)
            return (hc_ + attn.out_proj(lp["attn"], o), kg_, vg_, kl_, vl_)

        def global_branch(ops):
            hc_, kg_, vg_, kl_, vl_ = ops
            kg_ = jax.lax.dynamic_update_slice(
                kg_, k[None].astype(kg_.dtype), (gi, zero, pos, zero, zero))
            vg_ = jax.lax.dynamic_update_slice(
                vg_, v[None].astype(vg_.dtype), (gi, zero, pos, zero, zero))
            kc = jax.lax.dynamic_index_in_dim(kg_, gi, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vg_, gi, 0, keepdims=False)
            o = attn.decode_attention(q, kc, vc, pos, window=None,
                                      softcap=cfg.logit_softcap)
            return (hc_ + attn.out_proj(lp["attn"], o), kg_, vg_, kl_, vl_)

        hc, kg, vg, kl, vl = jax.lax.cond(loc, local_branch, global_branch,
                                          (hc, kg, vg, kl, vl))
        x = common.apply_norm(cfg, hc, lp["ln2"])
        if cfg.family == "moe":
            y, _ = moe.moe_ffn(cfg, lp["ffn"], x)
        else:
            y = mlp_forward(cfg, lp["ffn"], x)
        return (hc + y, kg, vg, kl, vl), None

    (h, kg, vg, kl, vl), _ = jax.lax.scan(
        body, (h, cache["kg"], cache["vg"], cache["kl"], cache["vl"]),
        (params["layers"], jnp.asarray(windows), jnp.asarray(thetas),
         is_local, l_idx, g_idx))
    new_cache = {"kg": kg, "vg": vg, "kl": kl, "vl": vl, "pos": pos + 1}
    return lm_logits(cfg, params, h)[:, 0], new_cache


def _ring_decode_attn(q, kc, vc, valid_len, softcap: float = 0.0):
    """Decode attention over a ring-buffer window cache (positions are
    unordered in the buffer; all valid slots attend — window semantics are
    enforced by eviction)."""
    import math as _m
    b, _, hh, hd = q.shape
    kv = kc.shape[2]
    g = hh // kv
    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, kc).astype(jnp.float32)
    scores = common.softcap(scores / _m.sqrt(hd), softcap)
    mask = jnp.arange(kc.shape[1]) < valid_len
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    prob = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", prob.astype(vc.dtype), vc)
    return out.reshape(b, 1, hh, hd)
