"""Assigned-architecture model zoo."""
from repro.models.common import ModelConfig, Spec
from repro.models.registry import (ModelBundle, ShapeSpec, SHAPES,
                                   get_bundle, get_config, list_archs)
