"""Mixture-of-Experts layer (Qwen3-MoE style: top-k softmax routing over 128
experts, SwiGLU experts, renormalized gates).

Baseline dispatch is the GShard/Switch dense one-hot formulation, grouped so
the (tokens, experts, capacity) dispatch tensor stays VMEM-friendly:
tokens are processed in groups (scan), each group builds a one-hot dispatch
einsum — all-to-all-free, lowers to plain matmuls + the mesh's existing
collectives, and shards cleanly with experts on the "model" axis.  A ragged
all-to-all dispatch is a recorded perf alternative (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Spec


def moe_specs(cfg: ModelConfig, stacked: int = 0) -> Dict[str, Spec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    return {
        "router": Spec(lead + (d, e), lax_ + ("embed", "experts"),
                       fan_in_dims=(len(lead),)),
        # per-expert ffn dim uses the distinct "expert_ffn" logical axis:
        # sharded over "data", and the expert einsums keep it sharded
        # end-to-end (2-D expert x ffn parallelism, no hoisted gathers).
        "w_gate": Spec(lead + (e, d, f),
                       lax_ + ("experts", "embed", "expert_ffn"),
                       fan_in_dims=(len(lead) + 1,)),
        "w_up": Spec(lead + (e, d, f),
                     lax_ + ("experts", "embed", "expert_ffn"),
                     fan_in_dims=(len(lead) + 1,)),
        "w_down": Spec(lead + (e, f, d),
                       lax_ + ("experts", "expert_ffn", "embed"),
                       fan_in_dims=(len(lead) + 1,)),
    }


def _capacity(group: int, cfg: ModelConfig) -> int:
    cap = int(group * cfg.experts_per_token * cfg.moe_capacity_factor /
              cfg.num_experts)
    return max(cap, cfg.experts_per_token)


def moe_ffn(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array,
            group_size: int = 0) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    Sequence-grouped one-hot dispatch: groups are *sequence* chunks per batch
    row (the batch dim survives intact, so data-parallel sharding propagates
    through the dispatch einsums), processed with lax.scan so the dispatch
    tensors are temporaries of one group.  Experts shard over "model", the
    per-expert ffn dim over "data" (see moe_specs).
    """
    b, s, d = x.shape
    g_sz = min(group_size or cfg.moe_group_size, s)
    n_groups = -(-s // g_sz)
    pad = n_groups * g_sz - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    xg = x.reshape(b, n_groups, g_sz, d).transpose(1, 0, 2, 3)
    cap = _capacity(g_sz, cfg)
    k = cfg.experts_per_token
    e = cfg.num_experts
    router = p["router"]

    def group_fn(carry, xi):                                  # xi (B, g, d)
        # --- routing --------------------------------------------------------
        logits = jnp.einsum("bgd,de->bge", xi, router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)       # (B, g, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)           # renormalize
        # --- capacity-bounded position within each expert (per batch row) ---
        onehot_i = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)  # (B,g,k,E)
        flat = onehot_i.reshape(-1, g_sz * k, e)
        pos_in_expert = jnp.cumsum(flat, axis=1) - flat
        pos = (pos_in_expert * flat).sum(-1).reshape(-1, g_sz, k)
        keep = pos < cap
        # --- dispatch tensor (B, g, k, E, C) collapsed over k ----------------
        disp = (jax.nn.one_hot(expert_idx, e, dtype=xi.dtype)[..., None] *
                jax.nn.one_hot(pos, cap, dtype=xi.dtype)[..., None, :])
        disp = disp * keep[..., None, None].astype(xi.dtype)
        comb = disp * gate_vals[..., None, None].astype(xi.dtype)
        disp_t = disp.sum(2)                                  # (B, g, E, C)
        # --- expert compute ---------------------------------------------------
        xe = jnp.einsum("bgec,bgd->becd", disp_t, xi)         # (B, E, C, d)
        # Expert-parallel reshard (the all-to-all of GShard-style MoE): the
        # dispatched tokens go from batch-sharded to expert-sharded so the
        # expert matmuls run with E on "model" and the ffn dim on "data"
        # without conflicting with the batch axis.
        from repro.models.common import maybe_constrain
        xe = maybe_constrain(xe, None, "model")
        hidden = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
        hidden = jax.nn.silu(hidden) * jnp.einsum("becd,edf->becf", xe,
                                                  p["w_up"])
        ye = jnp.einsum("becf,efd->becd", hidden, p["w_down"])
        yi = jnp.einsum("bgkec,becd->bgd", comb, ye)
        # --- load-balance auxiliary loss (Switch style) -----------------------
        density = onehot_i.sum(2).astype(jnp.float32).mean((0, 1))   # (E,)
        aux = e * jnp.mean(probs.mean((0, 1)) * density) * k
        return carry + aux, yi

    # Remat each group: backward re-runs routing+dispatch per group instead
    # of keeping every group's (B, E, C, d) dispatch tensors alive.
    aux_total, yg = jax.lax.scan(jax.checkpoint(group_fn),
                                 jnp.zeros((), jnp.float32), xg)
    y = yg.transpose(1, 0, 2, 3).reshape(b, n_groups * g_sz, d)[:, :s]
    return y, aux_total / n_groups
