"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Gated linear recurrence:
    r_t = sigmoid(W_r u_t + b_r)           (recurrence gate)
    i_t = sigmoid(W_i u_t + b_i)           (input gate)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill evaluates the recurrence with an associative scan (log-depth
on TPU); decode is the O(1) step.  The block wraps the recurrence with the
Griffin structure: conv1d(4) front, GeLU gate branch, output projection.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Spec

_C = 8.0


def rglru_specs(cfg: ModelConfig, stacked: int = 0) -> Dict[str, Spec]:
    d, r = cfg.d_model, cfg.rnn_width
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    return {
        "w_x": Spec(lead + (d, r), lax_ + ("embed", "rnn"),
                    fan_in_dims=(len(lead),)),
        "w_gate": Spec(lead + (d, r), lax_ + ("embed", "rnn"),
                       fan_in_dims=(len(lead),)),
        "conv_w": Spec(lead + (4, r), lax_ + ("conv", "rnn")),
        "conv_b": Spec(lead + (r,), lax_ + ("rnn",), init="zeros"),
        "w_r": Spec(lead + (r, r), lax_ + ("rnn", "rnn"),
                    fan_in_dims=(len(lead),)),
        "b_r": Spec(lead + (r,), lax_ + ("rnn",), init="zeros"),
        "w_i": Spec(lead + (r, r), lax_ + ("rnn", "rnn"),
                    fan_in_dims=(len(lead),)),
        "b_i": Spec(lead + (r,), lax_ + ("rnn",), init="zeros"),
        "lam": Spec(lead + (r,), lax_ + ("rnn",), init="ones"),
        "w_out": Spec(lead + (r, d), lax_ + ("rnn", "embed"),
                      fan_in_dims=(len(lead),)),
    }


def _gates(p, u):
    r_gate = jax.nn.sigmoid(u @ p["w_r"] + p["b_r"]).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(u @ p["w_i"] + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r_gate
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    return a, beta.astype(u.dtype) * (i_gate * u)


def _causal_conv(x, w, b):
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + b


def rglru_forward(cfg: ModelConfig, p: Dict[str, jax.Array],
                  x_in: jax.Array) -> jax.Array:
    """Full-sequence Griffin recurrent block.  (B, S, d) -> (B, S, d)."""
    gate_branch = jax.nn.gelu(x_in @ p["w_gate"])
    u = _causal_conv(x_in @ p["w_x"], p["conv_w"], p["conv_b"])
    a, b = _gates(p, u)                       # (B,S,R) each

    # associative scan over time: (a2, b2) o (a1, b1) = (a1*a2, a2*b1 + b2)
    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    _, h = jax.lax.associative_scan(
        combine, (a, b.astype(jnp.float32)), axis=1)
    y = (h.astype(x_in.dtype) * gate_branch) @ p["w_out"]
    return y


def rglru_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    r = cfg.rnn_width
    return {
        "h": jnp.zeros((batch, r), jnp.float32),
        "conv": jnp.zeros((batch, 3, r), dtype),
    }


def rglru_decode_step(cfg: ModelConfig, p: Dict[str, jax.Array],
                      state: Dict[str, jax.Array], x_tok: jax.Array
                      ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """One-token update.  x_tok (B, d) -> (state, y (B, d))."""
    gate_branch = jax.nn.gelu(x_tok @ p["w_gate"])
    u_raw = x_tok @ p["w_x"]                              # (B, R)
    hist = jnp.concatenate([state["conv"], u_raw[:, None, :]], axis=1)
    u = (hist * p["conv_w"]).sum(axis=1) + p["conv_b"]
    a, b = _gates(p, u)
    h = a * state["h"] + b.astype(jnp.float32)
    y = (h.astype(x_tok.dtype) * gate_branch) @ p["w_out"]
    return {"h": h, "conv": hist[:, 1:]}, y
