"""Serverless cost model: Lambda GB-seconds + invocations + S3 ops.

The paper's headline claim is a *dollar* claim as much as a wall-clock one
(Sec. 5: ~3000 Lambda workers at 3 GB each vs a fixed EC2 cluster), so every
simulated phase is billed, not just timed.  Constants default to the public
AWS price points the paper's experiments ran under (us-west-2, 2019-era
prices; the *ratios* are what matter for scheme-vs-scheme comparisons):

  - Lambda compute: $1.66667e-5 per GB-second, billed for each attempt's
    full duration — a straggler that loses the k-of-n race still runs (and
    bills) to completion, which is exactly why k-of-n saves time but not
    compute dollars, while `speculative`/`hedged` relaunches bill extra
    attempts on top.
  - Lambda invocations: $2e-7 per request (every attempt, retries and
    hedges included).
  - S3: $5e-6 per PUT, $4e-7 per GET.  Workers communicate through S3
    (paper Sec. 2): each attempt GETs its inputs and each *successful*
    attempt PUTs its output; per-phase `comm_units` add master-side traffic
    on the same meters.
  - Provisioned concurrency: $4.1667e-6 per GB-second while a prewarmed
    container sits idle (the real Lambda provisioned-concurrency price,
    ~25% of the execution rate).  The ``WarmPool``'s pinned-warm reserve
    bills this whether or not any job ever lands on it — the tenancy
    scheduler accrues ``provisioned_gb_seconds`` as the integral of the
    provisioned target over simulated time, times ``memory_gb``.

``CostModel`` is the frozen price sheet; ``CostLedger`` is the mutable
accumulator a ``FleetEngine`` carries across phases.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Price sheet for one simulated fleet (immutable, hashable)."""

    memory_gb: float = 3.0              # paper: 3 GB Lambda workers
    # per_attempt: each invocation bills its own duration (Lambda).
    # reserved: the whole fleet bills wall-clock per phase, idle included
    # (a fixed EC2/MPI cluster — stragglers hold every node hostage).
    billing: str = "per_attempt"
    usd_per_gb_second: float = 1.66667e-5
    usd_per_invocation: float = 2e-7
    usd_per_s3_put: float = 5e-6
    usd_per_s3_get: float = 4e-7
    # Per-attempt S3 traffic: inputs read at launch, output written on
    # success (stragglers that are cancelled before writing still read).
    gets_per_attempt: float = 2.0
    puts_per_success: float = 1.0
    # One master-side comm unit (the SimClock ``comm_units`` axis) in ops.
    gets_per_comm_unit: float = 1.0
    puts_per_comm_unit: float = 1.0
    # Idle prewarmed (provisioned-concurrency) rate: billed per GB-second
    # the pinned-warm reserve exists, independent of invocations.
    usd_per_provisioned_gb_second: float = 4.1667e-6

    def dollars(self, gb_seconds: float, invocations: float,
                s3_puts: float, s3_gets: float,
                provisioned_gb_seconds: float = 0.0) -> float:
        return (gb_seconds * self.usd_per_gb_second
                + invocations * self.usd_per_invocation
                + s3_puts * self.usd_per_s3_put
                + s3_gets * self.usd_per_s3_get
                + provisioned_gb_seconds * self.usd_per_provisioned_gb_second)


@dataclasses.dataclass
class CostLedger:
    """Running totals across phases; ``dollars`` is derived, never drifts."""

    gb_seconds: float = 0.0
    invocations: float = 0.0
    s3_puts: float = 0.0
    s3_gets: float = 0.0
    provisioned_gb_seconds: float = 0.0

    def add(self, other: "CostLedger") -> None:
        self.gb_seconds += other.gb_seconds
        self.invocations += other.invocations
        self.s3_puts += other.s3_puts
        self.s3_gets += other.s3_gets
        self.provisioned_gb_seconds += other.provisioned_gb_seconds

    def dollars(self, model: CostModel) -> float:
        return model.dollars(self.gb_seconds, self.invocations,
                             self.s3_puts, self.s3_gets,
                             self.provisioned_gb_seconds)

    def as_dict(self) -> dict:
        d = {"gb_seconds": self.gb_seconds,
             "invocations": self.invocations,
             "s3_puts": self.s3_puts, "s3_gets": self.s3_gets}
        # Additive (trace schema v4): emitted only when nonzero so every
        # pre-tenancy fixture row stays byte-identical.
        if self.provisioned_gb_seconds:
            d["provisioned_gb_seconds"] = self.provisioned_gb_seconds
        return d


def bill_phase(cost: CostModel, attempts, successes: int,
               comm_units: float) -> CostLedger:
    """Ledger entry for one phase.

    ``attempts`` is an iterable of (launch_time, end_time) pairs — every
    Lambda invocation of the phase, including failed tries, policy
    relaunches, and losers of k-of-n races (they run to completion).  An
    attempt may instead be a (launch, end, mem_scale) triple: it billed at
    ``mem_scale`` times the phase's Lambda size (OOM-escalated retries
    from the fault plane run on bigger instances).
    """
    attempts = list(attempts)
    billed = 0.0      # unscaled GB-second base (same sum order as ever)
    scaled = 0.0      # memory-escalated attempts, pre-multiplied by scale
    for a in attempts:
        dur = max(0.0, a[1] - a[0])
        if len(a) > 2 and a[2] != 1.0:
            scaled += a[2] * dur
        else:
            billed += dur
    n_attempts = len(attempts)
    return CostLedger(
        gb_seconds=cost.memory_gb * billed + cost.memory_gb * scaled,
        invocations=float(n_attempts),
        s3_puts=(cost.puts_per_success * successes
                 + cost.puts_per_comm_unit * comm_units),
        s3_gets=(cost.gets_per_attempt * n_attempts
                 + cost.gets_per_comm_unit * comm_units),
    )
