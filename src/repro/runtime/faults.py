"""Deterministic fault injection for the fleet engine (the chaos plane).

The lifecycle model's only failure mode used to be an i.i.d. per-attempt
coin whose retry at ``max_retries`` always succeeded.  Real serverless
breaks in correlated, structured ways; this module declares those ways as
data so they compose onto ``FleetEngine.run_phase`` deterministically:

  - ``BurstSpec``   — an "AZ event": every attempt in flight during
    ``[t_start, t_end)`` (absolute simulated seconds) dies with probability
    ``kill_fraction``, all from one seeded stream — correlated, not i.i.d.
  - ``ThrottleSpec`` — a concurrency cap: a launch that would exceed
    ``max_concurrent`` simultaneous attempts is rejected and re-queued
    after exponential backoff with jitter.  Every rejected try is billed
    as an invocation (the provider charges for throttled requests' control
    traffic the same way the master pays to re-issue them).
  - ``S3Spec``      — transient storage errors: each attempt's input GET
    and output PUT independently fail with the given probabilities; each
    retry adds ``retry_delay`` (exponentially growing) to the attempt and
    bills an extra S3 op.
  - ``OomSpec``     — an attempt whose effective Lambda size is below the
    phase's declared working set (``run_phase(working_set_gb=...)``, from
    ``scheduler.sizing``) is OOM-killed at ``kill_at_fraction`` of its
    run; with ``escalate`` the retry doubles the memory (billed at the
    escalated size) until it fits or the budget exhausts.
  - ``PoolDeathSpec`` — warm-pool container death: at the first phase
    launching at or after ``t``, a seeded ``fraction`` of the pool's idle
    containers are culled (the provider reclaimed them), so later phases
    pay cold starts a healthy pool would have absorbed.
  - ``CorruptionSpec`` — silent data corruption: a completed worker's
    result is *wrong* with probability ``prob`` inside the window.  The
    engine only marks the corruption (``engine.last_corruption``); the
    coded-matvec layer turns parity-check violations into erasures and
    decodes around them (corruption -> erasure -> ``coded_decode``).

A ``FaultPlan`` bundles any subset plus a ``seed``.  All fault randomness
comes from a dedicated generator folded from the phase key and that seed,
so (a) identical plans give bit-identical ``(seconds, dollars)`` and
traces, and (b) a run with no plan draws exactly the random stream it drew
before this module existed — default recordings stay byte-identical.

Named scenarios mirror the policy and sketch-family registries: a scenario
is a factory registered under a string key, so "which failure mode" is a
config axis for benchmarks and tests (``get_scenario("az_burst")``).

``PhaseExhaustedError`` is the typed surface of a retry budget that truly
ran out (``FleetConfig.fail_open=False``): the engine bills everything,
records the partial phase, advances the clock to the last observed event,
and raises with the finite-survivor mask so the algorithm layer can
degrade (accept partial sketch blocks, re-dispatch, or fall back to a
gradient step) instead of silently diverging.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class BurstSpec:
    """Correlated burst ("AZ event"): in-flight attempts in the window die."""

    t_start: float = 0.0           # absolute simulated seconds
    t_end: float = math.inf
    kill_fraction: float = 0.5     # P[an exposed attempt dies]

    def __post_init__(self):
        if not 0.0 <= self.kill_fraction <= 1.0:
            raise ValueError(
                f"kill_fraction must be in [0, 1], got {self.kill_fraction}")
        if self.t_end < self.t_start:
            raise ValueError("burst window must have t_end >= t_start")


@dataclasses.dataclass(frozen=True)
class ThrottleSpec:
    """Concurrency cap with exponential backoff + jitter on rejection."""

    max_concurrent: int = 8
    backoff: float = 0.05          # first rejection's base wait
    backoff_mult: float = 2.0      # exponential growth per consecutive try
    jitter: float = 0.02           # U[0, jitter) added to every wait
    t_start: float = 0.0
    t_end: float = math.inf

    def __post_init__(self):
        if self.max_concurrent < 1:
            raise ValueError(
                f"max_concurrent must be >= 1, got {self.max_concurrent}")


@dataclasses.dataclass(frozen=True)
class S3Spec:
    """Transient storage errors on per-attempt GETs and PUTs."""

    get_fail_prob: float = 0.0
    put_fail_prob: float = 0.0
    retry_delay: float = 0.02      # first retry's delay; doubles per retry
    max_tries: int = 5             # retries per op (success forced after)
    t_start: float = 0.0
    t_end: float = math.inf

    def __post_init__(self):
        for p in (self.get_fail_prob, self.put_fail_prob):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"S3 failure probs must be in [0,1], got {p}")


@dataclasses.dataclass(frozen=True)
class OomSpec:
    """OOM kill when effective memory < the phase's declared working set."""

    kill_at_fraction: float = 0.9  # fraction of the run before the kill
    escalate: bool = True          # retry at doubled memory (billed)
    max_memory_gb: float = 10.0    # Lambda's memory ceiling


@dataclasses.dataclass(frozen=True)
class PoolDeathSpec:
    """Cull a seeded fraction of idle warm containers at time ``t``."""

    t: float = 0.0
    fraction: float = 0.5

    def __post_init__(self):
        if not 0.0 <= self.fraction <= 1.0:
            raise ValueError(
                f"pool-death fraction must be in [0, 1], got {self.fraction}")


@dataclasses.dataclass(frozen=True)
class CorruptionSpec:
    """Silent result corruption on completed workers inside the window."""

    prob: float = 0.05
    t_start: float = 0.0
    t_end: float = math.inf

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(
                f"corruption prob must be in [0, 1], got {self.prob}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Any subset of fault scenarios, plus the seed their draws fold in."""

    burst: Optional[BurstSpec] = None
    throttle: Optional[ThrottleSpec] = None
    s3: Optional[S3Spec] = None
    oom: Optional[OomSpec] = None
    pool_death: Optional[PoolDeathSpec] = None
    corruption: Optional[CorruptionSpec] = None
    seed: int = 0

    def active(self) -> bool:
        return any(s is not None for s in (
            self.burst, self.throttle, self.s3, self.oom, self.pool_death,
            self.corruption))

    def events(self) -> list:
        """Declared fault windows as typed, JSON-ready dicts.

        This is the incident engine's ground-truth evidence stream
        (``repro.obs.incident``): each dict names the cause the window
        would produce, its ``[t_start, t_end)`` extent in absolute
        simulated seconds (``t_end: None`` for an open window — OOM and
        pool death have effects that persist to the end of the run), and
        a human-readable knob summary.  Deterministic: pure function of
        the plan's specs, sorted by (t_start, cause).
        """
        out = []

        def win(cause: str, t0: float, t1: float, detail: str) -> None:
            out.append({"cause": cause, "t_start": float(t0),
                        "t_end": None if math.isinf(t1) else float(t1),
                        "detail": detail})

        if self.burst is not None:
            b = self.burst
            win("az_burst", b.t_start, b.t_end,
                f"kill_fraction={b.kill_fraction}")
        if self.throttle is not None:
            th = self.throttle
            win("throttle", th.t_start, th.t_end,
                f"max_concurrent={th.max_concurrent}")
        if self.s3 is not None:
            s = self.s3
            win("s3_transient", s.t_start, s.t_end,
                f"get_fail={s.get_fail_prob},put_fail={s.put_fail_prob}")
        if self.oom is not None:
            o = self.oom
            win("oom", 0.0, math.inf,
                f"kill_at={o.kill_at_fraction},escalate={o.escalate}")
        if self.pool_death is not None:
            p = self.pool_death
            win("pool_death", p.t, math.inf, f"fraction={p.fraction}")
        if self.corruption is not None:
            c = self.corruption
            win("corruption", c.t_start, c.t_end, f"prob={c.prob}")
        return sorted(out, key=lambda e: (e["t_start"], e["cause"]))


class PhaseExhaustedError(RuntimeError):
    """A phase's retry budget truly ran out (``fail_open=False``).

    Raised by ``FleetEngine.run_phase`` *after* billing every attempt,
    recording the partial phase row, and advancing the clock to the last
    observed lifecycle event — so a caller that catches it resumes on a
    consistent (seconds, dollars) timeline.  ``mask`` is the boolean
    finite-survivor mask (workers whose results did land)."""

    def __init__(self, phase: object, num_workers: int, mask: np.ndarray,
                 elapsed: float):
        self.phase = phase
        self.num_workers = int(num_workers)
        self.mask = np.asarray(mask, dtype=bool)
        self.elapsed = float(elapsed)
        lost = self.num_workers - int(self.mask.sum())
        super().__init__(
            f"phase {phase!r}: retry budget exhausted on {lost} of "
            f"{num_workers} workers")


# ----------------------------------------------------------------- registry
ScenarioFactory = Callable[..., FaultPlan]

_SCENARIOS: Dict[str, ScenarioFactory] = {}


def register_scenario(name: str) -> Callable[[ScenarioFactory],
                                             ScenarioFactory]:
    def deco(fn: ScenarioFactory) -> ScenarioFactory:
        if name in _SCENARIOS and _SCENARIOS[name] is not fn:
            raise ValueError(f"scenario {name!r} already registered")
        _SCENARIOS[name] = fn
        return fn
    return deco


def get_scenario(name: str, **knobs) -> FaultPlan:
    try:
        factory = _SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; available: {available_scenarios()}"
        ) from None
    return factory(**knobs)


def available_scenarios() -> list:
    return sorted(_SCENARIOS)


@register_scenario("az_burst")
def az_burst(t_start: float = 0.5, t_end: float = 2.0,
             kill_fraction: float = 0.6, seed: int = 0) -> FaultPlan:
    return FaultPlan(burst=BurstSpec(t_start=t_start, t_end=t_end,
                                     kill_fraction=kill_fraction), seed=seed)


@register_scenario("throttle")
def throttle(max_concurrent: int = 8, backoff: float = 0.05,
             backoff_mult: float = 2.0, jitter: float = 0.02,
             t_start: float = 0.0, t_end: float = math.inf,
             seed: int = 0) -> FaultPlan:
    return FaultPlan(throttle=ThrottleSpec(
        max_concurrent=max_concurrent, backoff=backoff,
        backoff_mult=backoff_mult, jitter=jitter, t_start=t_start,
        t_end=t_end), seed=seed)


@register_scenario("s3_transient")
def s3_transient(get_fail_prob: float = 0.3, put_fail_prob: float = 0.15,
                 retry_delay: float = 0.02, max_tries: int = 5,
                 seed: int = 0) -> FaultPlan:
    return FaultPlan(s3=S3Spec(get_fail_prob=get_fail_prob,
                               put_fail_prob=put_fail_prob,
                               retry_delay=retry_delay,
                               max_tries=max_tries), seed=seed)


@register_scenario("oom")
def oom(kill_at_fraction: float = 0.9, escalate: bool = True,
        max_memory_gb: float = 10.0, seed: int = 0) -> FaultPlan:
    return FaultPlan(oom=OomSpec(kill_at_fraction=kill_at_fraction,
                                 escalate=escalate,
                                 max_memory_gb=max_memory_gb), seed=seed)


@register_scenario("pool_death")
def pool_death(t: float = 1.0, fraction: float = 0.75,
               seed: int = 0) -> FaultPlan:
    return FaultPlan(pool_death=PoolDeathSpec(t=t, fraction=fraction),
                     seed=seed)


@register_scenario("corruption")
def corruption(prob: float = 0.1, t_start: float = 0.0,
               t_end: float = math.inf, seed: int = 0) -> FaultPlan:
    return FaultPlan(corruption=CorruptionSpec(prob=prob, t_start=t_start,
                                               t_end=t_end), seed=seed)
