"""Termination/recovery policy registry for the fleet engine.

A policy decides, given the per-worker completion times of one distributed
phase, (a) when the master stops waiting, (b) which workers' results it has
at that point, and (c) what extra attempts it launched along the way (for
billing).  Policies are plain functions registered under a string key —
mirroring ``repro.sketching.registry`` — so "how does this phase terminate"
is a config axis (``SimClock.phase(policy=...)``), not an if-chain:

  wait_all      wait for every worker (uncoded baseline);
  k_of_n        proceed when any k of n finish (coded / sketched semantics);
  speculative   watch ``watch_fraction`` finish, then relaunch the detected
                stragglers (paper Sec. 5.3) — relaunches bill extra attempts;
  hedged        duplicate every request still outstanding at the
                ``hedge_quantile`` arrival time (Dean & Barroso tail-at-scale
                hedging) — cheaper detection than speculative, more
                duplicates;
  coded_decode  stream results in arrival order and stop at the first
                decodable prefix (paper Alg. 1 step 8); the caller supplies
                the decodability predicate via ``ctx.decodable``.

All policies are deterministic functions of (times, ctx): any randomness
(relaunch durations) is drawn through ``ctx.sample_relaunch``, which threads
the phase's actual per-worker work — the historical ``SimClock`` bug of
relaunching stragglers with unit work cannot recur here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class PhaseContext:
    """Everything a policy may consult beyond the completion times."""

    k: Optional[int] = None                 # k_of_n / coded_decode floor
    watch_fraction: float = 0.9             # speculative watch deadline
    hedge_quantile: float = 0.8             # hedged duplicate launch point
    decodable: Optional[Callable[[np.ndarray], bool]] = None
    # Fresh relaunch durations with the phase's true work (cold starts
    # included per the fleet config); () -> (n,) float array.
    sample_relaunch: Optional[Callable[[], np.ndarray]] = None


@dataclasses.dataclass
class PhaseOutcome:
    elapsed: float                          # master wait, pre-comm
    mask: np.ndarray                        # which workers' results arrived
    extra_attempts: List[Tuple[float, float]]  # (launch, end) relaunches
    # How many extra attempts actually completed and wrote output (a
    # duplicate cancelled because the original won does not PUT).
    extra_successes: int = 0


Policy = Callable[[np.ndarray, PhaseContext], PhaseOutcome]

_POLICIES: Dict[str, Policy] = {}


def register_policy(name: str) -> Callable[[Policy], Policy]:
    def deco(fn: Policy) -> Policy:
        if name in _POLICIES and _POLICIES[name] is not fn:
            raise ValueError(f"policy {name!r} already registered")
        _POLICIES[name] = fn
        return fn
    return deco


def get_policy(name: str) -> Policy:
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; available: {available_policies()}"
        ) from None


def available_policies() -> list:
    return sorted(_POLICIES)


@register_policy("wait_all")
def wait_all(times: np.ndarray, ctx: PhaseContext) -> PhaseOutcome:
    return PhaseOutcome(float(times.max()),
                        np.ones(times.shape, dtype=bool), [])


@register_policy("k_of_n")
def k_of_n(times: np.ndarray, ctx: PhaseContext) -> PhaseOutcome:
    if ctx.k is None:
        raise ValueError("k_of_n policy needs k")
    deadline = float(np.sort(times)[ctx.k - 1])
    return PhaseOutcome(deadline, times <= deadline, [])


def _relaunch_outstanding(times: np.ndarray, deadline: float,
                          ctx: PhaseContext) -> PhaseOutcome:
    """Shared speculative/hedged core: duplicate every worker still
    outstanding at ``deadline``; each copy finishes at min(original,
    deadline + relaunch) — relaunch is inf if the duplicate died.  The
    losing copy is cancelled when the winner returns (billed until then,
    but only winners count as extra successes / PUT output)."""
    effective = times.copy()
    relaunch = ctx.sample_relaunch()
    extra = []
    wins = 0
    for w in np.where(times > deadline)[0]:
        finish = deadline + float(relaunch[w])
        effective[w] = min(float(times[w]), finish)
        extra.append((deadline, effective[w]))
        wins += finish < float(times[w])
    return PhaseOutcome(float(effective.max()),
                        np.ones(times.shape[0], dtype=bool), extra, wins)


@register_policy("speculative")
def speculative(times: np.ndarray, ctx: PhaseContext) -> PhaseOutcome:
    # Deadline over the FINITE arrivals only: an exhausted worker (time
    # inf, fail_open=False) never arrives, so the watcher's order
    # statistic must not wait on it — with every time finite this is
    # exactly the historical np.sort(times)[k-1].
    k = max(1, int(np.floor(ctx.watch_fraction * times.shape[0])))
    finite = times[np.isfinite(times)]
    if finite.size == 0:
        deadline = 0.0
    else:
        deadline = float(np.sort(finite)[min(k, finite.size) - 1])
    return _relaunch_outstanding(times, deadline, ctx)


@register_policy("hedged")
def hedged(times: np.ndarray, ctx: PhaseContext) -> PhaseOutcome:
    """Duplicate every request still outstanding at the hedge deadline."""
    finite = times[np.isfinite(times)]
    if finite.size == 0:
        deadline = 0.0
    else:
        # Quantile of the finite arrivals (identical to the historical
        # all-times quantile when nothing exhausted).
        deadline = float(np.quantile(finite, ctx.hedge_quantile))
    return _relaunch_outstanding(times, deadline, ctx)


@register_policy("coded_decode")
def coded_decode(times: np.ndarray, ctx: PhaseContext) -> PhaseOutcome:
    """Stop at the first arrival-order prefix that decodes.

    With no predicate this degenerates to k_of_n (any k results suffice);
    with one, it reproduces the faithful streaming master of Alg. 1.
    """
    n = times.shape[0]
    order = np.argsort(times, kind="stable")
    k_min = ctx.k if ctx.k is not None else 1
    for k in range(max(1, k_min), n + 1):
        if not np.isfinite(times[order[k - 1]]):
            # The prefix has run out of arrivals (exhausted workers sort
            # last): no decodable set exists — fall through to the
            # wait-all outcome, whose inf elapsed surfaces the exhaustion.
            break
        mask = np.zeros(n, dtype=bool)
        mask[order[:k]] = True
        if ctx.decodable is None or ctx.decodable(mask):
            return PhaseOutcome(float(times[order[k - 1]]), mask, [])
    return PhaseOutcome(float(times.max()), np.ones(n, dtype=bool), [])
