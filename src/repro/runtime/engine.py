"""Discrete-event serverless fleet engine.

``FleetEngine`` is the single substrate every optimizer in this repo is
scored on.  One ``run_phase`` call simulates one distributed round:

  1. Each worker is launched (one LAUNCH event at t=0).  An attempt may hit
     a **cold start** (probability ``cold_start_prob``, extra U[lo, hi]
     delay), then runs for a duration drawn from the calibrated
     ``StragglerModel`` (body x tail, Fig. 1 shape).
  2. An attempt may **fail** mid-run (probability ``failure_rate``); the
     master detects the failure and schedules a retry LAUNCH after
     ``retry_backoff``.  The attempt at index ``max_retries`` always
     succeeds — serverless masters relaunch until the result lands.
  3. When every worker's lifecycle has resolved, the phase's
     **termination policy** (``runtime.policies`` registry) decides the
     master's wait time and result mask, possibly adding relaunch attempts
     of its own (speculative / hedged).
  4. Every attempt — retries, hedges, k-of-n losers — is billed through the
     ``CostModel`` (GB-seconds + invocation + S3 ops), and the phase is
     appended to the trace recorder if one is attached.

Two scheduler-era extensions (``repro.scheduler``):

  - ``run_phase(memory_gb=...)`` bills THIS phase at its own Lambda size —
    a per-phase ``CostModel.memory_gb`` override, so per-phase sizing is a
    cost axis instead of a fleet-wide constant.
  - ``FleetEngine(pool=WarmPool(...))`` replaces the i.i.d. cold-start coin
    flip with a warm-container pool keyed off absolute simulated time: an
    attempt launching at ``t`` (phase start, i.e. ``not_before`` or the
    current clock, plus the event offset) is cold exactly when no unexpired
    container is free, so bursty DAG schedules pay cold starts that steady
    sequential schedules do not.  Policy relaunches stay on the i.i.d.
    model (duplicates are a burst into fresh capacity by construction).

Determinism: all run durations come from ``model.sample_times`` under keys
folded from the phase key, and all lifecycle coin flips come from a numpy
``Generator`` seeded from the same key — identical seeds give bit-identical
``(seconds, dollars)``, which is what makes trace replay exact.  Pool state
mutates in phase-dispatch order, which the scheduler canonicalizes.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, List, Optional, Tuple

import jax
import numpy as np

from repro import obs
from repro.runtime import policies as _policies
from repro.runtime import trace as _trace_mod
from repro.runtime.cost import CostLedger, CostModel, bill_phase
from repro.runtime.faults import FaultPlan, PhaseExhaustedError


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Worker-lifecycle knobs layered on the calibrated StragglerModel.

    Defaults are all-off so the engine reproduces the pure order-statistic
    clock the optimizers were originally scored on; benchmarks and tests
    turn the lifecycle on explicitly (``fleet_bench`` sweeps these).
    """

    cold_start_prob: float = 0.0   # P[attempt hits a cold container]
    cold_start_lo: float = 0.5     # cold-start delay bounds, seconds
    cold_start_hi: float = 2.0
    failure_rate: float = 0.0      # P[attempt dies mid-run]
    max_retries: int = 3           # retry budget per worker
    retry_backoff: float = 0.05    # master detection + relaunch delay
    watch_fraction: float = 0.9    # speculative policy watch deadline
    hedge_quantile: float = 0.8    # hedged policy duplicate launch point
    # fail_open=True (the historical semantics): the attempt at index
    # ``max_retries`` cannot die — the master relaunches until the result
    # lands, so a phase always completes.  fail_open=False makes the budget
    # real: a worker whose final attempt dies is EXHAUSTED (its result
    # never arrives, every attempt still bills) and a phase that cannot
    # terminate without it raises ``faults.PhaseExhaustedError``.
    fail_open: bool = True


def _np_rng(key: jax.Array) -> np.random.Generator:
    """Numpy generator deterministically derived from a jax PRNG key."""
    try:
        data = jax.random.key_data(key)
    except (AttributeError, TypeError):
        data = key
    return np.random.default_rng(
        np.asarray(data, dtype=np.uint32).ravel().tolist())


class FleetEngine:
    """Accumulates simulated seconds *and* dollars across phases."""

    def __init__(self, model, fleet: Optional[FleetConfig] = None,
                 cost: Optional[CostModel] = None,
                 recorder=None, replay=None, pool=None, telemetry=None,
                 faults: Optional[FaultPlan] = None):
        self.model = model
        self.fleet = fleet if fleet is not None else FleetConfig()
        self.cost_model = cost if cost is not None else CostModel()
        self.ledger = CostLedger()
        self.seconds = 0.0
        self.recorder = recorder
        self.replay = replay
        self.pool = pool       # scheduler.WarmPool (or None: i.i.d. colds)
        # obs.Telemetry (span tracer + metrics) or the zero-overhead no-op.
        # Telemetry is pure observation: it draws no randomness and never
        # moves the clock, so attaching it cannot change (seconds, dollars).
        self.telemetry = telemetry if telemetry is not None else obs.NULL
        # runtime.faults.FaultPlan: deterministic chaos injected into every
        # phase.  All fault randomness comes from a generator folded from
        # the phase key and the plan's seed, never from ``rng`` — a run
        # with faults=None draws exactly the historical stream.
        self.faults = faults
        # Per-worker corruption flags of the most recent phase (None unless
        # the plan has a CorruptionSpec); the coded-matvec layer reads this.
        self.last_corruption: Optional[np.ndarray] = None
        self._pool_death_done = False
        self._phase_idx = 0

    # ------------------------------------------------------------- totals
    @property
    def dollars(self) -> float:
        return self.ledger.dollars(self.cost_model)

    def charge(self, elapsed: float, phase_name: Optional[str] = None
               ) -> None:
        """Add externally-computed phase time (no workers billed)."""
        if self.replay is not None:
            elapsed = self.replay.next_charge()
        elapsed = float(elapsed)
        t0 = self.seconds
        self.seconds += elapsed
        if self.recorder is not None:
            self.recorder.record_charge(self._phase_idx, elapsed)
        tel = self.telemetry
        if tel.enabled:
            tel.trace.emit(phase_name or f"charge{self._phase_idx}",
                           "charge", t0, t0 + elapsed)
            tel.metrics.counter("fleet.charges").inc()
        self._phase_idx += 1

    # ----------------------------------------------------- lifecycle core
    def _lifecycle(self, key: jax.Array, rng: np.random.Generator,
                   num_workers: int, work_per_worker: float,
                   flops_per_worker: Optional[float], t0: float = 0.0, *,
                   frng: Optional[np.random.Generator] = None,
                   eff_memory_gb: float = 0.0,
                   working_set_gb: Optional[float] = None
                   ) -> Tuple[np.ndarray, List[tuple], int, dict]:
        """Event-driven per-worker lifecycle: cold start -> running ->
        done | killed-with-retry | exhausted.  Returns (completion_times,
        attempts, successes, stats); ``attempts`` are (launch, end) pairs —
        or (launch, end, mem_scale) triples for OOM-escalated attempts —
        for billing, and ``stats`` carries retries / cold-start / injected-
        fault telemetry for the trace.

        ``t0`` is the phase's absolute launch time — the warm pool (when
        attached) is consulted at ``t0 + event_time``, so overlapped and
        bursty schedules see the pool as it stands at their true launch
        instant.  ``frng`` (present iff a FaultPlan is active) feeds every
        injected-fault draw; the base ``rng`` stream is untouched, so a
        plan-less run is bit-identical to the pre-chaos engine.

        An attempt can die three ways — OOM (deterministic, when the
        effective Lambda size is below ``working_set_gb``), a correlated
        burst hit, or the i.i.d. failure coin; the earliest death wins.
        Under ``fail_open`` the attempt at index ``max_retries`` is immune
        (the historical always-succeeds semantics); otherwise a death at
        the final attempt leaves the worker EXHAUSTED: ``done[w]`` stays
        inf and every attempt still bills."""
        fl = self.fleet
        fp = self.faults if frng is not None else None
        round_times: dict = {}
        stats = {"retries": 0, "warm": 0, "cold": 0,
                 "cold_delays": [], "exhausted": 0}   # type: dict
        # Per-attempt lifecycle records for the span tracer, collected only
        # when telemetry is live (the trace recorder never reads this key).
        events_out = [] if self.telemetry.enabled else None
        if events_out is not None:
            stats["events"] = events_out
        fstats = None
        if fp is not None:
            fstats = {"burst_kills": 0, "burst_exposed": 0, "throttled": 0,
                      "s3_get_retries": 0, "s3_put_retries": 0,
                      "oom_kills": 0, "oom_escalations": 0,
                      "pool_killed": 0, "peak_concurrency": 0,
                      "throttle_waits": []}
            stats["faults"] = fstats

        def duration(worker: int, attempt: int) -> float:
            # One jax sample round per retry wave, lazily — the common
            # failure-free case costs exactly one sample_times call.
            if attempt not in round_times:
                k = jax.random.fold_in(key, attempt)
                round_times[attempt] = np.asarray(
                    self.model.sample_times(k, num_workers, work_per_worker,
                                            flops_per_worker),
                    dtype=np.float64)
            return float(round_times[attempt][worker])

        done = np.full(num_workers, np.inf)
        attempts: List[tuple] = []
        successes = 0
        mem_scale = np.ones(num_workers)   # >1 only after OOM escalation
        running: list = []  # end-times heap of admitted in-flight attempts
        th = fp.throttle if fp is not None else None
        s3 = fp.s3 if fp is not None else None
        events: list = []   # (time, seq, worker, attempt, backoff_tries)
        for w in range(num_workers):
            heapq.heappush(events, (0.0, w, w, 0, 0))
        seq = num_workers
        while events:
            t, _, w, attempt, tries = heapq.heappop(events)
            if th is not None:
                while running and running[0] <= t:
                    heapq.heappop(running)
                if (th.t_start <= t0 + t < th.t_end
                        and len(running) >= th.max_concurrent):
                    # Rejected by the concurrency cap: re-queue after
                    # exponential backoff + jitter.  The rejected request
                    # is still billed as an invocation (run_phase adds it).
                    wait = (th.backoff * th.backoff_mult ** tries
                            + frng.uniform(0.0, th.jitter))
                    fstats["throttled"] += 1
                    fstats["throttle_waits"].append(float(wait))
                    heapq.heappush(events,
                                   (t + wait, seq, w, attempt, tries + 1))
                    seq += 1
                    continue
            if self.pool is not None:
                # Warm-pool model: cold exactly when no unexpired container
                # is free at the attempt's absolute launch time.
                cold = not self.pool.acquire(t0 + t)
            else:
                cold = (fl.cold_start_prob > 0.0
                        and rng.random() < fl.cold_start_prob)
            t_cold = (rng.uniform(fl.cold_start_lo, fl.cold_start_hi)
                      if cold else 0.0)
            if cold:
                stats["cold"] += 1
                stats["cold_delays"].append(float(t_cold))
            elif self.pool is not None:
                stats["warm"] += 1
            # S3 input GET transients: seeded retries delay the run start
            # (and bill extra GETs via run_phase).
            t_get = 0.0
            if (s3 is not None and s3.get_fail_prob > 0.0
                    and s3.t_start <= t0 + t < s3.t_end):
                for i in range(s3.max_tries):
                    if frng.random() >= s3.get_fail_prob:
                        break
                    t_get += s3.retry_delay * (2.0 ** i)
                    fstats["s3_get_retries"] += 1
            run = duration(w, attempt)
            start = t + t_cold + t_get
            # What kills this attempt, if anything — the earliest death
            # wins.  Under fail_open the final attempt is immune.
            final = fl.fail_open and attempt >= fl.max_retries
            t_die = math.inf
            cause = None
            oomspec = fp.oom if fp is not None else None
            if (not final and oomspec is not None
                    and working_set_gb is not None
                    and eff_memory_gb * mem_scale[w] < working_set_gb):
                t_die = start + oomspec.kill_at_fraction * run
                cause = "oom"
            b = fp.burst if fp is not None else None
            if (not final and b is not None and b.kill_fraction > 0.0
                    and t0 + start < b.t_end
                    and t0 + start + run > b.t_start):
                fstats["burst_exposed"] += 1
                if frng.random() < b.kill_fraction:
                    # The whole zone goes down at t_start: every attempt
                    # already running dies at that instant, later launches
                    # die on arrival — correlated, not i.i.d.
                    t_hit = max(start, b.t_start - t0)
                    if t_hit < t_die:
                        t_die, cause = t_hit, "burst"
            if (not final and fl.failure_rate > 0.0
                    and rng.random() < fl.failure_rate):
                t_fail = start + rng.uniform(0.05, 0.95) * run
                if t_fail < t_die:
                    t_die, cause = t_fail, "fail"
            if cause is not None:
                attempts.append(
                    (t, t_die) if mem_scale[w] == 1.0
                    else (t, t_die, float(mem_scale[w])))
                if cause == "fail":
                    stats["retries"] += 1
                elif cause == "burst":
                    fstats["burst_kills"] += 1
                else:
                    fstats["oom_kills"] += 1
                if events_out is not None:
                    events_out.append((w, attempt, t, t_cold, t_die, False))
                if self.pool is not None:
                    # A function error does not tear the container down.
                    self.pool.release(t0 + t_die)
                if th is not None:
                    heapq.heappush(running, t_die)
                    fstats["peak_concurrency"] = max(
                        fstats["peak_concurrency"], len(running))
                if attempt < fl.max_retries:
                    if cause == "oom" and oomspec.escalate:
                        # Retry at doubled memory (billed at that size).
                        mem_scale[w] = min(
                            mem_scale[w] * 2.0,
                            max(1.0, oomspec.max_memory_gb / eff_memory_gb))
                        fstats["oom_escalations"] += 1
                    heapq.heappush(events, (t_die + fl.retry_backoff, seq,
                                            w, attempt + 1, 0))
                    seq += 1
                else:
                    # Retry budget truly exhausted (fail_open=False): the
                    # result never arrives; every attempt above billed.
                    stats["exhausted"] += 1
            else:
                end = start + run
                # S3 output PUT transients: the worker lingers retrying
                # (billed for the longer run + the extra PUTs).
                if (s3 is not None and s3.put_fail_prob > 0.0
                        and s3.t_start <= t0 + end < s3.t_end):
                    for i in range(s3.max_tries):
                        if frng.random() >= s3.put_fail_prob:
                            break
                        end += s3.retry_delay * (2.0 ** i)
                        fstats["s3_put_retries"] += 1
                attempts.append(
                    (t, end) if mem_scale[w] == 1.0
                    else (t, end, float(mem_scale[w])))
                successes += 1
                done[w] = end
                if events_out is not None:
                    events_out.append((w, attempt, t, t_cold, end, True))
                if self.pool is not None:
                    self.pool.release(t0 + end)
                if th is not None:
                    heapq.heappush(running, end)
                    fstats["peak_concurrency"] = max(
                        fstats["peak_concurrency"], len(running))
        return done, attempts, successes, stats

    # ---------------------------------------------------------- telemetry
    def _phase_telemetry(self, name: str, deps: Tuple[str, ...], start: float,
                         elapsed: float, policy: str, num_workers: int,
                         k: Optional[int], entry: CostLedger,
                         stats: Optional[dict],
                         extra_attempts: Optional[list], *,
                         cost_model: Optional[CostModel] = None,
                         replayed: bool = False,
                         corrupted=None) -> None:
        """Emit one phase's span tree + metrics.  Pure observation of
        already-computed values — no RNG, no clock movement."""
        tel = self.telemetry
        dollars = entry.dollars(cost_model if cost_model is not None
                                else self.cost_model)
        attrs = {"policy": policy, "workers": int(num_workers),
                 "deps": list(deps), "gb_seconds": entry.gb_seconds,
                 "dollars": dollars}
        if k is not None:
            attrs["k"] = int(k)
        if replayed:
            attrs["replayed"] = True
        # Per-phase injected-fault signature: the nonzero fault counters
        # of THIS phase, attached to its span so the incident engine
        # (repro.obs.incident) can correlate an alert window with what
        # the chaos plane actually did there.  Plan-less runs never have
        # a "faults" stats dict, so healthy spans (and the committed
        # golden Perfetto fixture) are unchanged.
        injected = {kk: int(v)
                    for kk, v in sorted(((stats or {}).get("faults")
                                         or {}).items())
                    if kk not in ("throttle_waits", "burst_exposed",
                                  "peak_concurrency") and v}
        if corrupted is not None and bool(corrupted.any()):
            injected["corrupted_workers"] = int(corrupted.sum())
        if injected:
            attrs["faults"] = injected
        if stats is not None and stats.get("exhausted"):
            attrs["exhausted"] = int(stats["exhausted"])
        pid = tel.trace.emit(name, "phase", start, start + elapsed, **attrs)

        m = tel.metrics
        m.counter("fleet.phases").inc()
        m.histogram("phase.elapsed_s").observe(elapsed)
        m.histogram("phase.gb_seconds").observe(entry.gb_seconds)
        m.histogram("phase.dollars").observe(dollars)
        if stats is None:
            return

        # Per-phase straggler-tail quantile: the p95 of this round's
        # successful completion offsets, one sample per phase — the
        # health monitors' spike stream (per-worker samples feed the
        # drift CUSUM below; both are derived from already-computed
        # lifecycle events, so this stays observation-only).
        completions = sorted(t_end for (_, _, _, _, t_end, ok)
                             in stats.get("events", ()) if ok)
        if completions:
            rank = min(len(completions) - 1,
                       int(round(0.95 * (len(completions) - 1))))
            m.histogram("phase.tail_p95_s").observe(completions[rank])

        # Per-worker lifecycle slices: cold start, then the running slice
        # ("run" | "retry" on later attempts | "failed" when it died).
        for (w, attempt, t, t_cold, t_end, ok) in stats.get("events", ()):
            track = f"{name}/w{w}"
            if t_cold > 0.0:
                tel.trace.emit("cold", "attempt", start + t,
                               start + t + t_cold, parent=pid, track=track)
            slice_name = ("failed" if not ok
                          else "run" if attempt == 0 else "retry")
            tel.trace.emit(slice_name, "attempt", start + t + t_cold,
                           start + t_end, parent=pid, track=track,
                           attempt=attempt)
            if ok:
                # Completion time relative to phase launch: the Fig. 1
                # straggler-tail distribution, as percentiles.
                m.histogram("worker.completion_s").observe(t_end)
        # Policy relaunches (speculative / hedged duplicates).
        for i, (t_l, t_e) in enumerate(extra_attempts or ()):
            if math.isfinite(t_e):
                tel.trace.emit("relaunch", "attempt", start + t_l,
                               start + t_e, parent=pid,
                               track=f"{name}/spec{i}")
        m.counter("fleet.attempts").inc(len(stats.get("events", ()))
                                        or num_workers)
        m.counter("fleet.relaunches").inc(len(extra_attempts or ()))
        m.counter("fleet.retries").inc(stats["retries"])
        m.counter("fleet.cold_starts").inc(stats["cold"])
        m.counter("fleet.warm_hits").inc(stats["warm"])
        for kind, v in (stats.get("faults") or {}).items():
            # One counter per injected-event kind; healthy (plan-less)
            # runs emit nothing here, so existing metric streams and the
            # default health rules are untouched.
            if kind == "peak_concurrency" and v:
                m.gauge("fault.peak_concurrency").set(int(v))
            elif kind != "throttle_waits" and v:
                m.counter(f"fault.{kind}").inc(int(v))
        if stats.get("exhausted"):
            m.counter("fault.exhausted_workers").inc(stats["exhausted"])
        for d in stats["cold_delays"]:
            m.histogram("worker.cold_delay_s").observe(d)
        if self.pool is not None:
            m.gauge("pool.free").set(self.pool.free_at(self.seconds))
            m.gauge("pool.warm_hits_total").set(self.pool.warm_hits)
            m.gauge("pool.cold_starts_total").set(self.pool.cold_starts)
            m.gauge("pool.killed_total").set(self.pool.killed)
            served = stats["warm"] + stats["cold"]
            if served:
                # Per-phase hit rate — the spiky stream the health
                # monitors' pool-collapse detector watches.
                m.gauge("pool.phase_hit_rate").set(stats["warm"] / served)
            total = self.pool.warm_hits + self.pool.cold_starts
            if total:
                # True cumulative rate from the pool's own counters —
                # under a shared pool a tenant's phase ratio conflates
                # its neighbours' churn; this one does not.
                m.gauge("pool.hit_rate").set(self.pool.warm_hits / total)

    # ------------------------------------------------------------- phases
    def run_phase(self, key: jax.Array, num_workers: int, *,
                  work_per_worker: float = 1.0,
                  flops_per_worker: Optional[float] = None,
                  policy: str = "wait_all", k: Optional[int] = None,
                  comm_units: float = 0.0,
                  decodable: Optional[Callable[[np.ndarray], bool]] = None,
                  not_before: Optional[float] = None,
                  memory_gb: Optional[float] = None,
                  working_set_gb: Optional[float] = None,
                  phase_name: Optional[str] = None,
                  phase_deps: Tuple[str, ...] = ()
                  ) -> Tuple[float, np.ndarray]:
        """Simulate one distributed phase; returns (elapsed, finished_mask).

        ``elapsed`` includes the master-side communication charge
        (``comm_per_unit * comm_units``), matching the historical SimClock
        accounting; the cost ledger bills workers and comm separately.

        ``not_before`` is the phase's absolute launch time (simulated
        seconds).  Default None launches at the current clock — strictly
        sequential phases.  An earlier launch time models master-side
        pipeline overlap (paper Sec. 4.1: encode overlaps compute): the
        phase ran concurrently with whatever advanced the clock since,
        so the clock only moves to ``max(now, not_before + elapsed)`` and
        the overlapped makespan is never longer than the sequential one.
        Billing is unaffected — every attempt costs the same GB-seconds
        wherever it sits on the timeline.

        ``memory_gb`` bills this phase at its own Lambda size (a per-phase
        ``CostModel.memory_gb`` override, recorded in the trace row);
        None bills at the fleet-wide default.  ``working_set_gb`` declares
        the phase's true per-worker working set (``scheduler.sizing``) —
        inert unless a FaultPlan with an ``OomSpec`` is attached, in which
        case attempts whose effective memory is below it are OOM-killed.

        ``phase_name`` / ``phase_deps`` are telemetry-only annotations
        (span name + recorded dependency edges for critical-path
        reconstruction); they never reach the trace recorder or any
        numeric path.
        """
        tel = self.telemetry
        if self.replay is not None:
            elapsed, mask, entry, advance, row = self.replay.next_phase(
                policy=policy, num_workers=num_workers)
            t_end = self.seconds + advance
            self.seconds = t_end
            self.ledger.add(entry)
            corrupted_hex = (row.get("faults") or {}).get("corrupted")
            self.last_corruption = (
                None if corrupted_hex is None
                else _trace_mod._mask_from_hex(corrupted_hex, num_workers))
            if tel.enabled:
                # An overlapped recorded phase (advance < elapsed) started
                # before the pre-phase clock; recover its true interval.
                self._phase_telemetry(
                    phase_name or f"phase{self._phase_idx}", phase_deps,
                    t_end - elapsed, elapsed, policy, num_workers, k,
                    entry, None, None, replayed=True)
            self._phase_idx += 1
            if row.get("raised"):
                # The recording exhausted here; re-raise so the replayed
                # algorithm takes the same degradation path.
                if tel.enabled:
                    tel.metrics.counter("fleet.exhausted_phases").inc()
                raise PhaseExhaustedError(
                    phase_name or self._phase_idx - 1, num_workers,
                    mask, elapsed)
            return elapsed, mask

        rng = _np_rng(key)
        fp = self.faults
        frng = None
        if fp is not None and fp.active():
            # Dedicated fault stream: folded from the phase key AND the
            # plan seed, so injected chaos is reproducible per phase and
            # the base lifecycle stream is exactly the plan-less one.
            frng = _np_rng(jax.random.fold_in(key, 99991 + fp.seed))
        t0 = float(self.seconds if not_before is None else not_before)
        pool_killed = 0
        if (fp is not None and fp.pool_death is not None
                and self.pool is not None and not self._pool_death_done
                and t0 >= fp.pool_death.t):
            # The provider reclaimed a fraction of the idle containers;
            # applied once, at the first phase launching at or after t.
            pool_killed = self.pool.cull(
                fp.pool_death.fraction,
                np.random.default_rng(fp.seed + 0xDEAD))
            self._pool_death_done = True
        eff_memory_gb = float(self.cost_model.memory_gb
                              if memory_gb is None else memory_gb)
        done, attempts, successes, stats = self._lifecycle(
            key, rng, num_workers, work_per_worker, flops_per_worker, t0,
            frng=frng, eff_memory_gb=eff_memory_gb,
            working_set_gb=working_set_gb)
        fstats = stats.get("faults")
        if fstats is not None:
            fstats["pool_killed"] = pool_killed

        relaunch_cache: dict = {}

        def sample_relaunch() -> np.ndarray:
            # Duplicates live in the same fleet as originals: they can hit
            # cold containers and they can die (duration inf — the original
            # copy then wins; min() in the policy handles it).
            if "r" not in relaunch_cache:
                fl = self.fleet
                kr = jax.random.fold_in(key, 7777)
                run = np.asarray(
                    self.model.sample_times(kr, num_workers, work_per_worker,
                                            flops_per_worker),
                    dtype=np.float64)
                if fl.cold_start_prob > 0.0:
                    cold = rng.random(num_workers) < fl.cold_start_prob
                    run = run + cold * rng.uniform(
                        fl.cold_start_lo, fl.cold_start_hi, num_workers)
                if fl.failure_rate > 0.0:
                    run = np.where(rng.random(num_workers) < fl.failure_rate,
                                   np.inf, run)
                if frng is not None:
                    # Relaunches share the injected chaos: a burst window
                    # covering this phase kills duplicates with the same
                    # correlated coin, and an active concurrency cap
                    # serializes their admission (each batch of
                    # ``max_concurrent`` duplicates waits one more backoff
                    # + jitter step).  Extra draws come from the fault
                    # stream only — the plan-less stream stays identical.
                    b = fp.burst
                    if (b is not None and b.kill_fraction > 0.0
                            and b.t_start <= t0 < b.t_end):
                        run = np.where(
                            frng.random(num_workers) < b.kill_fraction,
                            np.inf, run)
                    th = fp.throttle
                    if th is not None and th.t_start <= t0 < th.t_end:
                        waves = np.arange(num_workers) // th.max_concurrent
                        run = run + waves * (
                            th.backoff
                            + frng.uniform(0.0, th.jitter, num_workers))
                relaunch_cache["r"] = run
            return relaunch_cache["r"]

        ctx = _policies.PhaseContext(
            k=k, watch_fraction=self.fleet.watch_fraction,
            hedge_quantile=self.fleet.hedge_quantile,
            decodable=decodable, sample_relaunch=sample_relaunch)
        outcome = _policies.get_policy(policy)(done, ctx)

        raised = not math.isfinite(float(outcome.elapsed))
        if raised:
            # The policy cannot terminate without an exhausted worker's
            # result.  The master stops at the last lifecycle event it
            # observed; everything that ran still bills, the partial phase
            # is recorded, and a typed error surfaces the survivors.
            mask = np.isfinite(done)
            elapsed = float(max((a[1] for a in attempts), default=0.0))
            extra_attempts = [e for e in outcome.extra_attempts
                              if math.isfinite(e[1])]
        else:
            mask = np.asarray(outcome.mask, dtype=bool)
            elapsed = float(outcome.elapsed
                            + self.model.comm_per_unit * comm_units)
            extra_attempts = list(outcome.extra_attempts)
        all_attempts = attempts + extra_attempts
        cost_model = (self.cost_model if memory_gb is None else
                      dataclasses.replace(self.cost_model,
                                          memory_gb=float(memory_gb)))
        entry = bill_phase(cost_model, all_attempts,
                           successes + outcome.extra_successes,
                           comm_units)
        if fstats is not None:
            # Throttle rejections bill control-plane invocations; S3
            # transients bill the extra ops their retries issued.
            entry.invocations += float(fstats["throttled"])
            entry.s3_gets += float(fstats["s3_get_retries"])
            entry.s3_puts += float(fstats["s3_put_retries"])
        if cost_model.billing == "reserved":
            # Fixed cluster: every node bills the phase's wall-clock
            # (idle-behind-the-straggler time included), not its own work.
            entry.gb_seconds = (cost_model.memory_gb * num_workers
                                * elapsed)
        if not_before is None:
            advance = elapsed   # not (now + e) - now: that rounds off a ULP
        else:
            advance = max(0.0, float(not_before) + elapsed - self.seconds)
        self.seconds += advance
        self.ledger.add(entry)
        corrupted = None
        if fp is not None and fp.corruption is not None:
            c = fp.corruption
            u = frng.random(num_workers)
            abs_done = t0 + done
            corrupted = (np.isfinite(done) & (abs_done >= c.t_start)
                         & (abs_done < c.t_end) & (u < c.prob))
        self.last_corruption = corrupted
        if tel.enabled:
            self._phase_telemetry(
                phase_name or f"phase{self._phase_idx}", phase_deps, t0,
                elapsed, policy, num_workers, k, entry, stats,
                extra_attempts, cost_model=cost_model,
                corrupted=corrupted)
            if raised:
                tel.metrics.counter("fleet.exhausted_phases").inc()
        if self.recorder is not None:
            # free_at, not len(): lazy TTL expiry means the raw pool still
            # holds containers no launch at the current clock could use.
            pool_free = (self.pool.free_at(self.seconds)
                         if self.pool is not None else None)
            self.recorder.record_phase(
                self._phase_idx, policy=policy, num_workers=num_workers,
                k=k, elapsed=elapsed, mask=mask,
                entry=entry, worker_times=done, advance=advance,
                memory_gb=None if memory_gb is None else float(memory_gb),
                stats=stats, pool_free=pool_free, corrupted=corrupted,
                raised=raised)
        self._phase_idx += 1
        if raised:
            raise PhaseExhaustedError(
                phase_name or self._phase_idx - 1, num_workers, mask,
                elapsed)
        return elapsed, mask
