"""Serverless runtime engine: discrete-event fleet simulation with cost
accounting, a termination-policy registry, and trace record/replay.

This package is the substrate every optimizer in the repo is scored on.
``core.straggler.SimClock`` is a thin facade over ``FleetEngine`` (same
``phase()``/``charge()`` API), so optimizer call sites are unchanged while
every run now reports simulated seconds *and* simulated dollars.

See ``src/repro/runtime/README.md`` for the event model, the cost-model
constants, and the trace JSONL schema.
"""
from repro.runtime.cost import CostLedger, CostModel, bill_phase
from repro.runtime.engine import FleetConfig, FleetEngine
from repro.runtime.faults import (BurstSpec, CorruptionSpec, FaultPlan,
                                  OomSpec, PhaseExhaustedError,
                                  PoolDeathSpec, S3Spec, ThrottleSpec,
                                  available_scenarios, get_scenario,
                                  register_scenario)
from repro.runtime.policies import (PhaseContext, PhaseOutcome,
                                    available_policies, get_policy,
                                    register_policy)
from repro.runtime.trace import (TraceRecorder, TraceReplayer,
                                 calibrate_faults_from_trace,
                                 calibrate_fleet_from_trace,
                                 calibrate_from_times, calibrate_from_trace,
                                 load_trace)

__all__ = [
    "CostLedger", "CostModel", "bill_phase",
    "FleetConfig", "FleetEngine",
    "BurstSpec", "CorruptionSpec", "FaultPlan", "OomSpec",
    "PhaseExhaustedError", "PoolDeathSpec", "S3Spec", "ThrottleSpec",
    "available_scenarios", "get_scenario", "register_scenario",
    "PhaseContext", "PhaseOutcome", "available_policies", "get_policy",
    "register_policy",
    "TraceRecorder", "TraceReplayer", "calibrate_faults_from_trace",
    "calibrate_fleet_from_trace",
    "calibrate_from_times", "calibrate_from_trace", "load_trace",
]
