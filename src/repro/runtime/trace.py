"""Fleet trace record / replay + empirical calibration.

A trace is a JSONL phase log.  Two row kinds:

  {"kind": "phase", "phase": 0, "policy": "k_of_n", "workers": 24, "k": 20,
   "elapsed": 1.23, "mask": "fffff0", "gb_seconds": 93.1, "invocations": 31,
   "s3_puts": 25.0, "s3_gets": 63.0, "worker_times": [...optional...]}
  {"kind": "charge", "phase": 1, "elapsed": 0.57}

``mask`` is the finished-worker bitmask, big-endian bit-packed and
hex-encoded (worker 0 = MSB of the first byte).  Floats are serialized via
``repr`` (json default), which round-trips IEEE doubles exactly — replaying
a recorded run reproduces bit-identical ``(seconds, dollars)`` totals.

Schema v2 (scheduler-era, every field optional => v1 traces replay
unchanged and v1 readers ignore the new keys):

  - ``memory_gb``: present when the phase was billed at a per-phase Lambda
    size (``run_phase(memory_gb=...)`` override).
  - ``pool``: ``{"warm": w, "cold": c, "free": f}`` when a ``WarmPool`` is
    attached — warm hits / cold starts among this phase's lifecycle
    attempts, and the pool's free-container count after the phase.
  - ``retries`` + ``cold_delays`` (opt-in, ``TraceRecorder(lifecycle=
    True)``): failure-retry count and the drawn cold-start delays of each
    phase — what ``calibrate_fleet_from_trace`` fits a ``FleetConfig``
    (failure rate, cold-start probability and bounds) from.

``worker_times`` (opt-in, ``TraceRecorder(worker_times=True)``) stores the
per-worker completion times of each phase; ``calibrate_from_trace`` fits a
``StragglerModel`` to their empirical shape (median base, lognormal body
spread, tail fraction and span — the paper's Fig. 1 statistics), closing
the loop from a recorded fleet back to a simulator that reproduces it.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Tuple

import numpy as np

from repro.core.straggler import StragglerModel
from repro.runtime.cost import CostLedger


def _mask_to_hex(mask: np.ndarray) -> str:
    return np.packbits(np.asarray(mask, dtype=np.uint8)).tobytes().hex()


def _mask_from_hex(s: str, n: int) -> np.ndarray:
    bits = np.unpackbits(np.frombuffer(bytes.fromhex(s), dtype=np.uint8))
    return bits[:n].astype(bool)


@dataclasses.dataclass
class TraceRecorder:
    """Collects phase rows; ``dump`` writes JSONL.

    ``lifecycle=True`` additionally records each phase's failure-retry
    count and drawn cold-start delays (schema v2) — the raw material for
    ``calibrate_fleet_from_trace``.  Off by default so default recordings
    stay byte-identical to pre-v2 traces."""

    worker_times: bool = False
    lifecycle: bool = False
    rows: List[dict] = dataclasses.field(default_factory=list)

    def record_phase(self, phase: int, *, policy: str, num_workers: int,
                     k: Optional[int], elapsed: float, mask: np.ndarray,
                     entry: CostLedger,
                     worker_times: Optional[np.ndarray] = None,
                     advance: Optional[float] = None,
                     memory_gb: Optional[float] = None,
                     stats: Optional[dict] = None,
                     pool_free: Optional[int] = None) -> None:
        row = {"kind": "phase", "phase": phase, "policy": policy,
               "workers": int(num_workers), "k": k,
               "elapsed": float(elapsed), "mask": _mask_to_hex(mask)}
        if advance is not None and advance != elapsed:
            # Overlapped phase (run_phase not_before=...): the clock moved
            # by less than the phase duration.  Absent for sequential
            # phases so pre-overlap traces replay unchanged.
            row["advance"] = float(advance)
        if memory_gb is not None:
            row["memory_gb"] = float(memory_gb)
        if pool_free is not None:
            # Pool attached: warm/cold split of this phase's lifecycle
            # attempts and the free-container count after the phase.
            row["pool"] = {"warm": int(stats["warm"]) if stats else 0,
                           "cold": int(stats["cold"]) if stats else 0,
                           "free": int(pool_free)}
        if self.lifecycle and stats is not None:
            row["retries"] = int(stats["retries"])
            row["cold_delays"] = [float(t) for t in stats["cold_delays"]]
        row.update(entry.as_dict())
        if self.worker_times and worker_times is not None:
            row["worker_times"] = [float(t) for t in worker_times]
        self.rows.append(row)

    def record_charge(self, phase: int, elapsed: float) -> None:
        self.rows.append({"kind": "charge", "phase": phase,
                          "elapsed": float(elapsed)})

    def dump(self, path) -> None:
        with open(path, "w") as f:
            for row in self.rows:
                f.write(json.dumps(row) + "\n")


class TraceReplayer:
    """Replays a recorded trace row-by-row; the engine consumes one row per
    phase()/charge() call and re-applies the recorded time and cost, so a
    replayed run is bit-identical to the recording."""

    def __init__(self, rows: List[dict]):
        self.rows = list(rows)
        self._i = 0

    def _next(self, kind: str) -> dict:
        if self._i >= len(self.rows):
            raise ValueError(f"trace exhausted at row {self._i} "
                             f"(wanted a {kind!r} row)")
        row = self.rows[self._i]
        if row["kind"] != kind:
            raise ValueError(f"trace row {self._i} is {row['kind']!r}, "
                             f"run wanted {kind!r} — phase structure drifted")
        self._i += 1
        return row

    def next_phase(self, *, policy: str, num_workers: int
                   ) -> Tuple[float, np.ndarray, CostLedger, float]:
        row = self._next("phase")
        if row["policy"] != policy or row["workers"] != num_workers:
            raise ValueError(
                f"trace row {self._i - 1} recorded "
                f"({row['policy']!r}, {row['workers']} workers), run asked "
                f"({policy!r}, {num_workers}) — not the same schedule")
        entry = CostLedger(gb_seconds=row["gb_seconds"],
                           invocations=row["invocations"],
                           s3_puts=row["s3_puts"], s3_gets=row["s3_gets"])
        return (row["elapsed"], _mask_from_hex(row["mask"], num_workers),
                entry, row.get("advance", row["elapsed"]))

    def next_charge(self) -> float:
        return self._next("charge")["elapsed"]


def load_trace(path) -> TraceReplayer:
    with open(path) as f:
        rows = [json.loads(line) for line in f if line.strip()]
    return TraceReplayer(rows)


# --------------------------------------------------------------- calibration
def calibrate_from_times(times, tail_cut: float = 1.25) -> StragglerModel:
    """Fit a StragglerModel to empirical per-worker job times (Fig. 1 shape).

    Workers above ``tail_cut`` x median are stragglers: their fraction gives
    ``p_tail`` and their span the tail bounds; the body's log-spread around
    the median gives ``body_sigma``.  Invocation overhead is not separable
    from a bare completion-time histogram, so it calibrates to 0.
    """
    t = np.asarray(times, dtype=np.float64).ravel()
    if t.size == 0 or not np.all(t > 0):
        raise ValueError("calibration needs positive per-worker times")
    med = float(np.median(t))
    body = t[t <= tail_cut * med]
    tail = t[t > tail_cut * med]
    sigma = float(np.std(np.log(body / med))) if body.size > 1 else 0.05
    p_tail = float(tail.size / t.size)
    if tail.size:
        tail_lo = max(0.05, float(tail.min() / med - 1.0))
        tail_hi = max(tail_lo + 0.05, float(tail.max() / med - 1.0))
    else:
        tail_lo, tail_hi = 0.3, 1.5
    return StragglerModel(base_time=med, body_sigma=max(sigma, 1e-3),
                          p_tail=p_tail, tail_lo=tail_lo, tail_hi=tail_hi,
                          invoke_overhead=0.0)


def calibrate_from_trace(path, tail_cut: float = 1.25) -> StragglerModel:
    """Pool every recorded phase's ``worker_times`` (normalized per phase so
    phases with different work mix) and fit the pooled shape."""
    with open(path) as f:
        rows = [json.loads(line) for line in f if line.strip()]
    pooled, medians = [], []
    for row in rows:
        wt = row.get("worker_times")
        if not wt:
            continue
        wt = np.asarray(wt, dtype=np.float64)
        med = float(np.median(wt))
        if med > 0:
            pooled.append(wt / med)
            medians.append(med)
    if not pooled:
        raise ValueError(f"no worker_times rows in {path}; record with "
                         "TraceRecorder(worker_times=True)")
    scale = float(np.mean(medians))   # representative per-phase base time
    return calibrate_from_times(np.concatenate(pooled) * scale,
                                tail_cut=tail_cut)


def calibrate_fleet_from_trace(path) -> "FleetConfig":
    """Fit a ``FleetConfig`` (failure rate + cold-start statistics) to a
    schema-v2 lifecycle trace (``TraceRecorder(lifecycle=True)``).

    Estimators, over all phase rows:

      - ``failure_rate``: retries / lifecycle launches.  Each lifecycle
        attempt below the retry cap fails independently with rate p, so
        launches per worker are geometric and failures/launches -> p
        (the retry-cap truncation bias is O(p^max_retries)).
      - ``cold_start_prob``: cold starts / lifecycle launches — the i.i.d.
        reading of the trace; a warm-pool trace yields the *effective*
        cold rate its schedule produced, which is the number a pool-less
        simulation of the same workload should use.
      - ``cold_start_lo`` / ``hi``: min / max of the recorded cold delays
        (consistent for the U[lo, hi] the engine draws from).

    The closing loop: a synthetic "public Lambda trace" recorded under a
    known fleet round-trips to that fleet's parameters (see
    ``tests/fixtures/lambda_trace_synthetic.jsonl``).
    """
    from repro.runtime.engine import FleetConfig   # engine does not import us
    with open(path) as f:
        rows = [json.loads(line) for line in f if line.strip()]
    phase_rows = [r for r in rows if r.get("kind") == "phase"]
    if not any("retries" in r for r in phase_rows):
        raise ValueError(
            f"no lifecycle rows in {path}; record with "
            "TraceRecorder(lifecycle=True)")
    launches = 0
    retries = 0
    delays: list = []
    for r in phase_rows:
        if "retries" not in r:
            continue
        retries += int(r["retries"])
        launches += int(r["workers"]) + int(r["retries"])
        delays.extend(r.get("cold_delays", ()))
    if launches == 0:
        raise ValueError(f"lifecycle rows in {path} contain no launches")
    failure_rate = retries / launches
    cold_prob = len(delays) / launches
    if delays:
        lo, hi = float(min(delays)), float(max(delays))
        if hi <= lo:
            hi = lo + 1e-6
    else:
        dflt = FleetConfig()
        lo, hi = dflt.cold_start_lo, dflt.cold_start_hi
    return FleetConfig(failure_rate=failure_rate, cold_start_prob=cold_prob,
                       cold_start_lo=lo, cold_start_hi=hi)
