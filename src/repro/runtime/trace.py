"""Fleet trace record / replay + empirical calibration.

A trace is a JSONL phase log.  Two row kinds:

  {"kind": "phase", "phase": 0, "policy": "k_of_n", "workers": 24, "k": 20,
   "elapsed": 1.23, "mask": "fffff0", "gb_seconds": 93.1, "invocations": 31,
   "s3_puts": 25.0, "s3_gets": 63.0, "worker_times": [...optional...]}
  {"kind": "charge", "phase": 1, "elapsed": 0.57}

``mask`` is the finished-worker bitmask, big-endian bit-packed and
hex-encoded (worker 0 = MSB of the first byte).  Floats are serialized via
``repr`` (json default), which round-trips IEEE doubles exactly — replaying
a recorded run reproduces bit-identical ``(seconds, dollars)`` totals.

Schema v2 (scheduler-era, every field optional => v1 traces replay
unchanged and v1 readers ignore the new keys):

  - ``memory_gb``: present when the phase was billed at a per-phase Lambda
    size (``run_phase(memory_gb=...)`` override).
  - ``pool``: ``{"warm": w, "cold": c, "free": f}`` when a ``WarmPool`` is
    attached — warm hits / cold starts among this phase's lifecycle
    attempts, and the pool's free-container count after the phase.
  - ``retries`` + ``cold_delays`` (opt-in, ``TraceRecorder(lifecycle=
    True)``): failure-retry count and the drawn cold-start delays of each
    phase — what ``calibrate_fleet_from_trace`` fits a ``FleetConfig``
    (failure rate, cold-start probability and bounds) from.

Schema v3 (chaos-era, again strictly additive => v1/v2 traces replay
unchanged and default recordings stay byte-identical — the new keys only
appear when a ``runtime.faults.FaultPlan`` injected something or a retry
budget exhausted):

  - ``faults``: per-phase injected-event counts — any non-zero subset of
    ``burst_kills`` / ``burst_exposed`` / ``throttled`` /
    ``s3_get_retries`` / ``s3_put_retries`` / ``oom_kills`` /
    ``oom_escalations`` / ``pool_killed`` / ``peak_concurrency``, plus
    ``corrupted`` (hex mask of silently-wrong results) and, under
    ``lifecycle=True``, the drawn ``throttle_waits`` —
    ``calibrate_faults_from_trace`` fits a ``FaultPlan`` back from these.
  - ``exhausted``: how many workers' retry budgets truly ran out
    (``FleetConfig.fail_open=False``).
  - ``raised``: the phase terminated in ``PhaseExhaustedError``; replay
    re-raises after applying the recorded partial time and cost, so a
    replayed algorithm takes the same degradation path.

Schema v4 (tenancy-era, strictly additive):

  - ``provisioned_gb_seconds``: idle provisioned-concurrency GB-seconds
    billed into the phase's ledger entry (shared-pool prewarming under
    ``repro.tenancy``).  Emitted only when nonzero, so single-job
    recordings stay byte-identical to v1–v3 traces.

``worker_times`` (opt-in, ``TraceRecorder(worker_times=True)``) stores the
per-worker completion times of each phase; ``calibrate_from_trace`` fits a
``StragglerModel`` to their empirical shape (median base, lognormal body
spread, tail fraction and span — the paper's Fig. 1 statistics), closing
the loop from a recorded fleet back to a simulator that reproduces it.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Tuple

import numpy as np

from repro.core.straggler import StragglerModel
from repro.runtime.cost import CostLedger


def _mask_to_hex(mask: np.ndarray) -> str:
    return np.packbits(np.asarray(mask, dtype=np.uint8)).tobytes().hex()


def _mask_from_hex(s: str, n: int) -> np.ndarray:
    bits = np.unpackbits(np.frombuffer(bytes.fromhex(s), dtype=np.uint8))
    return bits[:n].astype(bool)


@dataclasses.dataclass
class TraceRecorder:
    """Collects phase rows; ``dump`` writes JSONL.

    ``lifecycle=True`` additionally records each phase's failure-retry
    count and drawn cold-start delays (schema v2) — the raw material for
    ``calibrate_fleet_from_trace``.  Off by default so default recordings
    stay byte-identical to pre-v2 traces."""

    worker_times: bool = False
    lifecycle: bool = False
    rows: List[dict] = dataclasses.field(default_factory=list)

    def record_phase(self, phase: int, *, policy: str, num_workers: int,
                     k: Optional[int], elapsed: float, mask: np.ndarray,
                     entry: CostLedger,
                     worker_times: Optional[np.ndarray] = None,
                     advance: Optional[float] = None,
                     memory_gb: Optional[float] = None,
                     stats: Optional[dict] = None,
                     pool_free: Optional[int] = None,
                     corrupted: Optional[np.ndarray] = None,
                     raised: bool = False) -> None:
        row = {"kind": "phase", "phase": phase, "policy": policy,
               "workers": int(num_workers), "k": k,
               "elapsed": float(elapsed), "mask": _mask_to_hex(mask)}
        if advance is not None and advance != elapsed:
            # Overlapped phase (run_phase not_before=...): the clock moved
            # by less than the phase duration.  Absent for sequential
            # phases so pre-overlap traces replay unchanged.
            row["advance"] = float(advance)
        if memory_gb is not None:
            row["memory_gb"] = float(memory_gb)
        if pool_free is not None:
            # Pool attached: warm/cold split of this phase's lifecycle
            # attempts and the free-container count after the phase.
            row["pool"] = {"warm": int(stats["warm"]) if stats else 0,
                           "cold": int(stats["cold"]) if stats else 0,
                           "free": int(pool_free)}
        if self.lifecycle and stats is not None:
            row["retries"] = int(stats["retries"])
            row["cold_delays"] = [float(t) for t in stats["cold_delays"]]
        # Schema v3: injected-event record, keys only when events happened
        # (a plan-less run writes none of this — byte-identical to v2).
        faults = dict(stats.get("faults") or {}) if stats else {}
        waits = faults.pop("throttle_waits", None)
        frow = {kk: int(v) for kk, v in faults.items() if v}
        if self.lifecycle and waits:
            frow["throttle_waits"] = [float(t) for t in waits]
        if corrupted is not None and corrupted.any():
            frow["corrupted"] = _mask_to_hex(corrupted)
        if frow:
            row["faults"] = frow
        if stats and stats.get("exhausted"):
            row["exhausted"] = int(stats["exhausted"])
        if raised:
            row["raised"] = True
        row.update(entry.as_dict())
        if self.worker_times and worker_times is not None:
            row["worker_times"] = [float(t) for t in worker_times]
        self.rows.append(row)

    def record_charge(self, phase: int, elapsed: float) -> None:
        self.rows.append({"kind": "charge", "phase": phase,
                          "elapsed": float(elapsed)})

    def dump(self, path) -> None:
        with open(path, "w") as f:
            for row in self.rows:
                f.write(json.dumps(row) + "\n")


class TraceReplayer:
    """Replays a recorded trace row-by-row; the engine consumes one row per
    phase()/charge() call and re-applies the recorded time and cost, so a
    replayed run is bit-identical to the recording."""

    def __init__(self, rows: List[dict]):
        self.rows = list(rows)
        self._i = 0

    def _next(self, kind: str) -> dict:
        if self._i >= len(self.rows):
            raise ValueError(f"trace exhausted at row {self._i} "
                             f"(wanted a {kind!r} row)")
        row = self.rows[self._i]
        if row["kind"] != kind:
            raise ValueError(f"trace row {self._i} is {row['kind']!r}, "
                             f"run wanted {kind!r} — phase structure drifted")
        self._i += 1
        return row

    def next_phase(self, *, policy: str, num_workers: int
                   ) -> Tuple[float, np.ndarray, CostLedger, float, dict]:
        row = self._next("phase")
        if row["policy"] != policy or row["workers"] != num_workers:
            raise ValueError(
                f"trace row {self._i - 1} recorded "
                f"({row['policy']!r}, {row['workers']} workers), run asked "
                f"({policy!r}, {num_workers}) — not the same schedule")
        entry = CostLedger(gb_seconds=row["gb_seconds"],
                           invocations=row["invocations"],
                           s3_puts=row["s3_puts"], s3_gets=row["s3_gets"],
                           # Schema v4 (additive): idle provisioned-
                           # concurrency GB-seconds, absent pre-tenancy.
                           provisioned_gb_seconds=row.get(
                               "provisioned_gb_seconds", 0.0))
        return (row["elapsed"], _mask_from_hex(row["mask"], num_workers),
                entry, row.get("advance", row["elapsed"]), row)

    def next_charge(self) -> float:
        return self._next("charge")["elapsed"]


def load_trace(path) -> TraceReplayer:
    with open(path) as f:
        rows = [json.loads(line) for line in f if line.strip()]
    return TraceReplayer(rows)


# --------------------------------------------------------------- calibration
def calibrate_from_times(times, tail_cut: float = 1.25) -> StragglerModel:
    """Fit a StragglerModel to empirical per-worker job times (Fig. 1 shape).

    Workers above ``tail_cut`` x median are stragglers: their fraction gives
    ``p_tail`` and their span the tail bounds; the body's log-spread around
    the median gives ``body_sigma``.  Invocation overhead is not separable
    from a bare completion-time histogram, so it calibrates to 0.
    """
    t = np.asarray(times, dtype=np.float64).ravel()
    if t.size == 0 or not np.all(t > 0):
        raise ValueError("calibration needs positive per-worker times")
    med = float(np.median(t))
    body = t[t <= tail_cut * med]
    tail = t[t > tail_cut * med]
    sigma = float(np.std(np.log(body / med))) if body.size > 1 else 0.05
    p_tail = float(tail.size / t.size)
    if tail.size:
        tail_lo = max(0.05, float(tail.min() / med - 1.0))
        tail_hi = max(tail_lo + 0.05, float(tail.max() / med - 1.0))
    else:
        tail_lo, tail_hi = 0.3, 1.5
    return StragglerModel(base_time=med, body_sigma=max(sigma, 1e-3),
                          p_tail=p_tail, tail_lo=tail_lo, tail_hi=tail_hi,
                          invoke_overhead=0.0)


def calibrate_from_trace(path, tail_cut: float = 1.25) -> StragglerModel:
    """Pool every recorded phase's ``worker_times`` (normalized per phase so
    phases with different work mix) and fit the pooled shape."""
    with open(path) as f:
        rows = [json.loads(line) for line in f if line.strip()]
    pooled, medians = [], []
    for row in rows:
        wt = row.get("worker_times")
        if not wt:
            continue
        wt = np.asarray(wt, dtype=np.float64)
        med = float(np.median(wt))
        if med > 0:
            pooled.append(wt / med)
            medians.append(med)
    if not pooled:
        raise ValueError(f"no worker_times rows in {path}; record with "
                         "TraceRecorder(worker_times=True)")
    scale = float(np.mean(medians))   # representative per-phase base time
    return calibrate_from_times(np.concatenate(pooled) * scale,
                                tail_cut=tail_cut)


def calibrate_fleet_from_trace(path) -> "FleetConfig":
    """Fit a ``FleetConfig`` (failure rate + cold-start statistics) to a
    schema-v2 lifecycle trace (``TraceRecorder(lifecycle=True)``).

    Estimators, over all phase rows:

      - ``failure_rate``: retries / lifecycle launches.  Each lifecycle
        attempt below the retry cap fails independently with rate p, so
        launches per worker are geometric and failures/launches -> p
        (the retry-cap truncation bias is O(p^max_retries)).
      - ``cold_start_prob``: cold starts / lifecycle launches — the i.i.d.
        reading of the trace; a warm-pool trace yields the *effective*
        cold rate its schedule produced, which is the number a pool-less
        simulation of the same workload should use.
      - ``cold_start_lo`` / ``hi``: min / max of the recorded cold delays
        (consistent for the U[lo, hi] the engine draws from).

    The closing loop: a synthetic "public Lambda trace" recorded under a
    known fleet round-trips to that fleet's parameters (see
    ``tests/fixtures/lambda_trace_synthetic.jsonl``).
    """
    from repro.runtime.engine import FleetConfig   # engine does not import us
    with open(path) as f:
        rows = [json.loads(line) for line in f if line.strip()]
    phase_rows = [r for r in rows if r.get("kind") == "phase"]
    if not any("retries" in r for r in phase_rows):
        raise ValueError(
            f"no lifecycle rows in {path}; record with "
            "TraceRecorder(lifecycle=True)")
    launches = 0
    retries = 0
    delays: list = []
    for r in phase_rows:
        if "retries" not in r:
            continue
        retries += int(r["retries"])
        launches += int(r["workers"]) + int(r["retries"])
        delays.extend(r.get("cold_delays", ()))
    if launches == 0:
        raise ValueError(f"lifecycle rows in {path} contain no launches")
    failure_rate = retries / launches
    cold_prob = len(delays) / launches
    if delays:
        lo, hi = float(min(delays)), float(max(delays))
        if hi <= lo:
            hi = lo + 1e-6
    else:
        dflt = FleetConfig()
        lo, hi = dflt.cold_start_lo, dflt.cold_start_hi
    return FleetConfig(failure_rate=failure_rate, cold_start_prob=cold_prob,
                       cold_start_lo=lo, cold_start_hi=hi)


def calibrate_faults_from_trace(path) -> "FaultPlan":
    """Fit a ``runtime.faults.FaultPlan`` to a schema-v3 fault trace.

    The inverse of injection, for the knobs a trace identifies:

      - burst ``kill_fraction``: burst kills / burst-exposed attempts —
        each exposed attempt flips the same seeded coin, so the ratio is
        the maximum-likelihood estimate of the coin.
      - throttle ``max_concurrent``: the max recorded ``peak_concurrency``
        over rows where rejections actually happened — a saturated
        admission heap sits exactly at the cap.
      - throttle ``backoff``: the smallest recorded wait (first-rejection
        waits are ``backoff + U[0, jitter)``, so the min over many waits
        converges on ``backoff`` from above; needs ``lifecycle=True``
        rows).
      - S3 ``get_fail_prob``: GET retries / (launches + GET retries) —
        every try fails independently, so failures over total tries is
        again the ML estimate.

    Windows and seeds are not identifiable from counts alone and come
    back as the estimators' all-time defaults.
    """
    from repro.runtime.faults import (BurstSpec, FaultPlan, S3Spec,
                                      ThrottleSpec)
    with open(path) as f:
        rows = [json.loads(line) for line in f if line.strip()]
    frows = [(r, r["faults"]) for r in rows
             if r.get("kind") == "phase" and r.get("faults")]
    if not frows:
        raise ValueError(f"no fault rows in {path}; record a run with a "
                         "FaultPlan attached")
    kills = sum(f.get("burst_kills", 0) for _, f in frows)
    exposed = sum(f.get("burst_exposed", 0) for _, f in frows)
    burst = (BurstSpec(kill_fraction=kills / exposed) if exposed else None)
    throttle = None
    peaks = [f["peak_concurrency"] for _, f in frows
             if f.get("throttled") and f.get("peak_concurrency")]
    if peaks:
        waits = [w for _, f in frows for w in f.get("throttle_waits", ())]
        kw = {"max_concurrent": int(max(peaks))}
        if waits:
            kw["backoff"] = float(min(waits))
        throttle = ThrottleSpec(**kw)
    s3 = None
    get_retries = sum(f.get("s3_get_retries", 0) for _, f in frows)
    if get_retries:
        launches = sum(int(r["workers"]) + int(r.get("retries", 0))
                       for r, _ in frows)
        s3 = S3Spec(get_fail_prob=get_retries / (launches + get_retries))
    return FaultPlan(burst=burst, throttle=throttle, s3=s3)
