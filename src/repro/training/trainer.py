"""Distributed trainer with the production-run survival kit:

  * pjit train step (TP + FSDP + sequence-parallel activations per
    `distributed.sharding`), AdamW, global-norm clipping;
  * checkpoint/restart: atomic async checkpoints every K steps, automatic
    restore-from-latest, deterministic per-step data (replay-safe);
  * simulated chip failure -> restart loop (`run_with_restarts`), including
    ELASTIC restarts onto a smaller mesh (state is resharded on restore);
  * optional straggler-resilient data-parallel gradients: shard_map over the
    data axis with the paper-derived `resilient_psum` (k-of-n mean instead of
    wait-all) — OverSketch's termination rule applied to DP training.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.core.straggler import StragglerModel
from repro.data.pipeline import TokenPipeline
from repro.distributed import (activation_constraint, batch_shardings,
                               opt_state_shardings, param_shardings,
                               resilient_psum)
from repro.models.registry import ModelBundle, ShapeSpec
from repro.optim import adamw

Pytree = Any


class SimulatedFailure(RuntimeError):
    """Injected chip/worker failure (fault-tolerance tests)."""


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    arch: str
    smoke: bool = True
    steps: int = 50
    batch: int = 8
    seq: int = 128
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 20
    log_every: int = 10
    seed: int = 0
    lr: float = 3e-4
    warmup_steps: int = 20
    resilient_grads: bool = False
    grad_compression: bool = False   # int8 wire format for the DP reduction
    straggler: Optional[StragglerModel] = None
    seq_shard_activations: bool = True


class Trainer:
    def __init__(self, cfg: TrainerConfig, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        from repro.configs import smoke_config
        from repro.models.registry import get_config
        mcfg = smoke_config(cfg.arch) if cfg.smoke else get_config(cfg.arch)
        self.bundle = ModelBundle(mcfg)
        self.mcfg = mcfg
        self.ocfg = adamw.AdamWConfig(lr=cfg.lr, warmup_steps=cfg.warmup_steps,
                                      total_steps=cfg.steps)
        self.ckpt = CheckpointManager(cfg.ckpt_dir) if cfg.ckpt_dir else None

        self.p_shard = param_shardings(self.bundle, mesh)
        shape = ShapeSpec("train", "train", cfg.seq, cfg.batch)
        ins = self.bundle.input_specs(shape, reduced=True)
        self.b_shard = batch_shardings(self.bundle, mesh, ins)
        extra = {k: v for k, v in ins.items()
                 if k in ("frame_embeds", "patch_embeds")}
        self.pipeline = TokenPipeline(
            mcfg.vocab_size, cfg.batch,
            ins["tokens"].shape[1], seed=cfg.seed,
            sharding=self.b_shard, extra_specs=extra)
        self._build_step()

    # ------------------------------------------------------------ stepping --
    def _build_step(self):
        cfg, mesh = self.cfg, self.mesh
        constrain = activation_constraint(
            mesh, cfg.seq_shard_activations) if mesh is not None else None
        opt_shard = opt_state_shardings(self.p_shard, None)

        def loss_fn(params, batch):
            return self.bundle.loss(params, batch, constrain)

        if not cfg.resilient_grads:
            def step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                new_params, new_opt = adamw.apply(self.ocfg, grads,
                                                  opt_state, params)
                gn = adamw.global_norm(grads)
                return new_params, new_opt, {"loss": loss, "grad_norm": gn}

            self.step_fn = jax.jit(
                step,
                in_shardings=(self.p_shard, opt_shard, self.b_shard),
                out_shardings=(self.p_shard, opt_shard, None))
        else:
            # k-of-n resilient DP gradients: params replicated, batch sharded
            # over the data axis; each shard is a "worker" whose contribution
            # can miss the deadline (live=0) — the paper's Alg. 2 termination
            # rule as a gradient all-reduce.
            data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
            repl = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                self.p_shard)

            from repro.distributed.collectives import \
                compressed_resilient_psum
            reduce_fn = compressed_resilient_psum if cfg.grad_compression \
                else resilient_psum

            def shard_grads(params, batch, live):
                def local(params_l, batch_l, live_l):
                    # no sharding constraints inside shard_map: the mesh
                    # axes are manual here
                    loss_l, grads_l = jax.value_and_grad(
                        lambda p, b: self.bundle.loss(p, b, None))(
                            params_l, batch_l)
                    grads_r = reduce_fn(grads_l, live_l[0], data_axes[-1])
                    loss_r = resilient_psum({"l": loss_l}, live_l[0],
                                            data_axes[-1])["l"]
                    return grads_r, loss_r

                batch_specs = jax.tree.map(lambda s: s.spec, self.b_shard)
                return jax.shard_map(
                    local, mesh=mesh,
                    in_specs=(P(), batch_specs, P(data_axes)),
                    out_specs=(P(), P()), check_vma=False)(
                        params, batch, live)

            def step(params, opt_state, batch, live):
                grads, loss = shard_grads(params, batch, live)
                new_params, new_opt = adamw.apply(self.ocfg, grads,
                                                  opt_state, params)
                gn = adamw.global_norm(grads)
                return new_params, new_opt, {"loss": loss, "grad_norm": gn}

            self.step_fn = jax.jit(step)
            self.p_shard = repl
            self._data_axes = data_axes

    def init_state(self) -> Tuple[Pytree, Any]:
        with self.mesh:
            params = jax.jit(
                self.bundle.init,
                out_shardings=self.p_shard)(jax.random.PRNGKey(self.cfg.seed))
            opt_state = adamw.init(params)
        return params, opt_state

    # -------------------------------------------------------------- running --
    def run(self, params, opt_state, start_step: int = 0,
            fail_at: Optional[int] = None) -> Tuple[Pytree, Any, List[Dict]]:
        cfg = self.cfg
        history: List[Dict] = []
        key = jax.random.PRNGKey(cfg.seed + 17)
        n_workers = 1
        if cfg.resilient_grads:
            n_workers = 1
            for a in self._data_axes:
                n_workers *= self.mesh.shape[a]

        with self.mesh:
            for step in range(start_step, cfg.steps):
                if fail_at is not None and step == fail_at:
                    raise SimulatedFailure(f"chip lost at step {step}")
                batch = self.pipeline.device_batch(step)
                t0 = time.perf_counter()
                if cfg.resilient_grads:
                    key, k = jax.random.split(key)
                    if cfg.straggler is not None:
                        times = cfg.straggler.sample_times(k, n_workers)
                        kk = max(1, int(0.9 * n_workers))
                        live = (times <= jnp.sort(times)[kk - 1]).astype(
                            jnp.float32)
                    else:
                        live = jnp.ones((n_workers,), jnp.float32)
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch, live)
                else:
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch)
                dt = time.perf_counter() - t0
                rec = {"step": step, "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "step_time": dt}
                history.append(rec)
                if self.ckpt and (step + 1) % cfg.ckpt_every == 0:
                    self.ckpt.async_save(step + 1, {
                        "params": params, "opt": opt_state})
        if self.ckpt:
            self.ckpt.wait()
        return params, opt_state, history

    def run_with_restarts(self, fail_at: Optional[int] = None,
                          max_restarts: int = 3) -> List[Dict]:
        """Checkpoint-restart driver: a failure resumes from the latest
        checkpoint (or step 0), replaying deterministic data."""
        params, opt_state = self.init_state()
        all_hist: List[Dict] = []
        start, restarts = 0, 0
        while True:
            try:
                params, opt_state, hist = self.run(params, opt_state, start,
                                                   fail_at=fail_at)
                all_hist.extend(hist)
                return all_hist
            except SimulatedFailure:
                restarts += 1
                if restarts > max_restarts:
                    raise
                fail_at = None   # don't re-fail
                latest = self.ckpt.latest_step() if self.ckpt else None
                if latest is not None:
                    state = self.ckpt.restore(
                        latest,
                        {"params": jax.eval_shape(lambda: params),
                         "opt": jax.eval_shape(lambda: opt_state)},
                        {"params": self.p_shard,
                         "opt": opt_state_shardings(self.p_shard, None)})
                    params, opt_state = state["params"], state["opt"]
                    start = latest
                else:
                    params, opt_state = self.init_state()
                    start = 0
