"""OverSketched Newton as a first-class framework feature: train a softmax
readout head / linear probe on frozen backbone features with the paper's
algorithm (its Sec. 4.2 workload at LM scale).

This is the direct application of the paper's technique to the assigned
architecture pool (DESIGN.md §4): the probe objective is (weakly) convex, so
Thms 3.1/3.3 apply, and the Hessian square root has exactly the
matrix-product structure OverSketch accelerates.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import (Dataset, NewtonConfig, OverSketchConfig,
                        SoftmaxRegression, oversketched_newton)
from repro.core.straggler import StragglerModel
from repro.models.registry import ModelBundle
from repro.models import transformer


def extract_features(bundle: ModelBundle, params, tokens: jax.Array,
                     extra=None) -> jax.Array:
    """Frozen-backbone features: mean-pooled final hidden states (B, d)."""
    h, _ = transformer.forward_hidden(bundle.cfg, params, tokens, extra,
                                      remat=False)
    return h.mean(axis=1).astype(jnp.float32)


def train_osn_head(features: jax.Array, labels_onehot: jax.Array, *,
                   num_classes: int, sketch_dim: Optional[int] = None,
                   block_size: int = 128, iters: int = 8,
                   model: Optional[StragglerModel] = StragglerModel(),
                   seed: int = 0) -> Tuple[jax.Array, dict]:
    """Fit W (K, d) on (B, d) features with OverSketched Newton.

    Returns (w_flat, history).  Weakly-convex path (unregularized softmax):
    Newton-MR update + Eq. (6) line search, per the paper.
    """
    b, d = features.shape
    k = num_classes
    sketch_dim = sketch_dim or max(block_size,
                                   block_size * (-(-4 * d * k // block_size)))
    obj = SoftmaxRegression(num_classes=k)
    data = Dataset(x=features, y=labels_onehot)
    cfg = NewtonConfig(
        iters=iters, solver="pinv",
        sketch=OverSketchConfig(sketch_dim, block_size, 0.25),
        coded_block_rows=min(256, max(32, b // 8)), seed=seed)
    res = oversketched_newton(obj, data, jnp.zeros(k * d), cfg, model=model)
    return res.w, res.history
