from repro.training.trainer import Trainer, TrainerConfig, SimulatedFailure
from repro.training.osn_head import extract_features, train_osn_head
