"""Baseline optimizers (paper Sec. 5 comparisons) + the LM AdamW path."""
from repro.optim.first_order import FirstOrderConfig, first_order
from repro.optim.giant import GiantConfig, giant
from repro.optim.exact_newton import exact_newton
from repro.optim.gradient_coding import (assignment, decode_weights,
                                         gradient_coding_phase)
from repro.optim import adamw
