"""Gradient coding (Tandon et al., 2017) — replication-based straggler
mitigation for the gradient phase (paper Fig. 5b baseline).

Each worker holds r data shards (its own plus r-1 neighbours') and sends a
fixed linear combination of its shard gradients; the master recovers the exact
full gradient from ANY W-(r-1) workers.  The price: every worker reads and
processes r shards, so per-worker work AND communication scale by r — exactly
the effect the paper measures (gradient coding loses to mini-batch/ignore on
EPSILON, Fig. 7, because serverless communication dominates).

The decode itself is a deterministic linear combination, so the recovered
gradient equals the exact gradient; for simulation we charge the clock and
return the exact value.  `decode_weights` implements the cyclic-repetition
scheme's combination matrix for verification in tests.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import straggler


def assignment(num_workers: int, redundancy: int) -> np.ndarray:
    """Cyclic shard placement: worker i holds shards i, i+1, ..., i+r-1."""
    return np.stack([(np.arange(num_workers) + j) % num_workers
                     for j in range(redundancy)], axis=1)


def decode_weights(finished: np.ndarray, num_workers: int,
                   redundancy: int) -> Optional[np.ndarray]:
    """Find per-worker combination weights a_w such that
    sum_w a_w * (sum of w's shard gradients) = sum of all shard gradients,
    i.e. solve  A^T a = 1  restricted to finished workers.

    Returns None when the erasure pattern is unrecoverable (needs more than
    r-1 stragglers in a bad pattern)."""
    asn = assignment(num_workers, redundancy)
    b = np.zeros((num_workers, num_workers))
    for w in range(num_workers):
        b[w, asn[w]] = 1.0
    rows = np.where(finished)[0]
    if len(rows) == 0:
        return None
    bf = b[rows]                                  # (F, W_shards)
    target = np.ones(num_workers)
    sol, res, rank, _ = np.linalg.lstsq(bf.T, target, rcond=None)
    if not np.allclose(bf.T @ sol, target, atol=1e-6):
        return None
    weights = np.zeros(num_workers)
    weights[rows] = sol
    return weights


def gradient_coding_phase(clock: Optional[straggler.SimClock],
                          key: jax.Array, num_workers: int,
                          redundancy: int,
                          flops_per_worker: Optional[float] = None) -> None:
    """Charge the clock for one gradient-coded round: any W-(r-1) workers
    suffice, but each does r-fold work and r-fold communication."""
    if clock is None:
        return
    k = max(1, num_workers - (redundancy - 1))
    if flops_per_worker is not None:
        clock.phase(key, num_workers, policy="k_of_n", k=k,
                    flops_per_worker=flops_per_worker * redundancy,
                    comm_units=float(redundancy))
    else:
        clock.phase(key, num_workers, policy="k_of_n", k=k,
                    work_per_worker=float(redundancy),
                    comm_units=float(redundancy))
