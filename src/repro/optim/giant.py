"""GIANT: Globally Improved Approximate Newton Direction (Wang et al., 2018)
— the paper's main second-order serverful baseline (Fig. 4).

Two distributed stages per iteration:
  1. workers compute local gradients from their shard; master sums -> full g;
  2. workers compute a local Newton direction p_i = H_i^{-1} g from their
     *local* Hessian; master averages -> p.

Straggler handling variants (paper Fig. 6): wait_all (uncoded), gcode
(gradient coding on stage 1), ignore (drop stragglers in both stages — the
"mini-batch" curve).  Both stages are scored on the simulated clock.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro import obs, scheduler
from repro.core import solvers, straggler
from repro.core.objectives import Dataset
from repro.optim.gradient_coding import gradient_coding_phase
from repro.runtime.faults import PhaseExhaustedError


@dataclasses.dataclass(frozen=True)
class GiantConfig:
    iters: int = 20
    num_workers: int = 60
    policy: str = "wait_all"     # wait_all | gcode | ignore
    gcode_redundancy: int = 2
    unit_step: bool = True
    cg_iters: int = 30
    # Phase dispatch through the repro.scheduler DAG layer.  GIANT's two
    # stages have a true data edge (the local Newton solves consume the
    # summed gradient), so its iteration DAG is a chain and the DAG
    # schedule reproduces the sequential one bit-for-bit — the degenerate
    # end of the DAG-vs-sequential spectrum, kept as a schedule-equality
    # regression anchor.  Per-phase memory sizing still applies.
    schedule: str = "dag"        # dag | sequential
    phase_memory: bool = False   # bill each stage at its shard working set
    seed: int = 0
    track_test_error: bool = False


def _shard_bounds(n: int, w: int):
    per = -(-n // w)
    return [(i * per, min((i + 1) * per, n)) for i in range(w)]


def giant(objective, data: Dataset, w0: jax.Array, cfg: GiantConfig,
          model: Optional[straggler.StragglerModel] = straggler.StragglerModel()
          ) -> Dict[str, List[float]]:
    """Runs GIANT; requires objective.hess_sqrt + gradient on sub-datasets.

    ``model`` may also be a prebuilt ``straggler.SimClock`` (custom fleet /
    cost / trace config, see ``repro.runtime``)."""
    if cfg.schedule not in ("dag", "sequential"):
        raise ValueError(f"unknown schedule {cfg.schedule!r}")
    key = jax.random.PRNGKey(cfg.seed)
    if isinstance(model, straggler.SimClock):
        clock = model
    else:
        clock = straggler.SimClock(model) if model is not None else None
    n, d = data.x.shape
    bounds = _shard_bounds(n, cfg.num_workers)

    # Pad shards to equal size for a stacked vmap (last shard may be short).
    per = bounds[0][1] - bounds[0][0]
    xs, ys, wts = [], [], []
    for lo, hi in bounds:
        pad = per - (hi - lo)
        xs.append(jnp.pad(data.x[lo:hi], ((0, pad), (0, 0))))
        ys.append(jnp.pad(data.y[lo:hi], ((0, pad),) + ((0, 0),) * (data.y.ndim - 1)))
        wts.append(jnp.pad(jnp.ones(hi - lo), (0, pad)))
    xs, ys, wts = jnp.stack(xs), jnp.stack(ys), jnp.stack(wts)

    def local_grad(x_i, y_i, wt_i, w_vec):
        return jax.grad(lambda wv: objective.masked_value(
            wv, Dataset(x=x_i, y=y_i), wt_i))(w_vec)

    def local_newton(x_i, y_i, wt_i, w_vec, g):
        # Local Hessian via the shard's hess_sqrt (masked rows zeroed).
        a_i = objective.hess_sqrt(w_vec, Dataset(x=x_i, y=y_i))
        a_i = a_i * wt_i[: a_i.shape[0], None] if a_i.shape[0] == x_i.shape[0] \
            else a_i  # softmax hess_sqrt has n*K rows; mask repeats
        scale = x_i.shape[0] / jnp.maximum(wt_i.sum(), 1.0)
        h_i = scale * (a_i.T @ a_i) + \
            (objective.hess_reg + 1e-8) * jnp.eye(d, dtype=a_i.dtype)
        return solvers.psd_solve(h_i, g)

    lg = jax.jit(jax.vmap(local_grad, in_axes=(0, 0, 0, None)))
    ln = jax.jit(jax.vmap(local_newton, in_axes=(0, 0, 0, None, None)))
    val_fn = jax.jit(objective.value)
    grad_fn = jax.jit(objective.gradient)

    hist: Dict[str, List[float]] = {k: [] for k in (
        "iter", "fval", "gnorm", "step", "time", "cost", "test_error")}
    w = jnp.asarray(w0, jnp.float32)

    tel = clock.telemetry if clock is not None else obs.NULL
    run_span = tel.trace.begin(
        "giant", "run", clock.time if clock is not None else 0.0,
        policy=cfg.policy, workers=cfg.num_workers, schedule=cfg.schedule)
    if tel.enabled:
        tel.metrics.gauge("giant.cg_iters").set(cfg.cg_iters)

    grad_flops = 2.0 * per * d                    # local gradient pass
    # GIANT's local solves are CG / Hessian-free (Wang et al.): cg_iters
    # Hessian-vector products over the local shard per iteration.
    newton_flops = 2.0 * per * d * cfg.cg_iters
    # Both stages stream the same (per x d) shard; CG adds a few d-vectors.
    shard_bytes = scheduler.matvec_worker_bytes(per, d)
    shard_mem = (scheduler.lambda_memory_gb(shard_bytes)
                 if cfg.phase_memory else None)
    # True working set, declared unconditionally: inert billing-wise, but
    # an attached fault plan with an OomSpec kills undersized attempts.
    shard_ws = float(shard_bytes) / 2.0 ** 30
    for t in range(cfg.iters):
        key, k1, k2, k3 = jax.random.split(key, 4)
        it_span = tel.trace.begin(
            f"iter{t}", "iteration",
            clock.time if clock is not None else float(t))
        dag = (scheduler.DagRun(clock)
               if cfg.schedule == "dag" and clock is not None else None)

        def phase(k, name, deps, *, policy, kk=None, flops, comm):
            try:
                if dag is not None:
                    # Every dep here is the previous stage — the chain
                    # resolves to the engine's exact sequential path.  A
                    # dep that ran on the direct clock (the gcode round)
                    # has no DAG node; the barrier at the current clock
                    # stands in for its edge.
                    known = tuple(dd for dd in deps if dd in dag.results)
                    return dag.dispatch(scheduler.PhaseSpec(
                        name=name, workers=cfg.num_workers, policy=policy,
                        k=kk, flops_per_worker=flops, comm_units=comm,
                        memory_gb=shard_mem, working_set_gb=shard_ws,
                        deps=known), key=k,
                        sequential=len(known) < len(deps)).mask
                _, mask = clock.phase(k, cfg.num_workers, policy=policy,
                                      k=kk, flops_per_worker=flops,
                                      comm_units=comm, memory_gb=shard_mem,
                                      working_set_gb=shard_ws,
                                      phase_name=name)
                return mask
            except PhaseExhaustedError as e:
                # Fault plan exhausted the retry budget: attempts are
                # billed, the dead shards' results never arrive.  GIANT's
                # stages both average shard-local quantities, so the
                # finite-finisher mask gives honest drop semantics (the
                # "ignore" policy's math, forced by the fleet).
                tel.metrics.counter("giant.exhausted_phases").inc()
                return jnp.asarray(e.mask)

        # --- stage 1: gradient -------------------------------------------
        shard_sizes = wts.sum(axis=1)
        if cfg.policy == "ignore" and clock is not None:
            fin = phase(k1, "grad", (), policy="k_of_n",
                        kk=max(1, int(0.95 * cfg.num_workers)),
                        flops=grad_flops, comm=1.0)
        else:
            fin = jnp.ones((cfg.num_workers,), bool)
            if clock is not None:
                if cfg.policy == "gcode":
                    # Coded gradient round: stays on the direct clock (its
                    # internal schedule predates the DAG layer); the next
                    # stage launches after it either way.
                    gradient_coding_phase(clock, k1, cfg.num_workers,
                                          cfg.gcode_redundancy,
                                          flops_per_worker=grad_flops)
                else:
                    # wait_all's mask is all-True on a healthy fleet; under
                    # an exhausted fault plan it is the finite-finisher
                    # mask and the dead shards drop out of the average.
                    fin = phase(k1, "grad", (), policy="wait_all",
                                flops=grad_flops, comm=1.0)
        g_locals = lg(xs, ys, wts, w)
        finf = fin.astype(jnp.float32)
        weights = finf * shard_sizes
        g = (weights[:, None] * g_locals).sum(0) / jnp.maximum(
            weights.sum(), 1.0)
        # masked_value includes the regularizer per shard; averaging keeps it.

        # --- stage 2: local second-order directions -----------------------
        if cfg.policy == "ignore" and clock is not None:
            fin2 = phase(k2, "local-newton", ("grad",), policy="k_of_n",
                         kk=max(1, int(0.95 * cfg.num_workers)),
                         flops=newton_flops, comm=1.0)
        else:
            fin2 = jnp.ones((cfg.num_workers,), bool)
            if clock is not None:
                fin2 = phase(k2, "local-newton", ("grad",),
                             policy="wait_all", flops=newton_flops,
                             comm=1.0)
        p_locals = ln(xs, ys, wts, w, g)
        fin2f = fin2.astype(jnp.float32)
        p = -(fin2f[:, None] * p_locals).sum(0) / jnp.maximum(fin2f.sum(), 1.0)

        step = 1.0
        if not cfg.unit_step:
            from repro.core import linesearch
            step = float(linesearch.linesearch_strongly_convex(
                objective, data, w, p, g))
            if clock is not None:
                phase(k3, "linesearch", ("local-newton",),
                      policy="wait_all", flops=grad_flops * 6, comm=0.3)
        w = w + step * p

        hist["iter"].append(t)
        hist["fval"].append(float(val_fn(w, data)))
        hist["gnorm"].append(float(jnp.linalg.norm(grad_fn(w, data))))
        hist["step"].append(float(step))
        hist["time"].append(clock.time if clock is not None else float(t + 1))
        hist["cost"].append(clock.dollars if clock is not None else 0.0)
        if tel.enabled and dag is not None and dag.results:
            rep = dag.critical_path()
            tel.trace.set_attrs(it_span,
                                critical_path=list(rep.critical_path),
                                dag_makespan=rep.makespan)
        tel.trace.end(it_span,
                      clock.time if clock is not None else float(t + 1))
        if cfg.track_test_error and data.x_test is not None:
            hist["test_error"].append(
                float(objective.error(w, data.x_test, data.y_test)))
        else:
            hist["test_error"].append(float("nan"))
    hist["w"] = w
    tel.trace.end(run_span,
                  clock.time if clock is not None else float(cfg.iters))
    return hist
