"""AdamW — the framework's standard optimizer for the (non-convex) LM
training path.  Pure-pytree implementation (no optax dependency), with
decoupled weight decay, global-norm clipping and a linear-warmup cosine
schedule; state is a pytree so it checkpoints/reshards like params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init(params: Any) -> AdamWState:
    """First moment in param dtype (bf16-safe); second moment in f32 —
    bf16 cannot represent small squared-gradient magnitudes."""
    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree: Any) -> jax.Array:
    """Global L2 norm WITHOUT flattening: a 1-D reshape of a 2-D-sharded
    array forces GSPMD to all-gather the full tensor (observed: +7 GB/chip);
    an all-axis reduction keeps every shard local."""
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def apply(cfg: AdamWConfig, grads: Any, state: AdamWState,
          params: Any) -> tuple[Any, AdamWState]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    scale = scale.astype(jax.tree.leaves(grads)[0].dtype)
    grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    step = state.step + 1
    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(
        lambda v, g: cfg.b2 * v +
        (1 - cfg.b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)
    mu_hat_scale = 1.0 / (1 - cfg.b1 ** step.astype(jnp.float32))
    nu_hat_scale = 1.0 / (1 - cfg.b2 ** step.astype(jnp.float32))
    lr = schedule(cfg, state.step)

    def upd(p, m, v):
        u = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + cfg.eps)
        return (p - lr * (u + cfg.weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
