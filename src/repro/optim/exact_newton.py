"""Exact Newton baseline (paper Figs. 6-10): full Hessian computed
distributedly with speculative-execution straggler mitigation, i.e.
OverSketched Newton's loop with ``hessian_policy="exact_speculative"``."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.core import newton, straggler
from repro.core.objectives import Dataset


def exact_newton(objective, data: Dataset, w0,
                 iters: int = 20, gradient_policy: str = "coded",
                 seed: int = 0, unit_step: bool = True,
                 solver: str = "auto",
                 model: Optional[straggler.StragglerModel] = straggler.StragglerModel(),
                 track_test_error: bool = False) -> Dict[str, List[float]]:
    cfg = newton.NewtonConfig(
        iters=iters, hessian_policy="exact_speculative",
        gradient_policy=gradient_policy, unit_step=unit_step, solver=solver,
        seed=seed, track_test_error=track_test_error)
    res = newton.oversketched_newton(objective, data, w0, cfg, model=model)
    hist = res.history
    hist["w"] = res.w
    return hist
