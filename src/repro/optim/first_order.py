"""First-order baselines from the paper's experiments (Sec. 5.4):
gradient descent, Nesterov accelerated gradient, mini-batch SGD — each with a
straggler policy and the same simulated-wall-clock accounting as OverSketched
Newton, so convergence-vs-time plots are directly comparable (Fig. 11).

Straggler policies for the gradient phase:
  wait_all   — uncoded, wait for every worker;
  ignore     — mini-batch gradient: drop stragglers' shards (Fig. 5c);
  gcode      — gradient coding (Tandon et al.): exact gradient from any
               W-(r-1) workers at the cost of r-fold data replication
               (Fig. 5b) — modelled by `repro.optim.gradient_coding`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import straggler
from repro.core.objectives import Dataset
from repro.optim.gradient_coding import gradient_coding_phase


@dataclasses.dataclass(frozen=True)
class FirstOrderConfig:
    iters: int = 100
    lr: float = 1.0
    method: str = "gd"              # gd | nag | sgd
    policy: str = "ignore"          # wait_all | ignore | gcode
    num_workers: int = 60
    gcode_redundancy: int = 2       # r: data repeated r times per worker
    momentum: float = 0.9           # NAG
    batch_fraction: float = 0.2     # sgd
    backtracking: bool = True       # backtracking line search (Fig. 11 setup)
    bt_shrink: float = 0.5
    bt_c: float = 1e-4
    bt_max: int = 20
    seed: int = 0
    track_test_error: bool = False


def _worker_shards(n: int, w: int) -> jax.Array:
    """Row -> worker assignment, contiguous shards."""
    per = -(-n // w)
    return jnp.minimum(jnp.arange(n) // per, w - 1)


def _masked_gradient(objective, data: Dataset, w_vec: jax.Array,
                     shard_of_row: jax.Array, finished: jax.Array):
    """Mean gradient over the rows owned by finished workers (mini-batch /
    ignore-stragglers scheme).  Regularizer term included analytically."""
    row_ok = finished[shard_of_row]
    # Weighted data gradient: reuse gradient_via by masking rows via a scaled
    # dataset is wrong for nonlinear objectives; instead compute row-masked.
    g_fn = getattr(objective, "masked_gradient", None)
    if g_fn is not None:
        return g_fn(w_vec, data, row_ok)
    # Generic fallback: autodiff on the masked mean objective.
    def masked_value(wv):
        return objective.masked_value(wv, data, row_ok)
    return jax.grad(masked_value)(w_vec)


def _backtrack(objective, data, w, g, direction, cfg):
    f0 = objective.value(w, data)
    gtd = g @ direction
    t = cfg.lr
    for _ in range(cfg.bt_max):
        if float(objective.value(w + t * direction, data)) <= \
                float(f0 + cfg.bt_c * t * gtd):
            return t
        t *= cfg.bt_shrink
    return t


def first_order(objective, data: Dataset, w0: jax.Array,
                cfg: FirstOrderConfig,
                model: Optional[straggler.StragglerModel] = straggler.StragglerModel()
                ) -> Dict[str, List[float]]:
    key = jax.random.PRNGKey(cfg.seed)
    if isinstance(model, straggler.SimClock):
        clock, model = model, model.model
    else:
        clock = straggler.SimClock(model) if model is not None else None
    n = data.x.shape[0]
    shard_of_row = _worker_shards(n, cfg.num_workers)

    grad_fn = jax.jit(objective.gradient)
    val_fn = jax.jit(objective.value)
    masked_grad_fn = jax.jit(
        lambda wv, ok: _masked_gradient(objective, data, wv, shard_of_row, ok))

    hist: Dict[str, List[float]] = {k: [] for k in (
        "iter", "fval", "gnorm", "step", "time", "cost", "test_error")}
    w = jnp.asarray(w0, jnp.float32)
    velocity = jnp.zeros_like(w)
    d = data.x.shape[1]
    grad_flops = 2.0 * (n / cfg.num_workers) * d

    for t in range(cfg.iters):
        key, kp, kb = jax.random.split(key, 3)
        # Gradient evaluation point (NAG looks ahead).
        w_eval = w + cfg.momentum * velocity if cfg.method == "nag" else w

        if cfg.method == "sgd":
            nb = max(1, int(cfg.batch_fraction * n))
            idx = jax.random.choice(kb, n, (nb,), replace=False)
            sub = Dataset(x=data.x[idx], y=data.y[idx])
            g = objective.gradient(w_eval, sub)
            if clock is not None:
                clock.phase(kp, cfg.num_workers, policy="wait_all",
                            flops_per_worker=grad_flops * cfg.batch_fraction,
                            comm_units=0.5)
        elif cfg.policy == "wait_all" or model is None:
            g = grad_fn(w_eval, data)
            if clock is not None:
                clock.phase(kp, cfg.num_workers, policy="wait_all",
                            flops_per_worker=grad_flops, comm_units=1.0)
        elif cfg.policy == "ignore":
            _, finished = clock.phase(
                kp, cfg.num_workers, policy="k_of_n",
                k=max(1, int(0.95 * cfg.num_workers)),
                flops_per_worker=grad_flops, comm_units=1.0)
            g = masked_grad_fn(w_eval, finished)
        elif cfg.policy == "gcode":
            g = grad_fn(w_eval, data)   # gradient coding recovers it exactly
            gradient_coding_phase(clock, kp, cfg.num_workers,
                                  cfg.gcode_redundancy,
                                  flops_per_worker=grad_flops)
        else:
            raise ValueError(cfg.policy)

        if cfg.backtracking:
            step = _backtrack(objective, data, w_eval, g, -g, cfg)
            if clock is not None:   # line search costs a round (Fig.11 note)
                clock.phase(jax.random.fold_in(kp, 3), cfg.num_workers,
                            policy="wait_all",
                            flops_per_worker=grad_flops * 3, comm_units=0.3)
        else:
            step = cfg.lr

        if cfg.method == "nag":
            velocity = cfg.momentum * velocity - step * g
            w = w + velocity
        else:
            w = w - step * g

        hist["iter"].append(t)
        hist["fval"].append(float(val_fn(w, data)))
        hist["gnorm"].append(float(jnp.linalg.norm(grad_fn(w, data))))
        hist["step"].append(float(step))
        hist["time"].append(clock.time if clock is not None else float(t + 1))
        hist["cost"].append(clock.dollars if clock is not None else 0.0)
        if cfg.track_test_error and data.x_test is not None:
            hist["test_error"].append(
                float(objective.error(w, data.x_test, data.y_test)))
        else:
            hist["test_error"].append(float("nan"))
    hist["w"] = w
    return hist
