import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct stand-ins (no allocation), print
memory/cost analysis and the collective schedule, and emit the roofline
terms (EXPERIMENTS.md §Dry-run / §Roofline read from this output).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --json-out out.json
"""

import argparse
import json
import math
import re
import sys
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import (activation_constraint, batch_shardings,
                               cache_shardings, opt_state_shardings,
                               param_shardings)
from repro.launch.mesh import make_production_mesh
from repro.models.registry import SHAPES, ModelBundle, get_bundle
from repro.optim import adamw

# TPU v5e per-chip constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in the compiled HLO
    (per-device program => per-device collective bytes)."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line.split("(")[0] if "(" in line else line)
        if not m or "=" not in line:
            continue
        # only count op definitions: "%name = <shape(s)> <op>(...)"
        lhs, rhs = line.split("=", 1)
        op_m = _COLL_RE.search(rhs.split("(")[0])
        if not op_m:
            continue
        op = op_m.group(1)
        # result shapes live between '=' and the op name
        result_part = rhs.split(op)[0]
        size = 0.0
        for dt, dims in _SHAPE_RE.findall(result_part):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            size += n * _DTYPE_BYTES[dt]
        out[op] = out.get(op, 0.0) + size
    return out


def sharded_param_bytes(bundle: ModelBundle, mesh) -> float:
    """Analytic per-device parameter bytes under the sharding policy."""
    from repro.distributed.sharding import resolve_pspec
    from repro.models.common import Spec
    total = 0.0
    dtype_bytes = 2 if bundle.cfg.dtype == "bfloat16" else 4
    for s in jax.tree.leaves(bundle.specs(),
                             is_leaf=lambda x: isinstance(x, Spec)):
        spec = resolve_pspec(s.shape, s.axes, mesh)
        denom = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                denom *= mesh.shape[a]
        total += math.prod(s.shape) / denom * dtype_bytes
    return total


def active_param_count(bundle: ModelBundle) -> int:
    """Active (per-token) params — MoE counts k/E of expert weights."""
    from repro.models.common import Spec
    cfg = bundle.cfg
    total = 0
    for path, s in jax.tree_util.tree_flatten_with_path(
            bundle.specs(), is_leaf=lambda x: isinstance(x, Spec))[0]:
        n = math.prod(s.shape)
        name = jax.tree_util.keystr(path)
        if "experts" in s.axes and cfg.num_experts:
            n = int(n * cfg.experts_per_token / cfg.num_experts)
        total += n
    return total


# --------------------------------------------------------------- lowering ----
def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               mesh=None, seq_shard: bool = True,
               remat: bool = True) -> Tuple[Any, Dict[str, Any]]:
    bundle = get_bundle(arch)
    shape = SHAPES[shape_name]
    ok, why = bundle.supports(shape)
    if not ok:
        return None, {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    p_shard = param_shardings(bundle, mesh)
    params_abs = bundle.abstract()
    info: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                            "mesh": dict(mesh.shape),
                            "params": bundle.param_count(),
                            "active_params": active_param_count(bundle)}

    with mesh:
        if shape.kind == "train":
            ins = bundle.input_specs(shape)
            b_shard = batch_shardings(bundle, mesh, ins)
            opt_abs = jax.eval_shape(adamw.init, params_abs)
            opt_shard = opt_state_shardings(p_shard, params_abs)
            ocfg = adamw.AdamWConfig()
            constrain = activation_constraint(mesh, seq_shard)

            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: bundle.loss(p, batch, constrain))(params)
                new_params, new_opt = adamw.apply(ocfg, grads, opt_state,
                                                  params)
                return new_params, new_opt, loss

            lowered = jax.jit(
                train_step,
                in_shardings=(p_shard, opt_shard, b_shard),
                out_shardings=(p_shard, opt_shard, None),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, ins)
            tokens = shape.global_batch * shape.seq_len
            info["model_flops"] = 6 * info["active_params"] * tokens

        elif shape.kind == "prefill":
            ins = bundle.input_specs(shape)
            b_shard = batch_shardings(bundle, mesh, ins)
            cache_abs = jax.eval_shape(
                lambda: bundle.init_cache(shape.global_batch, shape.seq_len))
            c_shard = cache_shardings(bundle.cfg, cache_abs, mesh,
                                      long_context=shape.global_batch == 1)

            def prefill_step(params, cache, batch):
                return bundle.prefill(params, batch["tokens"], cache,
                                      batch.get("patch_embeds",
                                                batch.get("frame_embeds")))

            lowered = jax.jit(
                prefill_step,
                in_shardings=(p_shard, c_shard, b_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            ).lower(params_abs, cache_abs, ins)
            tokens = shape.global_batch * shape.seq_len
            info["model_flops"] = 2 * info["active_params"] * tokens

        else:   # decode
            ins = bundle.input_specs(shape)
            cache_abs = jax.eval_shape(
                lambda: bundle.init_cache(shape.global_batch, shape.seq_len))
            c_shard = cache_shardings(bundle.cfg, cache_abs, mesh,
                                      long_context=shape.global_batch == 1)
            tok_shard = batch_shardings(bundle, mesh, ins)["token"]

            def serve_step(params, cache, token):
                return bundle.decode(params, cache, token)

            lowered = jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard, tok_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            ).lower(params_abs, cache_abs, ins["token"])
            info["model_flops"] = 2 * info["active_params"] * \
                shape.global_batch
    return lowered, info


def analyze(lowered, info: Dict[str, Any]) -> Dict[str, Any]:
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # pre-0.5 jax: one dict per program
        cost = cost[0] if cost else None
    chips = 1
    for v in info["mesh"].values():
        chips *= v
    coll = collective_bytes(compiled.as_text())
    coll_total = sum(coll.values())
    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    bytes_accessed = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    # cost_analysis is per-device for SPMD programs
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    info.update({
        "chips": chips,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_accessed,
        "collective_bytes_per_chip": coll_total,
        "collectives": coll,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "roofline_seconds": terms,
        "bottleneck": max(terms, key=terms.get),
        "model_flops_per_chip": info["model_flops"] / chips,
        "useful_flop_fraction": (info["model_flops"] / chips / flops
                                 if flops else 0.0),
    })
    # Analytic model (XLA:CPU cost_analysis counts loop bodies once — see
    # repro/launch/analytic.py; these are the §Roofline primary numbers).
    try:
        from repro.launch import analytic
        from repro.models.registry import SHAPES, get_config
        costs = analytic.cell_costs(get_config(info["arch"]),
                                    SHAPES[info["shape"]], chips)
        a_terms = {
            "compute": costs.flops_per_chip / PEAK_FLOPS,
            "memory": costs.hbm_bytes_per_chip / HBM_BW,
            "collective": costs.coll_bytes_per_chip / ICI_BW,
        }
        info["analytic"] = {
            "flops_per_chip": costs.flops_per_chip,
            "hbm_bytes_per_chip": costs.hbm_bytes_per_chip,
            "coll_bytes_per_chip": costs.coll_bytes_per_chip,
            "roofline_seconds": a_terms,
            "bottleneck": max(a_terms, key=a_terms.get),
            "mfu_bound": (info["model_flops"] / chips / PEAK_FLOPS) /
                         max(a_terms.values()),
        }
    except Exception as e:   # pragma: no cover
        info["analytic_error"] = str(e)
    return info


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             seq_shard: bool = True, verbose: bool = True) -> Dict[str, Any]:
    lowered, info = lower_cell(arch, shape_name, multi_pod=multi_pod,
                               seq_shard=seq_shard)
    if lowered is None:
        if verbose:
            print(f"[skip] {arch} x {shape_name}: {info['skipped']}")
        return info
    info = analyze(lowered, info)
    if verbose:
        t = info["roofline_seconds"]
        print(f"[ok] {arch} x {shape_name} mesh={info['mesh']} "
              f"flops/chip={info['hlo_flops_per_chip']:.3e} "
              f"bytes/chip={info['hlo_bytes_per_chip']:.3e} "
              f"coll/chip={info['collective_bytes_per_chip']:.3e} "
              f"terms(ms)=[c {1e3*t['compute']:.2f} | m {1e3*t['memory']:.2f}"
              f" | x {1e3*t['collective']:.2f}] bound={info['bottleneck']} "
              f"useful={info['useful_flop_fraction']:.3f}")
        print(f"     memory/chip: args={info['memory']['argument_bytes']/1e9:.2f}GB "
              f"temps={info['memory']['temp_bytes']/1e9:.2f}GB "
              f"outputs={info['memory']['output_bytes']/1e9:.2f}GB "
              f"aliased={info['memory']['alias_bytes']/1e9:.2f}GB")
        if "analytic" in info:
            a = info["analytic"]
            t = a["roofline_seconds"]
            print(f"     analytic: flops/chip={a['flops_per_chip']:.3e} "
                  f"terms(ms)=[c {1e3*t['compute']:.2f} | m "
                  f"{1e3*t['memory']:.2f} | x {1e3*t['collective']:.2f}] "
                  f"bound={a['bottleneck']} mfu_bound={a['mfu_bound']:.3f}")
    return info


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--json-out", type=str, default=None)
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED_ARCHS
    cells = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    results = []
    for arch, shape in cells:
        try:
            results.append(run_cell(arch, shape, multi_pod=args.multi_pod,
                                    seq_shard=not args.no_seq_shard))
        except Exception as e:   # a failing cell is a bug — surface it
            print(f"[FAIL] {arch} x {shape}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            results.append({"arch": arch, "shape": shape,
                            "error": f"{type(e).__name__}: {e}"})
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=1, default=str)
    failed = [r for r in results if "error" in r]
    print(f"\n{len(results) - len(failed)}/{len(results)} cells passed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
