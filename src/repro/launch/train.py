"""Training launcher.

Runs the distributed trainer end-to-end on whatever devices exist (reduced
configs on CPU; the same code path drives a real pod when jax sees TPU
devices).  Fault-tolerance demo: `--fail-at N` injects a chip failure at
step N and the driver restarts from the latest checkpoint.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 30 \
      --batch 4 --seq 128 --ckpt-dir /tmp/ckpt --fail-at 17
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.launch.mesh import make_host_mesh, make_mesh
from repro.training.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen3-4b")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full architecture (default: smoke scale)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a simulated chip failure at this step")
    ap.add_argument("--resilient-grads", action="store_true",
                    help="straggler-resilient k-of-n gradient reduction")
    ap.add_argument("--mesh", type=str, default=None,
                    help='e.g. "2x4" => ("data","model") mesh')
    ap.add_argument("--json-out", type=str, default=None)
    args = ap.parse_args(argv)

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(dims)] if len(dims) <= 2 else \
            ("pod", "data", "model")
        mesh = make_mesh(dims, axes)
    else:
        mesh = make_host_mesh()

    cfg = TrainerConfig(
        arch=args.arch, smoke=not args.full_config, steps=args.steps,
        batch=args.batch, seq=args.seq, lr=args.lr,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resilient_grads=args.resilient_grads)
    trainer = Trainer(cfg, mesh)
    print(f"arch={args.arch} params={trainer.bundle.param_count():,} "
          f"mesh={dict(mesh.shape)} devices={len(jax.devices())}")

    hist = trainer.run_with_restarts(fail_at=args.fail_at)
    for rec in hist:
        if rec["step"] % max(1, cfg.log_every) == 0 or \
                rec["step"] == cfg.steps - 1:
            print(f"step {rec['step']:5d} loss {rec['loss']:.4f} "
                  f"gnorm {rec['grad_norm']:.3f} {rec['step_time']*1e3:.0f}ms")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(hist, f, indent=1)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} over {len(hist)} logged steps")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
