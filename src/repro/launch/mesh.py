"""Production meshes.  Functions, never module-level constants — importing
this module must not touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh helper for tests/examples."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist right now, as a 1-D ("data",) mesh."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",))
