"""Batched serving driver: prefill + decode loop with a continuous-batching
slot manager (requests of different lengths share the decode batch; finished
slots are refilled from the queue).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
      --requests 8 --batch 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelBundle


class BatchedServer:
    """Slot-based continuous batching over a fixed decode batch."""

    def __init__(self, bundle: ModelBundle, params, batch: int,
                 max_seq: int, eos_id: int = 2):
        self.bundle = bundle
        self.params = params
        self.batch = batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self._decode = jax.jit(bundle.decode, donate_argnums=(1,))

    def generate(self, prompts: List[np.ndarray], max_new: int
                 ) -> List[List[int]]:
        """Greedy-decode every prompt; prompts are padded to a common length
        per prefill wave, then decoded together."""
        out: List[List[int]] = [[] for _ in prompts]
        for wave_start in range(0, len(prompts), self.batch):
            wave = prompts[wave_start:wave_start + self.batch]
            pad_b = self.batch - len(wave)
            plen = max(len(p) for p in wave)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, p in enumerate(wave):
                toks[i, plen - len(p):] = p       # left-pad
            cache = self.bundle.init_cache(self.batch, self.max_seq)
            logits, cache = self.bundle.prefill(self.params,
                                                jnp.asarray(toks), cache)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            done = np.zeros(self.batch, bool)
            for _ in range(max_new):
                for i in range(len(wave)):
                    if not done[i]:
                        t = int(tok[i])
                        out[wave_start + i].append(t)
                        if t == self.eos_id:
                            done[i] = True
                if done[:len(wave)].all():
                    break
                logits, cache = self._decode(self.params, cache, tok)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args(argv)

    from repro.configs import smoke_config
    cfg = smoke_config(args.arch)
    bundle = ModelBundle(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    rs = np.random.RandomState(0)
    prompts = [rs.randint(1, cfg.vocab_size - 1,
                          rs.randint(4, args.prompt_len + 1))
               for _ in range(args.requests)]
    server = BatchedServer(bundle, params, args.batch, args.max_seq)
    t0 = time.perf_counter()
    outs = server.generate(prompts, args.max_new)
    dt = time.perf_counter() - t0
    total_new = sum(len(o) for o in outs)
    print(f"served {len(prompts)} requests, {total_new} new tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s on "
          f"{jax.default_backend()})")
    for i, o in enumerate(outs[:4]):
        print(f"  req{i}: prompt[{len(prompts[i])}] -> {o[:12]}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
