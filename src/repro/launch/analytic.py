"""Analytic roofline model (EXPERIMENTS.md §Roofline primary numbers).

Why this exists: XLA:CPU's ``cost_analysis()`` counts while-loop bodies ONCE
(verified: an 8-step scan reports 1/8 the flops of its unrolled twin), and
every layer loop in this codebase is a scan, so the compiled-artifact numbers
underestimate per-step flops/bytes by ~the layer count.  The dry-run
therefore reports BOTH: the HLO numbers (loop-body-once, useful for
schedule/shape inspection) and this analytic model (exact matmul arithmetic
from the architecture config, the numbers the roofline table uses).

Conventions:
  * flops count multiply-adds as 2 ops; attention counts QK^T + PV.
  * train multiplier: fwd + bwd(2x) + sqrt-L remat recompute (~1x) = 4x fwd.
  * per-chip = global / chips for flops (both batch and TP split work);
    HBM bytes and collective bytes are modeled per chip directly.
  * collective model (per chip): Megatron-SP pattern per layer =
    all-gather(h_full) + reduce-scatter(h_full) per matmul block pair, plus
    the DP gradient all-reduce (2x param bytes, ring), plus MoE
    dispatch/return gathers.  ICI time = bytes / 50 GB/s.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.models.common import ModelConfig
from repro.models.registry import ShapeSpec

BF16 = 2
F32 = 4


@dataclasses.dataclass
class CellCosts:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    detail: Dict[str, float]


def _attn_flops_per_token(cfg: ModelConfig, s_eff: float) -> float:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    proj = 2 * d * (h + 2 * kv) * hd + 2 * h * hd * d
    attn = 4 * h * hd * s_eff            # QK^T + PV
    return proj + attn


def _mlp_flops_per_token(cfg: ModelConfig) -> float:
    mats = 3 if cfg.mlp_type == "swiglu" else 2
    return 2 * mats * cfg.d_model * cfg.d_ff


def _moe_flops_per_token(cfg: ModelConfig) -> float:
    d, f, e, k = cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.experts_per_token
    router = 2 * d * e
    experts = k * 3 * 2 * d * f
    dispatch = 4 * k * cfg.moe_capacity_factor * d      # dispatch+combine
    return router + experts + dispatch


def _ssd_flops_per_token(cfg: ModelConfig) -> float:
    d, din, n = cfg.d_model, cfg.ssm_inner, cfg.ssm_state
    h, p, q = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_chunk
    proj = 2 * d * (2 * din + 2 * n + h) + 2 * din * d
    conv = 2 * cfg.ssm_conv * (din + 2 * n)
    # intra-chunk dual form: CB^T (Q*N) + (w .* L) x (Q*H*P) per token
    intra = 2 * q * n + 2 * q * h * p / max(h, 1) * h   # = 2qN + 2qHP
    states = 4 * n * h * p                              # build + apply state
    return proj + conv + intra + states


def _rglru_flops_per_token(cfg: ModelConfig) -> float:
    d, r = cfg.d_model, cfg.rnn_width
    return 2 * d * r * 2 + 2 * r * r * 2 + 10 * r + 2 * r * d


def _layer_mix(cfg: ModelConfig):
    """(n_global_attn, n_local_attn, n_mix) layer counts by kind."""
    n = cfg.num_layers
    if cfg.family == "ssm":
        return 0, 0, n
    if cfg.family == "hybrid":
        n_attn = n // cfg.attn_every
        return 0, n_attn, n - n_attn
    if cfg.local_global_pattern:
        pat = cfg.local_global_pattern + 1
        n_global = n // pat
        return n_global, n - n_global, 0
    return n, 0, 0


def cell_costs(cfg: ModelConfig, shape: ShapeSpec, chips: int,
               mesh_model: int = 16, mesh_data: int = 16,
               mesh=None) -> CellCosts:
    if mesh is not None:
        mesh_model = mesh.shape.get("model", 1)
        mesh_data = mesh.shape.get("data", 1)
        chips = 1
        for v in mesh.shape.values():
            chips *= v
    b, s = shape.global_batch, shape.seq_len
    n_g, n_l, n_m = _layer_mix(cfg)
    d = cfg.d_model

    # ----------------------------------------------------- flops per token --
    def fwd_flops_per_token(s_ctx: float) -> float:
        # causal: mean attended length = s/2 (global), ~window (local)
        f = 0.0
        f += n_g * _attn_flops_per_token(cfg, s_ctx / 2.0)
        f += n_l * _attn_flops_per_token(
            cfg, min(cfg.window_size or s_ctx, s_ctx / 2.0))
        if cfg.family == "ssm":
            f += n_m * _ssd_flops_per_token(cfg)
        elif cfg.family == "hybrid":
            f += n_m * _rglru_flops_per_token(cfg)
            f += cfg.num_layers * _mlp_flops_per_token(cfg)
        elif cfg.family == "moe":
            f += (n_g + n_l) * _moe_flops_per_token(cfg)
        else:
            f += (n_g + n_l) * _mlp_flops_per_token(cfg)
        if cfg.family == "encdec":
            # encoder (bidirectional, full S_enc) amortized per decoder token
            enc = cfg.encoder_layers * (
                _attn_flops_per_token(cfg, cfg.encoder_seq) +
                _mlp_flops_per_token(cfg)) * cfg.encoder_seq / max(s, 1)
            cross = cfg.num_layers * 4 * cfg.num_heads * \
                cfg.resolved_head_dim * cfg.encoder_seq
            f += enc + cross
        return f

    logits_flops = 2 * d * cfg.vocab_size

    if shape.kind == "train":
        tokens = b * s
        total = 4.0 * tokens * (fwd_flops_per_token(s) + logits_flops)
    elif shape.kind == "prefill":
        tokens = b * s
        total = tokens * fwd_flops_per_token(s) + b * logits_flops
    else:  # decode: context length = s
        tokens = b
        total = tokens * (fwd_flops_per_token_decode(cfg, s, n_g, n_l, n_m)
                          + logits_flops)
    flops_per_chip = total / chips

    # -------------------------------------------------- HBM bytes per chip --
    from repro.launch.dryrun import sharded_param_bytes
    from repro.launch.mesh import make_production_mesh
    from repro.models.registry import ModelBundle
    bundle = ModelBundle(cfg)
    try:
        m = mesh if mesh is not None else \
            make_production_mesh(multi_pod=(chips == 512))
        param_bytes_chip = sharded_param_bytes(bundle, m)
    except Exception:   # mesh unavailable (too few devices): policy estimate
        param_bytes_chip = bundle.param_count() * BF16 / mesh_model

    if shape.kind == "train":
        # fwd+bwd read params twice, opt reads/writes moments + params
        opt_bytes = param_bytes_chip * (1 + 2 + 2)   # mu bf16, nu f32 r/w
        act = (b / mesh_data / (2 if chips == 512 else 1)) * s * d * BF16
        act_traffic = act * cfg.num_layers * 6 / max(mesh_model, 1)
        hbm = 3 * param_bytes_chip + opt_bytes + act_traffic
    elif shape.kind == "prefill":
        cache_bytes = _cache_bytes_per_chip(cfg, b, s, chips)
        act = (b * s * d * BF16) / chips
        hbm = param_bytes_chip + cache_bytes + act * cfg.num_layers * 4
    else:
        cache_bytes = _cache_bytes_per_chip(cfg, b, s, chips)
        hbm = param_bytes_chip + cache_bytes
    # ----------------------------------------------- collective bytes/chip --
    if shape.kind == "train":
        h_local = (b / mesh_data / (2 if chips == 512 else 1)) * s * d * BF16
        per_layer = 2 * 2 * h_local            # AG + RS per block pair
        coll = per_layer * cfg.num_layers * 3   # fwd + 2x bwd
        coll += 2 * param_bytes_chip            # DP/pod grad all-reduce
        if cfg.family == "moe":
            coll += cfg.num_layers * 3 * 2 * h_local  # dispatch gathers
    elif shape.kind == "prefill":
        h_local = (b * s / chips) * d * BF16
        coll = 2 * 2 * h_local * cfg.num_layers
    else:
        coll = 2 * b * d * BF16 * cfg.num_layers / max(mesh_data, 1) + \
            b * cfg.vocab_size * F32 / max(chips, 1)
    return CellCosts(flops_per_chip=flops_per_chip,
                     hbm_bytes_per_chip=hbm,
                     coll_bytes_per_chip=coll,
                     detail={"param_bytes_per_chip": param_bytes_chip,
                             "tokens": tokens})


def fwd_flops_per_token_decode(cfg: ModelConfig, s_ctx: int,
                               n_g: int, n_l: int, n_m: int) -> float:
    """Decode reads the whole cache: attention cost is linear in context."""
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    proj = 2 * d * (h + 2 * kv) * hd + 2 * h * hd * d
    f = (n_g + n_l) * proj
    f += n_g * 4 * h * hd * s_ctx
    f += n_l * 4 * h * hd * min(cfg.window_size or s_ctx, s_ctx)
    if cfg.family == "ssm":
        f += n_m * _ssd_flops_per_token(cfg)
    elif cfg.family == "hybrid":
        f += n_m * _rglru_flops_per_token(cfg)
        f += cfg.num_layers * _mlp_flops_per_token(cfg)
    elif cfg.family == "moe":
        f += (n_g + n_l) * _moe_flops_per_token(cfg)
    else:
        f += (n_g + n_l) * _mlp_flops_per_token(cfg)
    if cfg.family == "encdec":
        f += cfg.num_layers * 4 * h * hd * cfg.encoder_seq   # cross attn
    return f


def _cache_bytes_per_chip(cfg: ModelConfig, b: int, s: int,
                          chips: int) -> float:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.family == "ssm":
        per = cfg.num_layers * b * (cfg.ssm_heads * cfg.ssm_head_dim *
                                    cfg.ssm_state + 3 *
                                    (cfg.ssm_inner + 2 * cfg.ssm_state))
        return per * BF16 / min(chips, 16)
    if cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.attn_every
        win = min(cfg.window_size or s, s)
        kv_b = 2 * n_attn * b * win * kv * hd
        rec = (cfg.num_layers - n_attn) * b * cfg.rnn_width * (F32 + 3 * BF16)
        return (kv_b * BF16 + rec) / min(chips, 256)
    n_layers = cfg.num_layers
    if cfg.windowed_decode_cache and cfg.window_size and \
            cfg.local_global_pattern:
        pat = cfg.local_global_pattern + 1
        n_g = n_layers // pat
        n_l = n_layers - n_g
        win = min(cfg.window_size, s)
        total = 2 * b * kv * hd * (n_g * s + n_l * win) * BF16
        return total / min(chips, 256)
    total = 2 * n_layers * b * s * kv * hd * BF16
    if cfg.family == "encdec":
        total += 2 * n_layers * b * cfg.encoder_seq * kv * hd * BF16
    return total / min(chips, 256)
