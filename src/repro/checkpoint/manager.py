"""Checkpoint/restart substrate.

Pytree state -> one .npy per leaf + a JSON manifest (tree structure, shapes,
dtypes, step).  Writes go to a temp directory and are atomically renamed, so
a worker dying mid-save never corrupts the latest checkpoint — the property
the fault-tolerance tests rely on.  Saves can run on a background thread
(async_save) so the train loop isn't blocked; restore places leaves onto the
given shardings (reshard-on-restore = elastic rescale support).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

Pytree = Any

# numpy can't natively serialize bf16/f8; store a bit-compatible view and
# record the logical dtype in the manifest.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name]), name
    return arr, name


def _from_saved(arr: np.ndarray, logical: str) -> np.ndarray:
    if logical in _VIEW_AS:
        return arr.view(getattr(ml_dtypes, logical))
    return arr


def _flatten_with_names(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- saving --
    def save(self, step: int, state: Pytree) -> str:
        names, leaves, _ = _flatten_with_names(state)
        host_leaves = [np.asarray(l) for l in leaves]
        tmp = os.path.join(self.directory, f".tmp-{step}")
        final = os.path.join(self.directory, f"step-{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(zip(names, host_leaves)):
            fname = f"leaf-{i:05d}.npy"
            savable, logical = _to_savable(arr)
            np.save(os.path.join(tmp, fname), savable)
            manifest["leaves"].append({
                "name": name, "file": fname,
                "shape": list(arr.shape), "dtype": logical})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish
        self._gc()
        return final

    def async_save(self, step: int, state: Pytree) -> None:
        """Snapshot to host memory synchronously, write on a thread."""
        self.wait()
        names, leaves, _ = _flatten_with_names(state)
        host = [np.asarray(l) for l in leaves]   # device->host now
        snap = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state), host)
        self._thread = threading.Thread(target=self.save, args=(step, snap))
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:08d}"),
                          ignore_errors=True)

    # ----------------------------------------------------------- restoring --
    def all_steps(self):
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step-"):
                out.append(int(d.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Pytree,
                shardings: Optional[Pytree] = None) -> Pytree:
        """Load ``step`` shaped like ``like``; placed onto ``shardings`` if
        given (which may correspond to a *different* mesh than at save time —
        elastic restore)."""
        path = os.path.join(self.directory, f"step-{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        names, like_leaves, treedef = _flatten_with_names(like)
        assert len(names) == len(manifest["leaves"]), \
            f"checkpoint has {len(manifest['leaves'])} leaves, " \
            f"state needs {len(names)}"
        by_name = {l["name"]: l for l in manifest["leaves"]}
        out = []
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(names))
        for name, like_leaf, shard in zip(names, like_leaves, shard_leaves):
            rec = by_name[name]
            arr = _from_saved(np.load(os.path.join(path, rec["file"])),
                              rec["dtype"])
            expect = tuple(getattr(like_leaf, "shape", arr.shape))
            assert tuple(arr.shape) == expect, \
                f"{name}: ckpt {arr.shape} != state {expect}"
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jax.numpy.asarray(
                    arr, dtype=getattr(like_leaf, "dtype", None)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, like: Pytree,
                       shardings: Optional[Pytree] = None
                       ) -> Optional[Pytree]:
        step = self.latest_step()
        if step is None:
            return None
        return self.restore(step, like, shardings)
