"""Token pipeline for LM training.

Deterministic synthetic corpus (seeded per-step PRNG over a Zipfian token
distribution with induced local structure so the loss actually falls), with
host-side prefetch and device placement onto the batch sharding.  On a real
cluster each host would read its own shard of a tokenized corpus; the
determinism-by-step contract (step -> batch, independent of world size) is
exactly what elastic rescale needs to keep the data order reproducible.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, batch: int, seq: int, *,
                 seed: int = 0, sharding=None, extra_specs: Optional[Dict] = None,
                 prefetch: int = 2):
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.sharding = sharding or {}
        self.extra_specs = extra_specs or {}
        self.prefetch = prefetch
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ batches --
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for a step (restart/elastic-safe)."""
        rs = np.random.RandomState(self.seed * 1_000_003 + step)
        # Zipf-ish marginal + markov-ish structure: next token is previous
        # token + small delta half the time.
        base = rs.zipf(1.5, size=(self.batch, self.seq))
        base = np.minimum(base, self.vocab_size - 2).astype(np.int32)
        shift = np.roll(base, 1, axis=1)
        mix = rs.rand(self.batch, self.seq) < 0.5
        tokens = np.where(mix, np.minimum(shift + 1, self.vocab_size - 1),
                          base)
        labels = np.roll(tokens, -1, axis=1)
        labels[:, -1] = -1
        out = {"tokens": tokens, "labels": labels}
        for name, sds in self.extra_specs.items():
            out[name] = rs.randn(*sds.shape).astype(np.float32) * 0.02
        return out

    def device_batch(self, step: int) -> Dict[str, jax.Array]:
        host = self.batch_at(step)
        out = {}
        for name, arr in host.items():
            shard = self.sharding.get(name)
            out[name] = jax.device_put(arr, shard) if shard is not None \
                else jnp.asarray(arr)
        return out

    # ----------------------------------------------------------- prefetch --
    def start(self, first_step: int) -> None:
        def worker():
            step = first_step
            while not self._stop.is_set():
                try:
                    self._q.put((step, self.device_batch(step)), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue
        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next(self):
        return self._q.get()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2)
