from repro.data.synthetic import (make_logistic_dataset, make_softmax_dataset,
                                  profile_dataset)
from repro.data.pipeline import TokenPipeline
