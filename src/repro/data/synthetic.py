"""Synthetic datasets matching the paper's generative models (Sec. 5.1):
features uniform on [-1, 1]^d, logistic labels from a random ground-truth
model; softmax labels from a random linear model (EMNIST stand-in).
LIBSVM profiles map onto these generators at CPU-scaled sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.objectives import Dataset
from repro.configs.paper import PROFILES, DatasetProfile


def make_logistic_dataset(key: jax.Array, n: int, d: int,
                          n_test: int = 0, cond: float = 1.0,
                          sorted_layout: bool = False) -> Dataset:
    """cond > 1 scales feature columns by a geometric spectrum so the
    problem's condition number grows — the regime where second-order
    methods shine over GD/NAG (paper Fig. 11).

    sorted_layout=True stores rows sorted by margin — the non-iid shard
    layout real cloud datasets have (S3 objects are not globally shuffled).
    Contiguous worker shards then see different local curvature, which is
    what separates locally-averaged second-order methods (GIANT) from the
    globally-sketched Hessian (paper Remark 2)."""
    kx, kw, kb, ky, kxt, kyt = jax.random.split(key, 6)
    w = jax.random.normal(kw, (d,))
    b = jax.random.normal(kb, ())
    scales = jnp.geomspace(1.0, 1.0 / max(cond, 1.0), d)

    def sample(kx_, ky_, m):
        x = jax.random.uniform(kx_, (m, d), minval=-1.0, maxval=1.0) * scales
        p = jax.nn.sigmoid(x @ w + b)
        y = jnp.where(jax.random.uniform(ky_, (m,)) < p, 1.0, -1.0)
        return x, y

    x, y = sample(kx, ky, n)
    if sorted_layout:
        order = jnp.argsort(x @ w)
        x, y = x[order], y[order]
    if n_test:
        xt, yt = sample(kxt, kyt, n_test)
        return Dataset(x=x, y=y, x_test=xt, y_test=yt)
    return Dataset(x=x, y=y)


def make_softmax_dataset(key: jax.Array, n: int, d: int, k: int,
                         n_test: int = 0) -> Dataset:
    kx, kw, ky, kxt, kyt = jax.random.split(key, 5)
    w = jax.random.normal(kw, (k, d))

    def sample(kx_, ky_, m):
        x = jax.random.normal(kx_, (m, d))
        y = jax.nn.one_hot(jax.random.categorical(ky_, x @ w.T), k)
        return x, y

    x, y = sample(kx, ky, n)
    if n_test:
        xt, yt = sample(kxt, kyt, n_test)
        return Dataset(x=x, y=y, x_test=xt, y_test=yt)
    return Dataset(x=x, y=y)


def profile_dataset(name: str, key: jax.Array, *,
                    full_scale: bool = False) -> Dataset:
    """Dataset for a paper profile at bench (default) or full scale."""
    prof: DatasetProfile = PROFILES[name]
    n = prof.n_train if full_scale else prof.bench_n
    d = prof.n_features if full_scale else prof.bench_d
    nt = prof.n_test if full_scale else prof.bench_test
    if prof.n_classes > 2:
        return make_softmax_dataset(key, n, d, prof.n_classes, nt)
    # Real-dataset stand-ins use the realistic non-iid storage layout and
    # mild ill-conditioning.
    return make_logistic_dataset(key, n, d, nt, cond=10.0,
                                 sorted_layout=True)
