"""Noise-aware regression diff over BENCH payloads / store snapshots.

``BENCH_*.json`` rows carry two kinds of numbers with very different
noise profiles:

  - ``us`` (wall-clock microseconds) — noisy on shared CI runners, so the
    gate uses a generous relative threshold plus an absolute floor
    (a 40 us -> 60 us jitter on a trivial row is not a regression).
  - simulated metrics in ``derived`` (``sim_s``, ``usd``, ``gb_s``) —
    deterministic given the same jax version, so drift there is a real
    behaviour change and the threshold is tight.

``diff_bench`` matches rows by name, classifies each as ``ok`` /
``regression`` / ``improvement`` / ``added`` / ``removed``, and returns a
``DiffReport`` with the table/summary renderers ``make_report --diff``
uses.  Per-row threshold overrides let known-noisy rows (prefix match)
carry their own tolerance.

The CLI is the CI regression gate::

    python -m repro.obs.diff BASE.json NEW.json [--gate]
    python -m repro.obs.diff --store artifacts/bench_history.jsonl \\
        --name kernels_bench [--gate]

Without ``--gate`` it always exits 0 (report-only — how the gate first
lands in CI); with ``--gate`` it exits 2 when any material regression
survives the thresholds, which is what flips the bench trajectory from
"archived" to "guarded".
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

#: Wall-clock (us) default thresholds: generous, CI runners are shared.
DEFAULT_REL_TOL = 0.35
DEFAULT_ABS_FLOOR_US = 50.0
#: Deterministic simulated metrics ride in ``derived``; tight threshold.
SIM_KEYS = ("sim_s", "usd", "gb_s", "seq_s")
DEFAULT_SIM_REL_TOL = 0.02


def parse_derived(derived: str) -> Dict[str, float]:
    """Numeric k=v pairs out of a ``derived`` blob; non-numeric skipped."""
    out: Dict[str, float] = {}
    for part in str(derived or "").split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            continue
    return out


@dataclasses.dataclass
class RowDiff:
    """One matched row's verdict."""

    name: str
    status: str            # ok | regression | improvement | added | removed
    base_us: float = float("nan")
    new_us: float = float("nan")
    ratio: float = float("nan")          # new/base wall-clock
    detail: str = ""                     # which threshold fired, or ""

    def as_row(self) -> Tuple[object, ...]:
        return (self.name, self.status, self.base_us, self.new_us,
                self.ratio, self.detail)


@dataclasses.dataclass
class DiffReport:
    rows: List[RowDiff]
    base_meta: dict
    new_meta: dict

    @property
    def regressions(self) -> List[RowDiff]:
        return [r for r in self.rows if r.status == "regression"]

    @property
    def improvements(self) -> List[RowDiff]:
        return [r for r in self.rows if r.status == "improvement"]

    def table(self, only_changed: bool = False) -> str:
        from repro.obs.export import format_table
        rows = [r for r in self.rows
                if not only_changed or r.status != "ok"]
        return format_table(("row", "status", "base_us", "new_us", "ratio",
                             "detail"), [r.as_row() for r in rows])

    def summary(self) -> str:
        n = {"ok": 0, "regression": 0, "improvement": 0, "added": 0,
             "removed": 0}
        for r in self.rows:
            n[r.status] += 1
        ident = " vs ".join(
            f"{m.get('git_sha', '?')}/{m.get('backend', '?')}"
            for m in (self.base_meta, self.new_meta))
        return (f"diff {ident}: {n['regression']} regression(s), "
                f"{n['improvement']} improvement(s), {n['ok']} ok, "
                f"{n['added']} added, {n['removed']} removed")

    def to_json(self) -> dict:
        return {"summary": self.summary(),
                "regressions": [r.name for r in self.regressions],
                "rows": [dataclasses.asdict(r) for r in self.rows]}


def _row_tol(name: str, rel_tol: float,
             per_row: Optional[Dict[str, float]]) -> float:
    """Longest-prefix per-row override, else the global tolerance."""
    if per_row:
        best = None
        for prefix, tol in per_row.items():
            if name.startswith(prefix) and \
                    (best is None or len(prefix) > len(best[0])):
                best = (prefix, tol)
        if best is not None:
            return best[1]
    return rel_tol


def diff_rows(base_rows: Sequence[dict], new_rows: Sequence[dict], *,
              rel_tol: float = DEFAULT_REL_TOL,
              abs_floor_us: float = DEFAULT_ABS_FLOOR_US,
              sim_rel_tol: float = DEFAULT_SIM_REL_TOL,
              per_row: Optional[Dict[str, float]] = None) -> List[RowDiff]:
    """Match rows by name and classify; see module docstring for the
    noise model.  Smaller is better for ``us`` and every SIM_KEY."""
    base = {r["name"]: r for r in base_rows}
    new = {r["name"]: r for r in new_rows}
    out: List[RowDiff] = []
    for name in base:
        if name not in new:
            out.append(RowDiff(name=name, status="removed",
                               base_us=float(base[name]["us"])))
    for name, nr in new.items():
        if name not in base:
            out.append(RowDiff(name=name, status="added",
                               new_us=float(nr["us"])))
            continue
        br = base[name]
        b_us, n_us = float(br["us"]), float(nr["us"])
        tol = _row_tol(name, rel_tol, per_row)
        ratio = n_us / b_us if b_us else float("inf")
        status, detail = "ok", ""
        if n_us > b_us * (1.0 + tol) and n_us - b_us > abs_floor_us:
            status = "regression"
            detail = f"us +{100 * (ratio - 1):.0f}% > {100 * tol:.0f}%"
        elif n_us < b_us * (1.0 - tol) and b_us - n_us > abs_floor_us:
            status, detail = "improvement", f"us {100 * (ratio - 1):.0f}%"
        # Deterministic simulated metrics: tight, overrides wall-clock ok.
        bd, nd = parse_derived(br.get("derived", "")), \
            parse_derived(nr.get("derived", ""))
        for key in SIM_KEYS:
            if key not in bd or key not in nd or bd[key] == 0:
                continue
            drift = nd[key] / bd[key] - 1.0
            if drift > sim_rel_tol:
                status = "regression"
                detail = (detail + "; " if detail else "") + \
                    f"{key} +{100 * drift:.1f}% > {100 * sim_rel_tol:.1f}%"
            elif drift < -sim_rel_tol and status == "ok":
                status = "improvement"
                detail = f"{key} {100 * drift:.1f}%"
        out.append(RowDiff(name=name, status=status, base_us=b_us,
                           new_us=n_us, ratio=ratio, detail=detail))
    out.sort(key=lambda r: ({"regression": 0, "improvement": 1, "added": 2,
                             "removed": 3, "ok": 4}[r.status], r.name))
    return out


def diff_bench(base_payload: dict, new_payload: dict, **kw) -> DiffReport:
    """Diff two BENCH payloads (or store ``bench`` records — both carry
    ``rows`` and key/meta fields)."""

    def meta(p):
        return p.get("meta") or {k: p.get(k) for k in
                                 ("git_sha", "backend", "jax_version",
                                  "config_hash")}
    return DiffReport(rows=diff_rows(base_payload.get("rows", []),
                                     new_payload.get("rows", []), **kw),
                      base_meta=meta(base_payload),
                      new_meta=meta(new_payload))


def diff_store(store_path, name: str, **kw) -> Optional[DiffReport]:
    """Diff the last two store snapshots for ``name`` (None if < 2)."""
    from repro.obs.store import Store
    pair = Store(store_path).last_two(kind="bench", name=name)
    if pair is None:
        return None
    return diff_bench(pair[0], pair[1], **kw)


# ----------------------------------------------------------------------- CLI
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.diff",
        description="noise-aware BENCH regression diff / CI gate")
    ap.add_argument("base", nargs="?", help="base BENCH_*.json")
    ap.add_argument("new", nargs="?", help="new BENCH_*.json")
    ap.add_argument("--store", default=None,
                    help="diff the last two store records instead of files")
    ap.add_argument("--name", default=None,
                    help="bench module name inside --store")
    ap.add_argument("--rel-tol", type=float, default=DEFAULT_REL_TOL)
    ap.add_argument("--abs-floor-us", type=float,
                    default=DEFAULT_ABS_FLOOR_US)
    ap.add_argument("--sim-rel-tol", type=float, default=DEFAULT_SIM_REL_TOL)
    ap.add_argument("--gate", action="store_true",
                    help="exit 2 on material regressions (default: report "
                         "only)")
    ap.add_argument("--all-rows", action="store_true",
                    help="print every row, not just changed ones")
    ap.add_argument("--json", dest="json_out", default=None,
                    help="also write the machine-readable verdict here")
    args = ap.parse_args(argv)

    kw = dict(rel_tol=args.rel_tol, abs_floor_us=args.abs_floor_us,
              sim_rel_tol=args.sim_rel_tol)
    if args.store is not None:
        if args.name is None:
            ap.error("--store needs --name")
        report = diff_store(args.store, args.name, **kw)
        if report is None:
            print(f"store has < 2 '{args.name}' records — nothing to diff "
                  "(gate passes vacuously)")
            return 0
    else:
        if not (args.base and args.new):
            ap.error("pass BASE NEW files or --store/--name")
        with open(args.base) as f:
            base = json.load(f)
        with open(args.new) as f:
            new = json.load(f)
        report = diff_bench(base, new, **kw)

    print(report.summary())
    print(report.table(only_changed=not args.all_rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(report.to_json(), f, indent=1)
    if args.gate and report.regressions:
        print(f"GATE FAILED: {len(report.regressions)} material "
              "regression(s)", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
