"""Fleet telemetry: span tracing, metrics, Perfetto export, critical path.

The observability layer for the simulated serverless stack.  One
``Telemetry`` object bundles a hierarchical span tracer (run -> iteration
-> DAG phase -> per-worker lifecycle attempt, all stamped on the
*simulated* clock) with a metrics registry (counters / gauges /
histograms); exporters render the result as a Perfetto-loadable Chrome
trace, a JSONL dump, or summary tables.

The default everywhere is ``obs.NULL`` — a zero-overhead no-op whose
methods return immediately, draw no randomness, and read no clock, so
attaching or detaching telemetry never changes a single simulated
``(seconds, dollars)`` total (the golden-trace tests pin this).

Attach points (see ``src/repro/obs/README.md`` for the span model and
metric names):

    tel = obs.Telemetry()
    clock = SimClock(model, telemetry=tel)        # fleet + scheduler seams
    res = oversketched_newton(obj, data, w0, cfg, model=clock)
    obs.perfetto.dump(obs.to_perfetto(tel.trace.spans), "run.perfetto.json")
    print(obs.phase_table(obs.telemetry_rows(tel)))
"""
from repro.obs.critical_path import (CriticalPathReport, PhaseSlack,
                                     critical_path, from_dag)
from repro.obs.export import (alert_table, alerts_from_rows,
                              bench_rows_table, critical_path_table,
                              dag_reports_from_rows, detector_table,
                              dump_jsonl, format_table, load_jsonl,
                              phase_summary_rows, phase_table,
                              telemetry_rows)
from repro.obs.health import (Alert, Cusum, HealthMonitors, RobustZScore,
                              Rule, default_rules)
from repro.obs.incident import (CAUSES, Evidence, Incident, IncidentConfig,
                                attribute, attribute_rows, dump_incidents,
                                incident_rows, incident_table)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               NullMetrics)
from repro.obs.perfetto import (counter_series, dumps_stable, to_perfetto,
                                validate_file, validate_trace)
from repro.obs.perfetto import dump as dump_perfetto
from repro.obs.console import render as render_console
from repro.obs.console import write_console
from repro.obs.diff import DiffReport, RowDiff, diff_bench, diff_rows, diff_store
from repro.obs.slo import SloPolicy, SloTracker
from repro.obs.span import NullTracer, Span, SpanTracer
from repro.obs.store import (Store, bench_record, config_hash, git_sha,
                             run_record)


class Telemetry:
    """A live tracer + metrics registry pair; pass to ``SimClock``.

    ``monitors`` optionally attaches a ``health.HealthMonitors`` (or
    ``monitors=True`` for the default rule set): the streaming anomaly
    detectors then watch every metric update and record ``Alert``s —
    still pure observation, the simulation cannot tell the difference.
    """

    enabled = True

    def __init__(self, monitors=None):
        self.trace = SpanTracer()
        # Gauges/histograms timestamp their points off the span tracer's
        # simulated-clock high-water mark — what counter tracks and SLO
        # burn charts plot against.
        self.metrics = MetricsRegistry(
            timesource=lambda: self.trace.last_time)
        self.health = None
        # Set by repro.obs.incident.attribute / repro.tenancy's scheduler
        # when those planes run; exports pick them up via getattr.
        self.incidents = None
        self.slo = None
        if monitors is True:
            monitors = HealthMonitors()
        if monitors is not None:
            monitors.attach(self)


class _NullTelemetry:
    """The zero-overhead default: both halves are no-ops."""

    enabled = False
    health = None
    incidents = None
    slo = None

    def __init__(self):
        self.trace = NullTracer()
        self.metrics = NullMetrics()


NULL = _NullTelemetry()


__all__ = [
    "Telemetry", "NULL",
    "Span", "SpanTracer", "NullTracer",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NullMetrics",
    "Alert", "Cusum", "HealthMonitors", "RobustZScore", "Rule",
    "default_rules",
    "Store", "bench_record", "run_record", "config_hash", "git_sha",
    "DiffReport", "RowDiff", "diff_bench", "diff_rows", "diff_store",
    "CriticalPathReport", "PhaseSlack", "critical_path", "from_dag",
    "to_perfetto", "dumps_stable", "dump_perfetto", "validate_trace",
    "validate_file", "counter_series",
    "telemetry_rows", "dump_jsonl", "load_jsonl", "format_table",
    "phase_table", "phase_summary_rows", "critical_path_table",
    "dag_reports_from_rows", "bench_rows_table",
    "alert_table", "alerts_from_rows", "detector_table",
    "CAUSES", "Evidence", "Incident", "IncidentConfig", "attribute",
    "attribute_rows", "dump_incidents", "incident_rows", "incident_table",
    "SloPolicy", "SloTracker",
    "render_console", "write_console",
]
