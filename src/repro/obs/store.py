"""Cross-run telemetry warehouse: an append-only JSONL store of per-run
summary records.

PR 6 made every run emit rich telemetry, but it all evaporated at process
exit: ``BENCH_*.json`` is overwritten per run and nothing kept per-run
metric snapshots.  This module is the persistence layer on top — one
JSONL file, one summary record per line, keyed by::

    (name, backend, jax_version, git_sha, config_hash)

so records from different machines, jax versions, and commits coexist in
one history and can be queried back out.  Two record kinds:

  - ``kind: "run"`` (``run_record``) — built from a live ``Telemetry``:
    the metrics snapshot, per-phase time/dollar aggregates, per-iteration
    critical-path stats, straggler completion-tail quantiles
    (p50/p95/p99, exact — the registry keeps full samples), survivor
    counts per sketch round, health-monitor alerts, and the kernel
    wall-clock profiler's measured per-path timings.  The last two tables
    are exactly what the ROADMAP's kernel auto-router and analytic launch
    planner need: measured path timings and PAST iterations' survivor
    statistics.
  - ``kind: "bench"`` (``bench_record``) — built from a ``BENCH_*.json``
    payload (rows + meta); legacy payloads without ``git_sha`` /
    ``config_hash`` are backfilled with ``"unknown"``, the same
    convention PR 4 used for the ``path`` field.

CLI (used by CI to maintain the bench history artifact)::

    python -m repro.obs.store append BENCH_kernels.json \\
        --store artifacts/bench_history.jsonl
    python -m repro.obs.store show --store artifacts/bench_history.jsonl
    python -m repro.obs.store history --store ... --name kernels_bench \\
        --row fused_gram_oversketch

``repro.obs.diff`` consumes the same store for history-aware regression
gating.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import subprocess
import sys
from typing import Dict, List, Optional

#: The identity fields every record carries; "unknown" when unavailable.
KEY_FIELDS = ("name", "backend", "jax_version", "git_sha", "config_hash")


def git_sha(cwd: Optional[str] = None) -> str:
    """Short git SHA of the working tree, or ``"unknown"`` outside a repo
    (or without git on PATH) — keys must never fail to stamp."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def config_hash(config: object) -> str:
    """Canonical 12-hex-digit hash of a JSON-able config blob.

    Canonical = sorted keys, minimal separators — the same dict hashes
    identically on any machine and Python, which is what makes the hash a
    usable cross-machine store/diff key.
    """
    blob = json.dumps(config, sort_keys=True, separators=(",", ":"),
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


# ------------------------------------------------------------ record builders
def _tail_quantiles(hist) -> Dict[str, float]:
    return {"p50": hist.percentile(50), "p95": hist.percentile(95),
            "p99": hist.percentile(99), "count": hist.count}


def run_record(name: str, telemetry, *, backend: str = "unknown",
               jax_version: str = "unknown", sha: str = "unknown",
               cfg_hash: str = "unknown", extra: Optional[dict] = None
               ) -> dict:
    """Summarize one live ``Telemetry`` into a store record.

    Reads the registry directly (full histogram samples, so the tail
    quantiles are exact) plus the span tree for phase aggregates and the
    per-iteration critical-path attrs the optimizer attached.
    """
    from repro.obs.export import phase_summary_rows

    reg = telemetry.metrics
    rec: dict = {"kind": "run", "name": name, "backend": backend,
                 "jax_version": jax_version, "git_sha": sha,
                 "config_hash": cfg_hash,
                 "metrics": reg.snapshot()}

    comp = reg.histograms.get("worker.completion_s")
    if comp is not None and comp.count:
        rec["straggler_tail"] = _tail_quantiles(comp)
    surv = reg.histograms.get("sketch.survivors")
    if surv is not None and surv.count:
        # Survivor counts per sketch round — the launch planner's
        # straggler-aware provisioning statistic (ROADMAP).
        rec["survivors"] = {"per_round": [float(v) for v in surv.values],
                            **_tail_quantiles(surv)}

    phase_rows = [s.as_row() for s in telemetry.trace.spans
                  if s.kind in ("phase", "charge")]
    if phase_rows:
        rec["phases"] = phase_summary_rows(phase_rows)

    cps = []
    for s in telemetry.trace.spans:
        if s.kind == "iteration" and "critical_path" in s.attrs:
            cps.append({"iteration": s.name,
                        "critical_path": list(s.attrs["critical_path"]),
                        "makespan": s.attrs.get("dag_makespan"),
                        "slack": s.attrs.get("slack", {})})
    if cps:
        rec["critical_paths"] = cps

    # Multi-tenant fleet aggregates (repro.tenancy): job latency tail +
    # admission counters, present only when a JobScheduler drove the run.
    lat = reg.histograms.get("job.latency_s")
    if lat is not None and lat.count:
        rec["fleet_jobs"] = {"latency": _tail_quantiles(lat),
                             **{n.split(".", 1)[1]: c.value
                                for n, c in sorted(reg.counters.items())
                                if n.startswith("jobs.")}}
        qw = reg.histograms.get("job.queue_wait_s")
        if qw is not None and qw.count:
            rec["fleet_jobs"]["queue_wait"] = _tail_quantiles(qw)

    # Measured kernel wall-clock per path/op (ops.set_profiler hook) —
    # the table a data-driven fused_path() router reads.
    kernel_us = {n: h.summary() for n, h in sorted(reg.histograms.items())
                 if n.startswith("kernel.") and n.endswith(".us")}
    if kernel_us:
        rec["kernel_us"] = kernel_us
    kernel_paths = {n: c.value for n, c in sorted(reg.counters.items())
                    if n.startswith("kernel.path.")}
    if kernel_paths:
        rec["kernel_paths"] = kernel_paths

    health = getattr(telemetry, "health", None)
    if health is not None:
        rec["alerts"] = [a.as_row() for a in health.alerts]
        rec["health"] = health.summary()

    # Attributed incidents (repro.obs.incident) and per-tenant SLO budget
    # state (repro.obs.slo), when the run carried them — the cross-run
    # store is where "which cause recurs across commits?" gets answered.
    incidents = getattr(telemetry, "incidents", None)
    if incidents:
        rec["incidents"] = [inc.as_row() for inc in incidents]
    slo = getattr(telemetry, "slo", None)
    if slo is not None:
        rec["slo"] = slo.summary()

    if extra:
        rec.update(extra)
    return rec


def bench_record(payload: dict, *, sha: Optional[str] = None,
                 cfg_hash: Optional[str] = None) -> dict:
    """Summarize one ``BENCH_*.json`` payload (meta + rows) into a store
    record.  Meta fields missing from legacy payloads are backfilled with
    ``"unknown"`` so old baselines still key (PR 4's ``path`` precedent).
    """
    meta = dict(payload.get("meta", {}))
    rows = []
    for r in payload.get("rows", []):
        rows.append({"name": r["name"], "us": float(r["us"]),
                     "path": r.get("path", "unknown"),
                     "derived": r.get("derived", "")})
    return {"kind": "bench",
            "name": meta.get("module", "unknown"),
            "backend": meta.get("backend", "unknown"),
            "jax_version": meta.get("jax_version", "unknown"),
            "git_sha": sha if sha is not None
            else meta.get("git_sha", "unknown"),
            "config_hash": cfg_hash if cfg_hash is not None
            else meta.get("config_hash", "unknown"),
            "profile": meta.get("profile", "unknown"),
            "utc": meta.get("utc", "unknown"),
            "rows": rows}


# ----------------------------------------------------------------- the store
class Store:
    """Append-only JSONL warehouse of run/bench summary records."""

    def __init__(self, path):
        self.path = pathlib.Path(path)

    def append(self, record: dict) -> dict:
        missing = [k for k in KEY_FIELDS if k not in record]
        if missing:
            raise ValueError(f"record missing key fields {missing}")
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(record, sort_keys=True) + "\n")
        return record

    def records(self, kind: Optional[str] = None, **filters) -> List[dict]:
        """All records, file order (= append order), optionally filtered
        by ``kind`` and exact key-field values (``name="kernels_bench"``)."""
        if not self.path.exists():
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                if not line.strip():
                    continue
                rec = json.loads(line)
                if kind is not None and rec.get("kind") != kind:
                    continue
                if any(rec.get(k) != v for k, v in filters.items()):
                    continue
                out.append(rec)
        return out

    def latest(self, kind: Optional[str] = None, **filters
               ) -> Optional[dict]:
        recs = self.records(kind=kind, **filters)
        return recs[-1] if recs else None

    def last_two(self, kind: Optional[str] = None, **filters
                 ) -> Optional[tuple]:
        """(previous, latest) — the pair the regression gate diffs."""
        recs = self.records(kind=kind, **filters)
        return (recs[-2], recs[-1]) if len(recs) >= 2 else None

    def history(self, row: str, **filters) -> List[dict]:
        """Time series of one bench row across records: the perf
        trajectory for a single kernel/bench shape."""
        out = []
        for rec in self.records(kind="bench", **filters):
            for r in rec.get("rows", []):
                if r["name"] == row:
                    out.append({"git_sha": rec["git_sha"],
                                "utc": rec.get("utc", "unknown"),
                                "us": r["us"], "path": r["path"]})
        return out

    def kernel_path_table(self, name: str = "kernels_bench", **filters
                          ) -> Dict[str, dict]:
        """Latest measured per-row timings ``{row: {us, path}}`` — the
        persisted table the kernel auto-router consults instead of
        assuming the fused path always wins (ROADMAP: measured kernel
        auto-routing)."""
        rec = self.latest(kind="bench", name=name, **filters)
        if rec is None:
            return {}
        return {r["name"]: {"us": r["us"], "path": r["path"]}
                for r in rec.get("rows", [])}


# ----------------------------------------------------------------------- CLI
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.obs.store",
        description="append/inspect the cross-run bench+telemetry store")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_append = sub.add_parser("append", help="append a BENCH_*.json payload")
    p_append.add_argument("bench", help="BENCH_*.json file")
    p_append.add_argument("--store", required=True)
    p_append.add_argument("--git-sha", default=None,
                          help="override the payload's git_sha")

    p_show = sub.add_parser("show", help="list records")
    p_show.add_argument("--store", required=True)
    p_show.add_argument("--name", default=None)

    p_hist = sub.add_parser("history", help="one bench row's trajectory")
    p_hist.add_argument("--store", required=True)
    p_hist.add_argument("--name", required=True)
    p_hist.add_argument("--row", required=True)

    args = ap.parse_args(argv)
    store = Store(args.store)

    if args.cmd == "append":
        with open(args.bench) as f:
            payload = json.load(f)
        rec = store.append(bench_record(payload, sha=args.git_sha))
        print(f"appended {rec['name']} @ {rec['git_sha']} "
              f"({len(rec['rows'])} rows) -> {store.path}")
        return 0

    from repro.obs.export import format_table
    if args.cmd == "show":
        filters = {} if args.name is None else {"name": args.name}
        recs = store.records(**filters)
        rows = [(r.get("kind"), r["name"], r["backend"], r["git_sha"],
                 r["config_hash"], r.get("utc", ""),
                 len(r.get("rows", [])) or len(r.get("phases", [])),
                 len(r.get("alerts", []))) for r in recs]
        print(format_table(("kind", "name", "backend", "git_sha",
                            "config_hash", "utc", "rows", "alerts"), rows))
        return 0

    if args.cmd == "history":
        hist = store.history(args.row, name=args.name)
        print(format_table(("git_sha", "utc", "us", "path"),
                           [(h["git_sha"], h["utc"], h["us"], h["path"])
                            for h in hist]))
        return 0
    return 2


if __name__ == "__main__":
    sys.exit(main())
