"""Chrome-trace / Perfetto JSON export of a recorded span tree.

``to_perfetto`` converts the tracer's spans into the Trace Event Format
(the JSON Perfetto and ``chrome://tracing`` both load): open
https://ui.perfetto.dev and drop the file in.  Timestamps are the
simulated clock in microseconds.

Track layout — what you see when the file opens:

  - **pid 1 "master"**: tid 1 carries the run + iteration slices (they
    nest); phases live on ``phases`` lanes (tid 10+), greedily packed so
    overlapping phases — the gradient chain running concurrently with the
    Hessian-sketch fan-out — land on *different* lanes and the overlap is
    visually inspectable.  Same for charge spans.
  - **pid 2 "workers"**: one tid per worker track (an attempt span's
    ``track`` label, e.g. ``"hessian/w7"``), allocated in first-seen
    order.  Each track shows that worker's lifecycle slices: ``cold``,
    ``run``, ``failed`` and ``retry`` attempts, speculative/hedged
    ``relaunch`` copies.

  - **pid 3 "counters"** (opt-in): Perfetto counter tracks (``ph: "C"``)
    rendered as area charts above the timeline — warm-pool hit rate,
    straggler-tail p95, per-tenant dollars, SLO burn gauges.  Pass the
    ``counters`` mapping (``counter_series`` builds it from a live
    telemetry's timestamped gauge points); the default export omits them
    entirely, so the committed golden trace stays byte-identical.

Serialization is byte-stable (``dumps_stable``: sorted keys, minimal
separators, floats via ``repr``) so a committed golden export can be
compared bytes-for-bytes forever; ``validate_trace`` is the schema check
CI runs against every exported trace (no negative durations, phase slices
present, worker tracks non-empty, counter samples well-formed).
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.span import Span

MASTER_PID = 1
WORKERS_PID = 2
COUNTERS_PID = 3          # counter tracks (opt-in)
MASTER_TID = 1            # run + iteration slices
PHASE_TID0 = 10           # first phase lane


def _us(seconds: float) -> float:
    return float(seconds) * 1e6


def _lane_pack(spans: Sequence[Span]) -> Dict[int, int]:
    """Greedy interval packing: span_id -> lane index.  Overlapping spans
    get distinct lanes; processing order (start, span_id) is deterministic."""
    lanes: List[float] = []       # lane -> last occupied end time
    out: Dict[int, int] = {}
    for s in sorted(spans, key=lambda s: (s.start, s.span_id)):
        for i, busy_until in enumerate(lanes):
            if s.start >= busy_until:
                lanes[i] = s.end
                out[s.span_id] = i
                break
        else:
            out[s.span_id] = len(lanes)
            lanes.append(s.end)
    return out


def counter_series(telemetry,
                   include_histograms: Sequence[str] = ("phase.tail_p95_s",)
                   ) -> Dict[str, List[Tuple[float, float]]]:
    """Build ``to_perfetto``'s ``counters`` mapping from a telemetry's
    timestamped instrument points.

    Every gauge that recorded ``(t, value)`` points (the registry's
    ``timesource`` must have been wired, which ``Telemetry`` does by
    default) becomes one counter track; histograms named in
    ``include_histograms`` contribute their raw observation stream too
    (the straggler tail as a sawtooth).  Names are sorted, points are in
    recording order — deterministic for a deterministic run.
    """
    out: Dict[str, List[Tuple[float, float]]] = {}
    metrics = getattr(telemetry, "metrics", None)
    if metrics is None:
        return out
    for name, g in sorted(getattr(metrics, "gauges", {}).items()):
        if g.points:
            out[name] = list(g.points)
    for name in include_histograms:
        h = getattr(metrics, "histograms", {}).get(name)
        if h is not None and h.points:
            out[name] = list(h.points)
    return out


def to_perfetto(spans: Iterable[Span],
                counters: Optional[Dict[str, Sequence[Tuple[float, float]]]]
                = None) -> dict:
    """Render spans as a Trace Event Format dict (see module docstring).

    ``counters`` optionally maps track name -> ``(t_seconds, value)``
    samples, emitted as ``ph: "C"`` counter events on pid 3.  Omitted by
    default so the plain span export is unchanged byte-for-byte.
    """
    spans = list(spans)
    events: List[dict] = []

    def meta(pid: int, tid: Optional[int], name: str, which: str) -> None:
        ev = {"ph": "M", "pid": pid, "name": which,
              "args": {"name": name}}
        if tid is not None:
            ev["tid"] = tid
        events.append(ev)

    meta(MASTER_PID, None, "master", "process_name")
    meta(MASTER_PID, MASTER_TID, "run", "thread_name")
    meta(WORKERS_PID, None, "workers", "process_name")

    def slice_event(s: Span, pid: int, tid: int) -> dict:
        ev = {"name": s.name, "cat": s.kind, "ph": "X",
              "ts": _us(s.start), "dur": _us(s.duration),
              "pid": pid, "tid": tid}
        if s.attrs:
            ev["args"] = s.attrs
        return ev

    # Master timeline: run + iteration slices nest on one tid.
    for s in spans:
        if s.kind in ("run", "iteration"):
            events.append(slice_event(s, MASTER_PID, MASTER_TID))

    # Phase lanes: pack so concurrent phases are side by side.
    phase_spans = [s for s in spans if s.kind in ("phase", "charge")]
    lanes = _lane_pack(phase_spans)
    for lane in sorted(set(lanes.values())):
        meta(MASTER_PID, PHASE_TID0 + lane, f"phases lane {lane}",
             "thread_name")
    for s in phase_spans:
        events.append(slice_event(s, MASTER_PID, PHASE_TID0 + lanes[s.span_id]))

    # Worker tracks: one tid per distinct track label, first-seen order.
    track_tid: Dict[str, int] = {}
    for s in spans:
        if s.kind != "attempt" or s.track is None:
            continue
        if s.track not in track_tid:
            track_tid[s.track] = 1 + len(track_tid)
            meta(WORKERS_PID, track_tid[s.track], s.track, "thread_name")
        events.append(slice_event(s, WORKERS_PID, track_tid[s.track]))

    # Counter tracks (opt-in): one ph "C" stream per metric name.
    if counters:
        meta(COUNTERS_PID, None, "counters", "process_name")
        for name in sorted(counters):
            for t, v in counters[name]:
                events.append({"name": name, "cat": "counter", "ph": "C",
                               "ts": _us(t), "pid": COUNTERS_PID, "tid": 0,
                               "args": {"value": float(v)}})

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def dumps_stable(trace: dict) -> str:
    """Deterministic serialization: byte-identical for identical spans."""
    return json.dumps(trace, sort_keys=True, separators=(",", ":")) + "\n"


def dump(trace: dict, path) -> None:
    with open(path, "w") as f:
        f.write(dumps_stable(trace))


def validate_trace(trace: dict, require_phases: Sequence[str] = (),
                   require_worker_tracks: bool = True,
                   require_counters: Sequence[str] = ()) -> None:
    """Schema check for an exported trace; raises ValueError on violation.

    Checks the trace-event invariants Perfetto needs (every slice has a
    name/pid/tid, no negative timestamp or duration; every counter sample
    a name/pid/ts and a numeric ``args.value``) plus the fleet-shape
    expectations CI asserts: the named phases are present as phase
    slices, at least one worker-lifecycle track is non-empty, and the
    named counter tracks carry at least one sample each.
    """
    problems: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("trace has no traceEvents list")
    phase_names = set()
    counter_names = set()
    worker_slices = 0
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph == "M":
            continue
        if ph == "C":
            for field in ("name", "pid", "ts"):
                if field not in ev:
                    problems.append(f"counter event {i}: missing {field!r}")
            if ev.get("ts", 0) < 0:
                problems.append(f"counter event {i} ({ev.get('name')}): "
                                "negative ts")
            value = (ev.get("args") or {}).get("value")
            if not isinstance(value, (int, float)):
                problems.append(f"counter event {i} ({ev.get('name')}): "
                                "args.value is not numeric")
            counter_names.add(ev.get("name"))
            continue
        if ph != "X":
            problems.append(f"event {i}: unexpected ph {ph!r}")
            continue
        for field in ("name", "pid", "tid", "ts", "dur"):
            if field not in ev:
                problems.append(f"event {i}: missing {field!r}")
        if ev.get("ts", 0) < 0:
            problems.append(f"event {i} ({ev.get('name')}): negative ts")
        if ev.get("dur", 0) < 0:
            problems.append(f"event {i} ({ev.get('name')}): negative dur")
        if ev.get("cat") == "phase":
            phase_names.add(ev.get("name"))
        if ev.get("pid") == WORKERS_PID:
            worker_slices += 1
    for want in require_phases:
        if want not in phase_names:
            problems.append(f"required phase slice {want!r} not in trace "
                            f"(saw {sorted(phase_names)})")
    for want in require_counters:
        if want not in counter_names:
            problems.append(f"required counter track {want!r} not in trace "
                            f"(saw {sorted(counter_names)})")
    if require_worker_tracks and worker_slices == 0:
        problems.append("no worker-lifecycle slices (pid 2 is empty)")
    if problems:
        raise ValueError("invalid Perfetto trace:\n  "
                         + "\n  ".join(problems))


def validate_file(path, require_phases: Sequence[str] = (),
                  require_worker_tracks: bool = True,
                  require_counters: Sequence[str] = ()) -> dict:
    """Load + validate an exported trace file; returns the parsed dict."""
    with open(path) as f:
        trace = json.load(f)
    validate_trace(trace, require_phases=require_phases,
                   require_worker_tracks=require_worker_tracks,
                   require_counters=require_counters)
    return trace
