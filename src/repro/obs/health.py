"""Online fleet/convergence health monitors over the live metric stream.

Bartan-Pilanci's distributed-sketching analyses (PAPERS.md) give exact
expected-error characterizations per sketch family, which makes
convergence health *predictable*: the MP-debias factor, CG iteration
counts, cost per iteration, and the straggler completion tail all have a
stationary regime under a healthy run.  Deviations — debias drift when
too many sketch blocks die, CG blowup on an ill-conditioned Hessian
estimate, a straggler-tail shift when the fleet degrades, a warm-pool
hit-rate collapse — are detectable anomalies, not noise.  This module
detects them online, as the metrics stream through the registry.

Two classical detectors, both streaming and O(1)-ish per sample:

  - ``RobustZScore`` — a rolling median/MAD window; a sample whose robust
    z-score against the *prior* window exceeds ``z`` fires.  Catches
    spikes (one pathological phase, one blown-up iteration cost).
  - ``Cusum`` — a two-sided CUSUM on samples standardized against a
    frozen baseline (the first ``min_samples`` observations): the
    classic small-persistent-shift detector.  Catches drift (a slowly
    degrading straggler tail, MP-debias creep as survivors thin out).

``HealthMonitors`` routes named metric streams to detector instances via
``Rule``s and attaches to a ``Telemetry`` as the registry's listener.
Everything here is **strictly observation-only**: detectors draw no
randomness, read no clock (alerts are stamped with the span tracer's
``last_time`` high-water mark), and never touch the simulation — golden
-trace replays stay bit-identical with monitors attached
(``tests/test_golden_trace.py`` pins this).  Alerts are emitted three
ways: appended to ``monitors.alerts``, dropped into the span tree as
zero-duration ``alert`` spans (so they sit next to the phase that
triggered them), and written to the JSONL export as ``kind: "alert"``
rows that ``make_report --trace`` tabulates.

Tuning (see obs/README.md for the full table): ``z`` / ``h`` up for
fewer, stronger alerts; ``min_samples`` up when the warm-up transient
(cold pools, first-iteration compilation) should not count as baseline.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

#: Default scale floors: ``scale = max(raw, rel_floor * |center|,
#: abs_floor)``.  Two jobs in one clamp: a deterministic (zero-variance)
#: baseline still scores instead of dividing by zero, and — more
#: important operationally — a stream that happens to be statistically
#: *tight* (per-worker completions cluster within ~1%) does not turn a
#: 3% wobble into a 7-sigma alert.  Detectors watching duration/cost
#: streams want ``rel_floor ~ 0.1`` (a deviation must be a meaningful
#: fraction of the stream's level to count); absolute-scale streams in
#: [0, 1] (debias factor, hit rate) want an ``abs_floor`` instead.
_REL_FLOOR = 1e-3
_ABS_FLOOR = 1e-12


def _scale_floor(scale: float, center: float, rel_floor: float,
                 abs_floor: float) -> float:
    return max(scale, rel_floor * abs(center), abs_floor)


@dataclasses.dataclass
class Alert:
    """One detected anomaly on one metric stream."""

    metric: str                 # registry name, e.g. "worker.completion_s"
    detector: str               # "zscore" | "cusum"
    t: float                    # simulated seconds (tracer high-water mark)
    value: float                # the sample that fired
    score: float                # robust z / CUSUM statistic at firing
    threshold: float            # the limit it crossed
    sample: int                 # 1-based index of the sample in its stream
    direction: str              # "high" | "low"

    def as_row(self) -> dict:
        return {"kind": "alert", "metric": self.metric,
                "detector": self.detector, "t": self.t,
                "value": self.value, "score": self.score,
                "threshold": self.threshold, "sample": self.sample,
                "direction": self.direction}


class RobustZScore:
    """Rolling median/MAD spike detector.

    A sample is scored against the window of the ``window`` samples
    *before* it (so a spike cannot mask itself), using the normalized MAD
    (1.4826 x) as the scale.  No alert until ``min_samples`` history
    exists.
    """

    name = "zscore"

    def __init__(self, window: int = 20, z: float = 4.0,
                 min_samples: int = 8, rel_floor: float = _REL_FLOOR,
                 abs_floor: float = _ABS_FLOOR):
        if min_samples < 2:
            raise ValueError("min_samples must be >= 2")
        self.window = int(window)
        self.z = float(z)
        self.min_samples = int(min_samples)
        self.rel_floor = float(rel_floor)
        self.abs_floor = float(abs_floor)
        self.buf: List[float] = []
        self.count = 0
        self.last_score = 0.0

    @staticmethod
    def _median(xs: Sequence[float]) -> float:
        ys = sorted(xs)
        n = len(ys)
        mid = n // 2
        return ys[mid] if n % 2 else 0.5 * (ys[mid - 1] + ys[mid])

    def update(self, x: float) -> Optional[Tuple[float, float, str]]:
        """Feed one sample; returns (score, threshold, direction) when it
        fires, else None.  The sample always joins the window afterwards."""
        x = float(x)
        fired = None
        self.count += 1
        if len(self.buf) >= self.min_samples:
            med = self._median(self.buf)
            mad = self._median([abs(b - med) for b in self.buf])
            scale = _scale_floor(1.4826 * mad, med, self.rel_floor,
                                 self.abs_floor)
            score = (x - med) / scale
            self.last_score = score
            if abs(score) > self.z:
                fired = (score, self.z, "high" if score > 0 else "low")
        self.buf.append(x)
        if len(self.buf) > self.window:
            self.buf.pop(0)
        return fired

    def state(self) -> dict:
        return {"window": len(self.buf), "samples": self.count,
                "last_score": self.last_score}


class Cusum:
    """Two-sided CUSUM against a frozen early baseline.

    The first ``min_samples`` observations define the baseline mean and
    (population) standard deviation; every later sample is standardized
    against it and accumulated into the classic one-sided statistics
    ``s_pos = max(0, s_pos + z - k)`` / ``s_neg = max(0, s_neg - z - k)``.
    Crossing ``h`` fires and resets both accumulators (so a persistent
    shift re-alerts at a bounded rate instead of once per sample).
    """

    name = "cusum"

    def __init__(self, k: float = 0.5, h: float = 5.0,
                 min_samples: int = 8, rel_floor: float = _REL_FLOOR,
                 abs_floor: float = _ABS_FLOOR):
        if min_samples < 2:
            raise ValueError("min_samples must be >= 2")
        self.k = float(k)
        self.h = float(h)
        self.min_samples = int(min_samples)
        self.rel_floor = float(rel_floor)
        self.abs_floor = float(abs_floor)
        self.baseline: List[float] = []
        self.mean = 0.0
        self.std = 0.0
        self.s_pos = 0.0
        self.s_neg = 0.0
        self.count = 0

    def update(self, x: float) -> Optional[Tuple[float, float, str]]:
        x = float(x)
        self.count += 1
        if len(self.baseline) < self.min_samples:
            self.baseline.append(x)
            if len(self.baseline) == self.min_samples:
                n = len(self.baseline)
                self.mean = sum(self.baseline) / n
                var = sum((b - self.mean) ** 2 for b in self.baseline) / n
                self.std = _scale_floor(math.sqrt(var), self.mean,
                                        self.rel_floor, self.abs_floor)
            return None
        z = (x - self.mean) / self.std
        self.s_pos = max(0.0, self.s_pos + z - self.k)
        self.s_neg = max(0.0, self.s_neg - z - self.k)
        if self.s_pos > self.h:
            score, self.s_pos, self.s_neg = self.s_pos, 0.0, 0.0
            return (score, self.h, "high")
        if self.s_neg > self.h:
            score, self.s_pos, self.s_neg = self.s_neg, 0.0, 0.0
            return (-score, self.h, "low")
        return None

    def state(self) -> dict:
        return {"samples": self.count, "s_pos": self.s_pos,
                "s_neg": self.s_neg,
                "baseline_mean": self.mean if self.baseline else float("nan"),
                "baseline_std": self.std if self.baseline else float("nan")}


@dataclasses.dataclass(frozen=True)
class Rule:
    """Route one metric stream to one detector factory."""

    metric: str                            # registry name to watch
    make: Callable[[], object]             # detector factory
    kinds: Tuple[str, ...] = ("gauge", "hist")   # event kinds that feed it


def default_rules() -> Tuple[Rule, ...]:
    """The shipped monitor set — one detector per predictable-health axis.

    Tuned for the simulator's scales AND its stream shapes.  The fleet
    streams (``worker.completion_s``, ``phase.tail_p95_s``) interleave
    heterogeneous phase types — gradient, Hessian-sketch, and line-search
    fan-outs have different worker counts and flop loads — so their
    in-stream variance understates healthy spread; those detectors carry
    ``rel_floor=0.25``: a deviation must exceed 25% of the stream's level
    (per scale unit) before it scores at all.  Per-iteration optimizer
    streams are homogeneous and keep the tight default floor.  The
    combination keeps healthy golden-trace replays silent (pinned by
    tests) while a real shift — e.g. phase work jumping 4x — still fires
    within a handful of samples.
    """
    return (
        # Straggler tails: per-worker completions drift (fleet degrades).
        # h=25: a legitimate 3x straggler tail scores z ~ 8, so isolated
        # tails at the model's few-percent rate can't sum to a firing,
        # while a sustained 4x shift (z ~ 12 every sample) fires within
        # two or three samples of the change.
        Rule("worker.completion_s", lambda: Cusum(k=0.75, h=25.0,
                                                  min_samples=16,
                                                  rel_floor=0.25)),
        # Per-phase p95 completion: spike = one pathological fan-out.
        Rule("phase.tail_p95_s", lambda: RobustZScore(window=20, z=4.0,
                                                      rel_floor=0.25)),
        # Cost per iteration (set by the optimizer loop).
        Rule("newton.iter_dollars", lambda: RobustZScore(window=12, z=4.0,
                                                         min_samples=4,
                                                         rel_floor=0.05)),
        Rule("newton.iter_seconds", lambda: RobustZScore(window=12, z=4.0,
                                                         min_samples=4,
                                                         rel_floor=0.05)),
        # CG iteration budget blowup.
        Rule("newton.cg_iters", lambda: RobustZScore(window=12, z=3.0,
                                                     min_samples=4)),
        Rule("giant.cg_iters", lambda: RobustZScore(window=12, z=3.0,
                                                    min_samples=4)),
        # Marchenko-Pastur debias factor drift (survivors thinning out).
        # The factor lives in (0, 1]; an absolute floor of 0.02 makes the
        # unit of drift "2 percentage points of debias".
        Rule("sketch.mp_debias", lambda: Cusum(k=0.5, h=6.0, min_samples=4,
                                               abs_floor=0.02)),
        # Warm-pool hit rate collapse.  Watches the *per-phase* ratio
        # (``pool.phase_hit_rate``), not the cumulative ``pool.hit_rate``:
        # the cumulative gauge is smoothed by all prior phases, so a
        # sudden collapse (container-death cull, tenant burst) barely
        # moves it while the phase stream drops to zero immediately.
        Rule("pool.phase_hit_rate", lambda: Cusum(k=0.5, h=6.0,
                                                  min_samples=6,
                                                  abs_floor=0.05)),
        # Coded-matvec corruption rate (per-phase gauge from the coded
        # engine whenever a fault plan's CorruptionSpec is attached; 0.0
        # on clean phases, so the baseline is exact and any sustained
        # corruption drifts the CUSUM up).  Unit of drift: 2% of blocks.
        Rule("coded.block_error_rate", lambda: Cusum(k=0.5, h=6.0,
                                                     min_samples=6,
                                                     abs_floor=0.02)),
    )


class HealthMonitors:
    """Registry listener that runs every matching rule's detector online.

    Attach with ``obs.Telemetry(monitors=HealthMonitors())`` (or
    ``monitors.attach(tel)`` after the fact).  Detectors are lazily
    instantiated per metric on first sample, so one monitor set serves
    any mix of optimizers.
    """

    def __init__(self, rules: Optional[Sequence[Rule]] = None):
        self.rules: Tuple[Rule, ...] = tuple(
            default_rules() if rules is None else rules)
        self._by_metric: Dict[str, List[Tuple[int, Rule]]] = {}
        for i, r in enumerate(self.rules):
            self._by_metric.setdefault(r.metric, []).append((i, r))
        self.detectors: Dict[Tuple[str, int], object] = {}
        self.alerts: List[Alert] = []
        self._tel = None

    # -------------------------------------------------------------- wiring
    def attach(self, telemetry) -> "HealthMonitors":
        """Become ``telemetry``'s metric listener (and alert emitter)."""
        self._tel = telemetry
        telemetry.metrics.listener = self
        telemetry.health = self
        return self

    # ------------------------------------------------------------ listener
    def on_metric(self, kind: str, name: str, delta: float,
                  value: float) -> None:
        rules = self._by_metric.get(name)
        if not rules:
            return
        for idx, rule in rules:
            if kind not in rule.kinds:
                continue
            key = (name, idx)
            det = self.detectors.get(key)
            if det is None:
                det = self.detectors[key] = rule.make()
            fired = det.update(value)
            if fired is None:
                continue
            score, threshold, direction = fired
            t = self._tel.trace.last_time if self._tel is not None else 0.0
            alert = Alert(metric=name, detector=det.name, t=t,
                          value=float(value), score=float(score),
                          threshold=float(threshold), sample=det.count,
                          direction=direction)
            self.alerts.append(alert)
            if self._tel is not None and self._tel.trace.enabled:
                self._tel.trace.emit(
                    f"alert:{name}", "alert", t, t, metric=name,
                    detector=det.name, value=float(value),
                    score=float(score), direction=direction)

    # -------------------------------------------------------------- export
    def alert_windows(self, merge_gap: float = 1.0
                      ) -> List[Tuple[float, float, List[Alert]]]:
        """Cluster alerts into time windows: consecutive alerts closer
        than ``merge_gap`` simulated seconds share one window.  Returns
        ``(t_start, t_end, alerts)`` triples in time order — the unit of
        attribution for ``repro.obs.incident``."""
        if not self.alerts:
            return []
        ordered = sorted(self.alerts, key=lambda a: (a.t, a.metric,
                                                     a.detector))
        windows: List[Tuple[float, float, List[Alert]]] = []
        t0 = t1 = ordered[0].t
        bucket = [ordered[0]]
        for a in ordered[1:]:
            if a.t - t1 <= merge_gap:
                t1 = a.t
                bucket.append(a)
            else:
                windows.append((t0, t1, bucket))
                t0 = t1 = a.t
                bucket = [a]
        windows.append((t0, t1, bucket))
        return windows

    def state_rows(self) -> List[dict]:
        """Per-detector state for reports and the JSONL ``health`` row."""
        rows = []
        for (metric, _), det in sorted(self.detectors.items(),
                                       key=lambda kv: (kv[0][0],
                                                       kv[1].name)):
            n_alerts = sum(1 for a in self.alerts
                           if a.metric == metric
                           and a.detector == det.name)
            rows.append({"metric": metric, "detector": det.name,
                         "alerts": n_alerts, **det.state()})
        return rows

    def summary(self) -> dict:
        return {"alerts": len(self.alerts),
                "metrics_watched": len(self.detectors),
                "by_metric": {m: sum(1 for a in self.alerts if a.metric == m)
                              for m in sorted({a.metric
                                               for a in self.alerts})}}
