"""Critical-path + slack analytics over one dispatched phase DAG.

The scheduler records *when* every phase of a DAG iteration actually ran
(``DagResult``/``DagRun`` per-phase start/finish); this module answers the
operator questions those numbers exist for:

  - **Which chain of phases is binding the makespan?**  Shaving a second
    off any phase on the critical path shortens the iteration; shaving a
    phase off it does nothing.
  - **How much slack does every other phase have?**  Classic CPM backward
    pass over the recorded intervals: a phase's slack is how far its
    finish could slip (its duration grow) before it would extend the
    makespan — the headroom the scheduler's pool-aware dispatch and the
    launch planner's per-phase sizing get to spend for free.

Inputs are plain ``{name: (start, finish, deps)}`` mappings so the module
depends on nothing else in the repo; ``from_dag(...)`` adapts a
``DagResult`` or ``DagRun`` (both expose ``.results`` / ``.start``), and
phase spans recorded with a ``deps`` attribute adapt through
``from_spans``-style dicts in ``obs.export``.

Chain identification walks backward from the phase that finishes last:
the binding predecessor of a phase is the dependency whose finish equals
the phase's start (the engine launches at ``max(dag_start, max dep
finish)``, so the equality is exact, not approximate); ties break
lexicographically so the report is deterministic.  A phase whose start
exceeds every dependency's finish was floored by something outside the
DAG (the dag start itself, or an explicit ``min_start``) — the chain
roots there.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

#: Input row: (start, finish, deps) — absolute simulated seconds + names.
PhaseTimes = Tuple[float, float, Sequence[str]]


@dataclasses.dataclass(frozen=True)
class PhaseSlack:
    """One phase's placement plus its CPM slack."""

    name: str
    start: float
    finish: float
    slack: float                  # seconds of headroom; 0 on the chain
    on_critical_path: bool
    deps: Tuple[str, ...]

    @property
    def duration(self) -> float:
        return self.finish - self.start


@dataclasses.dataclass(frozen=True)
class CriticalPathReport:
    """Makespan decomposition of one dispatched DAG."""

    start: float                          # DAG launch time
    makespan: float                       # last finish - start
    critical_path: Tuple[str, ...]        # binding chain, execution order
    phases: Dict[str, PhaseSlack]         # every phase, keyed by name

    @property
    def critical_seconds(self) -> float:
        """Sum of critical-phase durations (= makespan minus any float-in
        gap before the chain roots at the DAG start)."""
        return sum(self.phases[n].duration for n in self.critical_path)

    def rows(self) -> List[dict]:
        """Table-ready rows, critical chain first then by start time."""
        order = sorted(
            self.phases.values(),
            key=lambda p: (not p.on_critical_path, p.start, p.name))
        return [{"phase": p.name, "start": p.start, "finish": p.finish,
                 "duration": p.duration, "slack": p.slack,
                 "critical": p.on_critical_path} for p in order]


def critical_path(phases: Mapping[str, PhaseTimes],
                  start: Optional[float] = None) -> CriticalPathReport:
    """CPM analysis of recorded phase intervals.

    ``phases`` maps each phase name to its recorded ``(start, finish,
    deps)``; ``start`` is the DAG launch time (defaults to the earliest
    recorded start).  Durations are taken as recorded — this is analysis
    of what *did* happen, not a what-if simulator.
    """
    if not phases:
        raise ValueError("critical_path needs at least one phase")
    norm: Dict[str, Tuple[float, float, Tuple[str, ...]]] = {}
    for name, (s, f, deps) in phases.items():
        deps = tuple(deps)
        for d in deps:
            if d not in phases:
                raise ValueError(
                    f"phase {name!r} depends on unknown phase {d!r}")
        if f < s:
            raise ValueError(
                f"phase {name!r} finishes ({f}) before it starts ({s})")
        norm[name] = (float(s), float(f), deps)
    t0 = min(s for s, _, _ in norm.values()) if start is None else float(start)
    end = max(f for _, f, _ in norm.values())

    # Backward pass: latest finish each phase could have without moving
    # the makespan, given every successor's recorded start-to-finish span.
    children: Dict[str, List[str]] = {n: [] for n in norm}
    for name, (_, _, deps) in norm.items():
        for d in deps:
            children[d].append(name)
    latest_finish: Dict[str, float] = {}

    def lf(name: str) -> float:
        if name in latest_finish:
            return latest_finish[name]
        kids = children[name]
        if not kids:
            out = end
        else:
            # A child could start as late as lf(child) - duration(child);
            # this phase must finish by the earliest such latest-start.
            out = min(lf(c) - (norm[c][1] - norm[c][0]) for c in kids)
        latest_finish[name] = out
        return out

    for name in norm:
        lf(name)

    # Binding chain: walk back from the (lexicographically first) phase
    # that finishes last, following the dependency whose finish equals the
    # current phase's launch time.
    tail = min(n for n, (_, f, _) in norm.items() if f == end)
    chain = [tail]
    while True:
        s, _, deps = norm[chain[-1]]
        binding = sorted(d for d in deps if norm[d][1] == s)
        if not binding:
            break          # floored by the DAG start or a min_start
        chain.append(binding[0])
    chain.reverse()
    on_chain = set(chain)

    out: Dict[str, PhaseSlack] = {}
    for name, (s, f, deps) in norm.items():
        slack = latest_finish[name] - f
        # Float roundoff guard: a chain member's slack is 0 by definition.
        if name in on_chain:
            slack = 0.0
        out[name] = PhaseSlack(name=name, start=s, finish=f,
                               slack=max(0.0, slack),
                               on_critical_path=name in on_chain, deps=deps)
    return CriticalPathReport(start=t0, makespan=end - t0,
                              critical_path=tuple(chain), phases=out)


def from_dag(dag) -> CriticalPathReport:
    """Adapt a ``scheduler.DagResult`` or ``DagRun`` (anything exposing
    ``.results`` name->PhaseResult and ``.start``)."""
    return critical_path(
        {name: (r.start, r.finish, r.spec.deps)
         for name, r in dag.results.items()},
        start=dag.start)
