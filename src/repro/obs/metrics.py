"""Metrics registry: counters, gauges, and histograms for fleet telemetry.

Three instrument types, auto-created on first touch (``registry.counter
("fleet.cold_starts").inc()``), mirroring the Prometheus surface every
operator already knows:

  - ``Counter`` — monotone totals: attempts, retries, cold starts, warm
    hits, adaptive-sketch growth events, kernel-path selections.
  - ``Gauge`` — last-value-wins with the full series kept: adaptive sketch
    rows m, the measured Marchenko-Pastur debias factor, CG iteration
    budget, warm-pool free containers.
  - ``Histogram`` — full-sample distributions with exact percentiles (the
    sample counts here are thousands, not millions — no bucketing error):
    per-worker completion times (the Fig. 1 straggler tail), per-phase
    elapsed seconds, GB-seconds, and dollars, kernel wall-clock.

``NullMetrics`` is the zero-overhead default: every instrument lookup
returns one shared no-op instance.  Like the tracer, the registry draws no
randomness and reads no clock, so attaching it never perturbs a run.

Metric names are dotted paths (``fleet.cold_starts``, ``phase.dollars``,
``kernel.path.fused_tiled``); ``snapshot()`` returns them sorted, so the
JSONL export is deterministic.

A registry optionally carries a ``listener`` — anything with an
``on_metric(kind, name, delta, value)`` method (``repro.obs.health``'s
streaming anomaly detectors are the shipped one).  Every instrument update
forwards through it, which is what makes online monitoring possible
without a second instrumentation pass; a listener is itself pure
observation and must never mutate the run.

It may also carry a ``timesource`` — a zero-argument callable returning
the current simulated time (``Telemetry`` wires it to the span tracer's
``last_time`` high-water mark).  When present, every gauge ``set`` and
histogram ``observe`` also appends a ``(t, value)`` point to the
instrument's ``points`` list, which is what the Perfetto counter-track
export (``obs.counter_series``) and the console's burn charts render.
Reading a high-water mark draws no randomness and moves no clock, so the
observation-only contract is untouched; without a timesource (the
default for a bare ``MetricsRegistry()``) nothing extra is recorded.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class Counter:
    value: float = 0.0
    name: str = ""
    registry: Optional["MetricsRegistry"] = dataclasses.field(
        default=None, repr=False, compare=False)

    def inc(self, v: float = 1.0) -> None:
        self.value += v
        reg = self.registry
        if reg is not None and reg.listener is not None:
            reg.listener.on_metric("counter", self.name, v, self.value)


@dataclasses.dataclass
class Gauge:
    """Last value wins; the series is kept for per-iteration plots."""

    value: float = 0.0
    series: List[float] = dataclasses.field(default_factory=list)
    name: str = ""
    registry: Optional["MetricsRegistry"] = dataclasses.field(
        default=None, repr=False, compare=False)
    #: (t, value) pairs, recorded only when the registry has a timesource.
    points: List[tuple] = dataclasses.field(default_factory=list,
                                            compare=False)

    def set(self, v: float) -> None:
        self.value = float(v)
        self.series.append(self.value)
        reg = self.registry
        if reg is not None:
            if reg.timesource is not None:
                self.points.append((float(reg.timesource()), self.value))
            if reg.listener is not None:
                reg.listener.on_metric("gauge", self.name, self.value,
                                       self.value)


@dataclasses.dataclass
class Histogram:
    values: List[float] = dataclasses.field(default_factory=list)
    name: str = ""
    registry: Optional["MetricsRegistry"] = dataclasses.field(
        default=None, repr=False, compare=False)
    #: (t, value) pairs, recorded only when the registry has a timesource.
    points: List[tuple] = dataclasses.field(default_factory=list,
                                            compare=False)

    def observe(self, v: float) -> None:
        self.values.append(float(v))
        reg = self.registry
        if reg is not None:
            if reg.timesource is not None:
                self.points.append((float(reg.timesource()), float(v)))
            if reg.listener is not None:
                reg.listener.on_metric("hist", self.name, float(v), float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def total(self) -> float:
        return sum(self.values)

    def percentile(self, q: float) -> float:
        """Exact nearest-rank percentile, q in [0, 100]; NaN when empty."""
        if not self.values:
            return float("nan")
        xs = sorted(self.values)
        rank = max(0, min(len(xs) - 1, int(round(q / 100.0 * (len(xs) - 1)))))
        return xs[rank]

    def summary(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p95": self.percentile(95), "p99": self.percentile(99),
                "max": max(self.values) if self.values else float("nan")}


class MetricsRegistry:
    enabled = True

    def __init__(self, listener=None, timesource=None):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        # Optional on_metric(kind, name, delta, value) observer — the hook
        # repro.obs.health's online detectors attach through.  May be set
        # after instruments already exist; they all hold a registry
        # back-reference, so late attachment sees every later update.
        self.listener = listener
        # Optional zero-arg simulated-clock reader; when set, gauges and
        # histograms keep timestamped (t, value) points (module docstring).
        self.timesource = timesource

    def counter(self, name: str) -> Counter:
        return self.counters.setdefault(name,
                                        Counter(name=name, registry=self))

    def gauge(self, name: str) -> Gauge:
        return self.gauges.setdefault(name, Gauge(name=name, registry=self))

    def histogram(self, name: str) -> Histogram:
        return self.histograms.setdefault(name,
                                          Histogram(name=name, registry=self))

    def snapshot(self) -> dict:
        """Deterministic (sorted-name) dump of every instrument."""
        return {
            "counters": {n: c.value
                         for n, c in sorted(self.counters.items())},
            "gauges": {n: {"value": g.value, "n": len(g.series)}
                       for n, g in sorted(self.gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self.histograms.items())},
        }


class _NullInstrument:
    """One shared instance behind every NullMetrics lookup."""

    value = 0.0
    values: List[float] = []
    series: List[float] = []
    points: List[tuple] = []
    count = 0
    total = 0.0

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, q: float) -> float:
        return float("nan")

    def summary(self) -> dict:
        return {}


_NULL_INSTRUMENT = _NullInstrument()


class NullMetrics:
    enabled = False
    counters: Dict[str, Counter] = {}
    gauges: Dict[str, Gauge] = {}
    histograms: Dict[str, Histogram] = {}
    listener = None
    timesource = None

    def counter(self, name: str) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def snapshot(self) -> dict:
        return {"counters": {}, "gauges": {}, "histograms": {}}
