"""Per-tenant SLOs, multi-window burn rates, and error budgets.

SRE-style objective tracking on the simulated clock.  Each tenant gets a
frozen ``SloPolicy`` — a latency target, the fraction of jobs that must
hit it (``deadline_rate``), and optionally a cumulative cost ceiling —
and an ``SloTracker`` folds every completed job into:

  - **Error budget**: a deadline_rate of 0.99 allows 1% of jobs to be
    bad; ``budget_remaining`` is the fraction of that allowance still
    unspent (1.0 untouched, 0.0 exhausted, negative = blown).  With a
    cost ceiling the budget is the *minimum* of the reliability and cost
    axes — whichever budget is closer to gone governs.
  - **Multi-window burn rates**: the classic fast/slow pair.  Burn rate
    is (observed bad fraction) / (allowed bad fraction) over a trailing
    window — 1.0 means spending exactly on schedule, 14x means the fast
    window alone would exhaust a day's budget in ~100 minutes.  A page
    fires only when *both* windows exceed their thresholds (fast alone is
    noise, slow alone is stale), which is what ``should_shed`` checks.

Everything lands in the metrics registry as gauges (``slo.<tenant>.
budget_remaining`` / ``burn_fast`` / ``burn_slow``) and counters, so the
health detectors, the cross-run store, and the HTML console all see it
for free.  The tracker is pure observation: it draws no randomness,
reads no wall clock, and never mutates the run — admission control only
consults ``should_shed`` when ``AdmissionPolicy.budget_aware`` opts in
(``repro.tenancy.scheduler``), and that is a scheduler decision, not a
telemetry side effect.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SloPolicy:
    """One tenant's objective.  Windows are simulated seconds."""

    latency_target_s: float        # a job slower than this is "bad"
    deadline_rate: float = 0.99    # fraction of jobs that must hit it
    cost_ceiling_usd: Optional[float] = None  # cumulative dollars cap
    fast_window_s: float = 30.0    # fast burn window
    slow_window_s: float = 120.0   # slow burn window
    fast_burn: float = 6.0         # page when fast burn exceeds this ...
    slow_burn: float = 3.0         # ... AND slow burn exceeds this

    @property
    def allowed_bad(self) -> float:
        return max(1e-9, 1.0 - self.deadline_rate)


@dataclasses.dataclass
class _TenantState:
    policy: SloPolicy
    #: (t, bad) per completed job, arrival order == completion order here
    events: List[Tuple[float, bool]] = dataclasses.field(
        default_factory=list)
    dollars: float = 0.0
    #: (t, budget_remaining, burn_fast, burn_slow) after each job — the
    #: console's burn-chart series.
    series: List[Tuple[float, float, float, float]] = dataclasses.field(
        default_factory=list)


class SloTracker:
    """Folds completed jobs into per-tenant budgets and burn rates."""

    def __init__(self, policies: Dict[str, SloPolicy], telemetry=None):
        self.policies = dict(policies)
        self.telemetry = telemetry
        self._state: Dict[str, _TenantState] = {
            t: _TenantState(policy=p) for t, p in self.policies.items()}

    # ----------------------------------------------------------- recording
    def record_job(self, tenant: str, t: float, latency_s: float,
                   deadline_missed: bool, failed: bool,
                   dollars: float) -> None:
        """Fold one completed job.  A job is *bad* when it failed, missed
        its declared deadline, or ran past the policy's latency target."""
        st = self._state.get(tenant)
        if st is None:
            return
        pol = st.policy
        bad = bool(failed or deadline_missed
                   or latency_s > pol.latency_target_s)
        st.events.append((float(t), bad))
        st.dollars += float(dollars)
        remaining = self.budget_remaining(tenant)
        bf = self.burn_rate(tenant, t, pol.fast_window_s)
        bs = self.burn_rate(tenant, t, pol.slow_window_s)
        st.series.append((float(t), remaining, bf, bs))
        tel = self.telemetry
        if tel is not None and getattr(tel, "enabled", False):
            m = tel.metrics
            m.gauge(f"slo.{tenant}.budget_remaining").set(remaining)
            m.gauge(f"slo.{tenant}.burn_fast").set(bf)
            m.gauge(f"slo.{tenant}.burn_slow").set(bs)
            if bad:
                m.counter(f"slo.{tenant}.bad_jobs").inc()

    # ------------------------------------------------------------- queries
    def burn_rate(self, tenant: str, t: float, window_s: float) -> float:
        """(bad fraction over the trailing window) / (allowed fraction)."""
        st = self._state.get(tenant)
        if st is None:
            return 0.0
        lo = float(t) - window_s
        inside = [bad for (et, bad) in st.events if et >= lo]
        if not inside:
            return 0.0
        frac = sum(1 for bad in inside if bad) / len(inside)
        return frac / st.policy.allowed_bad

    def budget_remaining(self, tenant: str) -> float:
        """Fraction of the error budget left; min of reliability and cost
        axes when a cost ceiling is set.  Negative = budget blown."""
        st = self._state.get(tenant)
        if st is None:
            return 1.0
        pol = st.policy
        if st.events:
            frac = sum(1 for _, bad in st.events if bad) / len(st.events)
            rel = 1.0 - frac / pol.allowed_bad
        else:
            rel = 1.0
        if pol.cost_ceiling_usd is not None and pol.cost_ceiling_usd > 0:
            cost = 1.0 - st.dollars / pol.cost_ceiling_usd
            return min(rel, cost)
        return rel

    def should_shed(self, tenant: str, t: float) -> bool:
        """True when this tenant's budget is gone or both burn windows
        are paging — the signal ``budget_aware`` admission acts on."""
        st = self._state.get(tenant)
        if st is None:
            return False
        if self.budget_remaining(tenant) <= 0.0:
            return True
        pol = st.policy
        return (self.burn_rate(tenant, t, pol.fast_window_s) > pol.fast_burn
                and self.burn_rate(tenant, t, pol.slow_window_s)
                > pol.slow_burn)

    # ------------------------------------------------------------- exports
    def summary(self) -> dict:
        """Deterministic per-tenant summary (sorted tenants)."""
        out = {}
        for tenant in sorted(self._state):
            st = self._state[tenant]
            bad = sum(1 for _, b in st.events if b)
            last_t = st.events[-1][0] if st.events else 0.0
            out[tenant] = {
                "jobs": len(st.events), "bad_jobs": bad,
                "dollars": st.dollars,
                "budget_remaining": self.budget_remaining(tenant),
                "burn_fast": self.burn_rate(tenant, last_t,
                                            st.policy.fast_window_s),
                "burn_slow": self.burn_rate(tenant, last_t,
                                            st.policy.slow_window_s),
                "latency_target_s": st.policy.latency_target_s,
                "deadline_rate": st.policy.deadline_rate,
                "cost_ceiling_usd": st.policy.cost_ceiling_usd,
            }
        return out

    def rows(self) -> List[dict]:
        """JSONL-ready rows (``kind: "slo"``), one per tenant, carrying
        the full burn series for the console's charts."""
        out = []
        for tenant, summ in self.summary().items():
            row = {"kind": "slo", "tenant": tenant}
            row.update(summ)
            row["series"] = [[t, r, bf, bs]
                             for (t, r, bf, bs)
                             in self._state[tenant].series]
            out.append(row)
        return out
