"""JSONL export + summary-table rendering of recorded telemetry.

Two consumers:

  - ``dump_jsonl`` writes one run's telemetry as JSONL — span rows first
    (in emission order), then one ``{"kind": "metrics"}`` row with the
    registry snapshot — the machine-readable sibling of the Perfetto
    export, and what ``benchmarks.make_report --trace`` renders tables
    from.
  - The formatters: ``format_table`` is the one table renderer every
    benchmark summary shares (markdown-style, right-aligned numerics),
    ``phase_summary_rows`` aggregates phase spans into the per-phase
    time/dollar breakdown, and ``critical_path_rows`` tabulates a
    ``CriticalPathReport``.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence

from repro.obs.critical_path import CriticalPathReport, critical_path
from repro.obs.span import Span


# ----------------------------------------------------------------- JSONL
def telemetry_rows(telemetry) -> List[dict]:
    """Span rows, health-monitor alert/state rows (when monitors are
    attached), attributed incident rows (``repro.obs.incident``), SLO
    rows (``repro.obs.slo``), then one metrics row — JSON-ready."""
    rows = [s.as_row() for s in telemetry.trace.spans]
    health = getattr(telemetry, "health", None)
    if health is not None:
        rows.extend(a.as_row() for a in health.alerts)
        rows.append({"kind": "health", "detectors": health.state_rows(),
                     **health.summary()})
    incidents = getattr(telemetry, "incidents", None)
    if incidents:
        rows.extend(inc.as_row() for inc in incidents)
    slo = getattr(telemetry, "slo", None)
    if slo is not None:
        rows.extend(slo.rows())
    rows.append({"kind": "metrics", **telemetry.metrics.snapshot()})
    return rows


def dump_jsonl(telemetry, path) -> None:
    with open(path, "w") as f:
        for row in telemetry_rows(telemetry):
            f.write(json.dumps(row, sort_keys=True) + "\n")


def load_jsonl(path) -> List[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# ---------------------------------------------------------------- tables
def format_table(headers: Sequence[str], rows: Iterable[Sequence[object]],
                 floatfmt: str = ".4g") -> str:
    """Markdown table with aligned columns; floats via ``floatfmt``."""

    def fmt(v) -> str:
        if isinstance(v, bool):
            return "yes" if v else ""
        if isinstance(v, float):
            return format(v, floatfmt)
        return str(v)

    cells = [[fmt(v) for v in row] for row in rows]
    widths = [max([len(h)] + [len(r[i]) for r in cells])
              for i, h in enumerate(headers)]

    def line(vals):
        return "| " + " | ".join(v.ljust(w) for v, w in zip(vals, widths)) \
            + " |"

    out = [line(list(headers)),
           "|" + "|".join("-" * (w + 2) for w in widths) + "|"]
    out.extend(line(r) for r in cells)
    return "\n".join(out)


def phase_summary_rows(phase_rows: Iterable[dict]) -> List[dict]:
    """Aggregate phase span rows (``as_row`` dicts or JSONL rows) into the
    per-phase breakdown: count, total seconds, total dollars, workers."""
    agg: Dict[str, dict] = {}
    for r in phase_rows:
        if r.get("span_kind") not in ("phase", "charge"):
            continue
        name = r["name"]
        a = agg.setdefault(name, {"phase": name, "count": 0, "seconds": 0.0,
                                  "dollars": 0.0, "gb_seconds": 0.0,
                                  "workers": 0})
        a["count"] += 1
        a["seconds"] += r["end"] - r["start"]
        attrs = r.get("attrs", {})
        a["dollars"] += float(attrs.get("dollars", 0.0))
        a["gb_seconds"] += float(attrs.get("gb_seconds", 0.0))
        a["workers"] = max(a["workers"], int(attrs.get("workers", 0)))
    return sorted(agg.values(), key=lambda a: -a["seconds"])


def phase_table(phase_rows: Iterable[dict]) -> str:
    rows = phase_summary_rows(phase_rows)
    total_s = sum(r["seconds"] for r in rows)
    total_d = sum(r["dollars"] for r in rows)
    body = [(r["phase"], r["count"], r["workers"], r["seconds"],
             (100.0 * r["seconds"] / total_s) if total_s else 0.0,
             r["gb_seconds"], r["dollars"]) for r in rows]
    body.append(("TOTAL", sum(r["count"] for r in rows), "",
                 total_s, 100.0 if total_s else 0.0,
                 sum(r["gb_seconds"] for r in rows), total_d))
    return format_table(
        ("phase", "n", "workers", "seconds", "%time", "GB-s", "dollars"),
        body)


def critical_path_rows(report: CriticalPathReport) -> List[Sequence[object]]:
    return [(r["phase"], r["start"], r["finish"], r["duration"], r["slack"],
             r["critical"]) for r in report.rows()]


def critical_path_table(report: CriticalPathReport) -> str:
    head = (f"makespan {report.makespan:.4g}s; critical path: "
            + " -> ".join(report.critical_path)
            + f" ({report.critical_seconds:.4g}s on-chain)")
    return head + "\n" + format_table(
        ("phase", "start", "finish", "duration", "slack", "critical"),
        critical_path_rows(report))


def dag_reports_from_rows(rows: Iterable[dict]) -> List[CriticalPathReport]:
    """Reconstruct per-DAG critical-path reports from exported span rows.

    Phase spans dispatched through ``scheduler.DagRun`` carry a ``deps``
    attribute; spans sharing a parent (one iteration span) form one DAG.
    Groups in which no span recorded deps (pure sequential dispatch) are
    skipped.
    """
    groups: Dict[int, Dict[str, tuple]] = {}
    has_deps: Dict[int, bool] = {}
    for r in rows:
        if r.get("span_kind") != "phase":
            continue
        attrs = r.get("attrs", {})
        if "deps" not in attrs:
            continue
        parent = r.get("parent", 0)
        groups.setdefault(parent, {})[r["name"]] = (
            r["start"], r["end"], tuple(attrs["deps"]))
        has_deps[parent] = has_deps.get(parent, False) or bool(attrs["deps"])
    return [critical_path(g) for parent, g in sorted(groups.items())
            if has_deps[parent]]


# ------------------------------------------------------- health / alerts
def alerts_from_rows(rows: Iterable[dict]) -> List[dict]:
    """The ``kind: "alert"`` rows of a JSONL export (file order)."""
    return [r for r in rows if r.get("kind") == "alert"]


def alert_table(rows: Iterable[dict]) -> str:
    """Tabulate alert rows (``Alert.as_row()`` dicts carry
    ``kind: "alert"``, so a full JSONL export can be passed directly)."""
    alerts = alerts_from_rows(rows)
    body = [(a["t"], a["metric"], a["detector"], a["direction"], a["value"],
             a["score"], a["threshold"], a["sample"]) for a in alerts]
    return format_table(("t(s)", "metric", "detector", "dir", "value",
                         "score", "limit", "sample#"), body)


def detector_table(rows: Iterable[dict]) -> str:
    """Per-detector state table from a JSONL export's ``health`` row (or
    directly from ``HealthMonitors.state_rows()`` dicts)."""
    rows = list(rows)
    health = next((r for r in rows if r.get("kind") == "health"), None)
    states = health["detectors"] if health is not None else rows
    body = []
    for s in states:
        extras = "; ".join(f"{k}={format(v, '.4g') if isinstance(v, float) else v}"
                           for k, v in sorted(s.items())
                           if k not in ("metric", "detector", "alerts",
                                        "samples"))
        body.append((s["metric"], s["detector"], s.get("samples", ""),
                     s.get("alerts", 0), extras))
    return format_table(("metric", "detector", "samples", "alerts", "state"),
                        body)


# ------------------------------------------------- benchmark row formatter
def bench_rows_table(rows: Iterable[dict]) -> str:
    """The shared summary formatter for ``benchmarks.common.json_row``
    rows: the ``derived`` k=v blob is split back into columns."""
    rows = list(rows)
    keys: List[str] = []
    parsed = []
    for r in rows:
        kv = {}
        for part in str(r.get("derived", "")).split(";"):
            if "=" in part:
                k, v = part.split("=", 1)
                kv[k] = v
                if k not in keys:
                    keys.append(k)
        parsed.append(kv)
    headers = ["name", "us_per_call"] + keys
    body = [[r["name"], f"{r['us']:.1f}"] + [kv.get(k, "") for k in keys]
            for r, kv in zip(rows, parsed)]
    return format_table(headers, body)
