"""Hierarchical span tracer on the simulated clock.

A span is one timed thing that happened on the simulated timeline: the
whole run, one Newton/GIANT iteration, one DAG phase, or one per-worker
lifecycle slice (cold start / running / retry / failed attempt).  Spans
form a tree through ``parent_id``: the optimizer opens run and iteration
spans with ``begin``/``end`` (the tracer keeps an open-span stack, so
anything emitted in between — the fleet engine's phase and attempt spans —
parents itself automatically), while completed intervals whose start and
end are both known at emission time go through ``emit``.

All timestamps are *simulated seconds* (the fleet engine's clock), which
is the whole point: the tracer never reads a wall clock and never draws
randomness, so attaching it cannot perturb a run.  ``NullTracer`` is the
zero-overhead default — every method is a constant-time no-op, and
``enabled`` lets instrumentation sites skip even building attribute dicts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional


#: Span kinds, outermost first.  ``track`` is only meaningful for
#: worker-lifecycle kinds (it names the Perfetto worker track).  ``alert``
#: spans are zero-duration markers the health monitors drop into the tree
#: at the simulated instant an anomaly was detected; ``job`` spans cover a
#: tenant job's arrival-to-finish interval (``repro.tenancy``); and
#: ``incident`` spans cover an attributed alert window (``repro.obs.
#: incident``), linking the ranked cause back to the timeline.  The
#: Perfetto exporter skips alert/job/incident kinds (they live in the
#: JSONL export, the report tables, and the HTML console).
KINDS = ("run", "iteration", "phase", "charge", "attempt", "alert", "job",
         "incident")


@dataclasses.dataclass
class Span:
    """One closed interval on the simulated timeline."""

    span_id: int
    parent_id: int                 # 0 = root (no parent)
    name: str
    kind: str                      # one of KINDS
    start: float                   # simulated seconds
    end: float                     # NaN while still open
    track: Optional[str] = None    # worker-track label (attempt spans)
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.start

    def as_row(self) -> dict:
        """JSONL-ready dict (stable key order via sorted serialization)."""
        row = {"kind": "span", "id": self.span_id, "parent": self.parent_id,
               "name": self.name, "span_kind": self.kind,
               "start": float(self.start), "end": float(self.end)}
        if self.track is not None:
            row["track"] = self.track
        if self.attrs:
            row["attrs"] = self.attrs
        return row


class SpanTracer:
    """Collects spans; hierarchy comes from an explicit open-span stack."""

    enabled = True

    def __init__(self):
        self.spans: List[Span] = []
        self._by_id: Dict[int, Span] = {}
        self._stack: List[int] = []
        self._next_id = 1
        # High-water mark of every timestamp seen so far (simulated
        # seconds).  Consumers that observe the run through side channels —
        # the health monitors watch the metrics stream, which carries no
        # clock — stamp their records with this instead of guessing.
        self.last_time = 0.0

    # ------------------------------------------------------------ hierarchy
    @property
    def current(self) -> int:
        """Innermost open span id (0 when nothing is open)."""
        return self._stack[-1] if self._stack else 0

    def begin(self, name: str, kind: str, start: float, **attrs) -> int:
        """Open a span; children emitted before ``end`` parent under it."""
        sid = self._next_id
        self._next_id += 1
        span = Span(span_id=sid, parent_id=self.current, name=name,
                    kind=kind, start=float(start), end=math.nan,
                    attrs=dict(attrs))
        self.spans.append(span)
        self._by_id[sid] = span
        self._stack.append(sid)
        if span.start > self.last_time:
            self.last_time = span.start
        return sid

    def end(self, span_id: int, end: float) -> None:
        """Close an open span.  Closing out of order closes every span
        opened after it too (crash-robust unwinding)."""
        if span_id not in self._by_id:
            raise KeyError(f"unknown span id {span_id}")
        if float(end) > self.last_time:
            self.last_time = float(end)
        while self._stack:
            sid = self._stack.pop()
            self._by_id[sid].end = float(end)
            if sid == span_id:
                return
        raise ValueError(f"span {span_id} is not open")

    def emit(self, name: str, kind: str, start: float, end: float, *,
             parent: Optional[int] = None, track: Optional[str] = None,
             **attrs) -> int:
        """Record a completed span (start and end already known)."""
        sid = self._next_id
        self._next_id += 1
        span = Span(span_id=sid, parent_id=self.current if parent is None
                    else parent, name=name, kind=kind, start=float(start),
                    end=float(end), track=track, attrs=dict(attrs))
        self.spans.append(span)
        self._by_id[sid] = span
        if math.isfinite(span.end) and span.end > self.last_time:
            self.last_time = span.end
        return sid

    def set_attrs(self, span_id: int, **attrs) -> None:
        """Attach/overwrite attributes on an already-created span."""
        self._by_id[span_id].attrs.update(attrs)

    # -------------------------------------------------------------- queries
    def by_kind(self, kind: str) -> List[Span]:
        return [s for s in self.spans if s.kind == kind]

    def children(self, span_id: int) -> List[Span]:
        return [s for s in self.spans if s.parent_id == span_id]


class NullTracer:
    """Zero-overhead tracer: the default when no telemetry is attached.

    Every method returns immediately; ``begin``/``emit`` return span id 0
    so call sites never branch on whether telemetry is live.  Draws no
    randomness and reads no clock — attaching or detaching a tracer can
    never change a simulated ``(seconds, dollars)`` total.
    """

    enabled = False
    spans: List[Span] = []          # always empty; shared sentinel is fine
    current = 0
    last_time = 0.0

    def begin(self, name, kind, start, **attrs) -> int:
        return 0

    def end(self, span_id, end) -> None:
        pass

    def emit(self, name, kind, start, end, *, parent=None, track=None,
             **attrs) -> int:
        return 0

    def set_attrs(self, span_id, **attrs) -> None:
        pass

    def by_kind(self, kind) -> List[Span]:
        return []

    def children(self, span_id) -> List[Span]:
        return []
