"""Self-contained static HTML fleet console.

One run's exported telemetry rows (``repro.obs.export.telemetry_rows`` /
a loaded JSONL file) render into a single HTML file with **zero external
dependencies** — inline CSS, hand-built SVG, no JavaScript required, no
fonts or CDNs — so CI can archive it as a build artifact and anyone can
open it from disk.  Sections:

  - header: run extent, dollars, span/alert/incident counts;
  - an SVG **span timeline**: greedily lane-packed phase/charge spans,
    alert ticks, and translucent incident bands, every phase anchored as
    ``id="span-<id>"`` so incident evidence can deep-link into it;
  - **incident narratives** (``repro.obs.incident``): ranked cause, the
    full hypothesis table, and the evidence list with anchor links back
    to the supporting spans;
  - per-tenant **SLO burn charts** (``repro.obs.slo``): budget remaining
    and fast/slow burn rates over simulated time;
  - the familiar phase / alert / detector / incident summary tables, and
    the benchmark row table when a BENCH payload is passed.

Rendering is a pure function of the rows: no wall-clock timestamps, no
randomness (colors come from a deterministic string hash), so the same
telemetry yields byte-identical HTML.
"""
from __future__ import annotations

import html
import zlib
from typing import List, Optional, Sequence

from repro.obs.export import (alert_table, bench_rows_table, detector_table,
                              phase_table)
from repro.obs.incident import incident_table

_CSS = """
body { font-family: -apple-system, 'Segoe UI', Roboto, sans-serif;
       margin: 2em auto; max-width: 1000px; color: #1c2733;
       background: #fafbfc; }
h1 { font-size: 1.4em; border-bottom: 2px solid #d0d7de; }
h2 { font-size: 1.1em; margin-top: 2em; color: #30445c; }
pre { background: #f2f4f7; border: 1px solid #d0d7de; border-radius: 6px;
      padding: 0.8em; overflow-x: auto; font-size: 12px; }
svg { background: #fff; border: 1px solid #d0d7de; border-radius: 6px; }
.inc { border-left: 4px solid #c0392b; background: #fff;
       border-radius: 4px; padding: 0.6em 1em; margin: 0.8em 0;
       box-shadow: 0 1px 2px rgba(27,31,35,.08); }
.inc h3 { margin: 0 0 0.3em 0; font-size: 1em; }
.inc ul { margin: 0.3em 0; padding-left: 1.4em; font-size: 0.85em; }
.kpi { display: inline-block; background: #fff; border: 1px solid #d0d7de;
       border-radius: 6px; padding: 0.4em 0.9em; margin-right: 0.6em;
       font-size: 0.9em; }
.kpi b { display: block; font-size: 1.2em; }
a { color: #0969da; text-decoration: none; }
"""

_PALETTE = ("#4c78a8", "#f58518", "#54a24b", "#b279a2", "#e45756",
            "#72b7b2", "#eeca3b", "#9d755d", "#79706e", "#d67195")


def _esc(s) -> str:
    return html.escape(str(s), quote=True)


def _color(name: str) -> str:
    # crc32, not hash(): str hashing is salted per process and the console
    # must be byte-stable across runs.
    return _PALETTE[zlib.crc32(name.encode()) % len(_PALETTE)]


def _lane_pack(spans: List[dict]) -> List[int]:
    """Greedy first-fit lanes for possibly-overlapping intervals."""
    lanes: List[float] = []
    out = []
    for r in sorted(range(len(spans)), key=lambda i: (spans[i]["start"],
                                                      spans[i]["end"])):
        s = spans[r]
        for li, free_at in enumerate(lanes):
            if s["start"] >= free_at - 1e-12:
                lanes[li] = s["end"]
                break
        else:
            li = len(lanes)
            lanes.append(s["end"])
        out.append((r, li))
    lane_of = [0] * len(spans)
    for r, li in out:
        lane_of[r] = li
    return lane_of


def _timeline_svg(rows: Sequence[dict], width: int = 960) -> str:
    phases = [r for r in rows if r.get("kind") == "span"
              and r.get("span_kind") in ("phase", "charge")]
    alerts = [r for r in rows if r.get("kind") == "alert"]
    incidents = [r for r in rows if r.get("kind") == "incident"]
    if not phases:
        return "<p>(no phase spans recorded)</p>"
    t0 = min(r["start"] for r in phases)
    t1 = max(r["end"] for r in phases)
    extent = max(t1 - t0, 1e-9)
    lane_of = _lane_pack(phases)
    n_lanes = max(lane_of) + 1
    row_h, pad_top = 18, 24
    height = pad_top + n_lanes * row_h + 26

    def x(t: float) -> float:
        return round(10 + (t - t0) / extent * (width - 20), 2)

    parts = [f'<svg width="{width}" height="{height}" '
             'xmlns="http://www.w3.org/2000/svg" font-size="10">']
    # incident bands first, behind everything
    for inc in incidents:
        bx0, bx1 = x(inc["t_start"]), x(inc["t_end"])
        parts.append(
            f'<rect id="incident-band-{inc["id"]}" x="{bx0}" y="{pad_top}" '
            f'width="{max(bx1 - bx0, 2.0)}" '
            f'height="{n_lanes * row_h}" fill="#e45756" opacity="0.15">'
            f'<title>incident {inc["id"]}: {_esc(inc["cause"])}</title>'
            '</rect>')
    for r, lane in zip(phases, lane_of):
        px0, px1 = x(r["start"]), x(r["end"])
        y = pad_top + lane * row_h
        name = r["name"]
        dollars = float((r.get("attrs") or {}).get("dollars", 0.0))
        parts.append(
            f'<rect id="span-{r.get("id", 0)}" x="{px0}" y="{y + 2}" '
            f'width="{max(px1 - px0, 1.5)}" height="{row_h - 5}" '
            f'rx="2" fill="{_color(name.split("/")[-1].split(":")[0])}">'
            f'<title>{_esc(name)} [{r["start"]:.3f}s – {r["end"]:.3f}s] '
            f'${dollars:.6f}</title></rect>')
        if px1 - px0 > 7 * len(name) * 0.45:
            parts.append(f'<text x="{px0 + 3}" y="{y + row_h - 6}" '
                         f'fill="#fff">{_esc(name)}</text>')
    tick_y = pad_top + n_lanes * row_h
    for a in alerts:
        ax = x(a["t"])
        parts.append(
            f'<path d="M{ax} {tick_y} l-4 8 l8 0 z" fill="#c0392b">'
            f'<title>alert {_esc(a["metric"])} @ {a["t"]:.3f}s '
            f'({_esc(a["detector"])})</title></path>')
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        t = t0 + frac * extent
        parts.append(f'<text x="{x(t)}" y="14" fill="#666" '
                     f'text-anchor="middle">{t:.2f}s</text>')
    parts.append(f'<text x="10" y="{tick_y + 22}" fill="#666">'
                 f'{len(phases)} phase spans, {len(alerts)} alerts, '
                 f'{len(incidents)} incidents</text>')
    parts.append("</svg>")
    return "".join(parts)


def _burn_chart_svg(slo_row: dict, width: int = 460,
                    height: int = 130) -> str:
    series = slo_row.get("series") or []
    if not series:
        return "<p>(no jobs recorded)</p>"
    ts = [p[0] for p in series]
    t0, t1 = min(ts), max(ts)
    extent = max(t1 - t0, 1e-9)
    burns = [max(p[2], p[3]) for p in series]
    ymax = max(1.5, max(burns), 1.0)

    def x(t):
        return round(36 + (t - t0) / extent * (width - 46), 2)

    def y_budget(v):   # budget axis: [-0.2, 1.05] -> pixels
        v = max(-0.2, min(1.05, v))
        return round(10 + (1.05 - v) / 1.25 * (height - 30), 2)

    def y_burn(v):     # burn axis: [0, ymax]
        v = max(0.0, min(ymax, v))
        return round(10 + (ymax - v) / ymax * (height - 30), 2)

    def poly(pts, color, dash=""):
        path = " ".join(f"{px},{py}" for px, py in pts)
        d = f' stroke-dasharray="{dash}"' if dash else ""
        return (f'<polyline points="{path}" fill="none" '
                f'stroke="{color}" stroke-width="1.5"{d}/>')

    parts = [f'<svg width="{width}" height="{height}" '
             'xmlns="http://www.w3.org/2000/svg" font-size="9">']
    zero = y_budget(0.0)
    parts.append(f'<line x1="36" y1="{zero}" x2="{width - 8}" y2="{zero}" '
                 'stroke="#d0d7de"/>')
    parts.append(poly([(x(p[0]), y_budget(p[1])) for p in series],
                      "#2e7d32"))
    parts.append(poly([(x(p[0]), y_burn(p[2])) for p in series],
                      "#c0392b", dash="4 2"))
    parts.append(poly([(x(p[0]), y_burn(p[3])) for p in series],
                      "#f58518", dash="2 2"))
    parts.append(f'<text x="4" y="{y_budget(1.0) + 3}" '
                 'fill="#2e7d32">1.0</text>')
    parts.append(f'<text x="4" y="{zero + 3}" fill="#666">0.0</text>')
    parts.append(
        f'<text x="36" y="{height - 4}" fill="#666">'
        f'budget (green, left) · burn fast/slow (red/orange, right, '
        f'max {ymax:.1f}x) · t ∈ [{t0:.2f}s, {t1:.2f}s]</text>')
    parts.append("</svg>")
    return "".join(parts)


def _incident_html(inc: dict) -> str:
    hyp = ", ".join(f"{_esc(c)}={s:.2f}" for c, s in inc["hypotheses"])
    ev_items = []
    for e in inc["evidence"]:
        link = (f' <a href="#span-{e["span"]}">span {e["span"]}</a>'
                if e.get("span") else "")
        ev_items.append(f'<li>[{e["kind"]}, w={e["weight"]:.2f}] '
                        f'{_esc(e["detail"])}{link}</li>')
    blamed = []
    if inc.get("tenant"):
        blamed.append(f'tenant <b>{_esc(inc["tenant"])}</b>')
    if inc.get("phase"):
        cp = inc.get("on_critical_path")
        tag = "" if cp is None else (" (on critical path)" if cp
                                     else " (off critical path)")
        blamed.append(f'phase <b>{_esc(inc["phase"])}</b>{tag}')
    blame = " — blames " + ", ".join(blamed) if blamed else ""
    return (
        f'<div class="inc" id="incident-{inc["id"]}">'
        f'<h3><a href="#incident-band-{inc["id"]}">#{inc["id"]}</a> '
        f'{_esc(inc["cause"])} (score {inc["score"]:.2f}) '
        f'[{inc["t_start"]:.3f}s – {inc["t_end"]:.3f}s]</h3>'
        f'<p>{inc["n_alerts"]} alert(s) on '
        f'{_esc(", ".join(inc["alert_metrics"]))}{blame}. '
        f'Impact: {inc["impact_seconds"]:.3f}s, '
        f'${inc["impact_dollars"]:.6f}. Hypotheses: {hyp}.</p>'
        f'<ul>{"".join(ev_items)}</ul></div>')


def render(rows: Sequence[dict], *, bench: Optional[Sequence[dict]] = None,
           title: str = "fleet console") -> str:
    """Render telemetry rows (plus an optional BENCH payload's ``rows``
    list) into one self-contained HTML page.  Pure function of its
    inputs: byte-identical output for identical rows."""
    rows = list(rows)
    spans = [r for r in rows if r.get("kind") == "span"]
    phases = [r for r in spans if r.get("span_kind") in ("phase", "charge")]
    alerts = [r for r in rows if r.get("kind") == "alert"]
    incidents = [r for r in rows if r.get("kind") == "incident"]
    slo_rows = [r for r in rows if r.get("kind") == "slo"]
    extent = (max(r["end"] for r in phases)
              - min(r["start"] for r in phases)) if phases else 0.0
    dollars = sum(float((r.get("attrs") or {}).get("dollars", 0.0))
                  for r in phases)

    kpis = [("span rows", str(len(spans))),
            ("run extent", f"{extent:.3f}s"),
            ("phase dollars", f"${dollars:.6f}"),
            ("alerts", str(len(alerts))),
            ("incidents", str(len(incidents))),
            ("tenants w/ SLO", str(len(slo_rows)))]
    kpi_html = "".join(f'<span class="kpi"><b>{_esc(v)}</b>{_esc(k)}</span>'
                       for k, v in kpis)

    body = [f"<h1>{_esc(title)}</h1>", f"<p>{kpi_html}</p>",
            "<h2>Timeline</h2>", _timeline_svg(rows)]

    body.append("<h2>Incidents</h2>")
    if incidents:
        body.extend(_incident_html(inc) for inc in incidents)
        body.append("<pre>" + _esc(incident_table(incidents)) + "</pre>")
    else:
        body.append("<p>No incidents attributed.</p>")

    if slo_rows:
        body.append("<h2>Per-tenant SLO burn</h2>")
        for s in sorted(slo_rows, key=lambda r: r["tenant"]):
            shed = (' — <b style="color:#c0392b">budget exhausted</b>'
                    if s["budget_remaining"] <= 0 else "")
            body.append(
                f'<p><b>{_esc(s["tenant"])}</b>: {s["jobs"]} jobs, '
                f'{s["bad_jobs"]} bad, budget remaining '
                f'{s["budget_remaining"]:.3f}, burn fast/slow '
                f'{s["burn_fast"]:.2f}x / {s["burn_slow"]:.2f}x, '
                f'${s["dollars"]:.6f} spent{shed}</p>')
            body.append(_burn_chart_svg(s))

    if phases:
        body.append("<h2>Phases</h2>")
        body.append("<pre>" + _esc(phase_table(rows)) + "</pre>")
    if alerts:
        body.append("<h2>Alerts</h2>")
        body.append("<pre>" + _esc(alert_table(rows)) + "</pre>")
        body.append("<h2>Detectors</h2>")
        body.append("<pre>" + _esc(detector_table(rows)) + "</pre>")
    if bench:
        body.append("<h2>Benchmark rows</h2>")
        body.append("<pre>" + _esc(bench_rows_table(bench)) + "</pre>")

    return ("<!DOCTYPE html>\n<html><head><meta charset=\"utf-8\">"
            f"<title>{_esc(title)}</title><style>{_CSS}</style></head>"
            "<body>\n" + "\n".join(body) + "\n</body></html>\n")


def write_console(path, rows: Sequence[dict], *,
                  bench: Optional[Sequence[dict]] = None,
                  title: str = "fleet console") -> None:
    with open(path, "w") as f:
        f.write(render(rows, bench=bench, title=title))
