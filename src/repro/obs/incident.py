"""Alert -> root-cause attribution: typed, ranked, deterministic incidents.

The health monitors (``repro.obs.health``) say *that* something degraded;
this module says *why*.  ``attribute`` clusters a run's alerts into time
windows and correlates each window against every evidence stream the
stack records:

  - **Injected-fault signatures** — the per-phase nonzero fault counters
    the engine attaches to phase spans when a ``FaultPlan`` is active
    (``attrs["faults"]``: burst kills, throttle rejections, S3 retries,
    OOM kills, pool culls, corrupted workers).
  - **Declared fault windows** — ``FaultPlan.events()``: what the chaos
    plan *said* it would do, and when.
  - **Critical path & slack** — CPM reports reconstructed from the
    dispatched DAGs' recorded deps: whether the blamed phase was on the
    critical path (an incident there costs makespan; one in slack may
    not).
  - **Tenant attribution** — phase spans labelled ``tenant/job/phase``
    by the tenancy scheduler plus its ``job`` spans: which tenant's
    dollars dominate the window (a noisy neighbour is a cause in its own
    right).
  - **Sketch-quality gauges** — ``sketch.mp_debias`` / ``sketch.
    survivors`` / CG-count alerts point at sketch-quality drift rather
    than fleet trouble.

Every hypothesis accumulates weighted ``Evidence``; causes are ranked by
total weight and the window becomes one typed ``Incident`` (top cause,
full ranking, evidence list with span links, blamed tenant/phase/worker
cohort, seconds + dollars impact).  Attribution is a pure function of
already-recorded telemetry — it draws no randomness, reads no wall
clock, and never touches the simulation, so the same seed and the same
``FaultPlan`` yield byte-identical incident JSONL (pinned by a committed
golden fixture).  Like everything in ``obs``, it composes with the
inertness contract: running ``attribute`` after a run cannot change its
``(seconds, dollars)`` totals.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: The typed cause vocabulary, ranked hypotheses draw from exactly this
#: set.  The first six mirror the chaos-plane scenario registry
#: (``repro.runtime.faults.available_scenarios``); the rest are organic
#: causes no plan declares.
CAUSES = ("az_burst", "throttle", "s3_transient", "oom", "pool_death",
          "corruption", "pool_collapse", "tenant_hog", "sketch_quality",
          "workload_shift", "unknown")

#: Per-phase injected-fault counter -> the cause it is a signature of.
SIGNATURES = {
    "burst_kills": "az_burst",
    "throttled": "throttle",
    "s3_get_retries": "s3_transient",
    "s3_put_retries": "s3_transient",
    "oom_kills": "oom",
    "oom_escalations": "oom",
    "pool_killed": "pool_death",
    "corrupted_workers": "corruption",
}

#: Alert metric -> causes it is a known symptom of.  Straggler-stream
#: alerts are deliberately broad: most failure modes present as a fatter
#: completion tail, so the symptom only breaks ties that signatures and
#: declared windows leave open.
SYMPTOMS = {
    "worker.completion_s": ("az_burst", "throttle", "s3_transient", "oom",
                            "workload_shift"),
    "phase.tail_p95_s": ("az_burst", "throttle", "s3_transient", "oom",
                         "workload_shift"),
    "newton.iter_seconds": ("workload_shift",),
    "newton.iter_dollars": ("workload_shift",),
    "pool.phase_hit_rate": ("pool_death", "pool_collapse"),
    "coded.block_error_rate": ("corruption",),
    "sketch.mp_debias": ("sketch_quality",),
    "newton.cg_iters": ("sketch_quality",),
    "giant.cg_iters": ("sketch_quality",),
}

# Evidence weights: a declared plan window is the strongest signal (the
# chaos plane told us), a recorded per-phase signature nearly as strong
# (the engine saw it happen), symptoms only break ties.
W_PLAN = 4.0
W_SIGNATURE = 3.0
W_SYMPTOM = 0.5
W_TENANT = 2.0
W_ORGANIC = 1.5          # pool_collapse / workload_shift when nothing else fits


@dataclasses.dataclass(frozen=True)
class IncidentConfig:
    """Attribution knobs; the defaults match the simulator's scales."""

    merge_gap_s: float = 1.0   # alerts closer than this share a window
    pad_s: float = 0.5         # window padding when matching phase spans
    tenant_share: float = 0.65  # dollar share that makes a tenant a hog


@dataclasses.dataclass
class Evidence:
    """One weighted observation supporting one cause hypothesis."""

    cause: str      # the hypothesis this supports (one of CAUSES)
    kind: str       # "fault_plan"|"fault_stat"|"symptom"|"tenant"|"organic"
    detail: str     # human-readable statement
    weight: float
    t: float        # simulated seconds the observation anchors to
    span: Optional[int] = None   # supporting span id, when there is one

    def as_dict(self) -> dict:
        d = {"cause": self.cause, "kind": self.kind, "detail": self.detail,
             "weight": self.weight, "t": self.t}
        if self.span is not None:
            d["span"] = self.span
        return d


@dataclasses.dataclass
class Incident:
    """One attributed alert window."""

    id: int
    cause: str                       # top-ranked hypothesis
    score: float                     # its evidence weight
    t_start: float
    t_end: float
    hypotheses: List[Tuple[str, float]]   # full ranking, best first
    evidence: List[Evidence]
    n_alerts: int
    alert_metrics: List[str]
    tenant: Optional[str]            # blamed tenant (dollar-dominant)
    phase: Optional[str]             # blamed phase (dollar-dominant)
    on_critical_path: Optional[bool]  # blamed phase on the CPM chain?
    cohort: Dict[str, int]           # failed/retry attempt counts in window
    impact_seconds: float            # window extent over affected phases
    impact_dollars: float            # dollars of overlapping phases
    span: Optional[int] = None       # the linked "incident" span, if emitted

    def as_row(self) -> dict:
        """JSONL-ready dict (``kind: "incident"``), fully deterministic."""
        return {"kind": "incident", "id": self.id, "cause": self.cause,
                "score": round(self.score, 6),
                "t_start": self.t_start, "t_end": self.t_end,
                "hypotheses": [[c, round(s, 6)] for c, s in self.hypotheses],
                "evidence": [e.as_dict() for e in self.evidence],
                "n_alerts": self.n_alerts,
                "alert_metrics": self.alert_metrics,
                "tenant": self.tenant, "phase": self.phase,
                "on_critical_path": self.on_critical_path,
                "cohort": self.cohort,
                "impact_seconds": self.impact_seconds,
                "impact_dollars": self.impact_dollars}

    def narrative(self) -> str:
        """One-paragraph operator-readable story for reports/console."""
        parts = [f"[{self.t_start:.3f}s – {self.t_end:.3f}s] "
                 f"cause={self.cause} (score {self.score:.2f}, "
                 f"{self.n_alerts} alert(s) on "
                 f"{', '.join(self.alert_metrics)})."]
        if self.tenant:
            parts.append(f"Blamed tenant: {self.tenant}.")
        if self.phase:
            onoff = ("on" if self.on_critical_path else "off") \
                if self.on_critical_path is not None else "unknown vs"
            parts.append(f"Blamed phase: {self.phase} ({onoff} the "
                         "critical path).")
        if self.cohort.get("failed") or self.cohort.get("retries"):
            parts.append(f"Worker cohort: {self.cohort.get('failed', 0)} "
                         f"failed, {self.cohort.get('retries', 0)} retried "
                         f"attempts across {self.cohort.get('workers', 0)} "
                         "tracks.")
        parts.append(f"Impact: {self.impact_seconds:.3f}s, "
                     f"${self.impact_dollars:.6f}.")
        if len(self.hypotheses) > 1:
            alt = ", ".join(f"{c}={s:.2f}" for c, s in self.hypotheses[1:4])
            parts.append(f"Runners-up: {alt}.")
        return " ".join(parts)


# --------------------------------------------------------------- internals
def _overlaps(a0: float, a1: float, b0: float, b1: Optional[float]) -> bool:
    return a1 >= b0 and (b1 is None or a0 <= b1)


def _phase_rows(rows: Iterable[dict]) -> List[dict]:
    return [r for r in rows if r.get("kind") == "span"
            and r.get("span_kind") == "phase"]


def _tenants_from_rows(rows: Sequence[dict]) -> List[str]:
    """Tenant names, from the tenancy scheduler's job spans."""
    seen = []
    for r in rows:
        if r.get("kind") == "span" and r.get("span_kind") == "job":
            t = (r.get("attrs") or {}).get("tenant")
            if t and t not in seen:
                seen.append(t)
    return seen


def _critical_sets(rows: Sequence[dict]) -> List[set]:
    """Critical-path phase-name sets of every reconstructable DAG."""
    from repro.obs.export import dag_reports_from_rows
    try:
        return [set(rep.critical_path)
                for rep in dag_reports_from_rows(rows)]
    except Exception:   # noqa: BLE001 — CPM is best-effort evidence
        return []


def _attribute_window(idx: int, t0: float, t1: float, alerts: List[dict],
                      rows: Sequence[dict], fault_events: List[dict],
                      tenants: List[str], critical_sets: List[set],
                      cfg: IncidentConfig) -> Incident:
    lo, hi = t0 - cfg.pad_s, t1 + cfg.pad_s
    evidence: List[Evidence] = []
    phases = [r for r in _phase_rows(rows)
              if r["end"] >= lo and r["start"] <= hi]

    # (a) declared fault windows overlapping this alert window
    for ev in fault_events:
        if _overlaps(lo, hi, ev["t_start"], ev["t_end"]):
            end = "run end" if ev["t_end"] is None else f"{ev['t_end']:.3f}s"
            evidence.append(Evidence(
                ev["cause"], "fault_plan",
                f"FaultPlan declares {ev['cause']} "
                f"[{ev['t_start']:.3f}s – {end}] ({ev['detail']})",
                W_PLAN, ev["t_start"]))

    # (b) per-phase injected-fault signatures recorded on phase spans
    sig_totals: Dict[str, int] = {}
    for r in phases:
        for stat, count in sorted(((r.get("attrs") or {}).get("faults")
                                   or {}).items()):
            cause = SIGNATURES.get(stat)
            if cause is None or not count:
                continue
            sig_totals[stat] = sig_totals.get(stat, 0) + int(count)
            evidence.append(Evidence(
                cause, "fault_stat",
                f"phase {r['name']}: {stat}={int(count)}",
                W_SIGNATURE * min(1.0, 0.25 + 0.25 * math.log10(1 + count)),
                r["start"], span=r.get("id")))

    # (c) alert-metric symptom affinity
    metrics_seen: List[str] = []
    for a in alerts:
        if a["metric"] not in metrics_seen:
            metrics_seen.append(a["metric"])
        for cause in SYMPTOMS.get(a["metric"], ()):
            evidence.append(Evidence(
                cause, "symptom",
                f"{a['detector']} alert on {a['metric']} "
                f"(value {a['value']:.4g}, {a['direction']})",
                W_SYMPTOM, a["t"]))

    # (d) tenant attribution: who spent the window's dollars
    tenant_dollars: Dict[str, float] = {}
    for r in phases:
        name = r["name"]
        head = name.split("/", 1)[0]
        if head in tenants:
            d = float((r.get("attrs") or {}).get("dollars", 0.0))
            tenant_dollars[head] = tenant_dollars.get(head, 0.0) + d
    blamed_tenant = None
    if tenant_dollars:
        blamed_tenant, top_d = max(sorted(tenant_dollars.items()),
                                   key=lambda kv: kv[1])
        total_d = sum(tenant_dollars.values())
        share = top_d / total_d if total_d else 0.0
        if len(tenant_dollars) >= 2 and share >= cfg.tenant_share:
            evidence.append(Evidence(
                "tenant_hog", "tenant",
                f"tenant {blamed_tenant} holds {100 * share:.0f}% of the "
                f"window's phase dollars (${top_d:.6f} of ${total_d:.6f})",
                W_TENANT, t0))

    # (e) organic causes when the declared/recorded streams are silent
    declared = {ev["cause"] for ev in fault_events}
    sig_causes = {SIGNATURES[s] for s in sig_totals}
    if ("pool.phase_hit_rate" in metrics_seen
            and "pool_death" not in declared
            and "pool_killed" not in sig_totals):
        evidence.append(Evidence(
            "pool_collapse", "organic",
            "warm-pool hit rate collapsed with no declared or recorded "
            "container cull — organic pool churn", W_ORGANIC, t0))
    straggler = {"worker.completion_s", "phase.tail_p95_s"}
    if (set(metrics_seen) & straggler) and not declared and not sig_causes:
        evidence.append(Evidence(
            "workload_shift", "organic",
            "straggler tail shifted with no fault plan active and no "
            "injected-fault signature — the workload itself changed",
            W_ORGANIC, t0))

    # Rank hypotheses by accumulated evidence weight (name-ordered ties).
    scores: Dict[str, float] = {}
    for e in evidence:
        scores[e.cause] = scores.get(e.cause, 0.0) + e.weight
    ranked = sorted(scores.items(), key=lambda kv: (-kv[1], kv[0]))
    if not ranked:
        ranked = [("unknown", 0.0)]
    cause, score = ranked[0]

    # Blamed phase: the dollar-dominant overlapping phase; critical-path
    # membership from any reconstructed DAG that contains it.
    blamed_phase = None
    on_cp: Optional[bool] = None
    if phases:
        blamed = max(phases,
                     key=lambda r: (float((r.get("attrs") or {})
                                          .get("dollars", 0.0)),
                                    -r["start"]))
        blamed_phase = blamed["name"]
        if critical_sets:
            on_cp = any(blamed_phase in cs for cs in critical_sets)
            which = "ON" if on_cp else "OFF"
            evidence.append(Evidence(
                cause, "critical_path",
                f"blamed phase {blamed_phase} is {which} the CPM critical "
                "path of its DAG", 0.0, blamed["start"],
                span=blamed.get("id")))

    # Worker cohort: failed/retry attempt spans inside the window.
    failed = retries = 0
    tracks = set()
    for r in rows:
        if (r.get("kind") == "span" and r.get("span_kind") == "attempt"
                and r["end"] >= lo and r["start"] <= hi):
            if r["name"] == "failed":
                failed += 1
                tracks.add(r.get("track"))
            elif r["name"] == "retry":
                retries += 1
                tracks.add(r.get("track"))
    cohort = {"failed": failed, "retries": retries,
              "workers": len(tracks - {None})}

    if phases:
        impact_s = (max(r["end"] for r in phases)
                    - min(r["start"] for r in phases))
        impact_d = sum(float((r.get("attrs") or {}).get("dollars", 0.0))
                       for r in phases)
    else:
        impact_s, impact_d = t1 - t0, 0.0

    evidence.sort(key=lambda e: (-e.weight, e.cause, e.t, e.detail))
    return Incident(
        id=idx, cause=cause, score=score, t_start=t0, t_end=t1,
        hypotheses=ranked, evidence=evidence, n_alerts=len(alerts),
        alert_metrics=metrics_seen, tenant=blamed_tenant,
        phase=blamed_phase, on_critical_path=on_cp, cohort=cohort,
        impact_seconds=impact_s, impact_dollars=impact_d)


# ------------------------------------------------------------- public API
def attribute_rows(rows: Sequence[dict], alerts: Sequence[dict],
                   fault_events: Optional[Sequence[dict]] = None,
                   config: IncidentConfig = IncidentConfig()
                   ) -> List[Incident]:
    """Core attribution on exported rows: cluster ``alerts`` into windows
    (``merge_gap_s``), attribute each against ``rows`` (span rows) and the
    declared ``fault_events`` (``FaultPlan.events()``), and return
    incidents ranked most-severe (highest score) first."""
    if not alerts:
        return []
    fault_events = list(fault_events or ())
    ordered = sorted(alerts, key=lambda a: (a["t"], a["metric"],
                                            a["detector"]))
    windows: List[Tuple[float, float, List[dict]]] = []
    t0 = t1 = ordered[0]["t"]
    bucket = [ordered[0]]
    for a in ordered[1:]:
        if a["t"] - t1 <= config.merge_gap_s:
            t1 = a["t"]
            bucket.append(a)
        else:
            windows.append((t0, t1, bucket))
            t0 = t1 = a["t"]
            bucket = [a]
    windows.append((t0, t1, bucket))

    tenants = _tenants_from_rows(rows)
    critical_sets = _critical_sets(rows)
    incidents = [_attribute_window(i, w0, w1, ws, rows, fault_events,
                                   tenants, critical_sets, config)
                 for i, (w0, w1, ws) in enumerate(windows)]
    incidents.sort(key=lambda inc: (-inc.score, inc.t_start, inc.id))
    return incidents


def attribute(telemetry, faults=None,
              config: IncidentConfig = IncidentConfig()) -> List[Incident]:
    """Attribute a live ``Telemetry``'s alerts; the convenience entry.

    ``faults`` is the run's ``FaultPlan`` (or None).  When the telemetry
    is live, each incident is also dropped into the span tree as a linked
    ``incident`` span and the list is stored at ``telemetry.incidents``
    (so ``telemetry_rows`` / ``dump_jsonl`` export them).  Runs without
    monitors — or without alerts — attribute to an empty list.
    """
    health = getattr(telemetry, "health", None)
    alerts = [a.as_row() for a in health.alerts] if health is not None \
        else []
    rows = [s.as_row() for s in telemetry.trace.spans]
    events = faults.events() if faults is not None else []
    incidents = attribute_rows(rows, alerts, events, config)
    if getattr(telemetry, "enabled", False):
        for inc in incidents:
            inc.span = telemetry.trace.emit(
                f"incident:{inc.cause}", "incident", inc.t_start,
                inc.t_end, cause=inc.cause, score=round(inc.score, 6),
                n_alerts=inc.n_alerts,
                impact_dollars=inc.impact_dollars)
        telemetry.incidents = incidents
    return incidents


def incident_rows(incidents: Sequence[Incident]) -> List[dict]:
    return [inc.as_row() for inc in incidents]


def dump_incidents(incidents: Sequence[Incident], path) -> None:
    """Byte-stable incident JSONL (sorted keys) — the golden-fixture
    format: same seed + same FaultPlan => byte-identical file."""
    with open(path, "w") as f:
        for inc in incidents:
            f.write(json.dumps(inc.as_row(), sort_keys=True) + "\n")


def incident_table(rows_or_incidents) -> str:
    """Tabulate incidents (``Incident`` objects or ``kind: "incident"``
    JSONL rows, full exports welcome)."""
    from repro.obs.export import format_table
    body = []
    for r in rows_or_incidents:
        if isinstance(r, Incident):
            r = r.as_row()
        if r.get("kind") != "incident":
            continue
        body.append((r["t_start"], r["t_end"], r["cause"], r["score"],
                     r["n_alerts"], r.get("tenant") or "",
                     r.get("phase") or "", r["impact_seconds"],
                     r["impact_dollars"]))
    return format_table(("t0(s)", "t1(s)", "cause", "score", "alerts",
                         "tenant", "phase", "impact_s", "impact_usd"),
                        body)
