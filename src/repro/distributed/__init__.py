"""Distribution substrate: sharding policy + resilient collectives."""
from repro.distributed.sharding import (activation_constraint, batch_axes,
                                        batch_shardings, cache_shardings,
                                        opt_state_shardings, param_shardings,
                                        resolve_pspec)
from repro.distributed.collectives import resilient_psum
