"""Sharding policy: logical parameter/activation axes -> mesh axes.

Baseline layout (recorded in EXPERIMENTS.md as the §Perf starting point):
  params:  TP on "model" (heads / ffn / experts / vocab / rnn) + FSDP on
           "data" (embed);  replicated across "pod" (per-pod parameter copy,
           gradient all-reduce over pods).
  train activations: batch over ("pod","data"), sequence over "model"
           (sequence parallelism between layers — the attention/MLP internals
           re-gather what they need).
  decode caches: batch over ("pod","data") when divisible; kv_heads over
           "model" when divisible, else cache seq over "model";
           long-context (batch=1): cache seq over ("data","model").

Rules are applied with divisibility checks and the PartitionSpec constraint
that a mesh axis appears at most once per spec.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Pytree = Any

# logical axis -> candidate mesh axes (first that divides wins).
#
# Design note (EXPERIMENTS.md §Perf iteration 1): params sharded over "data"
# (FSDP) are loop-invariant inputs to the layer scan, and GSPMD hoists their
# all-gather OUT of the loop — the full stacked weights materialize per chip.
# So the parameter layout is pure 2-D tensor parallelism instead: every large
# matmul dim that the computation can consume *sharded* (heads/ffn/vocab/
# experts/rnn on "model"; the per-expert ffn dim additionally on "data" —
# expert einsums keep it sharded end-to-end).  Optimizer state gets ZeRO-1
# sharding over "data" (it lives outside the scan, so its gathers are not
# hoistable into oblivion).
PARAM_RULES: Dict[Optional[str], Tuple[str, ...]] = {
    "embed": (),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    # Fallback TP axis: when num_heads is not divisible by the model axis
    # (llava 56, qwen2 28, whisper 20 on a 16-wide axis) the head_dim
    # (128/256/64 — always divisible) carries the sharding so QKV/O weights
    # never replicate (§Perf iteration: -12.4 GB/chip on llava train).
    "head_dim": ("model",),
    "ffn": ("model",),
    "expert_ffn": ("data",),
    "experts": ("model",),
    "rnn": ("model",),
    "layers": (),
    "conv": (),
    "state": (),
    "classes": (),
    None: (),
}


def _mesh_size(mesh: Mesh, axis: str) -> int:
    return mesh.shape[axis] if axis in mesh.shape else 0


def resolve_pspec(shape: Sequence[int], axes: Sequence[Optional[str]],
                  mesh: Mesh,
                  rules: Dict[Optional[str], Tuple[str, ...]] = PARAM_RULES
                  ) -> P:
    """Logical axes -> PartitionSpec, honouring divisibility and the
    one-mesh-axis-per-spec constraint (first dim that claims an axis keeps
    it; later dims fall back to replication)."""
    used = set()
    entries = []
    for dim, logical in zip(shape, axes):
        choice = None
        for cand in rules.get(logical, ()):  # first candidate that fits
            size = _mesh_size(mesh, cand)
            if size and dim % size == 0 and cand not in used:
                choice = cand
                used.add(cand)
                break
        entries.append(choice)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_shardings(bundle, mesh: Mesh) -> Pytree:
    """NamedSharding tree aligned with the bundle's param tree."""
    from repro.models.common import Spec
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_pspec(s.shape, s.axes, mesh)),
        bundle.specs(), is_leaf=lambda x: isinstance(x, Spec))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch shards over (pod major)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _batch_spec(mesh: Mesh, batch: int):
    axes = batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return axes if axes and batch % total == 0 else None


def batch_shardings(bundle, mesh: Mesh, input_specs: Dict[str, Any]
                    ) -> Dict[str, NamedSharding]:
    """Shardings for a train/prefill batch dict (leading dim = batch)."""
    out = {}
    for name, sds in input_specs.items():
        b_ax = _batch_spec(mesh, sds.shape[0])
        spec = [b_ax] + [None] * (len(sds.shape) - 1)
        if name in ("frame_embeds", "patch_embeds") and len(sds.shape) == 3:
            pass  # (B, T, d): batch-sharded only
        out[name] = NamedSharding(mesh, P(*spec))
    return out


def activation_constraint(mesh: Mesh, seq_shard: bool = True):
    """Two-point Megatron-SP constraint hook for training.

    kind="carry": the residual stream *between* layers — batch over
      ("pod","data") and sequence over "model".  This is what the layer-scan
      remat saves, so it must be small.
    kind="inner": activations *inside* a block right before the TP matmuls —
      full sequence (forces the seq all-gather to live inside the loop, which
      keeps the weight all-gather out of GSPMD's reach: weights stay
      TP-sharded, activations pay a per-layer gather/reduce-scatter pair).
    """
    b_ax = batch_axes(mesh)

    def constrain(h, kind: str = "carry"):
        if h.ndim != 3:
            return h
        seq_ax = None
        if kind == "carry" and seq_shard and "model" in mesh.shape and \
                h.shape[1] % mesh.shape["model"] == 0:
            seq_ax = "model"
        spec = P(b_ax if b_ax else None, seq_ax)
        return jax.lax.with_sharding_constraint(h, NamedSharding(mesh, spec))

    return constrain


# ----------------------------------------------------------- cache policy ----
def cache_shardings(cfg, cache_abstract: Pytree, mesh: Mesh,
                    long_context: bool = False) -> Pytree:
    """Shardings for a serving cache tree (matched by structure)."""
    b_ax = batch_axes(mesh)
    model_sz = _mesh_size(mesh, "model")

    def kv_spec(shape):
        # (L, B, S, KV, hd)
        _, b, s, kv, _ = shape
        batch_ok = b_ax and all(b % _mesh_size(mesh, a) == 0 for a in b_ax) \
            and b >= max(_mesh_size(mesh, a) for a in b_ax)
        total_b = 1
        for a in b_ax:
            total_b *= _mesh_size(mesh, a)
        batch_ok = b_ax and b % total_b == 0
        if long_context or not batch_ok:
            # batch unshardable: spread the sequence over everything
            seq_axes = tuple(a for a in ("data", "model") if a in mesh.shape
                             and s % _mesh_size(mesh, a) == 0)
            # combined divisibility
            tot = 1
            for a in seq_axes:
                tot *= _mesh_size(mesh, a)
            seq_axes = seq_axes if tot and s % tot == 0 else ()
            return P(None, None, seq_axes or None)
        if model_sz and kv % model_sz == 0:
            return P(None, b_ax, None, "model")
        if model_sz and s % model_sz == 0:
            return P(None, b_ax, "model")
        return P(None, b_ax)

    def generic_spec(path_shape):
        shape = path_shape.shape
        if len(shape) == 5:             # KV cache (L,B,S,KV,hd)
            return kv_spec(shape)
        if len(shape) == 0:             # pos scalar
            return P()
        # recurrent / ssm states: (L, B, ...) — shard trailing big dim on model
        total_b = 1
        for a in b_ax:
            total_b *= _mesh_size(mesh, a)
        bspec = b_ax if (len(shape) > 1 and b_ax and
                         shape[1] % max(total_b, 1) == 0) else None
        entries = [None, bspec] + [None] * (len(shape) - 2)
        if model_sz:
            for i in range(len(shape) - 1, 1, -1):
                if shape[i] % model_sz == 0 and shape[i] >= model_sz:
                    entries[i] = "model"
                    break
        return P(*entries)

    return jax.tree.map(
        lambda a: NamedSharding(mesh, generic_spec(a)), cache_abstract)


def _zero1_spec(shard: NamedSharding, shape: Tuple[int, ...]) -> NamedSharding:
    """ZeRO-1: additionally shard the first free dim over "data"."""
    mesh = shard.mesh
    if "data" not in mesh.shape:
        return shard
    dsz = mesh.shape["data"]
    entries = list(shard.spec) + [None] * (len(shape) - len(shard.spec))
    used = {a for e in entries if e is not None
            for a in (e if isinstance(e, tuple) else (e,))}
    if "data" in used:
        return shard
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % dsz == 0 and dim >= dsz:
            entries[i] = "data"
            return NamedSharding(mesh, P(*entries))
    return shard


def opt_state_shardings(param_shardings_tree: Pytree, params_abstract: Pytree
                        ) -> Pytree:
    """AdamW moments: param sharding + ZeRO-1 over "data"; step replicated.

    params_abstract (optional) supplies leaf shapes for the ZeRO split; when
    None the moments just mirror the param shardings."""
    from repro.optim.adamw import AdamWState
    mesh = jax.tree.leaves(param_shardings_tree)[0].mesh
    if params_abstract is None:
        mom = param_shardings_tree
    else:
        mom = jax.tree.map(
            lambda sh, p: _zero1_spec(sh, p.shape),
            param_shardings_tree, params_abstract)
    return AdamWState(
        step=NamedSharding(mesh, P()),
        mu=mom,
        nu=mom)
