"""Straggler-resilient collectives — the paper's k-of-n philosophy lifted to
mesh reductions (DESIGN.md §2, beyond-paper generalisation).

`resilient_psum` is the TPU-native form of OverSketch's termination rule
(Alg. 2 step 4): every shard contributes `mask * value`; the reduction
divides by the count of live shards instead of the world size, so losing up
to `e` contributions re-weights instead of corrupting the mean.  Used for
(1) the distributed sketched-Hessian Gram and (2) the optional
straggler-resilient data-parallel gradient all-reduce in the trainer.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def resilient_psum(tree: Pytree, live: jax.Array, axis: str) -> Pytree:
    """Mean over live shards of ``axis``.

    tree: each shard's contribution (already a *mean* over its local data).
    live: local scalar {0,1} — whether this shard's result arrived in time.
    """
    livef = live.astype(jnp.float32)
    n_live = jax.lax.psum(livef, axis)
    scale = 1.0 / jnp.maximum(n_live, 1.0)

    def red(x):
        contrib = x * livef.astype(x.dtype)
        return jax.lax.psum(contrib, axis) * scale.astype(x.dtype)

    return jax.tree.map(red, tree)


def masked_allgather_mean(x: jax.Array, live: jax.Array, axis: str
                          ) -> Tuple[jax.Array, jax.Array]:
    """All-gather with survivor accounting; returns (stacked, live_mask)."""
    xs = jax.lax.all_gather(x * live.astype(x.dtype), axis)
    masks = jax.lax.all_gather(live, axis)
    return xs, masks


def compressed_resilient_psum(tree: Pytree, live: jax.Array, axis: str
                              ) -> Pytree:
    """`resilient_psum` with int8 wire format (4x less ICI traffic vs f32,
    2x vs bf16) — a distributed-optimization trick on top of the paper's
    k-of-n reduction.

    Per-leaf symmetric quantization with a globally-agreed scale: one scalar
    max-psum round, then the int8 payload reduction, then dequantize.  The
    quantization noise is zero-mean and bounded by scale/127 per element;
    convergence under compression is covered by
    tests/test_trainer_integration.py.
    """
    livef = live.astype(jnp.float32)
    n_live = jax.lax.psum(livef, axis)
    rescale = 1.0 / jnp.maximum(n_live, 1.0)

    def red(x):
        xf = x.astype(jnp.float32) * livef
        # scale agreement: max |x| across shards (tiny scalar all-reduce)
        scale = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis)
        scale = jnp.maximum(scale, 1e-20)
        q = jnp.clip(jnp.round(xf / scale * 127.0), -127, 127).astype(
            jnp.int8)
        # int8 payload over the wire; sum in int32 (<= 127 * shards fits)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        return (total.astype(jnp.float32) * (scale / 127.0) *
                rescale).astype(x.dtype)

    return jax.tree.map(red, tree)
