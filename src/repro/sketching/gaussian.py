"""Dense Gaussian family: blocks of iid N(0, 1/b) entries.

``S_i in R^{n x b}`` with entries N(0, 1/b) gives ``E[S_i S_i^T] = I``
exactly, and the sketched Gram of a single block is Wishart — the setting
where the Marchenko-Pastur inverse bias of ``sketching.debias`` is exact
(E[(S^T A)^+ ...] inflates by m/(m-d-1), Romanov, Zhang & Pilanci 2024,
Sec. 2).  The most accurate family per sketched row and the reference
point for the debiasing tests, but the only one with a dense O(n b d)
apply per block — the straggler clock charges that honestly, which is why
it loses the simulated wall-clock race it wins on epsilon.

The state stores per-block PRNG keys, not the n x b matrices: blocks are
regenerated inside the jitted Gram (cheaper than shipping them, exactly
like serverless workers re-deriving their sketch from a seed).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.sketching.base import SketchFamily
from repro.sketching.registry import register


@register("gaussian")
@dataclasses.dataclass(frozen=True)
class GaussianFamily(SketchFamily):

    def sample(self, key: jax.Array, num_rows: int) -> dict:
        return {"keys": jax.random.split(key, self.cfg.total_blocks)}

    def apply(self, state: dict, a: jax.Array,
              use_kernels: bool = False) -> jax.Array:
        n = a.shape[0]
        b = self.cfg.block_size
        inv_sqrt_b = 1.0 / jnp.sqrt(jnp.asarray(float(b), a.dtype))

        # lax.map streams blocks: one (n, b) sketch lives at a time, keeping
        # the regenerate-from-seed memory story (a vmap would materialize
        # all K blocks at once).
        def one(k):
            g = jax.random.normal(k, (n, b), dtype=a.dtype) * inv_sqrt_b
            return g.T @ a

        return jax.lax.map(one, state["keys"])

    def apply_flops(self, num_rows: int, d: int) -> float:
        return 2.0 * num_rows * self.cfg.block_size * d
