"""SRHT family: blocked subsampled randomized Hadamard transform.

Each block is an independent SRHT  ``S_i^T = sqrt(n_pad/b) P_i H_norm D_i``:
Rademacher signs D_i, the orthonormal Walsh-Hadamard mix H_norm (length
padded to n_pad = next power of two), and b rows sampled uniformly with
replacement (P_i).  Per-block unbiasedness: H_norm D_i is orthogonal on the
zero-padded embedding, and E[P_i^T P_i] = (b/n_pad) I, so
``E[S_i S_i^T] = I`` — the property the OverSketch Eq. 4 survivor rescale
needs.  Tighter embedding constants than Count-Sketch at equal m (Tropp
2011), at an O(n log n) mixing cost per block.

The Hadamard mix routes through the blocked Kronecker MXU kernel in
``repro.kernels.srht`` when ``use_kernels=True``; the pure-jnp butterfly in
``repro.kernels.ref`` is the oracle path.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.sketching.base import SketchFamily, next_pow2
from repro.sketching.registry import register


@register("srht")
@dataclasses.dataclass(frozen=True)
class SRHTFamily(SketchFamily):

    has_fused_gram = True

    def sample(self, key: jax.Array, num_rows: int) -> dict:
        ks, kp = jax.random.split(key)
        blocks = self.cfg.total_blocks
        n_pad = next_pow2(num_rows)
        sigma = jax.random.rademacher(ks, (blocks, num_rows),
                                      dtype=jnp.float32)
        rows = jax.random.randint(kp, (blocks, self.cfg.block_size), 0, n_pad,
                                  dtype=jnp.int32)
        return {"sigma": sigma, "rows": rows}

    def apply(self, state: dict, a: jax.Array,
              use_kernels: bool = False) -> jax.Array:
        n, d = a.shape
        n_pad = next_pow2(n)
        if use_kernels:
            from repro.kernels import ops as kops
            fwht = kops.fwht
        else:
            from repro.kernels import ref
            fwht = ref.fwht
        scale = jnp.sqrt(jnp.asarray(n_pad / self.cfg.block_size, a.dtype))

        # lax.map streams blocks so peak memory is ONE (n_pad, d) panel
        # (plus output), not the (K, n_pad, d) tensor a vmap would build —
        # only block_size of the n_pad mixed rows survive the gather anyway.
        def one(args):
            sigma, rows = args
            x = sigma[:, None] * a
            if n_pad != n:
                x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
            return fwht(x[None])[0][rows] * scale

        return jax.lax.map(one, (state["sigma"], state["rows"]))

    def gram_fused(self, state: dict, a: jax.Array,
                   survivors: jax.Array):
        # Streaming mix: the b sampled Hadamard rows are regenerated per
        # row-panel inside the kernel, so neither the (n_pad, d) mixed
        # panel nor A_tilde ever reaches HBM; the d-tiled output grid
        # keeps the fused path live for every d.
        from repro.kernels import ops as kops
        return kops.sketch_gram_srht(state["rows"], state["sigma"], a,
                                     survivors)

    def apply_flops(self, num_rows: int, d: int) -> float:
        n_pad = next_pow2(num_rows)
        return float(n_pad * max(1, int(math.log2(n_pad))) * d)
