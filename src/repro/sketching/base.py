"""SketchFamily protocol: the pluggable randomized-sketch axis.

The paper fixes one sketch family — stacked Count-Sketch blocks (Eq. 4) —
but the straggler-resilience argument only needs the *block structure*: a
sketch ``S = [S_1, ..., S_{N+e}]`` whose blocks ``S_i in R^{n x b}`` are
independent and satisfy ``E[S_i S_i^T] = I``.  Any such family gives an
unbiased sketched Gram ``H_hat = (1/N_avail) sum_{i in survivors} (S_i^T A)^T
(S_i^T A)`` under k-of-n block survival, so Alg. 2's "wait for any N of N+e"
semantics carry over verbatim.

This module defines the protocol every family implements:

  sample(key, num_rows) -> state     pytree of arrays (jit-transparent)
  apply(state, a)       -> (total_blocks, b, d) per-block  S_i^T A
  gram(state, a, survivors) -> (d, d) masked, rescaled Gram estimate
  gram_fused(state, a, survivors) -> (d, d) or None — optional fused
      sketch->Gram Pallas path (A_tilde never materialized); the kernel's
      d-tiled output grid means a family that has one takes it for ANY d.
      Families without an encode-matrix form return None and ``gram``
      falls back to apply+gram
  fused_path(d)         -> str       which gram path use_kernels takes:
      "fused" | "fused_tiled" | "unfused" (benchmark/bookkeeping hook)
  block_flops(num_rows, d) -> float  per-worker cost for the straggler clock
  comm_units(d)         -> float     per-worker master-I/O units

Families are frozen dataclasses (hashable) so jitted closures keyed on a
family instance can be lru_cached, mirroring ``newton._jitted_*``.

References: OverSketched Newton Eq. 4 / Alg. 2 (block semantics); Romanov,
Zhang & Pilanci 2024 "Newton Meets Marchenko-Pastur" (family-agnostic
debiasing, see ``sketching.debias``); Bartan & Pilanci 2020 "Distributed
Averaging Methods for Randomized Second Order Optimization" (per-worker
independent sketches, see ``newton`` sketch_mode="distributed-avg").
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

import repro.core.sketch as core_sketch
from repro.core.sketch import OverSketchConfig

SketchState = Any  # pytree of arrays; structure is family-specific


@dataclasses.dataclass(frozen=True)
class SketchFamily(abc.ABC):
    """A configured block-structured sketch family (see module docstring).

    ``cfg`` carries the shared dimension accounting — sketch_dim m = N*b,
    block_size b, straggler_tolerance zeta => total_blocks N+e — reused
    across families so any family drops into the Alg. 2 worker layout.
    """

    cfg: OverSketchConfig

    # Subclasses set this; used as the registry key and in benchmark rows.
    name = "abstract"

    @abc.abstractmethod
    def sample(self, key: jax.Array, num_rows: int) -> SketchState:
        """Draw an independent realization of all N+e blocks (fresh per
        Newton iteration, like the paper's per-iteration sketch)."""

    @abc.abstractmethod
    def apply(self, state: SketchState, a: jax.Array,
              use_kernels: bool = False) -> jax.Array:
        """Per-block application A (n, d) -> (total_blocks, b, d), unscaled
        by 1/sqrt(N) (the survivor rescale in ``gram`` absorbs it)."""

    # Families with a block-local encode-matrix form set this True (and
    # override gram_fused); it drives fused_path reporting.
    has_fused_gram = False

    def gram_fused(self, state: SketchState, a: jax.Array,
                   survivors: jax.Array) -> Optional[jax.Array]:
        """Fused streaming sketch->Gram (``kernels/sketch_gram.py``): the
        per-block panels ``A_tilde_i`` stay in VMEM and never round-trip
        through HBM.  The kernel tiles its output grid on d, so there is
        no VMEM decline path — a family that overrides this takes the
        fused kernel for every d.  Families without a block-local
        encode-matrix form (count-sketch scatter, SJLT layers, SRHT mix)
        keep the default None and ``gram`` routes through the two-kernel
        apply+gram fallback."""
        return None

    def fused_path(self, d: int) -> str:
        """Which path ``gram(use_kernels=True)`` takes for width d:
        ``"fused"`` (single resident output tile), ``"fused_tiled"``
        (d-tiled (d_i, d_j) grid) or ``"unfused"`` (apply+gram pair).
        Pure bookkeeping — benchmarks record it so perf rows are
        attributable to the grid that actually ran."""
        if not self.has_fused_gram:
            return "unfused"
        from repro.kernels.sketch_gram import fused_path as _fused_path
        return _fused_path(self.cfg.block_size, d)

    def gram(self, state: SketchState, a: jax.Array,
             survivors: Optional[jax.Array] = None,
             use_kernels: bool = False) -> jax.Array:
        """Masked H_hat = (1/N_avail) sum_i A_tilde_i^T A_tilde_i.

        Shared across families: per-block unbiasedness (E[S_i S_i^T] = I)
        makes dropping blocks + rescaling exact for every family.  On the
        kernel path the fused single-pass pipeline is preferred whenever
        the family provides one.
        """
        if use_kernels:
            if survivors is None:
                survivors = jnp.ones((self.cfg.total_blocks,), bool)
            fused = self.gram_fused(state, a, survivors)
            if fused is not None:
                return fused
            a_t = self.apply(state, a, use_kernels=True)
            return core_sketch.sketched_gram(a_t, survivors,
                                             use_kernels=True)
        a_t = self.apply(state, a)
        return core_sketch.sketched_gram(a_t, survivors)

    # ------------------------------------------------------------------ cost
    # Hooks for the straggler SimClock: per-worker flops and master-I/O for
    # one sketch-block worker (Alg. 2 step 3).  The default charges only the
    # Gram-tile matmul — the OverSketch family folds sketching into the coded
    # matmul workers (paper Sec. 4.1), so its apply cost is amortized.
    # Families whose apply is a separate pass override ``apply_flops``.

    def apply_flops(self, num_rows: int, d: int) -> float:
        """Per-block cost of forming A_tilde_i, in flops (0 if amortized)."""
        return 0.0

    def block_flops(self, num_rows: int, d: int) -> float:
        b = self.cfg.block_size
        gram_tile = 2.0 * b * min(d, b) ** 2
        return gram_tile + self.apply_flops(num_rows, d)

    def comm_units(self, d: int) -> float:
        """Master-I/O units per worker (one b x min(d,b) output tile)."""
        return 0.05


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (Hadamard sizes; static under jit)."""
    return 1 << max(0, (n - 1).bit_length())
