"""String-keyed registry of sketch families.

``get("srht", cfg)`` returns a configured ``SketchFamily``; families
self-register at import time via the ``@register`` decorator (mirroring
``repro.models.registry``).  The Newton loop resolves
``NewtonConfig.sketch_family`` through this table, so adding a family is
one module + one decorator — no optimizer changes.
"""
from __future__ import annotations

from typing import Callable, Dict, Type

from repro.core.sketch import OverSketchConfig
from repro.sketching.base import SketchFamily

_FAMILIES: Dict[str, Type[SketchFamily]] = {}


def register(name: str) -> Callable[[Type[SketchFamily]], Type[SketchFamily]]:
    def deco(cls: Type[SketchFamily]) -> Type[SketchFamily]:
        if name in _FAMILIES and _FAMILIES[name] is not cls:
            raise ValueError(f"sketch family {name!r} already registered")
        cls.name = name
        _FAMILIES[name] = cls
        return cls
    return deco


def get(name: str, cfg: OverSketchConfig, **kwargs) -> SketchFamily:
    """Instantiate family ``name`` with the shared dimension config."""
    try:
        cls = _FAMILIES[name]
    except KeyError:
        raise KeyError(
            f"unknown sketch family {name!r}; available: {available()}"
        ) from None
    return cls(cfg=cfg, **kwargs)


def available() -> list:
    return sorted(_FAMILIES)
