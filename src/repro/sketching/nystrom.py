"""Nystrom / row-sampling family: uniform row subsampling of hess_sqrt.

Each block samples b rows of A uniformly with replacement and rescales by
sqrt(n/b):  ``S_i^T = sqrt(n/b) P_i``.  Then ``E[S_i S_i^T] = (n/b)
E[P_i^T P_i] = I``, and the per-block Gram ``(S_i^T A)^T (S_i^T A)`` is the
classic Nystrom / subsampled-Newton estimate of A^T A.  No mixing at all:
apply is a gather, the cheapest family and the weakest on rows with
non-uniform leverage — the far end of the accuracy/cost axis from
"gaussian", which is exactly why the fig7 family sweep includes it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.sketching.base import SketchFamily
from repro.sketching.registry import register


@register("nystrom")
@dataclasses.dataclass(frozen=True)
class NystromFamily(SketchFamily):

    def sample(self, key: jax.Array, num_rows: int) -> dict:
        rows = jax.random.randint(
            key, (self.cfg.total_blocks, self.cfg.block_size), 0, num_rows,
            dtype=jnp.int32)
        return {"rows": rows}

    def apply(self, state: dict, a: jax.Array,
              use_kernels: bool = False) -> jax.Array:
        n = a.shape[0]
        scale = jnp.sqrt(jnp.asarray(n / self.cfg.block_size, a.dtype))
        return jax.vmap(lambda r: a[r])(state["rows"]) * scale

    def apply_flops(self, num_rows: int, d: int) -> float:
        return float(self.cfg.block_size * d)
