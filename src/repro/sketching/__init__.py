"""Pluggable sketching subsystem: block-structured sketch families behind a
string-keyed registry, plus Marchenko-Pastur direction debiasing.

Every family satisfies the per-block unbiasedness E[S_i S_i^T] = I that the
paper's Eq. 4 survivor-rescale argument needs, so each one inherits the
k-of-n straggler semantics of Alg. 2 unchanged.  ``get(name, cfg)`` is the
entry point used by ``core.newton`` (``NewtonConfig.sketch_family``).
"""
from repro.sketching.base import SketchFamily, next_pow2
from repro.sketching.registry import available, get, register
from repro.sketching.debias import (debias_direction, mp_factor, mp_stalled,
                                    rows_for_target)

# Importing a family module registers it.
from repro.sketching.oversketch import OverSketchFamily
from repro.sketching.srht import SRHTFamily
from repro.sketching.sjlt import SJLTFamily
from repro.sketching.gaussian import GaussianFamily
from repro.sketching.nystrom import NystromFamily
from repro.sketching.leverage import LeverageFamily

__all__ = [
    "SketchFamily", "available", "get", "register",
    "debias_direction", "mp_factor", "mp_stalled", "rows_for_target",
    "next_pow2",
    "OverSketchFamily", "SRHTFamily", "SJLTFamily", "GaussianFamily",
    "NystromFamily", "LeverageFamily",
]
