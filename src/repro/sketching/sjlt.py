"""SJLT family: sparse Johnson-Lindenstrauss transform (blocked OSNAP).

Each block S_i has s nonzeros of value +-1/sqrt(s) per row of A (Count-
Sketch is the s=1 special case), applied as s signed segment-sums.  Per-
block unbiasedness: diagonal entries of S_i S_i^T sum s slots of 1/s each
and cross-slot sign products are zero-mean, so ``E[S_i S_i^T] = I`` even
with intra-row bucket collisions.  s > 1 buys Count-Sketch's O(nnz) apply
cost a better distortion-vs-m trade (Nelson & Nguyen 2013) — the middle
ground between "oversketch" and "srht".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

import repro.core.sketch as core_sketch
from repro.sketching.base import SketchFamily
from repro.sketching.registry import register


@register("sjlt")
@dataclasses.dataclass(frozen=True)
class SJLTFamily(SketchFamily):

    nnz_per_row: int = 4
    has_fused_gram = True

    def sample(self, key: jax.Array, num_rows: int) -> dict:
        kh, ks = jax.random.split(key)
        shape = (self.cfg.total_blocks, self.nnz_per_row, num_rows)
        h = jax.random.randint(kh, shape, 0, self.cfg.block_size,
                               dtype=jnp.int32)
        sigma = jax.random.rademacher(ks, shape, dtype=jnp.float32)
        return {"h": h, "sigma": sigma}

    def apply(self, state: dict, a: jax.Array,
              use_kernels: bool = False) -> jax.Array:
        b = self.cfg.block_size
        if use_kernels:
            # Flatten the slot axis into extra blocks for the count-sketch
            # MXU kernel, then reduce the s slot outputs per block.
            from repro.kernels import ops as kops
            k, s, n = state["h"].shape
            flat = kops.count_sketch_apply(state["h"].reshape(k * s, n),
                                           state["sigma"].reshape(k * s, n),
                                           a, b)
            out = flat.reshape(k, s, b, -1).sum(axis=1)
        else:
            def one_block(h_b, s_b):
                slots = jax.vmap(
                    lambda h, s: core_sketch.apply_block(h, s, b, a))(h_b, s_b)
                return slots.sum(axis=0)
            out = jax.vmap(one_block)(state["h"], state["sigma"])
        return out / jnp.sqrt(jnp.asarray(float(self.nnz_per_row), out.dtype))

    def gram_fused(self, state: dict, a: jax.Array,
                   survivors: jax.Array):
        # Encode-matrix form: the s signed one-hot layers are summed into
        # a (tile_n, b) matrix in VMEM (count-sketch is the s = 1 slice of
        # the same encoder), so SJLT rides the same fused streaming kernel
        # as oversketch/srht — A_tilde never reaches HBM.
        from repro.kernels import ops as kops
        return kops.sketch_gram_sjlt(state["h"], state["sigma"], a,
                                     self.cfg.block_size, survivors)

    def fused_path(self, d: int) -> str:
        from repro.kernels.sketch_gram import fused_path as _fused_path
        return _fused_path(self.cfg.block_size, d, nnz=self.nnz_per_row)

    def apply_flops(self, num_rows: int, d: int) -> float:
        return 2.0 * self.nnz_per_row * num_rows * d
