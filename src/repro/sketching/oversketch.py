"""OverSketch family: the paper's stacked Count-Sketch blocks (Eq. 4).

This is the seed implementation from ``repro.core.sketch`` migrated behind
the ``SketchFamily`` protocol; ``repro.core`` re-exports are untouched and
the reference functions there remain the kernels' oracle.  Per-block
unbiasedness E[S_i S_i^T] = I is the Count-Sketch property the paper's
Lemma 6.1 builds on.

Cost model: sketching is folded into the coded matmul workers (paper
Sec. 4.1 amortizes encoding), so ``apply_flops`` stays 0 and a block worker
is charged only its Gram tile — matching the seed's clock accounting.
"""
from __future__ import annotations

import dataclasses

import jax

import repro.core.sketch as core_sketch
from repro.sketching.base import SketchFamily
from repro.sketching.registry import register


@register("oversketch")
@dataclasses.dataclass(frozen=True)
class OverSketchFamily(SketchFamily):

    has_fused_gram = True

    def sample(self, key: jax.Array, num_rows: int) -> core_sketch.CountSketch:
        return core_sketch.sample_countsketch(key, num_rows, self.cfg)

    def apply(self, state: core_sketch.CountSketch, a: jax.Array,
              use_kernels: bool = False) -> jax.Array:
        if use_kernels:
            from repro.kernels import ops as kops
            return kops.count_sketch_apply(state.h, state.sigma, a,
                                           self.cfg.block_size)
        return core_sketch.apply_sketch(state, a)

    def gram_fused(self, state: core_sketch.CountSketch, a: jax.Array,
                   survivors: jax.Array):
        # The kernel d-tiles its output grid, so the fused path runs for
        # every d (pick_d_tile sizes the tile to the VMEM budget).
        from repro.kernels import ops as kops
        return kops.sketch_gram_count(state.h, state.sigma, a,
                                      self.cfg.block_size, survivors)
