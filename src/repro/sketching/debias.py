"""Marchenko-Pastur debiasing of the sketched Newton direction.

Sketching the Hessian biases the *inverse*: for an m-row sketch of a rank-d
Gram, E[H_hat^{-1}] inflates relative to H^{-1} — for Gaussian sketches
E[H_hat^{-1}] = m/(m-d-1) H^{-1} exactly (inverse-Wishart), and under
Marchenko-Pastur asymptotics (m, d -> inf, d/m -> xi) the inflation is
1/(1 - xi) for *any* of the rotationally-mixed families here (universality:
Romanov, Zhang & Pilanci 2024, "Newton Meets Marchenko-Pastur", Thm 3.1).
The sketched direction p_hat = -H_hat^{-1} g is therefore too long in
expectation; rescaling by

    gamma = 1 - d/m

makes it asymptotically unbiased:  E[gamma * p_hat] -> p_newton.  That is
what turns independent per-worker sketches into an embarrassingly parallel
Newton step (average debiased directions, no Hessian communication) — the
``sketch_mode="distributed-avg"`` path of ``core.newton`` (cf. Bartan &
Pilanci 2020, Distributed Averaging Methods, Sec. 3).

With straggler-dropped blocks, m is the *surviving* sketch dimension
(survivor blocks x block_size), so the correction adapts per iteration to
whichever k-of-n subset actually arrived.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Below this survivor-dim margin the MP correction is extrapolating far
# outside its m > d regime; clamp so a bad straggler round cannot flip the
# direction's sign or zero it out.
MIN_FACTOR = 0.05


def mp_factor(dim: int, sketch_rows) -> jax.Array:
    """Debias factor gamma = max(1 - d/m, MIN_FACTOR); jit-safe in m."""
    m = jnp.maximum(jnp.asarray(sketch_rows, jnp.float32), 1.0)
    return jnp.maximum(1.0 - float(dim) / m, MIN_FACTOR)


def debias_direction(p: jax.Array, dim: int, sketch_rows) -> jax.Array:
    """Rescale a sketched Newton direction to be asymptotically unbiased."""
    return p * mp_factor(dim, sketch_rows).astype(p.dtype)


def mp_stalled(dim: int, sketch_rows, target: float) -> bool:
    """Is the sketch too biased to trust at this survivor dimension?

    The MP factor 1 - d/m is a *measured* per-iteration quantity (m = the
    sketch rows that actually arrived), so it says directly when the
    sketch dimension is the binding constraint: gamma below ``target``
    means the inverse-bias correction is throwing away more than
    (1 - target) of the step — grow the sketch now, before the f-decrease
    heuristic can even observe the resulting stall
    (``NewtonConfig.adaptive_metric="mp"``)."""
    return bool(mp_factor(dim, sketch_rows) < target)


def rows_for_target(dim: int, target: float) -> int:
    """Smallest sketch-row count whose MP factor meets ``target``."""
    if not 0.0 < target < 1.0:
        raise ValueError(f"target must be in (0, 1), got {target}")
    import math
    return int(math.ceil(dim / (1.0 - target)))
