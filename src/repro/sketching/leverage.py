"""Leverage-score row sampling: importance-weighted row subsampling.

Upgrades the uniform ``nystrom`` family on data with non-uniform leverage:
each block samples b rows with replacement from ``p_i = l_i / d`` where
``l_i = ||q_i||^2`` are the exact leverage scores of A (row norms of its
thin-QR Q factor), and rescales row i by ``1 / sqrt(b p_i)``.  Then

    E[S_i S_i^T] = b * E[e_r e_r^T / (b p_r)] = sum_r p_r e_r e_r^T / p_r
                 = I    (restricted to rows with l_i > 0),

so the per-block Gram is unbiased for A^T A and the family inherits Alg. 2's
k-of-n survivor semantics like every other registry entry.  Sampling by
leverage is the optimal importance distribution for row-sampled Grams
(Drineas-Mahoney-Muthukrishnan): rows that matter are kept, so spiky
matrices that break uniform Nystrom are handled at the same per-worker
cost.

The QR pass to get the scores is a one-time master-side O(n d^2) — the same
price as one exact Gram, amortized across the N+e blocks in the cost hook.
Because the scores depend on A, sampling happens lazily in ``apply`` (the
protocol's ``sample`` never sees A); the state carries only the key, so the
realization is still deterministic per Newton iteration.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.sketching.base import SketchFamily
from repro.sketching.registry import register


@register("leverage")
@dataclasses.dataclass(frozen=True)
class LeverageFamily(SketchFamily):

    def sample(self, key: jax.Array, num_rows: int) -> dict:
        # Scores depend on A, which apply() sees and sample() does not:
        # defer the draw, keep the key (one realization per iteration).
        return {"key": key}

    def apply(self, state: dict, a: jax.Array,
              use_kernels: bool = False) -> jax.Array:
        n, d = a.shape
        q, _ = jnp.linalg.qr(a)                      # thin QR, (n, d)
        lev = jnp.sum(q * q, axis=1)                 # leverage scores, sum=d
        p = lev / jnp.maximum(jnp.sum(lev), 1e-30)
        shape = (self.cfg.total_blocks, self.cfg.block_size)
        rows = jax.random.choice(state["key"], n, shape, replace=True, p=p)
        scale = 1.0 / jnp.sqrt(
            jnp.maximum(self.cfg.block_size * p[rows], 1e-30))
        return a[rows] * scale[..., None]

    def apply_flops(self, num_rows: int, d: int) -> float:
        # Master-side QR amortized over the fleet + the per-block gather.
        qr = 2.0 * num_rows * d * d / max(self.cfg.total_blocks, 1)
        return qr + float(self.cfg.block_size * d)
