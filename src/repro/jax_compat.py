"""Backfill newer jax public APIs on older jaxlib (container ships 0.4.37).

The distributed paths use the modern spellings — ``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)`` — which
moved out of ``jax.experimental`` after 0.4.x.  On versions that already
provide them this module is a no-op; otherwise it aliases the experimental
implementations so one codebase runs on both.  Imported for its side effect
from ``repro/__init__`` (before any mesh/shard_map call site).
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.sharding

if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    @functools.wraps(_experimental_shard_map)
    def _shard_map(f, *args, **kwargs):
        # post-0.4.x renamed check_rep -> check_vma
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _experimental_shard_map(f, *args, **kwargs)

    jax.shard_map = _shard_map

if not hasattr(jax.sharding, "AxisType"):
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType

# Signature inspection, not a probe call: calling make_mesh at import time
# would initialize the backend before the app can set JAX_PLATFORMS etc.
if hasattr(jax, "make_mesh"):
    _HAS_AXIS_TYPES = "axis_types" in inspect.signature(
        jax.make_mesh).parameters
else:
    _HAS_AXIS_TYPES = True   # nothing to wrap; call sites will fail loudly

if not _HAS_AXIS_TYPES:
    _orig_make_mesh = jax.make_mesh

    @functools.wraps(_orig_make_mesh)
    def _make_mesh(axis_shapes, axis_names, *, axis_types=None, devices=None):
        # axis_types only selects Auto vs Explicit sharding inference; 0.4.x
        # meshes are always Auto, so dropping the argument is faithful.
        return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = _make_mesh
