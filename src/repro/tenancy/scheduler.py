"""Job-level scheduler: many tenants, one fleet, one WarmPool, one ledger.

``JobScheduler`` turns the single-job discrete-event engine into a
platform simulator.  It consumes a workload (``tenancy.workload``) and
drives every job's phase DAG through ONE shared ``SimClock`` — so every
job's phases acquire containers from the same ``scheduler.WarmPool``,
bill the same ``CostLedger``, and appear on the same telemetry stream.

Canonical event order (the determinism contract):

  Events live on one heap keyed ``(t, rank, job_id, iteration, phase)``
  with rank arrival(0) < phase(1) < completion(2).  Same seed + same
  arrival trace => the same pop order => the same pool acquire/release
  interleaving => bit-identical warm/cold assignment, elapsed seconds,
  and dollars.  Phase PRNG keys fold (job id, iteration, name-CRC) into
  the run key, so a job's randomness is a function of its identity, not
  of its neighbours.

Admission (``AdmissionPolicy``): a platform concurrency cap with an
optional FIFO queue, plus SLO-aware rejection — a job whose *estimated*
completion (CPM median makespan x ``est_safety``, from its predicted
admission slot) already misses its deadline is refused at arrival rather
than admitted to fail.  The estimate is optimistic (it ignores straggler
tails and pool contention); admission is a policy, not an oracle.

Pool-aware dispatch (``TenancyConfig.pool_aware``): an off-critical-path
phase may be delayed within its static CPM slack to a moment when more
warm containers are free (``WarmPool.earliest_fit``), converting cold
starts into warm hits for free — the slack budget ``obs.critical_path``
measures is exactly what this spends.

Autoscaling + provisioned billing (``Autoscaler``): the provisioned
(pinned-warm) reserve tracks the observed arrival rate via Little's law
— target containers ~= headroom x rate x (median makespan x peak
workers per job) — EWMA-smoothed, clamped, refreshed on every arrival.
The reserve bills ``CostModel.usd_per_provisioned_gb_second`` for every
GB-second it is *configured*, used or not (that is what provisioned
concurrency costs), accrued piecewise-constant into the shared ledger's
``provisioned_gb_seconds`` and attributed to the ``_platform`` tenant.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from repro.runtime.cost import CostLedger
from repro.runtime.faults import PhaseExhaustedError
from repro.scheduler.spec import PhaseSpec, canonical_order
from repro.tenancy.workload import Job

_ARRIVE, _PHASE, _COMPLETE = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Platform admission knobs (see module docstring)."""

    max_inflight: int = 64        # concurrent-jobs cap
    queue: bool = True            # hold for a slot (FIFO) vs reject at cap
    slo_aware: bool = True        # reject jobs whose estimate misses SLO
    est_safety: float = 1.5       # multiplier on the median-CPM estimate
    # Error-budget-aware shedding (repro.obs.slo): when the run carries
    # per-tenant SLO policies (TenancyConfig.slo), reject arrivals from
    # exactly the tenant whose budget is exhausted or whose fast+slow
    # burn windows are both paging — the burning tenant sheds, everyone
    # else is untouched.  Off by default: SLO tracking alone is pure
    # observation; this flag is the explicit opt-in that lets it steer.
    budget_aware: bool = False


@dataclasses.dataclass(frozen=True)
class Autoscaler:
    """Arrival-rate-tracking provisioned-concurrency policy."""

    alpha: float = 0.3            # EWMA weight on new observations
    headroom: float = 1.2         # over-provisioning factor
    min_provisioned: int = 0
    max_provisioned: int = 512

    def target(self, rate: float, demand_per_job: float) -> int:
        """Little's law: containers ~= rate [jobs/s] x demand
        [container-seconds/job], plus headroom."""
        raw = self.headroom * rate * demand_per_job
        return max(self.min_provisioned,
                   min(self.max_provisioned, int(math.ceil(raw))))


@dataclasses.dataclass(frozen=True)
class TenancyConfig:
    admission: AdmissionPolicy = AdmissionPolicy()
    autoscaler: Optional[Autoscaler] = None
    pool_aware: bool = False
    slack_safety: float = 1.0     # fraction of static slack spendable
    # Per-tenant objectives (tenant name -> repro.obs.slo.SloPolicy).
    # When set, a SloTracker folds every completed job into error budgets
    # and burn rates; AdmissionPolicy.budget_aware decides whether that
    # state may also shed arrivals.  None = no SLO plane (default).
    slo: Optional[Dict[str, "object"]] = None


@dataclasses.dataclass
class JobRecord:
    """Outcome of one job, in arrival order on ``FleetResult.jobs``."""

    id: int
    tenant: str
    template: str
    t_arrival: float
    deadline: Optional[float]
    t_admit: Optional[float] = None
    t_finish: Optional[float] = None
    rejected: bool = False
    failed: bool = False
    dollars: float = 0.0

    @property
    def completed(self) -> bool:
        return self.t_finish is not None and not self.failed

    @property
    def latency(self) -> Optional[float]:
        return (None if self.t_finish is None
                else self.t_finish - self.t_arrival)

    @property
    def queue_wait(self) -> Optional[float]:
        return (None if self.t_admit is None
                else self.t_admit - self.t_arrival)

    @property
    def slo_missed(self) -> bool:
        """An admitted, deadline-bearing job that failed or finished late."""
        if self.rejected or self.deadline is None:
            return False
        return self.failed or (self.t_finish is not None
                               and self.t_finish > self.deadline)


@dataclasses.dataclass
class FleetResult:
    """One multi-tenant run: per-job outcomes + shared-platform totals."""

    jobs: List[JobRecord]
    seconds: float                    # fleet makespan (engine clock)
    dollars: float                    # everything, provisioned included
    tenants: Dict[str, CostLedger]    # per-tenant attribution (+ _platform)
    provisioned_gb_seconds: float
    peak_inflight: int                # max concurrently-admitted jobs
    # (job_id, iteration, phase, t_launch, warm_hits, cold_starts) per
    # dispatched phase — the warm/cold assignment determinism tests pin.
    phase_log: List[Tuple[int, int, str, float, int, int]]

    @property
    def completed(self) -> List[JobRecord]:
        return [j for j in self.jobs if j.completed]

    @property
    def rejected(self) -> List[JobRecord]:
        return [j for j in self.jobs if j.rejected]

    @property
    def failed(self) -> List[JobRecord]:
        return [j for j in self.jobs if j.failed]

    @property
    def slo_misses(self) -> int:
        return sum(j.slo_missed for j in self.jobs)

    @property
    def throughput(self) -> float:
        """Completed jobs per simulated second."""
        return len(self.completed) / self.seconds if self.seconds else 0.0

    def latency_quantile(self, q: float) -> float:
        lats = sorted(j.latency for j in self.completed)
        if not lats:
            return float("nan")
        i = min(len(lats) - 1, max(0, int(math.ceil(q * len(lats))) - 1))
        return lats[i]

    def summary(self) -> dict:
        return {"jobs": len(self.jobs),
                "completed": len(self.completed),
                "rejected": len(self.rejected),
                "failed": len(self.failed),
                "slo_misses": self.slo_misses,
                "seconds": self.seconds,
                "dollars": self.dollars,
                "provisioned_gb_seconds": self.provisioned_gb_seconds,
                "throughput": self.throughput,
                "peak_inflight": self.peak_inflight,
                "latency_p50": self.latency_quantile(0.50),
                "latency_p95": self.latency_quantile(0.95)}


class _TemplateInfo:
    """Static per-template scheduling data, computed once per run."""

    def __init__(self, template, model):
        self.specs: List[PhaseSpec] = canonical_order(template.specs)
        self.by_name = {s.name: s for s in self.specs}
        self.succs: Dict[str, List[str]] = {s.name: [] for s in self.specs}
        self.ndeps: Dict[str, int] = {}
        for s in self.specs:
            self.ndeps[s.name] = len(s.deps)
            for d in s.deps:
                self.succs[d].append(s.name)
        self.slack = template.phase_slack(model)
        self.est_makespan = template.expected_makespan(model)
        self.demand = self.est_makespan * template.expected_peak_workers(
            model) / max(1, template.iters)  # per-job container-seconds


class _JobState:
    __slots__ = ("job", "info", "job_key", "it_key", "iteration",
                 "remaining", "ndeps", "finish", "failed")

    def __init__(self, job, info, job_key):
        self.job = job
        self.info = info
        self.job_key = job_key
        self.failed = False
        self._start_iteration(0)

    def _start_iteration(self, i: int) -> None:
        self.iteration = i
        self.it_key = jax.random.fold_in(self.job_key, i)
        self.remaining = len(self.info.specs)
        self.ndeps = dict(self.info.ndeps)
        self.finish: Dict[str, float] = {}


class JobScheduler:
    """Drive a workload through one shared ``SimClock`` (see module doc).

    ``clock`` carries the shared engine: its pool, telemetry, recorder,
    and fault plan apply to every tenant.  ``key`` is the run's PRNG
    root; each phase's key folds (job id, iteration, phase-name CRC)
    into it."""

    def __init__(self, clock, key: jax.Array, jobs: Sequence[Job],
                 config: TenancyConfig = TenancyConfig()):
        ids = [j.id for j in jobs]
        if len(set(ids)) != len(ids):
            raise ValueError("job ids must be unique")
        self.clock = clock
        self.engine = clock.engine
        self.key = key
        self.jobs = sorted(jobs, key=lambda j: (j.t_arrival, j.id))
        self.config = config
        self.pool = self.engine.pool
        model = clock.model
        self._info: Dict[str, _TemplateInfo] = {}
        for j in self.jobs:
            if j.template.name not in self._info:
                self._info[j.template.name] = _TemplateInfo(j.template,
                                                            model)
        # --- mutable run state
        self._records: Dict[int, JobRecord] = {}
        self._states: Dict[int, _JobState] = {}
        self._inflight: Dict[int, float] = {}    # job id -> est finish
        self._peak_inflight = 0
        self._fifo: List[int] = []               # queued job ids
        self._phase_log: List[Tuple] = []
        self._tenants: Dict[str, CostLedger] = {}
        # --- provisioned-concurrency accrual (billed by configured target)
        self._mem_gb = self.engine.cost_model.memory_gb
        self._prov_target = self.pool.fresh if self.pool is not None else 0
        self._prov_t = self.engine.seconds
        self._prov_gbs = 0.0
        # --- autoscaler EWMA state
        self._last_arrival: Optional[float] = None
        self._ewma_gap: Optional[float] = None
        self._ewma_demand: Optional[float] = None
        # --- per-tenant SLO plane (repro.obs.slo)
        self.slo_tracker = None
        if config.slo:
            from repro.obs.slo import SloTracker
            self.slo_tracker = SloTracker(config.slo,
                                          telemetry=clock.telemetry)
            # Surface the tracker on the telemetry so exports/store pick
            # it up — but never set attributes on the shared obs.NULL.
            if getattr(clock.telemetry, "enabled", False):
                clock.telemetry.slo = self.slo_tracker

    # --------------------------------------------------------- telemetry
    @property
    def _m(self):
        return self.clock.telemetry.metrics

    def _tenant_ledger(self, tenant: str) -> CostLedger:
        led = self._tenants.get(tenant)
        if led is None:
            led = self._tenants[tenant] = CostLedger()
        return led

    # ------------------------------------------------------- provisioned
    def _accrue_provisioned(self, t: float) -> None:
        dt = t - self._prov_t
        if dt > 0 and self._prov_target > 0:
            gbs = self._prov_target * self._mem_gb * dt
            self._prov_gbs += gbs
            self.engine.ledger.provisioned_gb_seconds += gbs
            self._tenant_ledger("_platform").provisioned_gb_seconds += gbs
        self._prov_t = max(self._prov_t, t)

    def _set_provisioned(self, t: float, target: int) -> None:
        """Re-point the pinned-warm reserve: accrue at the old target,
        then top up / cool the pool toward the new one (the reserve is
        *refreshed* — consumed provisioned containers are replaced)."""
        self._accrue_provisioned(t)
        self._prov_target = target
        if self.pool.fresh < target:
            self.pool.prewarm(target - self.pool.fresh)
        elif self.pool.fresh > target:
            self.pool.cool(self.pool.fresh - target)
        self._m.gauge("pool.provisioned").set(target)

    def _autoscale(self, t: float, info: _TemplateInfo) -> None:
        auto = self.config.autoscaler
        if auto is None or self.pool is None:
            return
        if self._last_arrival is not None:
            gap = max(1e-9, t - self._last_arrival)
            self._ewma_gap = (gap if self._ewma_gap is None
                              else auto.alpha * gap
                              + (1 - auto.alpha) * self._ewma_gap)
        self._last_arrival = t
        self._ewma_demand = (info.demand if self._ewma_demand is None
                             else auto.alpha * info.demand
                             + (1 - auto.alpha) * self._ewma_demand)
        if self._ewma_gap is None:
            return                      # one arrival: no rate estimate yet
        target = auto.target(1.0 / self._ewma_gap, self._ewma_demand)
        if target != self._prov_target:
            self._set_provisioned(t, target)

    # --------------------------------------------------------- admission
    def _estimate(self, job: Job) -> float:
        return (self._info[job.template.name].est_makespan
                * self.config.admission.est_safety)

    def _predicted_start(self, t: float, queue_pos: int) -> float:
        """Optimistic slot prediction for a job ``queue_pos`` deep in the
        FIFO: the (pos+1)-th soonest estimated finish among inflight
        jobs (ignores contention — admission is advisory)."""
        if not self._inflight:
            return t
        ests = sorted(self._inflight.values())
        return max(t, ests[min(queue_pos, len(ests) - 1)])

    def _try_admit(self, heap, job: Job, t: float) -> None:
        adm = self.config.admission
        if (adm.budget_aware and self.slo_tracker is not None
                and self.slo_tracker.should_shed(job.tenant, t)):
            self._reject(job)
            self._m.counter(f"tenant.{job.tenant}.budget_shed").inc()
            return
        if adm.slo_aware and job.deadline is not None:
            start = (t if len(self._inflight) < adm.max_inflight
                     else self._predicted_start(t, len(self._fifo)))
            if start + self._estimate(job) > job.deadline:
                self._reject(job)
                return
        if len(self._inflight) < adm.max_inflight:
            self._admit(heap, job, t)
        elif adm.queue:
            self._fifo.append(job.id)
        else:
            self._reject(job)

    def _reject(self, job: Job) -> None:
        self._records[job.id].rejected = True
        m = self._m
        m.counter("jobs.rejected").inc()
        m.counter(f"tenant.{job.tenant}.rejected").inc()

    def _admit(self, heap, job: Job, t: float) -> None:
        info = self._info[job.template.name]
        st = _JobState(job, info, jax.random.fold_in(self.key, job.id))
        self._states[job.id] = st
        self._records[job.id].t_admit = t
        self._inflight[job.id] = t + self._estimate(job)
        self._peak_inflight = max(self._peak_inflight, len(self._inflight))
        m = self._m
        m.counter("jobs.admitted").inc()
        m.histogram("job.queue_wait_s").observe(t - job.t_arrival)
        m.gauge("fleet.inflight").set(len(self._inflight))
        self._push_ready(heap, st, t)

    def _push_ready(self, heap, st: _JobState, t_start: float) -> None:
        """Queue this iteration's root phases, ready at ``t_start``."""
        for spec in st.info.specs:
            if not spec.deps:
                heapq.heappush(heap, (t_start, _PHASE, st.job.id,
                                      st.iteration, spec.name))

    # ---------------------------------------------------------- dispatch
    def _dispatch(self, heap, st: _JobState, name: str, t_ready: float
                  ) -> None:
        job, info, cfg = st.job, st.info, self.config
        spec = info.by_name[name]
        t_launch = t_ready
        if (cfg.pool_aware and self.pool is not None
                and info.slack.get(name, 0.0) > 0.0):
            budget = cfg.slack_safety * info.slack[name]
            t_launch = self.pool.earliest_fit(t_ready, spec.workers,
                                              t_ready + budget)
        led = self.engine.ledger
        before = (led.gb_seconds, led.invocations, led.s3_puts,
                  led.s3_gets)
        warm0, cold0 = ((self.pool.warm_hits, self.pool.cold_starts)
                        if self.pool is not None else (0, 0))
        label = f"{job.tenant}/{job.id}/{name}"
        pkey = jax.random.fold_in(st.it_key, spec.key_fold)
        try:
            elapsed, _ = self.clock.phase(
                pkey, spec.workers, work_per_worker=spec.work_per_worker,
                flops_per_worker=spec.flops_per_worker, policy=spec.policy,
                k=spec.k, comm_units=spec.comm_units,
                decodable=spec.decodable, not_before=t_launch,
                memory_gb=spec.memory_gb,
                working_set_gb=spec.working_set_gb, phase_name=label,
                phase_deps=tuple(f"{job.tenant}/{job.id}/{d}"
                                 for d in spec.deps))
            finish = t_launch + float(elapsed)
        except PhaseExhaustedError as err:
            finish = t_launch + err.elapsed
            st.failed = True
        # Per-tenant attribution: the ledger-field deltas of this phase.
        tled = self._tenant_ledger(job.tenant)
        tled.gb_seconds += led.gb_seconds - before[0]
        tled.invocations += led.invocations - before[1]
        tled.s3_puts += led.s3_puts - before[2]
        tled.s3_gets += led.s3_gets - before[3]
        self._records[job.id].dollars += self.engine.cost_model.dollars(
            led.gb_seconds - before[0], led.invocations - before[1],
            led.s3_puts - before[2], led.s3_gets - before[3])
        if self.pool is not None:
            self._phase_log.append(
                (job.id, st.iteration, name, t_launch,
                 self.pool.warm_hits - warm0,
                 self.pool.cold_starts - cold0))
        else:
            self._phase_log.append(
                (job.id, st.iteration, name, t_launch, 0, 0))
        if st.failed:
            heapq.heappush(heap, (finish, _COMPLETE, job.id, 0, ""))
            return
        st.finish[name] = finish
        st.remaining -= 1
        for succ in info.succs[name]:
            st.ndeps[succ] -= 1
            if st.ndeps[succ] == 0:
                ready = max(st.finish[d]
                            for d in info.by_name[succ].deps)
                heapq.heappush(heap, (ready, _PHASE, job.id,
                                      st.iteration, succ))
        if st.remaining == 0:
            it_end = max(st.finish.values())
            if st.iteration + 1 < job.template.iters:
                st._start_iteration(st.iteration + 1)
                self._push_ready(heap, st, it_end)
            else:
                heapq.heappush(heap, (it_end, _COMPLETE, job.id, 0, ""))

    # ---------------------------------------------------------- complete
    def _complete(self, job_id: int, t: float) -> None:
        rec = self._records[job_id]
        job = self._states[job_id].job
        rec.t_finish = t
        rec.failed = self._states[job_id].failed
        self._inflight.pop(job_id, None)
        m = self._m
        m.counter("jobs.failed" if rec.failed else "jobs.completed").inc()
        if rec.latency is not None:
            m.histogram("job.latency_s").observe(rec.latency)
        if rec.slo_missed:
            m.counter("jobs.slo_missed").inc()
        m.gauge("fleet.inflight").set(len(self._inflight))
        tled = self._tenant_ledger(job.tenant)
        m.gauge(f"tenant.{job.tenant}.dollars").set(
            tled.dollars(self.engine.cost_model))
        extra = {}
        if self.slo_tracker is not None:
            # Fold the outcome into the tenant's error budget, and stamp
            # the job span with a warm-pool snapshot so incident
            # attribution can see the pool state each job finished under.
            self.slo_tracker.record_job(
                job.tenant, t, rec.latency or 0.0,
                deadline_missed=(rec.deadline is not None
                                 and t > rec.deadline),
                failed=rec.failed, dollars=rec.dollars)
            extra["budget_remaining"] = self.slo_tracker.budget_remaining(
                job.tenant)
            if self.pool is not None:
                extra["pool_free"] = self.pool.free_at(t)
        self.clock.telemetry.trace.emit(
            f"job/{job.tenant}/{job_id}", "job", job.t_arrival, t,
            track=f"tenant/{job.tenant}", tenant=job.tenant,
            template=job.template.name, latency=rec.latency,
            queue_wait=rec.queue_wait, failed=rec.failed,
            slo_missed=rec.slo_missed, **extra)

    # --------------------------------------------------------------- run
    def run(self) -> FleetResult:
        heap: List[Tuple] = []
        self._job_by_id = {j.id: j for j in self.jobs}
        for job in self.jobs:
            self._records[job.id] = JobRecord(
                id=job.id, tenant=job.tenant, template=job.template.name,
                t_arrival=job.t_arrival, deadline=job.deadline)
            heapq.heappush(heap, (job.t_arrival, _ARRIVE, job.id, 0, ""))
        m = self._m
        while heap:
            t, rank, job_id, _it, name = heapq.heappop(heap)
            if rank == _ARRIVE:
                job = self._job_by_id[job_id]
                m.counter("jobs.arrived").inc()
                m.counter(f"tenant.{job.tenant}.jobs").inc()
                self._autoscale(t, self._info[job.template.name])
                self._try_admit(heap, job, t)
            elif rank == _PHASE:
                st = self._states[job_id]
                if st.failed:
                    continue            # job aborted mid-iteration
                self._dispatch(heap, st, name, t)
            else:
                self._complete(job_id, t)
                adm = self.config.admission
                while (self._fifo
                       and len(self._inflight) < adm.max_inflight):
                    self._admit(heap, self._job_by_id[self._fifo.pop(0)],
                                t)
        end = self.engine.seconds
        self._accrue_provisioned(end)
        return FleetResult(
            jobs=[self._records[j.id] for j in self.jobs],
            seconds=end, dollars=self.engine.dollars,
            tenants=self._tenants,
            provisioned_gb_seconds=self._prov_gbs,
            peak_inflight=self._peak_inflight,
            phase_log=self._phase_log)
