"""Multi-tenant workload: registered job templates + seeded arrivals.

The paper's economic argument (Sec. 5: ~3000 transient Lambda workers
beating a fixed cluster on dollars) presumes a *shared* platform; a
workload is the demand side of that platform.  A ``JobTemplate`` declares
one job class — a tenant label, a per-iteration ``PhaseSpec`` DAG (the
same declaration the single-job scheduler runs), an iteration count, and
an optional relative deadline (the job's SLO).  Templates live in a
process-global registry like sketch families do, so benchmarks and traces
refer to them by name.

``generate_workload`` draws a seeded Poisson arrival process over a
template mix (``numpy.random.default_rng(seed)`` — same trace for the
same config, forever); ``workload_from_trace`` replays explicit
``(arrival_time, template)`` rows instead.  Either way the output is a
flat, arrival-sorted list of ``Job``s for ``tenancy.JobScheduler``.

Template-level estimates (``expected_makespan`` / ``phase_slack`` /
``expected_peak_workers``) run CPM on *median* phase durations from the
``StragglerModel`` price sheet — estimates for admission control and
autoscaling, not ground truth: the simulated fleet still draws straggler
tails, cold starts, and retries.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.obs.critical_path import critical_path
from repro.scheduler.spec import PhaseSpec, canonical_order


@dataclasses.dataclass(frozen=True)
class JobTemplate:
    """One registered job class: a named, deadline-bearing iteration DAG."""

    name: str
    tenant: str
    specs: Tuple[PhaseSpec, ...]
    iters: int = 1
    # Relative SLO: the job should finish within deadline_s of ARRIVAL
    # (queueing included).  None = best-effort tenant, never rejected on
    # feasibility and never counted as an SLO miss.
    deadline_s: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))
        canonical_order(self.specs)     # validates names/deps/cycles
        if not self.name:
            raise ValueError("template needs a non-empty name")
        if not self.tenant:
            raise ValueError(f"template {self.name!r}: needs a tenant")
        if self.iters < 1:
            raise ValueError(f"template {self.name!r}: iters must be >= 1")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(
                f"template {self.name!r}: deadline_s must be > 0")

    # ------------------------------------------------- planning estimates
    @staticmethod
    def expected_duration(spec: PhaseSpec, model) -> float:
        """Median duration of one phase: invoke overhead + median body
        (lognormal median = base_time * work) + master comm."""
        work = (spec.flops_per_worker / model.flops_per_second
                if spec.flops_per_worker is not None
                else spec.work_per_worker)
        return (model.invoke_overhead + model.base_time * work
                + model.comm_per_unit * spec.comm_units)

    def expected_schedule(self, model) -> Dict[str, tuple]:
        """CPM forward pass over ONE iteration: name -> (start, finish,
        deps) under median durations, iteration starting at 0."""
        finish: Dict[str, float] = {}
        sched: Dict[str, tuple] = {}
        for spec in canonical_order(self.specs):
            start = max((finish[d] for d in spec.deps), default=0.0)
            end = start + self.expected_duration(spec, model)
            finish[spec.name] = end
            sched[spec.name] = (start, end, spec.deps)
        return sched

    def expected_makespan(self, model) -> float:
        """Median end-to-end runtime: iterations are sequential barriers."""
        sched = self.expected_schedule(model)
        return self.iters * max(f for _, f, _ in sched.values())

    def phase_slack(self, model) -> Dict[str, float]:
        """Static per-phase CPM slack (seconds a phase can be delayed
        without moving the iteration makespan) — the budget pool-aware
        dispatch spends converting cold starts into warm hits."""
        report = critical_path(self.expected_schedule(model), start=0.0)
        return {n: p.slack for n, p in report.phases.items()}

    def expected_peak_workers(self, model) -> int:
        """Peak concurrent containers under the median schedule — the
        autoscaler's per-job capacity demand."""
        sched = self.expected_schedule(model)
        by_name = {s.name: s for s in self.specs}
        events: List[Tuple[float, int]] = []
        for name, (s, f, _) in sched.items():
            events.append((s, by_name[name].workers))
            events.append((f, -by_name[name].workers))
        events.sort()
        peak = cur = 0
        for _, dw in events:
            cur += dw
            peak = max(peak, cur)
        return peak


# ------------------------------------------------------------- registry
_TEMPLATES: Dict[str, JobTemplate] = {}


def register(template: JobTemplate, *, overwrite: bool = False
             ) -> JobTemplate:
    if template.name in _TEMPLATES and not overwrite:
        raise ValueError(f"job template {template.name!r} already "
                         f"registered (overwrite=True to replace)")
    _TEMPLATES[template.name] = template
    return template


def get_template(name: str) -> JobTemplate:
    try:
        return _TEMPLATES[name]
    except KeyError:
        raise KeyError(f"unknown job template {name!r}; registered: "
                       f"{available_templates()}") from None


def available_templates() -> List[str]:
    return sorted(_TEMPLATES)


# ------------------------------------------------------------- arrivals
@dataclasses.dataclass(frozen=True)
class Job:
    """One arrival: a template instance with an id and an absolute clock."""

    id: int
    template: JobTemplate
    t_arrival: float

    @property
    def tenant(self) -> str:
        return self.template.tenant

    @property
    def deadline(self) -> Optional[float]:
        d = self.template.deadline_s
        return None if d is None else self.t_arrival + d


DEFAULT_MIX: Tuple[Tuple[str, float], ...] = (
    ("newton_small", 0.45), ("newton_large", 0.15),
    ("giant", 0.15), ("matvec", 0.25))


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Seeded Poisson arrival process over a template mix."""

    seed: int = 0
    rate: float = 4.0               # mean arrivals per simulated second
    n_jobs: int = 100
    mix: Tuple[Tuple[str, float], ...] = DEFAULT_MIX


def generate_workload(cfg: WorkloadConfig) -> List[Job]:
    """Draw the arrival trace: exponential inter-arrival gaps + weighted
    template picks, all from one ``default_rng(cfg.seed)`` stream."""
    if cfg.n_jobs < 1:
        raise ValueError(f"n_jobs must be >= 1, got {cfg.n_jobs}")
    if cfg.rate <= 0:
        raise ValueError(f"rate must be > 0, got {cfg.rate}")
    names = [n for n, _ in cfg.mix]
    weights = np.asarray([w for _, w in cfg.mix], dtype=float)
    if len(names) == 0 or (weights < 0).any() or weights.sum() <= 0:
        raise ValueError(f"bad template mix: {cfg.mix!r}")
    templates = [get_template(n) for n in names]
    rng = np.random.default_rng(cfg.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / cfg.rate, size=cfg.n_jobs))
    picks = rng.choice(len(names), size=cfg.n_jobs,
                       p=weights / weights.sum())
    return [Job(i, templates[int(picks[i])], float(arrivals[i]))
            for i in range(cfg.n_jobs)]


def workload_from_trace(rows) -> List[Job]:
    """Trace-driven arrivals: ``rows`` is an iterable of ``(t, template)``
    pairs or ``{"t": ..., "template": ...}`` dicts.  Job ids follow the
    input order; the returned list is arrival-sorted (id tiebreak), the
    canonical event order the scheduler consumes."""
    jobs = []
    for i, row in enumerate(rows):
        if isinstance(row, Mapping):
            t, name = row["t"], row["template"]
        else:
            t, name = row
        jobs.append(Job(i, get_template(str(name)), float(t)))
    jobs.sort(key=lambda j: (j.t_arrival, j.id))
    return jobs


# ------------------------------------------- default template catalogue
# Small, fast shapes (fleet phases are ~0.2-0.5 simulated seconds) so the
# 1k-10k job benchmark sweeps stay tractable; worker counts and the
# grad || hess -> linesearch shape mirror scheduler_bench's Newton DAG.
register(JobTemplate(
    name="newton_small", tenant="batch", iters=1, deadline_s=6.0,
    specs=(PhaseSpec("grad", workers=6, policy="k_of_n", k=5,
                     flops_per_worker=3e5),
           PhaseSpec("hess", workers=10, policy="k_of_n", k=8,
                     flops_per_worker=4e5),
           PhaseSpec("linesearch", workers=4, flops_per_worker=2e5,
                     deps=("grad", "hess")))))
register(JobTemplate(
    name="newton_large", tenant="batch", iters=2, deadline_s=20.0,
    specs=(PhaseSpec("grad", workers=12, policy="k_of_n", k=10,
                     flops_per_worker=6e5),
           PhaseSpec("hess", workers=24, policy="k_of_n", k=20,
                     flops_per_worker=8e5),
           PhaseSpec("linesearch", workers=6, flops_per_worker=3e5,
                     deps=("grad", "hess")))))
register(JobTemplate(
    name="giant", tenant="train", iters=2, deadline_s=10.0,
    specs=(PhaseSpec("local", workers=8, policy="k_of_n", k=6,
                     flops_per_worker=5e5),
           PhaseSpec("reduce", workers=4, flops_per_worker=2e5,
                     comm_units=1.0, deps=("local",)))))
register(JobTemplate(
    name="matvec", tenant="serving", iters=1, deadline_s=2.0,
    specs=(PhaseSpec("matvec", workers=8, policy="k_of_n", k=6,
                     flops_per_worker=2e5, comm_units=1.0),)))
