"""Multi-tenant fleet plane: workloads, shared-pool job scheduling, SLOs.

Everything below PR 9's tenancy layer simulates ONE optimizer run at a
time; this package simulates the *platform* — seeded Poisson /
trace-driven arrivals of heterogeneous Newton/GIANT jobs
(``workload``), a job-level scheduler sharing one ``scheduler.WarmPool``
and one ``CostLedger`` across every concurrent run, SLO-aware admission,
and an arrival-rate autoscaler for the billable provisioned-concurrency
reserve (``scheduler``).  Deterministic end to end: same seed + same
arrival trace => bit-identical warm/cold assignment, seconds, dollars.
"""
from repro.tenancy.scheduler import (AdmissionPolicy, Autoscaler,
                                     FleetResult, JobRecord, JobScheduler,
                                     TenancyConfig)
from repro.tenancy.workload import (DEFAULT_MIX, Job, JobTemplate,
                                    WorkloadConfig, available_templates,
                                    generate_workload, get_template,
                                    register, workload_from_trace)

__all__ = [
    "AdmissionPolicy", "Autoscaler", "FleetResult", "JobRecord",
    "JobScheduler", "TenancyConfig",
    "DEFAULT_MIX", "Job", "JobTemplate", "WorkloadConfig",
    "available_templates", "generate_workload", "get_template",
    "register", "workload_from_trace",
]
