"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA  [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.common import ModelConfig
from repro.models.registry import register


@register("qwen3-32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=25600, vocab_size=151_936,
        qk_norm=True, rope_theta=1_000_000.0, max_seq=131_072)


SMOKE = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
             head_dim=16, d_ff=128, vocab_size=512, max_seq=256)
