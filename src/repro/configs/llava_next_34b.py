"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling; the vision tower is a STUB (input_specs provides
precomputed patch embeddings prepended to the text sequence)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.models.common import ModelConfig
from repro.models.registry import register


@register("llava-next-34b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="dense",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        head_dim=128, d_ff=20_480, vocab_size=64_000,
        frontend="patch_stub", num_patches=576,
        rope_theta=5_000_000.0, max_seq=131_072)


SMOKE = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
             head_dim=16, d_ff=128, vocab_size=512, num_patches=8,
             max_seq=256)
