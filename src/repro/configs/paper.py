"""The paper's own experimental workloads (Sec. 5 datasets), expressed as
dataset profiles.  LIBSVM is unavailable offline; `full` sizes mirror the
paper's table for the dry-run/simulation path, `bench` sizes are CPU-scaled
for the convergence benchmarks (same generative model: uniform-cube features,
logistic labels / categorical softmax labels).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DatasetProfile:
    name: str
    n_train: int
    n_features: int
    n_test: int
    n_classes: int = 2          # 2 => logistic (+-1), >2 => softmax
    # CPU-scaled benchmark size
    bench_n: int = 4000
    bench_d: int = 200
    bench_test: int = 1000


# bench sizes keep the paper's n >> sketch-dim >> workers regime at CPU scale
# (n/d large enough that GIANT's per-worker local Hessians are well-posed and
# the exact-Hessian worker count dwarfs the sketched one, as in the paper).
PROFILES = {
    # bench_d stays large relative to n/workers so the Hessian phase
    # dominates each iteration (d^2 per worker vs n/W*d), the regime the
    # paper's experiments live in; webpage/a9a keep their TRUE feature dims.
    "synthetic": DatasetProfile("synthetic", 300_000, 3000, 100_000,
                                bench_n=12_000, bench_d=400),
    "epsilon": DatasetProfile("epsilon", 400_000, 2000, 100_000,
                              bench_n=12_000, bench_d=400),
    "webpage": DatasetProfile("webpage", 48_000, 300, 15_000,
                              bench_n=8000, bench_d=300),
    "a9a": DatasetProfile("a9a", 32_000, 123, 16_000,
                          bench_n=8000, bench_d=123),
    "emnist": DatasetProfile("emnist", 240_000, 784, 40_000, n_classes=10,
                             bench_n=2400, bench_d=98),
}

# Paper worker/sketch setups per experiment (Sec. 5.1-5.2), kept for the
# simulated-time benchmarks so worker counts match the paper's ratios.
WORKER_SETUP = {
    "synthetic": dict(giant_workers=60, mv_workers=60, exact_hessian=3600,
                      sketch_workers=600, sketch_dim_mult=10),
    "epsilon": dict(giant_workers=100, mv_workers=100, exact_hessian=10_000,
                    sketch_workers=1500, sketch_dim_mult=15),
    "webpage": dict(giant_workers=30, mv_workers=30, exact_hessian=900,
                    sketch_workers=300, sketch_dim_mult=10),
    "a9a": dict(giant_workers=30, mv_workers=30, exact_hessian=900,
                sketch_workers=300, sketch_dim_mult=10),
    "emnist": dict(giant_workers=60, mv_workers=60, exact_hessian=3600,
                   sketch_workers=360, sketch_dim_mult=6),
}
