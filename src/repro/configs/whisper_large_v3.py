"""whisper-large-v3 [audio]: 32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866 — enc-dec, conv frontend STUB (input_specs provides precomputed
frame embeddings)  [arXiv:2212.04356; unverified]"""
from repro.models.common import ModelConfig
from repro.models.registry import register


@register("whisper-large-v3")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="encdec",
        num_layers=32, encoder_layers=32, d_model=1280,
        num_heads=20, num_kv_heads=20, head_dim=64, d_ff=5120,
        vocab_size=51_866, encoder_seq=1500,
        norm_type="layernorm", mlp_type="gelu", pos_embed="learned",
        qkv_bias=True, frontend="audio_stub", max_seq=32_768)


SMOKE = dict(num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
             num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
             encoder_seq=24, max_seq=256)
