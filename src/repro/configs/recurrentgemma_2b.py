"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attn, 1:2  [arXiv:2402.19427; hf]"""
from repro.models.common import ModelConfig
from repro.models.registry import register


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256_000,
        rnn_width=2560, attn_every=3, window_size=2048,
        tie_embeddings=True, rope_theta=10_000.0, max_seq=1_048_576)


SMOKE = dict(num_layers=6, d_model=64, num_heads=4, num_kv_heads=1,
             head_dim=16, d_ff=128, vocab_size=512, rnn_width=64,
             window_size=16, max_seq=256)
