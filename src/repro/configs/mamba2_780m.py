"""mamba2-780m [ssm]: 48L d_model=1536 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality)  [arXiv:2405.21060; unverified]"""
from repro.models.common import ModelConfig
from repro.models.registry import register


@register("mamba2-780m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        num_layers=48, d_model=1536, vocab_size=50_280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=128,
        tie_embeddings=True, max_seq=1_048_576)


SMOKE = dict(num_layers=2, d_model=64, vocab_size=512, ssm_state=16,
             ssm_head_dim=16, ssm_chunk=16, max_seq=256)
