"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global, 128k  [hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.common import ModelConfig
from repro.models.registry import register


@register("gemma3-27b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense",
        num_layers=62, d_model=5376, num_heads=32, num_kv_heads=16,
        head_dim=128, d_ff=21_504, vocab_size=262_144,
        qk_norm=True, tie_embeddings=True,
        local_global_pattern=5, window_size=1024,
        rope_theta=10_000.0, global_rope_theta=1_000_000.0,
        # beyond-paper serving optimization (EXPERIMENTS.md §Perf C):
        # local layers keep ring-buffer window caches => 2.4x decode bound
        windowed_decode_cache=True,
        max_seq=131_072)


SMOKE = dict(num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
             head_dim=16, d_ff=128, vocab_size=512, window_size=16,
             max_seq=256)
