"""Assigned architecture configs (exact public dims) + smoke-scale variants.

Importing this package populates the model registry.  ``smoke_config(name)``
returns the same family at test scale (few layers, narrow width, tiny vocab)
for CPU forward/train-step smoke tests; the FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation).
"""
from repro.configs import (gemma3_27b, llava_next_34b, mamba2_780m,
                           qwen2_7b, qwen3_4b, qwen3_32b, qwen3_moe_30b_a3b,
                           qwen3_moe_235b_a22b, recurrentgemma_2b,
                           whisper_large_v3, paper)
from repro.models.registry import get_config

_SMOKE = {
    "recurrentgemma-2b": recurrentgemma_2b.SMOKE,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b.SMOKE,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b.SMOKE,
    "whisper-large-v3": whisper_large_v3.SMOKE,
    "gemma3-27b": gemma3_27b.SMOKE,
    "qwen3-32b": qwen3_32b.SMOKE,
    "qwen3-4b": qwen3_4b.SMOKE,
    "qwen2-7b": qwen2_7b.SMOKE,
    "mamba2-780m": mamba2_780m.SMOKE,
    "llava-next-34b": llava_next_34b.SMOKE,
}

ASSIGNED_ARCHS = tuple(sorted(_SMOKE))


def smoke_config(name: str):
    return get_config(name).scaled(**_SMOKE[name])
