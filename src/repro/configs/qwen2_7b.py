"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias  [arXiv:2407.10671; hf]"""
from repro.models.common import ModelConfig
from repro.models.registry import register


@register("qwen2-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b", family="dense",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        head_dim=128, d_ff=18_944, vocab_size=152_064,
        qkv_bias=True, rope_theta=1_000_000.0, max_seq=131_072)


SMOKE = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
             head_dim=16, d_ff=128, vocab_size=512, max_seq=256)
