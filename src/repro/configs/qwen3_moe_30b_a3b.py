"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128e top-8  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.common import ModelConfig
from repro.models.registry import register


@register("qwen3-moe-30b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
        head_dim=128, d_ff=768, vocab_size=151_936,
        num_experts=128, experts_per_token=8,
        qk_norm=True, rope_theta=1_000_000.0, max_seq=131_072)


SMOKE = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
             head_dim=16, d_ff=32, vocab_size=512, num_experts=8,
             experts_per_token=2, moe_capacity_factor=8.0, max_seq=256)
