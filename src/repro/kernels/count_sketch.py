"""Pallas TPU kernel: Count-Sketch apply  A_tilde_k = S_k^T A  for K blocks.

TPU adaptation (see DESIGN.md §2): Count-Sketch is a scatter-add on CPUs/GPUs;
TPUs have no efficient scatter but a 128x128 systolic MXU.  We therefore
materialize, per (row-tile, sketch-block), the signed one-hot bucket matrix
``O[r, c] = sigma_r * 1{h_r == c}`` in VMEM via ``broadcasted_iota`` and
compute ``A_tilde_k += O^T @ A_tile`` as an MXU matmul.  Arithmetic intensity
rises from O(1) (scatter) to O(b) and the op becomes MXU-bound.

Grid: (K, d_tiles, n_tiles) with the n (reduction) dimension innermost so each
(K, d_tile) output block stays resident in VMEM across its accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_N = 256
DEFAULT_TILE_D = 256


def _kernel(h_ref, sigma_ref, a_ref, out_ref, *, block_size: int):
    i = pl.program_id(2)  # innermost: reduction over row tiles

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    h = h_ref[0, :]                       # (tn,) int32
    sigma = sigma_ref[0, :]               # (tn,)
    a = a_ref[...]                        # (tn, td)
    tn = h.shape[0]
    # Signed one-hot bucket matrix in VMEM: (tn, b).
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, block_size), 1)
    onehot = jnp.where(h[:, None] == iota, sigma[:, None], 0.0)
    onehot = onehot.astype(a.dtype)
    # MXU: (b, tn) @ (tn, td) -> (b, td)
    out_ref[...] += jax.lax.dot_general(
        onehot, a, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_size", "tile_n", "tile_d",
                                             "interpret"))
def count_sketch_apply(h: jax.Array, sigma: jax.Array, a: jax.Array,
                       block_size: int, *, tile_n: int = DEFAULT_TILE_N,
                       tile_d: int = DEFAULT_TILE_D,
                       interpret: bool = False) -> jax.Array:
    """(K, n) x (K, n) x (n, d) -> (K, block_size, d).  Pads n and d to tiles."""
    k, n = h.shape
    d = a.shape[1]
    tn = min(tile_n, max(8, n))
    td = min(tile_d, max(128, d))
    n_pad = (-n) % tn
    d_pad = (-d) % td
    if n_pad or d_pad:
        a = jnp.pad(a, ((0, n_pad), (0, d_pad)))
        # Padded rows get sigma 0 so they contribute nothing (bucket 0).
        h = jnp.pad(h, ((0, 0), (0, n_pad)))
        sigma = jnp.pad(sigma, ((0, 0), (0, n_pad)))
    n_t, d_t = (n + n_pad) // tn, (d + d_pad) // td

    out = pl.pallas_call(
        functools.partial(_kernel, block_size=block_size),
        grid=(k, d_t, n_t),
        in_specs=[
            pl.BlockSpec((1, tn), lambda kk, j, i: (kk, i)),
            pl.BlockSpec((1, tn), lambda kk, j, i: (kk, i)),
            pl.BlockSpec((tn, td), lambda kk, j, i: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, block_size, td), lambda kk, j, i: (kk, 0, j)),
        out_shape=jax.ShapeDtypeStruct((k, block_size, d + d_pad),
                                       jnp.float32),
        interpret=interpret,
    )(h, sigma.astype(jnp.float32), a.astype(jnp.float32))
    return out[:, :, :d]
