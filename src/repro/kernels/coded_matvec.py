"""Pallas TPU kernel: coded block mat-vec (paper Alg. 1 worker compute).

Each coded row-block (systematic or parity) is multiplied with the replicated
vector; the straggler-erasure mask is fused so erased workers never write.
This is memory-bound (one pass over the encoded matrix); the kernel's job is
to keep it at streaming bandwidth with VMEM-tiled row blocks and to avoid a
separate masking pass over the output.

Grid: (W, s_tiles) with the reduction over the vector innermost.

``parity_residuals`` is the kernel's master-side companion: one fused
masked pass over the (g+1, g+1, b) product grid computing every row/column
single-parity-check residual at once — the corruption detector's inner
loop (``core.coded.detect_corrupted``), kept here with the worker kernel
because both are the per-phase hot path over the same coded layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_S = 512


def _kernel(er_ref, enc_ref, x_ref, out_ref):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keep = 1.0 - er_ref[0].astype(out_ref.dtype)
    enc = enc_ref[0]                     # (b, ts)
    x = x_ref[...]                       # (ts,)
    out_ref[0, :] += keep * jnp.dot(enc, x,
                                    preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_s", "interpret"))
def coded_block_matvec(enc: jax.Array, x: jax.Array, erased: jax.Array, *,
                       tile_s: int = DEFAULT_TILE_S,
                       interpret: bool = False) -> jax.Array:
    """(W, b, s) x (s,) x (W,) bool -> (W, b) masked block products."""
    w, b, s = enc.shape
    ts = min(tile_s, max(128, s))
    s_pad = (-s) % ts
    if s_pad:
        enc = jnp.pad(enc, ((0, 0), (0, 0), (0, s_pad)))
        x = jnp.pad(x, (0, s_pad))
    st = (s + s_pad) // ts

    return pl.pallas_call(
        _kernel,
        grid=(w, st),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1, b, ts), lambda i, j: (i, 0, j)),
            pl.BlockSpec((ts,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((w, b), jnp.float32),
        interpret=interpret,
    )(erased, enc.astype(jnp.float32), x.astype(jnp.float32))


@jax.jit
def parity_residuals(products: jax.Array, known: jax.Array):
    """Per-line parity-check residuals of a coded product grid.

    products: ((g+1), (g+1), b) block products (erased cells arbitrary);
    known: ((g+1), (g+1)) bool arrival mask.  Every row and column of the
    extended grid satisfies sum(systematic) - parity = 0, so over known
    cells the signed line sums are exact-zero residual vectors unless a
    known cell's value is corrupted.  Returns ``(row_res, row_mag,
    col_res, col_mag)``: the L2 residual of each line's constraint and
    the L2 magnitude of the line's known values (the relative-tolerance
    scale).  Unknown cells contribute zero to both, so the caller must
    gate on line completeness — a line with a missing cell has no
    checkable constraint.
    """
    n = products.shape[0]
    sgn = jnp.where(jnp.arange(n) == n - 1, -1.0, 1.0)
    vals = jnp.where(known[..., None], products, 0.0).astype(jnp.float32)
    row_res = jnp.linalg.norm(jnp.einsum("c,rcb->rb", sgn, vals), axis=-1)
    col_res = jnp.linalg.norm(jnp.einsum("r,rcb->cb", sgn, vals), axis=-1)
    row_mag = jnp.sqrt((vals ** 2).sum(axis=(1, 2)))
    col_mag = jnp.sqrt((vals ** 2).sum(axis=(0, 2)))
    return row_res, row_mag, col_res, col_mag
