"""Public jitted wrappers for the Pallas kernels.

On TPU the kernels run compiled; on this CPU container they run in
``interpret=True`` mode (the Pallas interpreter executes the kernel body in
Python), which is the validation path mandated by the target spec.  The
backend is auto-detected; callers can force either mode.

Profiling hooks: ``set_profiler(metrics_registry)`` attaches an
``obs.MetricsRegistry`` to every entry point below — each call is then
timed wall-clock (``kernel.<op>.us`` histogram + ``kernel.<op>.calls``
counter, with ``block_until_ready`` so async dispatch does not hide the
work).  This is the MEASURED per-backend latency table the ROADMAP's
kernel auto-routing item consumes, replacing assumptions with data.  The
default (no profiler) is a single ``is None`` check per call — numerics
are never touched either way.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import coded_matvec as _cmv
from repro.kernels import count_sketch as _cs
from repro.kernels import oversketch_matmul as _og
from repro.kernels import sketch_gram as _sg
from repro.kernels import srht as _srht

_PROFILER = None    # obs.MetricsRegistry while attached, else None


def set_profiler(metrics) -> None:
    """Attach (or with None detach) a metrics registry to all kernel entry
    points; see the module docstring."""
    global _PROFILER
    _PROFILER = metrics


def get_profiler():
    return _PROFILER


def _timed(op: str, fn, *args, **kwargs):
    if _PROFILER is None:
        return fn(*args, **kwargs)
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    out = jax.block_until_ready(out)
    _PROFILER.histogram(f"kernel.{op}.us").observe(
        (time.perf_counter() - t0) * 1e6)
    _PROFILER.counter(f"kernel.{op}.calls").inc()
    return out


def _interpret(explicit: Optional[bool]) -> bool:
    if explicit is not None:
        return explicit
    return jax.default_backend() != "tpu"


def count_sketch_apply(h: jax.Array, sigma: jax.Array, a: jax.Array,
                       block_size: int,
                       interpret: Optional[bool] = None) -> jax.Array:
    """S^T A for all K sketch blocks: (K,n),(K,n),(n,d) -> (K,b,d)."""
    return _timed("count_sketch_apply", _cs.count_sketch_apply,
                  h, sigma, a, block_size,
                  interpret=_interpret(interpret))


def oversketch_gram(a_tilde: jax.Array, survivors: jax.Array,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Masked Gram (K,b,d),(K,) -> (d,d), rescaled by survivor count."""
    return _timed("oversketch_gram", _og.oversketch_gram,
                  a_tilde, survivors, interpret=_interpret(interpret))


def sketch_gram_count(h: jax.Array, sigma: jax.Array, a: jax.Array,
                      block_size: int, survivors: jax.Array,
                      interpret: Optional[bool] = None,
                      tile_n: int = _sg.DEFAULT_TILE_N,
                      d_tile: Optional[int] = None) -> jax.Array:
    """Fused count-sketch Gram (K,n),(K,n),(n,d),(K,) -> (d,d); A_tilde
    never hits HBM (streaming apply + in-register masked Gram).  The
    output is d-tiled past the VMEM budget (``d_tile`` defaults to
    ``pick_d_tile``; see ``fused_path`` for which grid a shape gets)."""
    return _timed("sketch_gram_count", _sg.sketch_gram_count,
                  h, sigma, a, block_size, survivors,
                  tile_n=tile_n, d_tile=d_tile,
                  interpret=_interpret(interpret))


def sketch_gram_sjlt(h: jax.Array, sigma: jax.Array, a: jax.Array,
                     block_size: int, survivors: jax.Array,
                     interpret: Optional[bool] = None,
                     tile_n: int = _sg.DEFAULT_TILE_N,
                     d_tile: Optional[int] = None) -> jax.Array:
    """Fused SJLT Gram (K,s,n),(K,s,n),(n,d),(K,) -> (d,d); the s signed
    one-hot layers are summed into the encode matrix in VMEM."""
    return _timed("sketch_gram_sjlt", _sg.sketch_gram_sjlt,
                  h, sigma, a, block_size, survivors,
                  tile_n=tile_n, d_tile=d_tile,
                  interpret=_interpret(interpret))


def sketch_gram_srht(rows: jax.Array, sigma: jax.Array, a: jax.Array,
                     survivors: jax.Array,
                     interpret: Optional[bool] = None,
                     tile_n: int = _sg.DEFAULT_TILE_N,
                     d_tile: Optional[int] = None) -> jax.Array:
    """Fused SRHT Gram (K,b),(K,n),(n,d),(K,) -> (d,d); the Hadamard mix
    rows are regenerated block-locally so the mixed panel never exists."""
    return _timed("sketch_gram_srht", _sg.sketch_gram_srht,
                  rows, sigma, a, survivors,
                  tile_n=tile_n, d_tile=d_tile,
                  interpret=_interpret(interpret))


# Grid-choice helpers, re-exported for benchmarks and tests: which fused
# grid a (block_size, d) problem gets ("fused" single-tile vs
# "fused_tiled") and the d_tile the default routing picks.
fused_path = _sg.fused_path
pick_d_tile = _sg.pick_d_tile


def fwht(x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    """Orthonormal Walsh-Hadamard transform along axis 1 of (K, n, d).
    Dispatches monolithic-panel vs two-pass tiled on the VMEM budget."""
    return _timed("fwht", _srht.fwht, x, interpret=_interpret(interpret))


def fwht_two_pass(x: jax.Array,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Force the two-pass tiled FWHT (local + across Kronecker passes)."""
    return _timed("fwht_two_pass", _srht.fwht_two_pass, x,
                  interpret=_interpret(interpret))


def coded_block_matvec(enc: jax.Array, x: jax.Array, erased: jax.Array,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Masked coded block products (W,b,s),(s,),(W,) -> (W,b)."""
    return _timed("coded_block_matvec", _cmv.coded_block_matvec,
                  enc, x, erased, interpret=_interpret(interpret))
