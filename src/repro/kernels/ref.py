"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def count_sketch_apply(h: jax.Array, sigma: jax.Array, a: jax.Array,
                       block_size: int) -> jax.Array:
    """S^T A for all sketch blocks.

    h:     (K, n) int32 bucket indices in [0, block_size)
    sigma: (K, n) Rademacher signs
    a:     (n, d)
    ->     (K, block_size, d)
    """
    def one(hk, sk):
        return jax.ops.segment_sum(a * sk[:, None].astype(a.dtype), hk,
                                   num_segments=block_size)
    return jax.vmap(one)(h, sigma)


def sjlt_apply(h: jax.Array, sigma: jax.Array, a: jax.Array,
               block_size: int) -> jax.Array:
    """SJLT (OSNAP) apply: s signed segment-sum layers per block, / sqrt(s).

    h:     (K, s, n) int32 bucket indices in [0, block_size)
    sigma: (K, s, n) Rademacher signs
    a:     (n, d)
    ->     (K, block_size, d)
    """
    s = h.shape[1]

    def one(hk, sk):
        def slot(ht, st):
            return jax.ops.segment_sum(a * st[:, None].astype(a.dtype), ht,
                                       num_segments=block_size)
        return jax.vmap(slot)(hk, sk).sum(axis=0)

    out = jax.vmap(one)(h, sigma)
    return out / jnp.sqrt(jnp.asarray(float(s), out.dtype))


def oversketch_gram(a_tilde: jax.Array, survivors: jax.Array) -> jax.Array:
    """H_hat = (1/N_avail) sum_k m_k A_tilde_k^T A_tilde_k.

    a_tilde: (K, b, d); survivors: (K,) bool -> (d, d)
    """
    m = survivors.astype(a_tilde.dtype)
    n_avail = jnp.maximum(m.sum(), 1.0)
    return jnp.einsum("k,kbd,kbe->de", m, a_tilde, a_tilde) / n_avail


def fwht(x: jax.Array) -> jax.Array:
    """Orthonormal Walsh-Hadamard transform along axis 1 of (K, n, d).

    Radix-2 butterfly (Sylvester / natural ordering): the oracle for the
    blocked Kronecker-matmul Pallas kernel.  n must be a power of two.
    """
    k, n, d = x.shape
    if n & (n - 1):
        raise ValueError(f"fwht length {n} must be a power of two")

    def one(xb):
        y, h = xb, 1
        while h < n:
            y = y.reshape(n // (2 * h), 2, h, d)
            y = jnp.stack([y[:, 0] + y[:, 1], y[:, 0] - y[:, 1]], axis=1)
            y = y.reshape(n, d)
            h *= 2
        return y / jnp.sqrt(jnp.asarray(n, y.dtype))

    return jax.vmap(one)(x)


def srht_apply(rows: jax.Array, sigma: jax.Array, a: jax.Array) -> jax.Array:
    """Blocked SRHT apply, the unfused oracle: sign, zero-pad to n_pad =
    next power of two, orthonormal FWHT, gather the b sampled rows, scale
    by sqrt(n_pad/b).

    rows: (K, b) int32 sampled Hadamard-row indices in [0, n_pad)
    sigma: (K, n) Rademacher signs
    a:    (n, d)
    ->    (K, b, d)
    """
    n, d = a.shape
    n_pad = 1 << max(0, (n - 1).bit_length())
    b = rows.shape[1]
    scale = jnp.sqrt(jnp.asarray(n_pad / b, jnp.float32))

    def one(rk, sk):
        x = sk[:, None] * a.astype(jnp.float32)
        if n_pad != n:
            x = jnp.pad(x, ((0, n_pad - n), (0, 0)))
        return fwht(x[None])[0][rk] * scale

    return jax.vmap(one)(rows, sigma)


def sketch_gram_count(h: jax.Array, sigma: jax.Array, a: jax.Array,
                      block_size: int, survivors: jax.Array) -> jax.Array:
    """Unfused apply+gram composition: the fused count-sketch oracle."""
    return oversketch_gram(count_sketch_apply(h, sigma, a, block_size),
                           survivors)


def sketch_gram_srht(rows: jax.Array, sigma: jax.Array, a: jax.Array,
                     survivors: jax.Array) -> jax.Array:
    """Unfused apply+gram composition: the fused SRHT oracle."""
    return oversketch_gram(srht_apply(rows, sigma, a), survivors)


def sketch_gram_sjlt(h: jax.Array, sigma: jax.Array, a: jax.Array,
                     block_size: int, survivors: jax.Array) -> jax.Array:
    """Unfused apply+gram composition: the fused SJLT oracle."""
    return oversketch_gram(sjlt_apply(h, sigma, a, block_size), survivors)


def coded_block_matvec(enc: jax.Array, x: jax.Array,
                       erased: jax.Array) -> jax.Array:
    """Per-worker block products with straggler masking.

    enc: (W, b, s) coded row-blocks; x: (s,); erased: (W,) bool -> (W, b)
    """
    prods = jnp.einsum("wbs,s->wb", enc, x)
    return jnp.where(erased[:, None], 0.0, prods)
