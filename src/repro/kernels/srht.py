"""Pallas TPU kernels: batched blocked fast Walsh-Hadamard transform (FWHT).

The SRHT sketch block is ``S_i^T A = sqrt(n_pad/b) * P_i H_norm (D_i A)``:
random signs, an orthonormal Hadamard mix, then b sampled rows.  The mix is
the hot loop.  A butterfly FWHT is O(n log n) but VPU-bound scalar shuffling;
on TPU we instead use the Sylvester identity ``H_{n1*n2} = H_{n1} (x) H_{n2}``
(x = Kronecker) to express the transform of a (n1*n2, td) panel as TWO MXU
matmuls with small dense Hadamard matrices:

    X = reshape(x, (n1, n2, td));   Y = H_{n1} @_1 X;   Y = H_{n2} @_2 Y

The Hadamard factors are materialized in VMEM from ``broadcasted_iota`` via
``H[i, j] = (-1)^popcount(i & j)`` — no host constants, same trick as the
count-sketch one-hot kernel.  Arithmetic intensity rises from O(1) to
O(sqrt(n)) and the op becomes MXU-bound.

Two kernels share that identity:

* ``_fwht_panel`` (monolithic): grid (K, d_tiles), each invocation holds one
  full (n, td) panel in VMEM and does both contractions.  VMEM ~
  2 * n * td * 4 bytes (in + out blocks) + (n1^2 + n2^2) * 4 for the
  factors — fine up to n ~ 4096 at td = 256, but n >> VMEM cannot compile.

* ``fwht_two_pass`` (tiled): the same Kronecker split executed as two
  pallas_calls that never hold a full panel.  Split the row index
  g = q * n2 + r (q = high bits, r = low bits); then
  ``H_n[g, g'] = H_{n1}[q, q'] * H_{n2}[r, r']`` and the transform
  factorizes into a LOCAL pass (contract r' with H_{n2} inside each
  contiguous n2-row chunk; grid (K, n1, d_tiles), VMEM ~ 2 * n2 * td * 4)
  and an ACROSS pass (contract q' with H_{n1}, a strided matmul over the
  chunk axis; grid (K, n2/tr, d_tiles), VMEM ~ 2 * n1 * tr * td * 4).
  Peak VMEM drops from O(n * td) to O(sqrt(n) * td) and any power-of-two n
  compiles.  The intermediate makes one HBM round-trip — the price of
  streaming; the factor matrices stay O(n1^2 + n2^2) = O(n).

``fwht`` dispatches between them on the documented VMEM panel budget.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_D = 256
DEFAULT_TILE_R = 8
# Monolithic-panel budget: double-buffered in+out (n, td) blocks must fit
# comfortably under the ~16 MB/core VMEM ceiling next to the factor
# matrices; beyond this the dispatcher switches to the two-pass kernel.
MAX_PANEL_BYTES = 4 * 1024 * 1024


def _split_pow2(n: int):
    log = int(math.log2(n)) if n > 1 else 0
    n1 = 1 << (log // 2)
    return n1, n // n1


def _hadamard(n: int, dtype) -> jax.Array:
    """Unnormalized Sylvester-Hadamard matrix H[i,j] = (-1)^popcount(i&j)."""
    i = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    bits = jax.lax.population_count(jnp.bitwise_and(i, j))
    return jnp.where(bits % 2 == 0, 1.0, -1.0).astype(dtype)


def _panel_kernel(x_ref, out_ref, *, n1: int, n2: int):
    x = x_ref[0]                                    # (n1*n2, td)
    td = x.shape[1]
    h1 = _hadamard(n1, x.dtype)
    h2 = _hadamard(n2, x.dtype)
    # Contract the n1 (high-bit) index: (n1, n1) @ (n1, n2*td).
    y = jax.lax.dot_general(h1, x.reshape(n1, n2 * td),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # Contract the n2 (low-bit) index: (n2, n2) x (n1, n2, td) -> (n2, n1, td).
    y = jax.lax.dot_general(h2, y.reshape(n1, n2, td),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y.transpose(1, 0, 2).reshape(n1 * n2, td)
    out_ref[0] = y * (1.0 / math.sqrt(float(n1 * n2)))


def _local_kernel(x_ref, out_ref, *, n2: int):
    """Pass A: one contiguous (n2, td) chunk, contract r' with H_{n2}."""
    x = x_ref[0, 0]                                 # (n2, td)
    h2 = _hadamard(n2, x.dtype)
    out_ref[0, 0] = jax.lax.dot_general(
        h2, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)


def _across_kernel(x_ref, out_ref, *, n1: int, scale: float):
    """Pass B: a strided (n1, tr, td) slab, contract q' with H_{n1}."""
    x = x_ref[0]                                    # (n1, tr, td)
    tr, td = x.shape[1], x.shape[2]
    h1 = _hadamard(n1, x.dtype)
    y = jax.lax.dot_general(h1, x.reshape(n1, tr * td),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    out_ref[0] = y.reshape(n1, tr, td) * scale


def _check_pow2(n: int) -> None:
    if n & (n - 1):
        raise ValueError(f"fwht length {n} must be a power of two")


def _pad_d(x: jax.Array, tile_d: int):
    d = x.shape[-1]
    td = min(tile_d, max(128, d))
    d_pad = (-d) % td
    if d_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad)))
    return x, td, (d + d_pad) // td


@functools.partial(jax.jit, static_argnames=("tile_d", "tile_r", "interpret"))
def fwht_two_pass(x: jax.Array, *, tile_d: int = DEFAULT_TILE_D,
                  tile_r: int = DEFAULT_TILE_R,
                  interpret: bool = False) -> jax.Array:
    """Two-pass tiled orthonormal FWHT along axis 1 of (K, n, d).

    Kronecker decomposition streamed as local + across passes so VMEM
    holds O(sqrt(n) * tile) instead of a full (n, tile_d) panel; any
    power-of-two n compiles.  Matches ``fwht`` / the butterfly oracle.
    """
    k, n, d = x.shape
    _check_pow2(n)
    n1, n2 = _split_pow2(n)
    x, td, d_t = _pad_d(x, tile_d)
    d_tot = td * d_t
    x4 = x.astype(jnp.float32).reshape(k, n1, n2, d_tot)

    mid = pl.pallas_call(
        functools.partial(_local_kernel, n2=n2),
        grid=(k, n1, d_t),
        in_specs=[pl.BlockSpec((1, 1, n2, td), lambda kk, q, j: (kk, q, 0, j))],
        out_specs=pl.BlockSpec((1, 1, n2, td), lambda kk, q, j: (kk, q, 0, j)),
        out_shape=jax.ShapeDtypeStruct((k, n1, n2, d_tot), jnp.float32),
        interpret=interpret,
    )(x4)

    tr = min(tile_r, n2)                 # both powers of two => tr | n2
    out = pl.pallas_call(
        functools.partial(_across_kernel, n1=n1,
                          scale=1.0 / math.sqrt(float(n))),
        grid=(k, n2 // tr, d_t),
        in_specs=[pl.BlockSpec((1, n1, tr, td),
                               lambda kk, m, j: (kk, 0, m, j))],
        out_specs=pl.BlockSpec((1, n1, tr, td),
                               lambda kk, m, j: (kk, 0, m, j)),
        out_shape=jax.ShapeDtypeStruct((k, n1, n2, d_tot), jnp.float32),
        interpret=interpret,
    )(mid)
    return out.reshape(k, n, d_tot)[:, :, :d]


def panel_vmem_bytes(n: int, tile_d: int = DEFAULT_TILE_D,
                     d: int = DEFAULT_TILE_D) -> int:
    """VMEM footprint of the monolithic kernel's resident panel (the
    dispatch quantity; see kernels/README.md for the full budget)."""
    td = min(tile_d, max(128, d))
    n1, n2 = _split_pow2(max(n, 1))
    return 2 * n * td * 4 + (n1 * n1 + n2 * n2) * 4


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret",
                                             "max_panel_bytes"))
def fwht(x: jax.Array, *, tile_d: int = DEFAULT_TILE_D,
         interpret: bool = False,
         max_panel_bytes: int = MAX_PANEL_BYTES) -> jax.Array:
    """Orthonormal Walsh-Hadamard transform along axis 1 of (K, n, d).

    n must be a power of two (callers zero-pad; padded rows mix harmlessly
    since the transform is linear).  Satisfies fwht(fwht(x)) == x.
    Dispatches to the monolithic panel kernel while the panel fits
    ``max_panel_bytes`` of VMEM, else to the two-pass tiled kernel.
    """
    k, n, d = x.shape
    _check_pow2(n)
    if panel_vmem_bytes(n, tile_d, d) > max_panel_bytes:
        return fwht_two_pass(x, tile_d=tile_d, interpret=interpret)
    n1, n2 = _split_pow2(n)
    x, td, d_t = _pad_d(x, tile_d)
    d_tot = td * d_t

    out = pl.pallas_call(
        functools.partial(_panel_kernel, n1=n1, n2=n2),
        grid=(k, d_t),
        in_specs=[pl.BlockSpec((1, n, td), lambda kk, j: (kk, 0, j))],
        out_specs=pl.BlockSpec((1, n, td), lambda kk, j: (kk, 0, j)),
        out_shape=jax.ShapeDtypeStruct((k, n, d_tot), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32))
    return out[:, :, :d]
