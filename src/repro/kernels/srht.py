"""Pallas TPU kernel: batched blocked fast Walsh-Hadamard transform (FWHT).

The SRHT sketch block is ``S_i^T A = sqrt(n_pad/b) * P_i H_norm (D_i A)``:
random signs, an orthonormal Hadamard mix, then b sampled rows.  The mix is
the hot loop.  A butterfly FWHT is O(n log n) but VPU-bound scalar shuffling;
on TPU we instead use the Sylvester identity ``H_{n1*n2} = H_{n1} (x) H_{n2}``
(x = Kronecker) to express the transform of a (n1*n2, td) panel as TWO MXU
matmuls with small dense Hadamard matrices:

    X = reshape(x, (n1, n2, td));   Y = H_{n1} @_1 X;   Y = H_{n2} @_2 Y

The Hadamard factors are materialized in VMEM from ``broadcasted_iota`` via
``H[i, j] = (-1)^popcount(i & j)`` — no host constants, same trick as the
count-sketch one-hot kernel.  Arithmetic intensity rises from O(1) to
O(sqrt(n)) and the op becomes MXU-bound.

Grid: (K, d_tiles); each kernel invocation transforms one (n_pad, td) panel
of one sketch block, so VMEM holds ~ n_pad * td * 4 bytes + the two factor
matrices (n1^2 + n2^2 <= 2 * n_pad).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_D = 256


def _hadamard(n: int, dtype) -> jax.Array:
    """Unnormalized Sylvester-Hadamard matrix H[i,j] = (-1)^popcount(i&j)."""
    i = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    bits = jax.lax.population_count(jnp.bitwise_and(i, j))
    return jnp.where(bits % 2 == 0, 1.0, -1.0).astype(dtype)


def _kernel(x_ref, out_ref, *, n1: int, n2: int):
    x = x_ref[0]                                    # (n1*n2, td)
    td = x.shape[1]
    h1 = _hadamard(n1, x.dtype)
    h2 = _hadamard(n2, x.dtype)
    # Contract the n1 (high-bit) index: (n1, n1) @ (n1, n2*td).
    y = jax.lax.dot_general(h1, x.reshape(n1, n2 * td),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # Contract the n2 (low-bit) index: (n2, n2) x (n1, n2, td) -> (n2, n1, td).
    y = jax.lax.dot_general(h2, y.reshape(n1, n2, td),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y.transpose(1, 0, 2).reshape(n1 * n2, td)
    out_ref[0] = y * (1.0 / math.sqrt(float(n1 * n2)))


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def fwht(x: jax.Array, *, tile_d: int = DEFAULT_TILE_D,
         interpret: bool = False) -> jax.Array:
    """Orthonormal Walsh-Hadamard transform along axis 1 of (K, n, d).

    n must be a power of two (callers zero-pad; padded rows mix harmlessly
    since the transform is linear).  Satisfies fwht(fwht(x)) == x.
    """
    k, n, d = x.shape
    if n & (n - 1):
        raise ValueError(f"fwht length {n} must be a power of two")
    log = int(math.log2(n)) if n > 1 else 0
    n1 = 1 << (log // 2)
    n2 = n // n1
    td = min(tile_d, max(128, d))
    d_pad = (-d) % td
    if d_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad)))
    d_t = (d + d_pad) // td

    out = pl.pallas_call(
        functools.partial(_kernel, n1=n1, n2=n2),
        grid=(k, d_t),
        in_specs=[pl.BlockSpec((1, n, td), lambda kk, j: (kk, 0, j))],
        out_specs=pl.BlockSpec((1, n, td), lambda kk, j: (kk, 0, j)),
        out_shape=jax.ShapeDtypeStruct((k, n, d + d_pad), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32))
    return out[:, :, :d]
