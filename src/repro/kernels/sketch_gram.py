"""Pallas TPU kernel: fused sketch->Gram streaming pipeline, d-tiled.

The paper's per-iteration hot path (Alg. 2 steps 3-5) is "sketch then
multiply": form ``A_tilde_k = S_k^T A`` for every sketch block, then
accumulate the survivor-masked Gram ``G = (1/N_avail) sum_k m_k
A_tilde_k^T A_tilde_k``.  The unfused pipeline costs two HBM round-trips —
``A_tilde`` (K, b, d) is written by the apply kernel and re-read by the
Gram kernel.  This kernel fuses the two: it streams row-panels of A,
applies the sketch block-locally, keeps running ``A_tilde_k`` column
panels in VMEM accumulators, and folds the masked Gram contribution into
the output tile when a block's reduction completes.  ``A_tilde`` never
touches HBM.

All supported families reduce to the same structure — a per-(block,
row-tile) *encode matrix* ``E in R^{tn x b}`` materialized in VMEM from
``broadcasted_iota`` (no host constants), followed by an MXU matmul:

  count-sketch:  E[r, c] = sigma_r * 1{h_r == c}
                 (the signed one-hot bucket matrix of ``count_sketch.py``)
  SJLT/OSNAP:    E[r, c] = (1/sqrt(s)) sum_t sigma_{t,r} * 1{h_{t,r} == c}
                 (s signed one-hot layers summed; count-sketch is s = 1,
                 intra-row bucket collisions sum exactly like the
                 segment-sum reference)
  SRHT:          E[r, c] = sigma_r * (-1)^popcount((o + r) & rows_c) / sqrt(b)
                 (the sampled-row slice of the Hadamard mix: H is symmetric,
                 so gathering b rows of H D A is a matmul with b *columns*
                 of H, each regenerated from the global row index o + r.
                 The SRHT scale sqrt(n_pad/b) * 1/sqrt(n_pad) collapses to
                 1/sqrt(b), so n_pad appears only through the bit pattern,
                 and zero rows past n never need to be streamed.)

Grid: ``(d_i, d_j, K, n_tiles)`` with the row-panel reduction innermost.
Each program owns one ``(d_tile, d_tile)`` block of the Gram output and
two ``(b, d_tile)`` VMEM scratch accumulators holding the column panels
``A_tilde_k[:, i_tile]`` and ``A_tilde_k[:, j_tile]``; the resident
working set is a function of ``d_tile`` — never of d — so the fused path
compiles for ANY d.  ``pick_d_tile`` chooses the largest tile that fits
``MAX_FUSED_VMEM_BYTES`` (``d_tile == d_pad`` recovers the single-tile
kernel exactly: one program, no encode recompute).  Past one tile, with
t tiles per side, the encode matmul is recomputed (2t - 1)x and A's
column panels are re-read 2t x — the price of never materializing
``A_tilde`` (see kernels/README.md for the budget table and the
recompute accounting).  The caller divides by the survivor count (same
convention as ``oversketch_matmul``).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_TILE_N = 256
# Budget for the kernel's resident VMEM working set (headroom under the
# ~16 MB/core ceiling).  Since the grid tiles the output, the budget is a
# function of d_tile, not d: it bounds the TILE, never declines the call —
# pick_d_tile shrinks the tile until the working set fits.
MAX_FUSED_VMEM_BYTES = 12 * 1024 * 1024
MIN_D_TILE = 128


def fused_vmem_bytes(block_size: int, d_tile: int,
                     tile_n: int = DEFAULT_TILE_N, nnz: int = 1) -> int:
    """Working-set bytes for one (d_i, d_j) program: two double-buffered A
    column panels, the encode matrix (nnz sign/bucket layers), two A_tilde
    scratch accumulators, one output tile (see kernels/README.md)."""
    td = d_tile + ((-d_tile) % 128)
    return 4 * (4 * tile_n * td + tile_n * block_size
                + 2 * nnz * tile_n + 2 * block_size * td + td * td)


def fits_fused_vmem(block_size: int, d_tile: int,
                    tile_n: int = DEFAULT_TILE_N, nnz: int = 1) -> bool:
    """Does a (d_tile, d_tile) output tile's working set fit the budget?
    Used only to PICK d_tile (pick_d_tile) — no caller declines on it."""
    return fused_vmem_bytes(block_size, d_tile, tile_n,
                            nnz) <= MAX_FUSED_VMEM_BYTES


def pick_d_tile(block_size: int, d: int, tile_n: int = DEFAULT_TILE_N,
                nnz: int = 1) -> int:
    """Largest output tile within the VMEM budget: d_pad itself when the
    whole (d_pad, d_pad) output fits (single-tile grid, zero recompute),
    otherwise the largest power-of-two multiple of 128 that fits (floor
    MIN_D_TILE, the lane width — below it the MXU runs padded anyway)."""
    d_pad = d + ((-d) % 128)
    if fits_fused_vmem(block_size, d_pad, tile_n, nnz):
        return d_pad
    td = MIN_D_TILE
    while 2 * td < d_pad and fits_fused_vmem(block_size, 2 * td, tile_n, nnz):
        td *= 2
    return td


def fused_path(block_size: int, d: int, tile_n: int = DEFAULT_TILE_N,
               nnz: int = 1) -> str:
    """Which fused grid a (b, d) problem gets: ``"fused"`` (one resident
    output tile — the pre-tiling kernel, zero encode recompute) or
    ``"fused_tiled"`` (multi-tile (d_i, d_j) grid).  Families without an
    encode-matrix form report ``"unfused"`` via SketchFamily.fused_path."""
    d_pad = d + ((-d) % 128)
    return "fused" if pick_d_tile(block_size, d, tile_n, nnz) >= d_pad \
        else "fused_tiled"


def _encode_count(meta, sigma, offset, block_size):
    """Summed signed one-hot layers (tn, b): meta/sigma are (s, tn) slices
    (s = 1 is plain count-sketch; s > 1 is SJLT, scaled by 1/sqrt(s))."""
    s, tn = sigma.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, block_size), 1)
    enc = jnp.zeros((tn, block_size), jnp.float32)
    for t in range(s):   # s is static and tiny (1..8): unrolled layers
        enc = enc + jnp.where(meta[t][:, None] == iota,
                              sigma[t][:, None], 0.0)
    if s > 1:
        enc = enc * (1.0 / math.sqrt(float(s)))
    return enc


def _encode_srht(meta, sigma, offset, block_size):
    """Sampled Hadamard mix (tn, b): meta is the (b,) sampled-row vector,
    sigma the (1, tn) sign slice."""
    tn = sigma.shape[-1]
    g = jax.lax.broadcasted_iota(jnp.int32, (tn, block_size), 0) + offset
    bits = jax.lax.population_count(jnp.bitwise_and(g, meta[None, :]))
    had = jnp.where(bits % 2 == 0, 1.0, -1.0)
    return sigma[0][:, None] * had * (1.0 / math.sqrt(float(block_size)))


_ENCODERS = {"count": _encode_count, "srht": _encode_srht}


def _kernel_single(mask_ref, meta_ref, sigma_ref, a_ref, out_ref, acc_ref, *,
                   mode: str, block_size: int, tile_n: int):
    """Single-tile specialization (d_t == 1): the whole (d_pad, d_pad)
    output is resident, A streams once per block, zero encode recompute."""
    kk = pl.program_id(2)
    r = pl.program_id(3)

    @pl.when((kk == 0) & (r == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(r == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                            # (tn, d_pad)
    enc = _ENCODERS[mode](meta_ref[0], sigma_ref[0], r * tile_n, block_size)
    # MXU: (b, tn) @ (tn, d_pad) accumulated into the resident panel.
    acc_ref[...] += jax.lax.dot_general(
        enc.astype(a.dtype), a, (((0,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(r == pl.num_programs(3) - 1)
    def _fold_gram():
        at = acc_ref[...]                     # (b, d_pad) complete A_tilde_k
        m = mask_ref[0]
        out_ref[...] += m * jax.lax.dot_general(
            at, at, (((0,), (0,)), ((), ())),
            preferred_element_type=out_ref.dtype)


def _kernel_tiled(mask_ref, meta_ref, sigma_ref, ai_ref, aj_ref, out_ref,
                  acc_i_ref, acc_j_ref, *, mode: str, block_size: int,
                  tile_n: int):
    """General d-tiled grid: each program owns one (td, td) output tile and
    two (b, td) A_tilde column-panel accumulators.  On diagonal tiles
    (i == j) the j-panel is the i-panel, so its matmul is skipped and the
    fold contracts acc_i with itself."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    kk = pl.program_id(2)
    r = pl.program_id(3)

    @pl.when((kk == 0) & (r == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(r == 0)
    def _init_acc():
        acc_i_ref[...] = jnp.zeros_like(acc_i_ref)
        acc_j_ref[...] = jnp.zeros_like(acc_j_ref)

    # (tn, b) encode matrix for this (block, row-panel); padded rows carry
    # sigma 0 so they contribute nothing.
    enc = _ENCODERS[mode](meta_ref[0], sigma_ref[0], r * tile_n, block_size)
    ai = ai_ref[...]                          # (tn, td) column panel i
    enc = enc.astype(ai.dtype)
    acc_i_ref[...] += jax.lax.dot_general(
        enc, ai, (((0,), (0,)), ((), ())),
        preferred_element_type=acc_i_ref.dtype)

    @pl.when(i != j)
    def _acc_j():
        acc_j_ref[...] += jax.lax.dot_general(
            enc, aj_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=acc_j_ref.dtype)

    @pl.when(r == pl.num_programs(3) - 1)
    def _fold_gram():
        # Block k's panels are complete: fold its masked Gram tile.
        m = mask_ref[0]
        at_i = acc_i_ref[...]
        at_j = jnp.where(i == j, at_i, acc_j_ref[...])
        out_ref[...] += m * jax.lax.dot_general(
            at_i, at_j, (((0,), (0,)), ((), ())),
            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("mode", "block_size", "tile_n", "d_tile",
                                    "interpret"))
def _sketch_gram(mask: jax.Array, meta: jax.Array, sigma: jax.Array,
                 a: jax.Array, *, mode: str, block_size: int, tile_n: int,
                 d_tile: int, interpret: bool) -> jax.Array:
    k, s, n = sigma.shape
    d = a.shape[1]
    tn = min(tile_n, max(8, n))
    td = max(MIN_D_TILE, d_tile + ((-d_tile) % 128))
    d_pad128 = d + ((-d) % 128)
    single = td >= d_pad128          # whole output fits one resident tile
    if single:
        td = d_pad128
    n_pad, d_pad = (-n) % tn, (-d) % td
    if n_pad or d_pad:
        a = jnp.pad(a, ((0, n_pad), (0, d_pad)))
        # Padded rows get sigma 0 so they contribute nothing.
        sigma = jnp.pad(sigma, ((0, 0), (0, 0), (0, n_pad)))
        if mode == "count":
            meta = jnp.pad(meta, ((0, 0), (0, 0), (0, n_pad)))
    n_t, d_t = (n + n_pad) // tn, (d + d_pad) // td
    meta_spec = (pl.BlockSpec((1, s, tn), lambda i, j, kk, r: (kk, 0, r))
                 if mode == "count"
                 else pl.BlockSpec((1, block_size),
                                   lambda i, j, kk, r: (kk, 0)))
    common = dict(mode=mode, block_size=block_size, tile_n=tn)
    in_specs = [
        pl.BlockSpec((1,), lambda i, j, kk, r: (kk,)),
        meta_spec,
        pl.BlockSpec((1, s, tn), lambda i, j, kk, r: (kk, 0, r)),
        pl.BlockSpec((tn, td), lambda i, j, kk, r: (r, i)),
    ]
    operands = [mask, meta, sigma.astype(jnp.float32),
                a.astype(jnp.float32)]
    if single:
        kernel = functools.partial(_kernel_single, **common)
        scratch = [pltpu.VMEM((block_size, td), jnp.float32)]
    else:
        kernel = functools.partial(_kernel_tiled, **common)
        in_specs.append(pl.BlockSpec((tn, td), lambda i, j, kk, r: (r, j)))
        operands.append(a.astype(jnp.float32))
        scratch = [pltpu.VMEM((block_size, td), jnp.float32),
                   pltpu.VMEM((block_size, td), jnp.float32)]

    out = pl.pallas_call(
        kernel,
        grid=(d_t, d_t, k, n_t),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((td, td), lambda i, j, kk, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d + d_pad, d + d_pad), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(*operands)
    n_avail = jnp.maximum(mask.sum(), 1.0)
    return out[:d, :d] / n_avail


@functools.partial(jax.jit, static_argnames=("block_size", "tile_n",
                                             "d_tile", "interpret"))
def sketch_gram_count(h: jax.Array, sigma: jax.Array, a: jax.Array,
                      block_size: int, survivors: jax.Array, *,
                      tile_n: int = DEFAULT_TILE_N,
                      d_tile: int = None,
                      interpret: bool = False) -> jax.Array:
    """Fused count-sketch Gram: (K,n),(K,n),(n,d),(K,) -> (d,d).

    Equivalent to ``oversketch_gram(count_sketch_apply(h, sigma, a, b),
    survivors)`` with ``A_tilde`` kept in VMEM.  ``d_tile`` defaults to
    ``pick_d_tile`` (the largest output tile within the VMEM budget).
    """
    if d_tile is None:
        d_tile = pick_d_tile(block_size, a.shape[1], tile_n)
    return _sketch_gram(survivors.astype(jnp.float32), h[:, None, :],
                        sigma[:, None, :], a, mode="count",
                        block_size=block_size, tile_n=tile_n, d_tile=d_tile,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_size", "tile_n",
                                             "d_tile", "interpret"))
def sketch_gram_sjlt(h: jax.Array, sigma: jax.Array, a: jax.Array,
                     block_size: int, survivors: jax.Array, *,
                     tile_n: int = DEFAULT_TILE_N,
                     d_tile: int = None,
                     interpret: bool = False) -> jax.Array:
    """Fused SJLT Gram: (K,s,n),(K,s,n),(n,d),(K,) -> (d,d).

    h/sigma carry s bucket/sign layers per block (OSNAP, s nonzeros per
    row of A); the encode matrix sums the s signed one-hot layers in VMEM
    and scales by 1/sqrt(s), so intra-row collisions add exactly like the
    slot-summed segment-sum reference (``ref.sjlt_apply``).
    """
    if d_tile is None:
        d_tile = pick_d_tile(block_size, a.shape[1], tile_n,
                             nnz=h.shape[1])
    return _sketch_gram(survivors.astype(jnp.float32), h, sigma, a,
                        mode="count", block_size=block_size, tile_n=tile_n,
                        d_tile=d_tile, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile_n", "d_tile",
                                             "interpret"))
def sketch_gram_srht(rows: jax.Array, sigma: jax.Array, a: jax.Array,
                     survivors: jax.Array, *,
                     tile_n: int = DEFAULT_TILE_N,
                     d_tile: int = None,
                     interpret: bool = False) -> jax.Array:
    """Fused SRHT Gram: (K,b),(K,n),(n,d),(K,) -> (d,d).

    rows are the b sampled Hadamard-row indices per block (in [0, n_pad));
    equivalent to the SRHT family's sign -> pad -> FWHT -> gather -> Gram
    chain, but block-local: the b needed mix rows are regenerated per
    row-panel so the (n_pad, d) mixed panel never exists.
    """
    b = rows.shape[1]
    if d_tile is None:
        d_tile = pick_d_tile(b, a.shape[1], tile_n)
    return _sketch_gram(survivors.astype(jnp.float32), rows,
                        sigma[:, None, :], a, mode="srht", block_size=b,
                        tile_n=tile_n, d_tile=d_tile, interpret=interpret)
