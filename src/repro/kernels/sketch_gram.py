"""Pallas TPU kernel: fused sketch->Gram streaming pipeline.

The paper's per-iteration hot path (Alg. 2 steps 3-5) is "sketch then
multiply": form ``A_tilde_k = S_k^T A`` for every sketch block, then
accumulate the survivor-masked Gram ``G = (1/N_avail) sum_k m_k
A_tilde_k^T A_tilde_k``.  The unfused pipeline costs two HBM round-trips —
``A_tilde`` (K, b, d) is written by the apply kernel and re-read by the
Gram kernel.  This kernel fuses the two: it streams row-panels of A once,
applies the sketch block-locally, keeps the running ``A_tilde_k`` panel in
a VMEM accumulator, and folds the masked Gram contribution into the
resident (d, d) output tile when a block's reduction completes.
``A_tilde`` never touches HBM.

Both supported families reduce to the same structure — a per-(block,
row-tile) *encode matrix* ``E in R^{tn x b}`` materialized in VMEM from
``broadcasted_iota`` (no host constants), followed by an MXU matmul:

  count-sketch:  E[r, c] = sigma_r * 1{h_r == c}
                 (the signed one-hot bucket matrix of ``count_sketch.py``)
  SRHT:          E[r, c] = sigma_r * (-1)^popcount((o + r) & rows_c) / sqrt(b)
                 (the sampled-row slice of the Hadamard mix: H is symmetric,
                 so gathering b rows of H D A is a matmul with b *columns*
                 of H, each regenerated from the global row index o + r.
                 The SRHT scale sqrt(n_pad/b) * 1/sqrt(n_pad) collapses to
                 1/sqrt(b), so n_pad appears only through the bit pattern,
                 and zero rows past n never need to be streamed.)

Grid: (K, n_tiles) with the row-panel reduction innermost.  VMEM holds one
(tn, d_pad) panel of A, the (tn, b) encode matrix, the (b, d_pad)
``A_tilde_k`` accumulator, and the resident (d_pad, d_pad) output — see
kernels/README.md for the budget formula.  The caller divides by the
survivor count (same convention as ``oversketch_matmul``).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_TILE_N = 256
# Budget for the kernel's resident VMEM working set (headroom under the
# ~16 MB/core ceiling).  The resident (d_pad, d_pad) output is the binding
# term: past it, callers must use the unfused apply+gram pair, which tiles
# d — SketchFamily.gram_fused returns None on fits_fused_vmem() == False
# so the registry fallback engages automatically.
MAX_FUSED_VMEM_BYTES = 12 * 1024 * 1024


def fused_vmem_bytes(block_size: int, d: int,
                     tile_n: int = DEFAULT_TILE_N) -> int:
    """Working-set bytes: double-buffered A panel, encode matrix, A_tilde
    scratch, resident output (see kernels/README.md)."""
    d_pad = d + ((-d) % 128)
    return 4 * (2 * tile_n * d_pad + tile_n * block_size
                + block_size * d_pad + d_pad * d_pad)


def fits_fused_vmem(block_size: int, d: int,
                    tile_n: int = DEFAULT_TILE_N) -> bool:
    return fused_vmem_bytes(block_size, d, tile_n) <= MAX_FUSED_VMEM_BYTES


def _encode_count(meta, sigma, offset, block_size):
    """Signed one-hot bucket matrix (tn, b): meta is the (tn,) h slice."""
    tn = sigma.shape[0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, block_size), 1)
    return jnp.where(meta[:, None] == iota, sigma[:, None], 0.0)


def _encode_srht(meta, sigma, offset, block_size):
    """Sampled Hadamard mix (tn, b): meta is the (b,) sampled-row vector."""
    tn = sigma.shape[0]
    g = jax.lax.broadcasted_iota(jnp.int32, (tn, block_size), 0) + offset
    bits = jax.lax.population_count(jnp.bitwise_and(g, meta[None, :]))
    had = jnp.where(bits % 2 == 0, 1.0, -1.0)
    return sigma[:, None] * had * (1.0 / math.sqrt(float(block_size)))


_ENCODERS = {"count": _encode_count, "srht": _encode_srht}


def _kernel(mask_ref, meta_ref, sigma_ref, a_ref, out_ref, acc_ref, *,
            mode: str, block_size: int, tile_n: int):
    kk = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when((kk == 0) & (i == 0))
    def _init_out():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(i == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sigma = sigma_ref[0]                      # (tn,) signs; 0 on padded rows
    a = a_ref[...]                            # (tn, d_pad)
    enc = _ENCODERS[mode](meta_ref[0], sigma, i * tile_n, block_size)
    # MXU: (b, tn) @ (tn, d_pad) accumulated into the resident A_tilde panel.
    acc_ref[...] += jax.lax.dot_general(
        enc.astype(a.dtype), a, (((0,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(i == pl.num_programs(1) - 1)
    def _fold_gram():
        at = acc_ref[...]                     # (b, d_pad) complete A_tilde_k
        m = mask_ref[0]
        out_ref[...] += m * jax.lax.dot_general(
            at, at, (((0,), (0,)), ((), ())),
            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("mode", "block_size", "tile_n",
                                    "interpret"))
def _sketch_gram(mask: jax.Array, meta: jax.Array, sigma: jax.Array,
                 a: jax.Array, *, mode: str, block_size: int, tile_n: int,
                 interpret: bool) -> jax.Array:
    k, n = sigma.shape
    d = a.shape[1]
    tn = min(tile_n, max(8, n))
    n_pad, d_pad = (-n) % tn, (-d) % 128
    if n_pad or d_pad:
        a = jnp.pad(a, ((0, n_pad), (0, d_pad)))
        # Padded rows get sigma 0 so they contribute nothing.
        sigma = jnp.pad(sigma, ((0, 0), (0, n_pad)))
        if mode == "count":
            meta = jnp.pad(meta, ((0, 0), (0, n_pad)))
    n_t, d_tot = (n + n_pad) // tn, d + d_pad
    meta_spec = (pl.BlockSpec((1, tn), lambda kk, i: (kk, i))
                 if mode == "count"
                 else pl.BlockSpec((1, block_size), lambda kk, i: (kk, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, mode=mode, block_size=block_size,
                          tile_n=tn),
        grid=(k, n_t),
        in_specs=[
            pl.BlockSpec((1,), lambda kk, i: (kk,)),
            meta_spec,
            pl.BlockSpec((1, tn), lambda kk, i: (kk, i)),
            pl.BlockSpec((tn, d_tot), lambda kk, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((d_tot, d_tot), lambda kk, i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d_tot, d_tot), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_size, d_tot), jnp.float32)],
        interpret=interpret,
    )(mask, meta, sigma.astype(jnp.float32), a.astype(jnp.float32))
    n_avail = jnp.maximum(mask.sum(), 1.0)
    return out[:d, :d] / n_avail


@functools.partial(jax.jit, static_argnames=("block_size", "tile_n",
                                             "interpret"))
def sketch_gram_count(h: jax.Array, sigma: jax.Array, a: jax.Array,
                      block_size: int, survivors: jax.Array, *,
                      tile_n: int = DEFAULT_TILE_N,
                      interpret: bool = False) -> jax.Array:
    """Fused count-sketch Gram: (K,n),(K,n),(n,d),(K,) -> (d,d).

    Equivalent to ``oversketch_gram(count_sketch_apply(h, sigma, a, b),
    survivors)`` with ``A_tilde`` kept in VMEM.
    """
    return _sketch_gram(survivors.astype(jnp.float32), h, sigma, a,
                        mode="count", block_size=block_size, tile_n=tile_n,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def sketch_gram_srht(rows: jax.Array, sigma: jax.Array, a: jax.Array,
                     survivors: jax.Array, *,
                     tile_n: int = DEFAULT_TILE_N,
                     interpret: bool = False) -> jax.Array:
    """Fused SRHT Gram: (K,b),(K,n),(n,d),(K,) -> (d,d).

    rows are the b sampled Hadamard-row indices per block (in [0, n_pad));
    equivalent to the SRHT family's sign -> pad -> FWHT -> gather -> Gram
    chain, but block-local: the b needed mix rows are regenerated per
    row-panel so the (n_pad, d) mixed panel never exists.
    """
    b = rows.shape[1]
    return _sketch_gram(survivors.astype(jnp.float32), rows, sigma, a,
                        mode="srht", block_size=b, tile_n=tile_n,
                        interpret=interpret)
