"""Pallas TPU kernel: straggler-masked blocked Gram accumulation
``G = sum_k m_k * A_tilde_k^T @ A_tilde_k`` (OverSketch computation+reduction
phases, paper Alg. 2 steps 3-5, fused).

The survivor mask is applied *inside* the accumulation loop, so a straggling
block's contribution is never read from HBM into the MXU — on real hardware
the mask also gates the DMA.  The caller divides by the survivor count
(keeping the kernel a pure masked sum keeps it reusable for the distributed
resilient-psum path, where the rescale happens after the cross-chip
reduction).

Grid: (d_i, d_j, K*b_tiles) with the fused (block, row-tile) reduction
innermost so each (d_i, d_j) output tile accumulates in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_D = 256
DEFAULT_TILE_B = 256


def _kernel(mask_ref, ai_ref, aj_ref, out_ref):
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    m = mask_ref[0]                       # scalar mask for this sketch block
    ai = ai_ref[0]                        # (tb, tdi)
    aj = aj_ref[0]                        # (tb, tdj)
    contrib = jax.lax.dot_general(
        ai, aj, (((0,), (0,)), ((), ())),
        preferred_element_type=out_ref.dtype)
    out_ref[...] += m * contrib


@functools.partial(jax.jit, static_argnames=("tile_d", "tile_b", "interpret"))
def oversketch_gram(a_tilde: jax.Array, survivors: jax.Array, *,
                    tile_d: int = DEFAULT_TILE_D,
                    tile_b: int = DEFAULT_TILE_B,
                    interpret: bool = False) -> jax.Array:
    """(K, b, d) x (K,) bool -> (d, d) masked Gram / survivor count."""
    k, b, d = a_tilde.shape
    tb = min(tile_b, max(8, b))
    td = min(tile_d, max(128, d))
    b_pad, d_pad = (-b) % tb, (-d) % td
    if b_pad or d_pad:
        a_tilde = jnp.pad(a_tilde, ((0, 0), (0, b_pad), (0, d_pad)))
    bt, dt = (b + b_pad) // tb, (d + d_pad) // td
    mask = survivors.astype(jnp.float32)

    out = pl.pallas_call(
        _kernel,
        grid=(dt, dt, k * bt),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j, r: (r // bt,)),
            pl.BlockSpec((1, tb, td), lambda i, j, r: (r // bt, r % bt, i)),
            pl.BlockSpec((1, tb, td), lambda i, j, r: (r // bt, r % bt, j)),
        ],
        out_specs=pl.BlockSpec((td, td), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d + d_pad, d + d_pad), jnp.float32),
        interpret=interpret,
    )(mask, a_tilde.astype(jnp.float32), a_tilde.astype(jnp.float32))
    n_avail = jnp.maximum(mask.sum(), 1.0)
    return out[:d, :d] / n_avail
