"""OverSketched Newton reproduction on JAX/Pallas."""
from repro import jax_compat  # noqa: F401  (backfills newer jax APIs)
