"""Warm-container pool: stateful cold-start dynamics keyed off the event clock.

The fleet engine's historical cold-start model is an i.i.d. coin flip per
attempt (``FleetConfig.cold_start_prob``), which cannot express the thing
that actually distinguishes schedules on Lambda: a *steady* sequential
schedule keeps re-hitting the same warm containers, while a *bursty* DAG
schedule that launches two fan-outs concurrently needs twice the container
footprint at once — the provider has no warm container to give, so the
burst pays cold starts the sequential schedule never sees.

``WarmPool`` models exactly that and nothing more:

  - ``acquire(t)``: a launch at absolute simulated time ``t`` takes the
    most-recently-used container that is free (``available_at <= t``) and
    not expired (``t - available_at <= ttl``).  MRU selection mirrors
    provider behaviour (hot containers stay hot; idle ones age out) and is
    what makes steady schedules cheap.  Returns True (warm) or False
    (cold start — a new container is created for this attempt).
  - ``release(t)``: the attempt ended at ``t``; its container re-enters the
    pool idle from ``t``.  Failed attempts release too — a function error
    does not tear the container down.
  - Containers idle longer than ``ttl`` are expired lazily at the next
    acquire; ``capacity`` (optional) LRU-evicts beyond a pool-size cap.

Prewarmed (provisioned) containers are *pinned to first use*: like
provisioned concurrency they are kept warm by the provider and never TTL
out while unused.  Once acquired they behave like any other container —
released with an idle-since time and subject to TTL.  ``prewarm`` /
``cool`` resize the provisioned set at runtime (the tenancy autoscaler's
knob); the idle GB-seconds they bill are accounted by the caller (see
``runtime/cost.py``).

The pool is attached to a ``FleetEngine`` (``SimClock(..., pool=...)``) and
consulted *instead of* the coin flip; the cold-start delay itself still
comes from ``FleetConfig.cold_start_lo/hi``.  State mutates in dispatch
order: an overlapped phase (``not_before`` in the past) acquires at its
launch time but against the pool as it exists when the phase is
*dispatched* — a deliberate approximation that keeps the simulation
single-pass and deterministic under the scheduler's canonical phase order.

Policy relaunches (speculative / hedged duplicates) bypass the pool and
keep the i.i.d. model: duplicates are by construction a burst into fresh
capacity.
"""
from __future__ import annotations

import bisect
from typing import List, Optional


class WarmPool:
    """Container pool with TTL expiry; all times are absolute simulated
    seconds on the fleet engine's clock."""

    def __init__(self, ttl: float = 300.0, capacity: Optional[int] = None,
                 prewarmed: int = 0):
        if ttl <= 0:
            raise ValueError(f"pool ttl must be positive, got {ttl}")
        if capacity is not None and capacity < 1:
            raise ValueError(f"pool capacity must be >= 1, got {capacity}")
        self.ttl = float(ttl)
        self.capacity = capacity
        # Sorted idle-since times; entry i is a container free from _free[i].
        self._free: List[float] = []
        # Provisioned containers, pinned warm until first use: never in
        # _free, so lazy TTL expiry cannot discard them before a late
        # first dispatch.
        self._fresh = int(prewarmed)
        self.warm_hits = 0
        self.cold_starts = 0
        self.killed = 0

    # ------------------------------------------------------------ lifecycle
    def _expire(self, t: float) -> None:
        cut = bisect.bisect_left(self._free, t - self.ttl)
        if cut:
            del self._free[:cut]

    def acquire(self, t: float) -> bool:
        """Take a warm container for a launch at time ``t``; True if one was
        available (no cold start), False if the attempt starts cold."""
        t = float(t)
        self._expire(t)
        # MRU: the container with the largest available_at <= t.  Released
        # containers outrank provisioned ones (which are idle "since 0"):
        # hot containers stay hot, the provisioned reserve drains last.
        i = bisect.bisect_right(self._free, t) - 1
        if i >= 0:
            del self._free[i]
            self.warm_hits += 1
            return True
        if self._fresh > 0:
            self._fresh -= 1
            self.warm_hits += 1
            return True
        self.cold_starts += 1
        return False

    def release(self, t: float) -> None:
        """Return a container to the pool, idle from time ``t``."""
        bisect.insort(self._free, float(t))
        if (self.capacity is not None
                and self._fresh + len(self._free) > self.capacity):
            # LRU evict: the provisioned reserve is the longest-idle.
            if self._fresh:
                self._fresh -= 1
            else:
                del self._free[0]

    def prewarm(self, k: int) -> None:
        """Provision ``k`` more pinned-warm containers (autoscale up)."""
        self._fresh += max(0, int(k))

    def cool(self, k: int) -> int:
        """Decommission up to ``k`` unused provisioned containers
        (autoscale down); returns how many were actually removed."""
        take = min(max(0, int(k)), self._fresh)
        self._fresh -= take
        return take

    @property
    def fresh(self) -> int:
        """Provisioned containers still pinned warm (never used)."""
        return self._fresh

    def cull(self, fraction: float, rng) -> int:
        """Kill a seeded random ``fraction`` of the idle containers — the
        fault plane's container-death event (the provider reclaimed them
        out from under the tenant).  In-flight containers are unaffected;
        they die with their attempt's own fault, not here.  Returns how
        many containers were culled."""
        n = self._fresh + len(self._free)
        k = int(round(float(fraction) * n))
        if k <= 0:
            return 0
        # Index space [0, _fresh) is the provisioned reserve, the rest maps
        # onto _free — same sorted layout the single-list pool exposed.
        idx = rng.choice(n, size=k, replace=False)
        fresh_killed = 0
        for i in sorted(idx, reverse=True):
            if i < self._fresh:
                fresh_killed += 1
            else:
                del self._free[i - self._fresh]
        self._fresh -= fresh_killed
        self.killed += k
        return k

    # ------------------------------------------------------------- inspect
    def snapshot(self, t: float) -> dict:
        """Telemetry-friendly state: cumulative hit/miss/kill counters plus
        the warm, unexpired container count a launch at ``t`` would see."""
        return {"warm_hits": self.warm_hits,
                "cold_starts": self.cold_starts,
                "killed": self.killed,
                "free": self.free_at(t),
                "containers": self._fresh + len(self._free)}

    def free_at(self, t: float) -> int:
        """How many warm, unexpired containers a launch at ``t`` could use."""
        t = float(t)
        lo = bisect.bisect_left(self._free, t - self.ttl)
        hi = bisect.bisect_right(self._free, t)
        return max(0, hi - lo) + self._fresh

    def earliest_fit(self, t: float, need: int, deadline: float) -> float:
        """Earliest launch time in ``[t, deadline]`` at which the most of a
        ``need``-container burst lands warm.  Candidates are the release
        times of currently busy-until-then containers; returns ``t`` when
        waiting gains nothing.  Pool-aware dispatch spends per-phase slack
        (``obs.critical_path``) through this: delaying an off-critical-path
        phase to a candidate returned here converts cold starts into warm
        hits without moving the makespan."""
        t = float(t)
        deadline = float(deadline)
        best_t, best_n = t, min(need, self.free_at(t))
        if best_n >= need or deadline <= t:
            return best_t
        lo = bisect.bisect_right(self._free, t)
        hi = bisect.bisect_right(self._free, deadline)
        for cand in self._free[lo:hi]:
            n = min(need, self.free_at(cand))
            if n > best_n:
                best_t, best_n = cand, n
                if best_n >= need:
                    break
        return best_t

    def __len__(self) -> int:
        return self._fresh + len(self._free)
