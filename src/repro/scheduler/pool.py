"""Warm-container pool: stateful cold-start dynamics keyed off the event clock.

The fleet engine's historical cold-start model is an i.i.d. coin flip per
attempt (``FleetConfig.cold_start_prob``), which cannot express the thing
that actually distinguishes schedules on Lambda: a *steady* sequential
schedule keeps re-hitting the same warm containers, while a *bursty* DAG
schedule that launches two fan-outs concurrently needs twice the container
footprint at once — the provider has no warm container to give, so the
burst pays cold starts the sequential schedule never sees.

``WarmPool`` models exactly that and nothing more:

  - ``acquire(t)``: a launch at absolute simulated time ``t`` takes the
    most-recently-used container that is free (``available_at <= t``) and
    not expired (``t - available_at <= ttl``).  MRU selection mirrors
    provider behaviour (hot containers stay hot; idle ones age out) and is
    what makes steady schedules cheap.  Returns True (warm) or False
    (cold start — a new container is created for this attempt).
  - ``release(t)``: the attempt ended at ``t``; its container re-enters the
    pool idle from ``t``.  Failed attempts release too — a function error
    does not tear the container down.
  - Containers idle longer than ``ttl`` are expired lazily at the next
    acquire; ``capacity`` (optional) LRU-evicts beyond a pool-size cap.

The pool is attached to a ``FleetEngine`` (``SimClock(..., pool=...)``) and
consulted *instead of* the coin flip; the cold-start delay itself still
comes from ``FleetConfig.cold_start_lo/hi``.  State mutates in dispatch
order: an overlapped phase (``not_before`` in the past) acquires at its
launch time but against the pool as it exists when the phase is
*dispatched* — a deliberate approximation that keeps the simulation
single-pass and deterministic under the scheduler's canonical phase order.

Policy relaunches (speculative / hedged duplicates) bypass the pool and
keep the i.i.d. model: duplicates are by construction a burst into fresh
capacity.
"""
from __future__ import annotations

import bisect
from typing import List, Optional


class WarmPool:
    """Container pool with TTL expiry; all times are absolute simulated
    seconds on the fleet engine's clock."""

    def __init__(self, ttl: float = 300.0, capacity: Optional[int] = None,
                 prewarmed: int = 0):
        if ttl <= 0:
            raise ValueError(f"pool ttl must be positive, got {ttl}")
        if capacity is not None and capacity < 1:
            raise ValueError(f"pool capacity must be >= 1, got {capacity}")
        self.ttl = float(ttl)
        self.capacity = capacity
        # Sorted idle-since times; entry i is a container free from _free[i].
        self._free: List[float] = [0.0] * int(prewarmed)
        self.warm_hits = 0
        self.cold_starts = 0

    # ------------------------------------------------------------ lifecycle
    def _expire(self, t: float) -> None:
        cut = bisect.bisect_left(self._free, t - self.ttl)
        if cut:
            del self._free[:cut]

    def acquire(self, t: float) -> bool:
        """Take a warm container for a launch at time ``t``; True if one was
        available (no cold start), False if the attempt starts cold."""
        t = float(t)
        self._expire(t)
        # MRU: the container with the largest available_at <= t.
        i = bisect.bisect_right(self._free, t) - 1
        if i >= 0:
            del self._free[i]
            self.warm_hits += 1
            return True
        self.cold_starts += 1
        return False

    def release(self, t: float) -> None:
        """Return a container to the pool, idle from time ``t``."""
        bisect.insort(self._free, float(t))
        if self.capacity is not None and len(self._free) > self.capacity:
            del self._free[0]   # LRU evict: the longest-idle container

    def cull(self, fraction: float, rng) -> int:
        """Kill a seeded random ``fraction`` of the idle containers — the
        fault plane's container-death event (the provider reclaimed them
        out from under the tenant).  In-flight containers are unaffected;
        they die with their attempt's own fault, not here.  Returns how
        many containers were culled."""
        n = len(self._free)
        k = int(round(float(fraction) * n))
        if k <= 0:
            return 0
        idx = rng.choice(n, size=k, replace=False)
        for i in sorted(idx, reverse=True):
            del self._free[i]
        self.killed = getattr(self, "killed", 0) + k
        return k

    # ------------------------------------------------------------- inspect
    def snapshot(self, t: float) -> dict:
        """Telemetry-friendly state: cumulative hit/miss counters plus the
        warm, unexpired container count a launch at ``t`` would see."""
        return {"warm_hits": self.warm_hits,
                "cold_starts": self.cold_starts,
                "free": self.free_at(t), "containers": len(self._free)}

    def free_at(self, t: float) -> int:
        """How many warm, unexpired containers a launch at ``t`` could use."""
        t = float(t)
        lo = bisect.bisect_left(self._free, t - self.ttl)
        hi = bisect.bisect_right(self._free, t)
        return max(0, hi - lo)

    def __len__(self) -> int:
        return len(self._free)
