"""Phase-DAG scheduler: the layer between optimizers and the fleet engine.

Optimizers declare one iteration as a DAG of ``PhaseSpec``s (workers,
termination policy, per-worker work, declared Lambda size, dependency
edges); the scheduler dispatches independent phases concurrently through
``FleetEngine.run_phase(not_before=...)``, bills each phase at its own
Lambda size, and — with a ``WarmPool`` attached to the engine — makes
cold-start dynamics a function of the schedule's shape instead of a coin
flip.

See ``src/repro/scheduler/README.md`` for the DAG model, pool semantics,
and the trace schema v2 fields this subsystem adds.
"""
from repro.scheduler.dag import DagResult, DagRun, PhaseResult, run_dag
from repro.scheduler.pool import WarmPool
from repro.scheduler.sizing import (LAMBDA_MAX_GB, LAMBDA_MIN_GB,
                                    LAMBDA_STEP_GB, distavg_worker_bytes,
                                    lambda_memory_gb, matvec_worker_bytes,
                                    sketch_worker_bytes)
from repro.scheduler.spec import PhaseSpec, canonical_order, validate_dag

__all__ = [
    "DagResult", "DagRun", "PhaseResult", "run_dag",
    "WarmPool",
    "LAMBDA_MAX_GB", "LAMBDA_MIN_GB", "LAMBDA_STEP_GB",
    "distavg_worker_bytes", "lambda_memory_gb", "matvec_worker_bytes",
    "sketch_worker_bytes",
    "PhaseSpec", "canonical_order", "validate_dag",
]
