"""Phase specifications and DAG validation for the phase-DAG scheduler.

A ``PhaseSpec`` is one distributed round, declared: everything
``FleetEngine.run_phase`` needs (workers, termination policy, per-worker
work, master comm), plus the two axes the scheduler adds — the phase's
declared Lambda size (``memory_gb``, a per-phase ``CostModel`` override;
None bills at the fleet-wide default) and its dependency edges (``deps``,
names of phases whose *results* this phase consumes).

Dispatch order is canonicalized (``canonical_order``): Kahn's algorithm
with the ready set popped in lexicographic name order.  Two declarations
of the same DAG in different topological orders therefore dispatch — and
bill, and draw randomness — identically, which is what makes the
scheduler's ``(seconds, dollars)`` a function of the DAG, not of the
declaration order.

Per-phase PRNG keys fold a stable CRC-32 of the phase name into the run
key (``key_fold``) — Python's salted ``hash`` would break cross-process
reproducibility.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class PhaseSpec:
    """One declared distributed phase of an iteration DAG."""

    name: str
    workers: int
    policy: str = "wait_all"
    k: Optional[int] = None
    work_per_worker: float = 1.0
    flops_per_worker: Optional[float] = None
    comm_units: float = 0.0
    # Declared per-worker working set -> Lambda size for billing this phase.
    # None = the fleet-wide CostModel.memory_gb (the paper's fixed 3 GB).
    memory_gb: Optional[float] = None
    # The phase's TRUE per-worker working set in GB (scheduler.sizing,
    # before headroom/rounding).  Inert unless a fault plan with an
    # OomSpec is attached to the engine: attempts billed below this are
    # then OOM-killed — undersizing memory_gb becomes a failure mode, not
    # just a discount.
    working_set_gb: Optional[float] = None
    deps: Tuple[str, ...] = ()
    decodable: Optional[Callable] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("phase needs a non-empty name")
        if self.workers < 1:
            raise ValueError(f"phase {self.name!r}: workers must be >= 1")
        if self.memory_gb is not None and self.memory_gb <= 0:
            raise ValueError(f"phase {self.name!r}: memory_gb must be > 0")
        if self.working_set_gb is not None and self.working_set_gb <= 0:
            raise ValueError(
                f"phase {self.name!r}: working_set_gb must be > 0")
        object.__setattr__(self, "deps", tuple(self.deps))

    @property
    def key_fold(self) -> int:
        """Stable per-name fold constant for the run's PRNG key."""
        return zlib.crc32(self.name.encode("utf-8")) & 0x7FFFFFFF


def validate_dag(specs: Sequence[PhaseSpec]) -> None:
    """Raise ValueError on duplicate names, unknown deps, or cycles."""
    canonical_order(specs)


def canonical_order(specs: Sequence[PhaseSpec]) -> List[PhaseSpec]:
    """Kahn's topological sort, ready set in lexicographic name order.

    The canonical order is a pure function of the DAG (names + edges):
    permuting the declaration order never changes the dispatch order.
    Validates as it sorts: duplicate names, unknown deps, and cycles all
    raise ValueError.
    """
    seen = set()
    for s in specs:
        if s.name in seen:
            raise ValueError(f"duplicate phase name {s.name!r}")
        seen.add(s.name)
    for s in specs:
        for d in s.deps:
            if d not in seen:
                raise ValueError(
                    f"phase {s.name!r} depends on unknown phase {d!r}")
    by_name = {s.name: s for s in specs}
    indeg = {s.name: len(set(s.deps)) for s in specs}
    children: dict = {s.name: [] for s in specs}
    for s in specs:
        for d in set(s.deps):
            children[d].append(s.name)
    ready = sorted(n for n, deg in indeg.items() if deg == 0)
    order: List[PhaseSpec] = []
    while ready:
        n = ready.pop(0)
        order.append(by_name[n])
        grew = False
        for c in children[n]:
            indeg[c] -= 1
            if indeg[c] == 0:
                ready.append(c)
                grew = True
        if grew:
            ready.sort()
    if len(order) != len(specs):
        stuck = sorted(n for n, deg in indeg.items() if deg > 0)
        raise ValueError(f"phase DAG has a cycle through {stuck}")
    return order
