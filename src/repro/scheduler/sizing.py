"""Per-phase Lambda memory sizing.

The paper fixes every worker at 3 GB (Sec. 5), but the phases of one
Newton iteration have very different working sets: a coded-matvec worker
holds one encoded block and a vector, a Hessian-sketch worker holds a
sketch block plus a Gram tile, a distributed-averaging worker holds a
whole d x d system.  Lambda bills GB-seconds, so right-sizing each phase
is a straight cost axis — ``PhaseSpec.memory_gb`` carries the declared
size and the fleet engine bills that phase through a per-phase
``CostModel`` override.

``lambda_memory_gb`` maps a working-set byte count to a billable Lambda
size: bytes x headroom (interpreter + runtime overhead), rounded UP to
the 64 MB allocation granularity of the paper-era Lambda platform, and
clamped to the platform bounds.  Deterministic, pure, and intentionally
conservative — undersizing a real Lambda OOMs the worker; oversizing
just costs money.
"""
from __future__ import annotations

import math

LAMBDA_MIN_GB = 0.125      # 128 MB platform floor
LAMBDA_MAX_GB = 10.0       # current platform ceiling
LAMBDA_STEP_GB = 0.0625    # 64 MB allocation granularity

FLOAT32_BYTES = 4


def lambda_memory_gb(working_set_bytes: float, headroom: float = 2.0,
                     floor: float = LAMBDA_MIN_GB,
                     ceil: float = LAMBDA_MAX_GB) -> float:
    """Billable Lambda size (GB) for a declared per-worker working set."""
    if working_set_bytes < 0:
        raise ValueError("working_set_bytes must be >= 0")
    gb = working_set_bytes * headroom / 2.0 ** 30
    stepped = math.ceil(gb / LAMBDA_STEP_GB) * LAMBDA_STEP_GB
    return float(min(ceil, max(floor, stepped)))


def matvec_worker_bytes(block_rows: int, cols: int,
                        dtype_bytes: int = FLOAT32_BYTES) -> float:
    """Coded-matvec worker: one encoded (block_rows x cols) block, the
    input vector, and the output block."""
    return float(dtype_bytes) * (block_rows * cols + cols + block_rows)


def sketch_worker_bytes(block_size: int, d: int,
                        dtype_bytes: int = FLOAT32_BYTES) -> float:
    """Hessian-sketch worker (Alg. 2): one (block_size x d) sketch block
    plus its (d x d)-bounded Gram tile contribution."""
    return float(dtype_bytes) * (block_size * d + d * d)


def distavg_worker_bytes(block_size: int, d: int,
                         dtype_bytes: int = FLOAT32_BYTES) -> float:
    """Distributed-averaging worker: sketch block, local d x d system,
    and its factorization workspace."""
    return float(dtype_bytes) * (block_size * d + 2 * d * d + 2 * d)
