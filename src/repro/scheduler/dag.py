"""Phase-DAG dispatch on top of ``FleetEngine.run_phase(not_before=...)``.

The scheduler sits between optimizers and the fleet engine: an optimizer
declares one iteration as ``PhaseSpec``s with dependency edges, and the
scheduler dispatches each phase at the absolute launch time

    launch(p) = max(dag_start, max over deps d of finish(d))

via the engine's ``not_before`` machinery — so two phases with no path
between them (the gradient round and the Hessian-sketch fan-out, paper
Sec. 4.1 / Bartan-Pilanci's concurrent sketch round) run concurrently on
the simulated timeline, while billing stays position-independent.

Two entry points:

  - ``DagRun`` — the imperative handle optimizers use: ``dispatch(spec)``
    one phase at a time, with data-dependent specs allowed (the coded
    matvec's decode-failure retry phase only exists when the decode
    failed).  Finish times are tracked per name; later dispatches name
    their deps.
  - ``run_dag(clock, key, specs)`` — the declarative form: validates the
    DAG, canonicalizes the dispatch order (see ``spec.canonical_order``),
    and dispatches everything.  ``sequential=True`` dispatches the same
    canonical order with every edge treated as a full barrier at the
    current clock — the makespan upper bound every DAG schedule is
    measured against.

Exactness contracts:

  - A phase whose launch time equals the current clock takes the engine's
    sequential path (``not_before=None``) — no ``(now + e) - now`` float
    re-rounding — so a DAG whose edges serialize every phase reproduces
    the sequential schedule's ``(seconds, dollars)`` bit-for-bit.
  - Phase keys fold the spec's stable ``key_fold`` into the run key (or
    the caller passes an explicit per-phase key), so a phase's duration
    draw depends only on its name, never on dispatch order.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax

from repro.scheduler.spec import PhaseSpec, canonical_order


@dataclasses.dataclass
class PhaseResult:
    """One dispatched phase on the absolute simulated timeline."""

    spec: PhaseSpec
    start: float          # absolute launch time
    elapsed: float        # master wait incl. comm (= run_phase's elapsed)
    finish: float         # start + elapsed
    mask: object          # finished-worker mask from the termination policy


@dataclasses.dataclass
class DagResult:
    """What ``run_dag`` hands back."""

    order: List[str]                      # canonical dispatch order
    results: Dict[str, PhaseResult]
    start: float
    makespan: float                       # max finish - start

    def finish(self, name: str) -> float:
        return self.results[name].finish

    def critical_path(self):
        """Makespan-binding chain + per-phase slack of this dispatched DAG
        (an ``obs.CriticalPathReport``; see ``repro.obs.critical_path``)."""
        from repro import obs
        return obs.from_dag(self)


class DagRun:
    """Imperative phase-DAG dispatch against one clock.

    ``clock`` is a ``core.straggler.SimClock`` (or anything with its
    ``phase()``/``time`` surface).  ``key`` seeds per-phase keys for specs
    dispatched without an explicit key.
    """

    def __init__(self, clock, key: Optional[jax.Array] = None,
                 start: Optional[float] = None):
        self.clock = clock
        self.key = key
        self.start = float(clock.time if start is None else start)
        self.results: Dict[str, PhaseResult] = {}
        self.last: Optional[str] = None   # most recently dispatched name

    def launch_time(self, spec: PhaseSpec) -> float:
        missing = [d for d in spec.deps if d not in self.results]
        if missing:
            raise ValueError(
                f"phase {spec.name!r} depends on undispatched {missing}")
        return max([self.start]
                   + [self.results[d].finish for d in spec.deps])

    def dispatch(self, spec: PhaseSpec, key: Optional[jax.Array] = None,
                 sequential: bool = False,
                 min_start: Optional[float] = None) -> PhaseResult:
        """Simulate one phase at its DAG launch time; returns its result.

        ``sequential=True`` ignores the edges and launches at the current
        clock — the barrier baseline.  ``min_start`` floors the launch
        time — how a caller expresses a dependency on work that ran on
        the direct clock outside the DAG (e.g. the coded matvec's
        one-time encode phases).  Phases launching exactly at the current
        clock take the engine's ``not_before=None`` path either way,
        keeping serialized DAGs bit-identical to sequential runs.
        """
        if spec.name in self.results:
            raise ValueError(f"phase {spec.name!r} already dispatched")
        if key is None:
            if self.key is None:
                raise ValueError(
                    f"phase {spec.name!r}: DagRun has no base key; pass one "
                    "to DagRun(...) or dispatch(..., key=...)")
            key = jax.random.fold_in(self.key, spec.key_fold)
        now = float(self.clock.time)
        nb = now if sequential else self.launch_time(spec)
        if min_start is not None:
            nb = max(nb, float(min_start))
        elapsed, mask = self.clock.phase(
            key, spec.workers, policy=spec.policy, k=spec.k,
            work_per_worker=spec.work_per_worker,
            flops_per_worker=spec.flops_per_worker,
            comm_units=spec.comm_units, decodable=spec.decodable,
            not_before=None if nb == now else nb,
            memory_gb=spec.memory_gb,
            working_set_gb=spec.working_set_gb,
            phase_name=spec.name, phase_deps=spec.deps)
        finish = float(self.clock.time) if nb == now else nb + elapsed
        res = PhaseResult(spec=spec, start=nb, elapsed=float(elapsed),
                          finish=finish, mask=mask)
        self.results[spec.name] = res
        self.last = spec.name
        return res

    @property
    def makespan(self) -> float:
        if not self.results:
            return 0.0
        return max(r.finish for r in self.results.values()) - self.start

    def critical_path(self):
        """Critical-path + slack report over the phases dispatched so far
        (an ``obs.CriticalPathReport``; see ``repro.obs.critical_path``)."""
        from repro import obs
        return obs.from_dag(self)


def run_dag(clock, key: jax.Array, specs: Sequence[PhaseSpec], *,
            sequential: bool = False,
            start: Optional[float] = None) -> DagResult:
    """Validate, canonicalize, and dispatch a whole phase DAG.

    The dispatch order — hence every duration draw, pool interaction, and
    ledger addition — is the canonical topological order, a pure function
    of the DAG: declaring the same phases in any topological order gives
    bit-identical ``(seconds, dollars)``.
    """
    order = canonical_order(specs)
    run = DagRun(clock, key=key, start=start)
    for s in order:
        run.dispatch(s, sequential=sequential)
    return DagResult(order=[s.name for s in order], results=run.results,
                     start=run.start, makespan=run.makespan)
